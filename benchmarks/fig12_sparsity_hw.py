"""Fig 12: hardware metrics vs sparsity — OT depth & latency (b), power &
energy (c), memory footprint & BRAM (d) all scale with the non-zero
synapse count, while logic (a) is set by architectural parameters only."""
from __future__ import annotations

from benchmarks.common import simulate_inference, trained_shd_snn
from repro.core.memory_model import HardwareConfig
from repro.snn import QuantConfig


HW = HardwareConfig(n_spus=64, unified_mem_depth=256, concentration=3,
                    weight_bits=6, potential_bits=9, max_neurons=1020,
                    max_post_neurons=320)


def run(quick: bool = False) -> list[tuple]:
    rows = []
    levels = (0.6, 0.9) if quick else (0.5, 0.7, 0.82, 0.9)
    for s in levels:
        cfg, params, (xte, yte) = trained_shd_snn(
            sparsity=s, steps=20 if quick else 60,
            timesteps=20 if quick else 40)
        q, program, rep = simulate_inference(
            cfg, params, HW, QuantConfig(6, 9), xte[0], encode=False)
        report = program.report
        tag = f"sparsity={s}"
        rows += [
            (f"fig12.ot_depth[{tag}]", report.ot_depth, "grows w/ density"),
            (f"fig12.latency_ms[{tag}]", rep.latency_us / 1e3, ""),
            (f"fig12.energy_mj[{tag}]", rep.energy_mj, ""),
            (f"fig12.memory_kb[{tag}]", report.resources.memory_kb, ""),
            (f"fig12.brams[{tag}]", report.resources.brams, ""),
            (f"fig12.logic[{tag}]",
             report.resources.luts + report.resources.ffs,
             "must be ~constant"),
        ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
