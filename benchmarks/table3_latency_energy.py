"""Table 3: MNIST latency / power / energy vs the paper's published point
(SupraSNN column: 0.149 ms, 0.172 W, 0.02563 mJ/image, 0.27675 nJ/syn).

The network is trained briefly on the synthetic MNIST (container is
offline), so spike statistics differ slightly from the paper's run; the
hardware point (16 SPUs, UM 128, K=3, 4-bit weights) is exact.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import simulate_inference, trained_mnist_snn
from repro.configs.snn_paper import MNIST_HW
from repro.snn import QuantConfig


PAPER = {"latency_ms": 0.149, "power_w": 0.172, "energy_mj": 0.02563,
         "energy_per_syn_nj": 0.27675, "ot_depth": 661}


def _prune_to_sparsity(params, cfg, target: float):
    """Magnitude-prune the float weights so post-quantization sparsity hits
    the paper's deployed level (88.74%) — the paper's network reaches this
    through converged training on real MNIST; our synthetic short run does
    not, so the HARDWARE point is reproduced on a calibrated network."""
    import jax.numpy as jnp
    out = dict(params)
    ws = [np.asarray(params[f"w{i}"]) * np.asarray(params[f"mask{i}"])
          for i in range(cfg.n_layers)]
    flat = np.concatenate([np.abs(w).ravel() for w in ws])
    keep = int(round(len(flat) * (1.0 - target)))
    thresh = np.partition(flat, -keep)[-keep]
    for i, w in enumerate(ws):
        out[f"mask{i}"] = jnp.asarray((np.abs(w) >= thresh)
                                      .astype(np.float32))
    return out


def run(quick: bool = False) -> list[tuple]:
    cfg, params, (xte, yte) = trained_mnist_snn(steps=20 if quick else 80)
    rows = []
    for tag, p in (("", params),
                   ("@paper_sparsity",
                    _prune_to_sparsity(params, cfg, 0.8874))):
        samples = xte[:3 if quick else 10]
        reports = []
        q = report = None
        for s in samples:
            q, program, rep = simulate_inference(
                cfg, p, MNIST_HW, QuantConfig(4, 5), s, encode=True)
            report = program.report
            reports.append(rep)
        lat_ms = float(np.mean([r.latency_us for r in reports])) / 1e3
        rows += [
            (f"table3.latency_ms{tag}", lat_ms,
             f"paper={PAPER['latency_ms']}"),
            (f"table3.power_w{tag}", reports[0].power_w,
             f"paper={PAPER['power_w']}"),
            (f"table3.energy_mj{tag}",
             float(np.mean([r.energy_mj for r in reports])),
             f"paper={PAPER['energy_mj']}"),
            (f"table3.energy_per_syn_nj{tag}",
             float(np.mean([r.energy_per_synapse_nj for r in reports])),
             f"paper={PAPER['energy_per_syn_nj']}"),
            (f"table3.ot_depth{tag}", report.ot_depth,
             f"paper={PAPER['ot_depth']}"),
            (f"table3.sparsity_postq{tag}", q.sparsity, "paper=0.8874"),
            (f"table3.brams{tag}", report.resources.brams, "paper=33.5"),
            (f"table3.logic_cells{tag}",
             report.resources.luts + report.resources.ffs, "paper=6144"),
        ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
