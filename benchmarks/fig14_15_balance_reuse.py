"""Fig 14 (SPU balance: max/min/std synapse counts vs UM depth) and
Fig 15 (post-neuron centralization + weight reuse vs UM depth)."""
from __future__ import annotations

import numpy as np

from benchmarks.fig13_partitioning import _hw, _instance
from repro.core import compile as compile_program


def run(quick: bool = False) -> list[tuple]:
    g = _instance(quick)
    rows = []
    # find a tight-but-feasible anchor from the post-RR requirement
    from repro.core import BASELINES
    from repro.core.memory_model import spu_usage
    res = BASELINES["post_neuron_rr"](g, _hw(10 ** 9, g))
    anchor = max(spu_usage(len(np.unique(g.weight[res.assign == i])),
                           len(np.unique(g.post[res.assign == i])), 3)
                 for i in range(16))
    factors = (1.0, 3.0) if quick else (0.9, 1.2, 2.0, 4.0)
    for f in factors:
        d = int(anchor * f)
        report = compile_program(g, _hw(d, g), seed=0,
                                 max_iters=60000).report
        syn = report.spu_synapse_counts
        tag = f"um={d}"
        rows += [
            (f"fig14.syn_max[{tag}]", int(syn.max()), ""),
            (f"fig14.syn_min[{tag}]", int(syn.min()), ""),
            (f"fig14.syn_std[{tag}]", float(syn.std()),
             "drops as UM grows"),
            (f"fig15.posts_per_spu[{tag}]",
             float(report.spu_post_counts.mean()),
             "grows as UM grows"),
            (f"fig15.weights_per_spu[{tag}]",
             float(report.spu_weight_counts.mean()), ""),
        ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
