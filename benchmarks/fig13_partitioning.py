"""Fig 13: the probabilistic partitioner vs the three round-robin baselines
across Unified-Memory depth constraints — minimum feasible OT depth (a)
and total memory footprint (b).

The paper's instance is SHD with 9-bit weights (33k synapses, 64 SPUs).
We run a same-shape scaled instance (sparse 700-300-20 SRNN) so the whole
sweep stays tractable on one CPU; the qualitative claims under test:

  * framework tracks synapse-RR (the balance optimum) when memory is
    relaxed, keeps finding feasible mappings when memory is far tighter
    than any baseline needs;
  * post-neuron RR is strong under tight memory but cannot exploit
    additional memory (flat OT depth);
  * weight-RR needs mid memory and schedules worst.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import trained_shd_snn
from repro.core import compile as compile_program
from repro.core import BASELINES, HardwareConfig, from_quantized, schedule
from repro.core.memory_model import spu_usage, total_memory_kb
from repro.snn import QuantConfig, quantize


def _instance(quick: bool):
    cfg, params, _ = trained_shd_snn(sparsity=0.87, steps=5,
                                     hidden=96 if quick else 128,
                                     timesteps=10)
    q = quantize(params, cfg, QuantConfig(weight_bits=9, potential_bits=18))
    return from_quantized(q)


def _hw(depth: int, g) -> HardwareConfig:
    return HardwareConfig(n_spus=16, unified_mem_depth=depth,
                          concentration=3, weight_bits=9,
                          potential_bits=18, max_neurons=g.n_neurons,
                          max_post_neurons=g.n_internal)


def run(quick: bool = False) -> list[tuple]:
    g = _instance(quick)
    rows = [("fig13.n_synapses", g.n_synapses, "")]

    # baseline requirements: minimum UM depth each baseline needs
    base_ot, base_um = {}, {}
    for name, fn in BASELINES.items():
        res = fn(g, _hw(10 ** 9, g))
        need = max(spu_usage(len(np.unique(g.weight[res.assign == i])),
                             len(np.unique(g.post[res.assign == i])), 3)
                   for i in range(16))
        tables = schedule(g, res.assign, _hw(10 ** 9, g))
        base_ot[name], base_um[name] = tables.depth, need
        rows.append((f"fig13.{name}.min_um_depth", need, ""))
        rows.append((f"fig13.{name}.ot_depth", tables.depth, ""))

    depths = [int(base_um["post_neuron_rr"] * f)
              for f in ((1.0, 2.5) if quick else (0.95, 1.1, 1.6, 2.5, 4.0))]
    for d in depths:
        hw = _hw(d, g)
        program = compile_program(g, hw, seed=0, max_iters=200000)
        report = program.report
        rows.append((f"fig13.framework.ot_depth[um={d}]",
                     report.ot_depth if report.feasible else -1,
                     f"feasible={report.feasible}"))
        rows.append((f"fig13.framework.memory_kb[um={d}]",
                     total_memory_kb(hw, report.ot_depth), ""))
    # headline check: with relaxed memory the framework reaches the
    # synapse-RR optimum within a few percent (paper: 536 vs 539)
    hw = _hw(int(base_um["synapse_rr"] * 1.2), g)
    program = compile_program(g, hw, seed=0, max_iters=60000)
    rows.append(("fig13.framework_vs_synapse_rr",
                 program.ot_depth / base_ot["synapse_rr"],
                 "paper ratio ~0.99"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
