"""Fig 11: SHD accuracy vs weight-sparsity level (reduced scale: synthetic
SHD, short training; the paper's qualitative claim is that accuracy
degrades gracefully until very high sparsity)."""
from __future__ import annotations

from benchmarks.common import accuracy, trained_shd_snn


LEVELS_FULL = (0.0, 0.4, 0.7, 0.82, 0.9)
LEVELS_QUICK = (0.0, 0.82)


def run(quick: bool = False) -> list[tuple]:
    rows = []
    for s in (LEVELS_QUICK if quick else LEVELS_FULL):
        cfg, params, (xte, yte) = trained_shd_snn(
            sparsity=s, steps=40 if quick else 120)
        acc = accuracy(cfg, params, xte, yte, encode=False)
        rows.append((f"fig11.acc@sparsity={s}", acc, "chance=0.05"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
