"""Kernel-level benchmarks.

The Pallas kernels TARGET TPU; on this CPU container ``interpret=True``
executes the kernel body in Python, so wall-clock is meaningless. What IS
measurable here and carries to hardware:

  * tile-skip fraction — the MC-tree block-occupancy predicate
    (spike_accum skips weight tiles whose spike tile is all-zero); with
    real spike rasters this is the latency/energy ∝ sparsity property of
    the paper at MXU granularity;
  * flops avoided = skipped_tiles * tile_flops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_mnist_snn
from repro.snn.train import rate_encode


def tile_skip_stats(spikes: np.ndarray, block_pre: int = 128) -> float:
    """Fraction of (batch-block x pre-block) tiles with zero spikes."""
    b, n = spikes.shape
    pad = (-n) % block_pre
    s = np.pad(spikes, ((0, 0), (0, pad)))
    tiles = s.reshape(b, -1, block_pre)
    return float((tiles.sum(-1) == 0).mean())


def run(quick: bool = False) -> list[tuple]:
    cfg, params, (xte, yte) = trained_mnist_snn(steps=10 if quick else 40)
    spikes = np.asarray(rate_encode(jnp.asarray(xte[:16]), cfg.timesteps,
                                    jax.random.PRNGKey(0)))
    spikes = spikes.reshape(-1, 784)
    skip = tile_skip_stats(spikes)
    rows = [("kernel.spike_accum.tile_skip_frac@mnist", skip,
             "latency ∝ (1 - skip) on TPU"),
            ("kernel.spike_accum.spike_rate", float(spikes.mean()), "")]
    for rate in (0.01, 0.05, 0.2):
        rng = np.random.default_rng(0)
        s = (rng.random((64, 2048)) < rate).astype(np.float32)
        rows.append((f"kernel.spike_accum.tile_skip_frac@rate={rate}",
                     tile_skip_stats(s), ""))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
