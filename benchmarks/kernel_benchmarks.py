"""Kernel- and executor-level benchmarks.

The Pallas kernels TARGET TPU; on this CPU container ``interpret=True``
executes the kernel body in Python, so kernel wall-clock is meaningless.
What IS measurable here and carries to hardware:

  * tile-skip fraction — the MC-tree block-occupancy predicate
    (spike_accum skips weight tiles whose spike tile is all-zero); with
    real spike rasters this is the latency/energy ∝ sparsity property of
    the paper at MXU granularity;
  * flops avoided = skipped_tiles * tile_flops;
  * mapped-executor throughput — one compiled ``Program`` artifact
    driven through its engines: the compiled batched executor
    (``program.run(ext)``, XLA end to end, fused megakernel tier) vs
    the Python reference (``ExecutionSpec(engine="python")``),
    batch=16 on the MNIST-scale graph. The acceptance bar is >= 20x;
    this IS real wall-clock;
  * kernel-tier shootout — the same batch through
    ``ExecutionSpec(kernel="fused")`` (one Pallas launch per timestep)
    vs ``kernel="lif"`` (segment-sum + small NU kernel), on both the
    MNIST-scale and the fig13 SHD-scale (700-320, ~33k synapses,
    9-bit weights) shapes. Bit-exact by construction; the rows track
    the fusion win.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_mnist_snn
from repro.configs.snn_paper import mnist_scale_random_graph
from repro.core import compile as compile_program
from repro.core.execution import ExecutionSpec
from repro.snn.train import rate_encode


def tile_skip_stats(spikes: np.ndarray, block_pre: int = 128) -> float:
    """Fraction of (batch-block x pre-block) tiles with zero spikes."""
    b, n = spikes.shape
    pad = (-n) % block_pre
    s = np.pad(spikes, ((0, 0), (0, pad)))
    tiles = s.reshape(b, -1, block_pre)
    return float((tiles.sum(-1) == 0).mean())


def _best_of(fn, repeats: int) -> float:
    """Best-of-N wall seconds; the first (warming) call is untimed."""
    fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _tier_rows(program, ext, prefix: str, repeats: int) -> list[tuple]:
    """Fused-vs-lif kernel-tier shootout rows for one program+batch."""
    fused, lif = ExecutionSpec(kernel="fused"), ExecutionSpec(kernel="lif")
    t_fused = _best_of(lambda: program.run(ext, fused), repeats)
    t_lif = _best_of(lambda: program.run(ext, lif), repeats)
    s_f, v_f, st_f = program.run(ext, fused)
    s_l, v_l, st_l = program.run(ext, lif)
    exact = (np.array_equal(s_f, s_l) and np.array_equal(v_f, v_l)
             and np.array_equal(st_f["packet_counts"],
                                st_l["packet_counts"]))
    batch, t_steps = ext.shape[0], ext.shape[1]
    return [
        (f"{prefix}.wall_ms", t_fused * 1e3,
         f"fused tier, B={batch} T={t_steps}"),
        (f"{prefix}.kernel_lif_wall_ms", t_lif * 1e3,
         "split segment-sum + NU-kernel tier, same batch"),
        (f"{prefix}.fused_speedup_vs_lif", t_lif / t_fused,
         "one Pallas launch per timestep vs three-op pipeline"),
        (f"{prefix}.tokens_per_s", batch * t_steps / t_fused,
         "timestep-frames per second, whole batch, fused tier"),
        (f"{prefix}.tiers_bit_exact", float(exact),
         "spikes+v+packets identical across tiers"),
    ]


def engine_speedup(quick: bool = False, batch: int = 16) -> list[tuple]:
    """Compiled batched executor vs Python reference on MNIST-scale graph.

    The Python engine is timed on ``n_ref`` images and scaled linearly to
    ``batch`` (it is a per-image loop with no cross-image state); the JAX
    engine (fused megakernel tier, the platform default) is timed on the
    full batch after a warm-up compile, and the ``"lif"`` split-pipeline
    tier is raced against it on the same batch.
    """
    n_syn = 4000 if quick else 12000
    t_steps = 10 if quick else 20
    n_ref = 1 if quick else 2
    repeats = 2 if quick else 3
    g, hw = mnist_scale_random_graph(n_synapses=n_syn)
    program = compile_program(g, hw, max_iters=40000)
    rng = np.random.default_rng(0)
    ext = (rng.random((batch, t_steps, 784)) < 0.2).astype(np.int32)

    tiers = _tier_rows(program, ext, "engine.jax", repeats)
    jax_s = tiers[0][1] / 1e3                      # fused wall seconds
    s_jax, v_jax, _ = program.run(ext)             # owned engine, reused

    py_spec = ExecutionSpec(engine="python")
    t0 = time.perf_counter()
    for i in range(n_ref):
        program.run(ext[i], py_spec)
    py_per_image = (time.perf_counter() - t0) / n_ref
    py_batch_s = py_per_image * batch

    s_ref, v_ref, _ = program.run(ext[0], "oracle")
    exact = (np.array_equal(s_jax[0], s_ref)
             and np.array_equal(v_jax[0], v_ref))
    rows = [
        (f"engine.jax.batch{batch}_wall_ms", jax_s * 1e3,
         f"T={t_steps} E={n_syn}, fused tier"),
        ("engine.python.per_image_ms", py_per_image * 1e3,
         f"measured on {n_ref} image(s)"),
        (f"engine.jax.speedup_batch{batch}", py_batch_s / jax_s,
         "acceptance: >= 20x"),
        ("engine.jax.bit_exact_vs_oracle", float(exact), ""),
        ("compile.seconds", program.report.compile_seconds, ""),
        ("compile.ot_depth", program.report.ot_depth, ""),
    ]
    rows += tiers

    # SHD-scale shape (fig13): 700-320 SRNN, ~33k synapses, 9-bit
    # weights — the dense plane packs to int16 here, not int8
    from benchmarks.partitioner_throughput import fig13_shd_instance
    g2, hw2 = fig13_shd_instance()
    program2 = compile_program(g2, hw2, max_iters=2000)
    ext2 = (rng.random((batch, t_steps, g2.n_inputs)) < 0.1) \
        .astype(np.int32)
    rows += _tier_rows(program2, ext2, "engine.jax.shd", repeats)
    return rows


def run(quick: bool = False) -> list[tuple]:
    cfg, params, (xte, yte) = trained_mnist_snn(steps=10 if quick else 40)
    spikes = np.asarray(rate_encode(jnp.asarray(xte[:16]), cfg.timesteps,
                                    jax.random.PRNGKey(0)))
    spikes = spikes.reshape(-1, 784)
    skip = tile_skip_stats(spikes)
    rows = [("kernel.spike_accum.tile_skip_frac@mnist", skip,
             "latency ∝ (1 - skip) on TPU"),
            ("kernel.spike_accum.spike_rate", float(spikes.mean()), "")]
    for rate in (0.01, 0.05, 0.2):
        rng = np.random.default_rng(0)
        s = (rng.random((64, 2048)) < rate).astype(np.float32)
        rows.append((f"kernel.spike_accum.tile_skip_frac@rate={rate}",
                     tile_skip_stats(s), ""))
    rows += engine_speedup(quick=quick)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
