"""Benchmark runner: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig13]

Prints ``name,value,derived`` CSV rows per benchmark plus wall time.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "benchmarks.table3_latency_energy",   # Table 3
    "benchmarks.fig11_sparsity_accuracy", # Fig 11
    "benchmarks.fig12_sparsity_hw",       # Fig 12
    "benchmarks.fig13_partitioning",      # Fig 13
    "benchmarks.fig14_15_balance_reuse",  # Fig 14 + 15
    "benchmarks.kernel_benchmarks",       # Pallas kernel structure
    "benchmarks.roofline_table",          # §Roofline aggregation
]


SMOKE_MODULES = ["benchmarks.kernel_benchmarks"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke run: kernel/executor benchmarks only, "
                         "quick mode")
    args = ap.parse_args()
    modules = MODULES
    if args.smoke:
        args.quick = True
        modules = SMOKE_MODULES

    failures = 0
    for mod_name in modules:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run(quick=args.quick)
            dt = time.time() - t0
            print(f"# {mod_name} ({dt:.1f}s)")
            for name, value, derived in rows:
                print(f"{name},{value},{derived}")
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED")
            traceback.print_exc()
        sys.stdout.flush()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
