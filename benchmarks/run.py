"""Benchmark runner: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig13]
                                            [--json out.json]

Prints ``name,value,derived`` CSV rows per benchmark plus wall time.
``--smoke`` runs the CI subset in quick mode and (unless overridden
with ``--json``) writes every row to ``BENCH_smoke.json`` so the perf
trajectory — compile seconds, OT depth, engine tokens/s, partitioner
speedup — is captured as a CI artifact per commit.
"""
from __future__ import annotations

import argparse
import importlib
import json
import platform
import sys
import time
import traceback

MODULES = [
    "benchmarks.table3_latency_energy",   # Table 3
    "benchmarks.fig11_sparsity_accuracy", # Fig 11
    "benchmarks.fig12_sparsity_hw",       # Fig 12
    "benchmarks.fig13_partitioning",      # Fig 13
    "benchmarks.fig14_15_balance_reuse",  # Fig 14 + 15
    "benchmarks.kernel_benchmarks",       # Pallas kernel structure
    "benchmarks.partitioner_throughput",  # mapping-subsystem speedup
    "benchmarks.scheduler_throughput",    # scheduling-subsystem speedup
    "benchmarks.serving_throughput",      # serving-subsystem smoke
    "benchmarks.serving_soak",            # sustained-load trace replay
    "benchmarks.compiler_scale",          # mapping-at-scale subsystem
    "benchmarks.analysis_verify",         # static-verifier wall time
    "benchmarks.roofline_table",          # §Roofline aggregation
]


SMOKE_MODULES = ["benchmarks.kernel_benchmarks",
                 "benchmarks.partitioner_throughput",
                 "benchmarks.scheduler_throughput",
                 "benchmarks.serving_throughput",
                 "benchmarks.serving_soak",
                 "benchmarks.compiler_scale",
                 "benchmarks.analysis_verify"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke run: kernel/executor + partitioner "
                         "benchmarks only, quick mode, JSON artifact")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all rows to PATH as JSON "
                         "(default BENCH_smoke.json under --smoke)")
    args = ap.parse_args()
    modules = MODULES
    if args.smoke:
        args.quick = True
        modules = SMOKE_MODULES
        if args.json is None:
            args.json = "BENCH_smoke.json"

    failures = 0
    all_rows: dict[str, float] = {}
    timings: dict[str, float] = {}
    for mod_name in modules:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run(quick=args.quick)
            dt = time.time() - t0
            timings[mod_name] = dt
            print(f"# {mod_name} ({dt:.1f}s)")
            for name, value, derived in rows:
                print(f"{name},{value},{derived}")
                try:
                    all_rows[name] = float(value)
                except (TypeError, ValueError):
                    all_rows[name] = value
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED")
            traceback.print_exc()
        sys.stdout.flush()

    if args.json:
        payload = {
            "meta": {
                "quick": bool(args.quick),
                "python": platform.python_version(),
                "platform": platform.platform(),
                "modules": list(timings),
                "module_seconds": timings,
                "failures": failures,
            },
            "rows": all_rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json} ({len(all_rows)} rows)")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
