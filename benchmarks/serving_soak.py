"""Serving soak benchmark: sustained trace replay under load.

Pure simulation — no jax import, no engine — so the rows are
bit-deterministic and cheap enough for CI. Two scenarios share one
deterministic seed:

* **steady**: Poisson arrivals at ~60% of engine capacity with a
  batch-hold window — the nominal operating point. Expect zero shed
  and a p99 inside the SLO.
* **burst**: on/off bursty arrivals whose peaks exceed capacity,
  against a bounded queue (``max_queue``) with ``reject`` shedding and
  a dispatch deadline — the overload point. Expect a nonzero but
  *bounded* shed fraction, and every served request still inside its
  deadline.
* **deadline**: the same bursty shape against an *unbounded* queue
  with only a dispatch deadline — overload shows up as deadline
  sheds (bounded, burst-tail sized) instead of queue-full rejections.

Rows (land in BENCH_smoke.json via ``benchmarks.run --smoke``):

* ``serve.soak.sim_seconds``         — simulated seconds replayed
  (acceptance floor: >= 60)
* ``serve.soak.requests``            — total offered requests
* ``serve.soak.offered_qps``         — offered load over both traces
* ``serve.soak.p50_ms`` / ``serve.soak.p99_ms`` — served-request
  latency percentiles across both scenarios
* ``serve.soak.shed_frac``           — shed fraction (burst scenario
  sheds; steady does not)
* ``serve.soak.deadline_miss_frac``  — deadline sheds / offered
* ``serve.soak.deterministic``       — 1.0 iff a second same-seed
  replay reproduces identical served counts, shed counts and
  bit-identical latencies
* ``serve.soak.slo_ok``              — 1.0 iff the per-scenario
  ``assert_slo`` bars pass (steady: p99 <= 2 ms, no shed; burst:
  p99 <= 25 ms, shed <= 25%)
* ``serve.stage.queue_us`` / ``fill_us`` / ``pad_us`` /
  ``compute_us``                     — mean per-stage latency over all
  served requests
* ``serve.stage.sum_exact``          — 1.0 iff per-request stages sum
  bit-exactly to ``latencies_us`` everywhere
"""
from __future__ import annotations

SEED = 2026
SLO = {"steady": dict(slo_p99_ms=2.0, max_shed_frac=0.0),
       "burst": dict(slo_p99_ms=25.0, max_shed_frac=0.25),
       "deadline": dict(slo_p99_ms=10.0, max_shed_frac=0.25,
                        max_deadline_miss_frac=0.25)}


def _scenarios(duration_s: float):
    from repro.serve.batcher import BatchPolicy, linear_service_model
    from repro.serve.replay import ArrivalTrace

    # capacity under this model: bucket 8 costs 400 us -> 20k req/s
    service = linear_service_model(200.0, 25.0)
    steady = (
        ArrivalTrace.poisson(12_000.0, duration_s, seed=SEED, n_streams=8),
        BatchPolicy(max_batch=8, max_wait_us=300.0),
    )
    burst = (
        ArrivalTrace.bursty(4_000.0, duration_s, seed=SEED + 1,
                            n_streams=8, burst_factor=6.0,
                            period_s=0.5, duty=0.15),
        BatchPolicy(max_batch=8, max_wait_us=200.0, max_queue=64,
                    deadline_us=20_000.0, shed="reject"),
    )
    deadline = (
        ArrivalTrace.bursty(4_000.0, duration_s, seed=SEED + 2,
                            n_streams=8, burst_factor=6.0,
                            period_s=0.5, duty=0.15),
        BatchPolicy(max_batch=8, max_wait_us=200.0, deadline_us=5_000.0),
    )
    return service, {"steady": steady, "burst": burst,
                     "deadline": deadline}


def _replay_all(duration_s: float):
    from repro.serve.replay import replay
    service, scen = _scenarios(duration_s)
    return {name: replay(trace, policy, service)
            for name, (trace, policy) in scen.items()}


def run(quick: bool = False) -> list[tuple]:
    # the acceptance floor is 60 simulated seconds even in --quick;
    # the full run soaks longer to surface slow queue drift
    duration_s = 60.0 if quick else 180.0
    reports = _replay_all(duration_s)
    reports2 = _replay_all(duration_s)
    deterministic = all(
        reports[k].fingerprint() == reports2[k].fingerprint()
        for k in reports)
    slo_ok = all(not rep.check(**SLO[name])
                 for name, rep in reports.items())

    requests = sum(r.requests for r in reports.values())
    served = sum(r.served for r in reports.values())
    shed = requests - served
    dl = sum(r.shed["deadline"] for r in reports.values())
    lat_ms = []
    for r in reports.values():
        for res in r.results.values():
            lat_ms.append(res.latencies_us[res.served] / 1e3)
    import numpy as np
    lat_ms = np.concatenate(lat_ms)
    p50, p99 = np.percentile(lat_ms, [50, 99])
    stages = {k: sum(r.stages_us[k] * r.served for r in reports.values())
              / max(served, 1)
              for k in ("queue_wait", "batch_fill", "pad", "compute")}
    sum_exact = all(r.stage_sum_exact for r in reports.values())

    return [
        ("serve.soak.sim_seconds", duration_s, ""),
        ("serve.soak.requests", requests, ""),
        ("serve.soak.offered_qps",
         round(requests / duration_s, 1), ""),
        ("serve.soak.p50_ms", round(float(p50), 4), ""),
        ("serve.soak.p99_ms", round(float(p99), 4), ""),
        ("serve.soak.shed_frac", round(shed / requests, 5), ""),
        ("serve.soak.deadline_miss_frac", round(dl / requests, 5), ""),
        ("serve.soak.deterministic", float(deterministic),
         "same seed => identical latencies/shed"),
        ("serve.soak.slo_ok", float(slo_ok),
         "per-scenario p99 + shed bars"),
        ("serve.stage.queue_us", round(stages["queue_wait"], 3), ""),
        ("serve.stage.fill_us", round(stages["batch_fill"], 3), ""),
        ("serve.stage.pad_us", round(stages["pad"], 3), ""),
        ("serve.stage.compute_us", round(stages["compute"], 3), ""),
        ("serve.stage.sum_exact", float(sum_exact),
         "stages sum bit-exactly to latency"),
    ]


if __name__ == "__main__":
    for row in run(quick=True):
        print(*row, sep=",")
