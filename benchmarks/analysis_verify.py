"""Static-verifier throughput: ``Program.verify()`` wall time.

The verifier is the gate between "artifact on disk" and "artifact in
the serving registry" (``ProgramRegistry.register(verify=True)``), so
its wall time is a serving-control-plane latency. Two rows per shape:

* ``analysis.verify.golden.*``  — the pinned tiny golden artifact
  (the CI load-path floor);
* ``analysis.verify.shd.*``     — the paper's fig13 SHD instance
  shape (~33k synapses, 16 SPUs), compiled with the fast hypergraph
  mapper. The acceptance bound is wall < 1 s — verification must stay
  negligible next to the compile it guards.

Both rows assert zero diagnostics: a verifier that flags its own
compiler's output is a correctness failure, not a perf number.
"""
from __future__ import annotations

import time
from pathlib import Path

from repro.core import Program, compile as compile_program

from benchmarks.partitioner_throughput import fig13_shd_instance

GOLDEN = Path(__file__).parent.parent / "tests" / "golden" / \
    "tiny_program_v1.npz"


def _verify_rows(tag: str, program, budget_ms: float | None):
    best = float("inf")
    rep = None
    for _ in range(3):
        t0 = time.perf_counter()
        rep = program.verify()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    assert rep is not None and rep.ok, \
        f"verifier flagged a clean compile ({tag}): {rep.summary()}"
    if budget_ms is not None:
        assert best < budget_ms, \
            f"{tag} verify took {best:.1f} ms (budget {budget_ms} ms)"
    return [
        (f"analysis.verify.{tag}.diagnostics", len(rep.diagnostics),
         "count (must be 0)"),
        (f"analysis.verify.{tag}.wall_ms", round(best, 3),
         "best-of-3 full verify() wall"),
    ]


def run(quick: bool = False):
    rows = _verify_rows("golden", Program.load(GOLDEN), budget_ms=None)

    g, hw = fig13_shd_instance()
    p = compile_program(g, hw, method="hypergraph")
    # acceptance bound: < 1 s on the SHD-shape artifact
    rows += _verify_rows("shd", p, budget_ms=1000.0)
    rows.append(("analysis.verify.shd.n_synapses", g.n_synapses, "shape"))
    return rows
