"""Partitioner throughput: the vectorized mapping core vs the legacy loop.

Two claims of the mapping-subsystem refactor are measured here:

1. **Bit-exact speedup.** ``repro.core.partition.partition`` (the
   vectorized core behind ``compile``) reproduces the legacy pure-Python
   loop (``repro.core.mapping.legacy``) bit-for-bit on the same
   (graph, hw, seed) while running the SAME number of iterations ≥10×
   faster on the paper's fig13 SHD instance shape (700-in/300-hidden
   SRNN + readout, 9-bit weights, ~33k synapses, 16 SPUs). Both sides
   run the full-fidelity member scan (no ``scan_cap`` sampling — the cap
   exists only to keep the *legacy* Python scan bearable; the array core
   does not need it).

2. **Portfolio search.** ``compile(search=SearchConfig(restarts=8))``
   finds a feasible mapping on a tight-memory config where the
   single-seed compile exhausts its iteration budget infeasible.

Timing is best-of-N with the GC paused — standard practice to cut
container noise; parity is asserted, not sampled.
"""
from __future__ import annotations

import gc
import time

import numpy as np

from repro.core import SearchConfig, compile as compile_program, random_graph
from repro.core.mapping.legacy import partition_legacy
from repro.core.memory_model import HardwareConfig
from repro.core.partition import partition

FULL_SCAN = 1 << 30


def fig13_shd_instance():
    """The paper's fig13 SHD instance shape: 700-300-20 SRNN, 9-bit
    weights, ~33k nonzero synapses, 16 SPUs."""
    g = random_graph(700, 320, 33000, seed=0, weight_lo=-255, weight_hi=255)
    hw = HardwareConfig(n_spus=16, unified_mem_depth=120, concentration=3,
                        weight_bits=9, potential_bits=18,
                        max_neurons=g.n_neurons,
                        max_post_neurons=g.n_internal)
    return g, hw


def _timed(fn, repeats: int) -> tuple[float, object]:
    """Best-of-N wall time with the GC paused during each run."""
    best, out = float("inf"), None
    for _ in range(repeats):
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        gc.enable()
        best = min(best, dt)
    return best, out


def run(quick: bool = False) -> list[tuple]:
    g, hw = fig13_shd_instance()    # quick shortens the run, not the shape
    if quick:
        # CI smoke lane: the best-of-3 full-fidelity scan above burned
        # ~25 s per run for a claim the tier-1 parity tests already pin
        # bit-exactly. Smoke keeps one reduced-iteration sampled-scan
        # timing (the compile default, scan_cap=384) as the tracked
        # trajectory row; the >= 10x acceptance measurement only runs in
        # full (non-quick) mode.
        iters = 400
        legacy_s, legacy = _timed(
            lambda: partition_legacy(g, hw, seed=0, max_iters=iters), 1)
        vec_s, vec = _timed(
            lambda: partition(g, hw, seed=0, max_iters=iters), 1)
        parity = (np.array_equal(legacy.assign, vec.assign)
                  and np.array_equal(legacy.scores, vec.scores)
                  and legacy.iterations == vec.iterations)
        assert parity, "vectorized partitioner diverged from the legacy loop"
        rows = [
            ("partitioner.instance.synapses", g.n_synapses,
             "fig13 SHD shape"),
            ("partitioner.iterations", iters, "smoke: reduced"),
            ("partitioner.parity", float(parity), "bit-exact assignment"),
            ("partitioner.sampled.legacy.seconds", legacy_s,
             "scan_cap=384"),
            ("partitioner.sampled.vectorized.seconds", vec_s,
             "scan_cap=384"),
            ("partitioner.sampled.speedup", legacy_s / vec_s,
             "smoke: reduced iters; >=10x bar measured in full mode"),
        ]
        return rows + _portfolio_rows()

    iters = 3000
    repeats = 3        # best-of-3: min wall time is the robust estimator
    legacy_s, legacy = _timed(
        lambda: partition_legacy(g, hw, seed=0, max_iters=iters,
                                 scan_cap=FULL_SCAN), repeats)
    vec_s, vec = _timed(
        lambda: partition(g, hw, seed=0, max_iters=iters,
                          scan_cap=FULL_SCAN), repeats)
    parity = (np.array_equal(legacy.assign, vec.assign)
              and np.array_equal(legacy.scores, vec.scores)
              and legacy.iterations == vec.iterations
              and legacy.score_history == vec.score_history)
    assert parity, "vectorized partitioner diverged from the legacy loop"

    # sampled-scan flavor (the compile default, scan_cap=384) for context
    cap_legacy_s, _ = _timed(
        lambda: partition_legacy(g, hw, seed=0, max_iters=iters), 1)
    cap_vec_s, _ = _timed(
        lambda: partition(g, hw, seed=0, max_iters=iters), 1)

    rows = [
        ("partitioner.instance.synapses", g.n_synapses, "fig13 SHD shape"),
        ("partitioner.iterations", iters, "same on both sides"),
        ("partitioner.parity", float(parity), "bit-exact assignment"),
        ("partitioner.legacy.seconds", legacy_s, "full-fidelity scan"),
        ("partitioner.vectorized.seconds", vec_s, "full-fidelity scan"),
        ("partitioner.speedup", legacy_s / vec_s, "acceptance: >= 10x"),
        ("partitioner.sampled.legacy.seconds", cap_legacy_s, "scan_cap=384"),
        ("partitioner.sampled.vectorized.seconds", cap_vec_s,
         "scan_cap=384"),
        ("partitioner.sampled.speedup", cap_legacy_s / cap_vec_s, ""),
    ]
    return rows + _portfolio_rows()


def _portfolio_rows() -> list[tuple]:
    # portfolio search on a tight config where the single-seed compile
    # exhausts its budget infeasible; the portfolio both rescues
    # feasibility (another restart / a baseline) and picks the
    # shallowest-OT candidate among the feasible ones
    gt = random_graph(24, 48, 2000, seed=3)
    hwt = HardwareConfig(n_spus=8, unified_mem_depth=18, concentration=3,
                         max_neurons=128, max_post_neurons=64)
    budget = 1000
    single = compile_program(gt, hwt, seed=0, max_iters=budget)
    t0 = time.perf_counter()
    port = compile_program(gt, hwt, search=SearchConfig(
        restarts=8, max_iters=20 * budget))
    port_s = time.perf_counter() - t0
    trace = port.report.search
    base_depths = [c.ot_depth for c in trace.candidates
                   if c.feasible and c.strategy != "framework"]
    return [
        ("portfolio.single_seed.feasible", float(single.feasible),
         f"max_iters={budget}"),
        ("portfolio.feasible", float(port.feasible), "restarts=8"),
        ("portfolio.candidates", port.report.candidates_tried, ""),
        ("portfolio.selected", 0.0, trace.selected.strategy),
        ("portfolio.compile_seconds", port_s, ""),
        ("portfolio.ot_depth", port.ot_depth,
         f"best feasible baseline: {min(base_depths, default=-1)}"),
    ]


if __name__ == "__main__":
    for r in run(quick=True):
        print(f"{r[0]},{r[1]},{r[2]}")
