"""Serving subsystem smoke benchmark: sharded execution + micro-batcher.

Rows (land in BENCH_smoke.json via ``benchmarks.run --smoke``):

* ``serve.sharded.devices``   — virtual devices the measurement ran on
* ``serve.sharded.bit_exact`` — 1.0 iff spikes, v_final AND packet
  counts from the shard_map runner are byte-identical to the
  single-device engine, over a ragged batch that does not divide the
  device count (pad-and-mask path exercised)
* ``serve.sharded.speedup``   — single-device engine time / sharded
  time on the same batch (measured honestly: forced-host CPU devices
  share the physical cores, so expect ~1x in CI; the row tracks the
  trajectory, the acceptance bar is bit_exact)
* ``serve.sharded.dispatch_us`` — per-call overhead of the shard_map
  path at a tiny batch (the reason ``ShardedRunner`` routes
  B < devices x min_shard through the single-device engine)
* ``serve.batcher.p50_ms`` / ``serve.batcher.p99_ms`` — deterministic
  micro-batcher drain under the linear service model
* ``serve.batcher.deterministic`` — 1.0 iff two same-seed drains report
  identical latencies
* ``bench.first_request_ms`` / ``bench.steady_p50_ms`` — median
  genuinely-first request over a few COLD engines after AOT bucket
  precompile vs the p50 of subsequent identical requests; acceptance
  is first <= 2x steady

jax locks the host device count at first backend init, and the smoke
runner imports other jax-using benchmarks first — so the measurement
re-execs this module in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

N_DEVICES = 8
_ROWS_TAG = "SERVING_ROWS_JSON:"


# ---------------------------------------------------------------------------
# Parent entry point: re-exec with the forced device count.
# ---------------------------------------------------------------------------

def run(quick: bool = False) -> list[tuple]:
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{N_DEVICES}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [str(root / "src"), env.get("PYTHONPATH")] if p)
    cmd = [sys.executable, "-m", "benchmarks.serving_throughput",
           "--emit-json"] + (["--quick"] if quick else [])
    proc = subprocess.run(cmd, cwd=root, env=env, capture_output=True,
                          text=True, timeout=1200)
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(_ROWS_TAG):
            payload = json.loads(line[len(_ROWS_TAG):])
    if proc.returncode != 0 or payload is None:
        raise RuntimeError(
            f"serving measurement subprocess failed "
            f"(rc={proc.returncode}):\n{proc.stdout}\n{proc.stderr}")
    return [tuple(row) for row in payload]


# ---------------------------------------------------------------------------
# Child: the actual measurement (runs under the forced device count).
# ---------------------------------------------------------------------------

def _timed(fn, repeats: int) -> float:
    fn()                                 # warm the compilation cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(quick: bool) -> list[tuple]:
    import jax
    import numpy as np

    from repro.core import HardwareConfig, compile, random_graph
    from repro.core.execution import ExecutionSpec
    from repro.serve import BatchPolicy, MicroBatcher, linear_service_model
    from repro.serve.sharded import ShardedRunner

    n_dev = len(jax.devices())
    rows: list[tuple] = [("serve.sharded.devices", n_dev,
                          "virtual devices (XLA forced-host)")]

    g = random_graph(n_inputs=48, n_internal=40, n_synapses=700, seed=0)
    hw = HardwareConfig(
        n_spus=8, unified_mem_depth=4 * (g.n_synapses // 8 + g.n_internal),
        concentration=2, max_neurons=g.n_neurons,
        max_post_neurons=g.n_internal)
    program = compile(g, hw, max_iters=20000)
    runner = program.sharded_runner()

    # -- bit-exactness on a ragged batch (pad-and-mask path) ----------------
    t_steps = 20
    b_ragged = 3 * n_dev + 1
    rng = np.random.default_rng(0)
    ext = (rng.random((b_ragged, t_steps, g.n_inputs)) < 0.3) \
        .astype(np.int32)
    s1, v1, st1 = program.run(ext)                    # single-device engine
    s2, v2, st2 = program.run(ext, ExecutionSpec(mesh="auto"))
    exact = (s1.tobytes() == s2.tobytes() and v1.tobytes() == v2.tobytes()
             and np.array_equal(st1["packet_counts"], st2["packet_counts"]))
    rows.append(("serve.sharded.bit_exact", float(exact),
                 f"spikes+v+packets identical, ragged B={b_ragged} "
                 f"over {n_dev} devices"))

    # -- throughput: one big batch, engine vs sharded runner ----------------
    # 32x the device count: below ~256 samples the per-shard dispatch
    # overhead of forced-host devices dominates and the row under-reports
    b_perf = 32 * n_dev
    ext_p = (rng.random((b_perf, t_steps, g.n_inputs)) < 0.3) \
        .astype(np.int32)
    repeats = 3 if quick else 5
    t_single = _timed(lambda: program.run(ext_p), repeats)
    t_sharded = _timed(lambda: runner.run(ext_p), repeats)
    rows.append(("serve.sharded.speedup", t_single / t_sharded,
                 f"B={b_perf}, single {t_single * 1e3:.1f}ms vs "
                 f"sharded {t_sharded * 1e3:.1f}ms"))

    # -- dispatch overhead: why tiny batches fall back ----------------------
    # min_shard=0 forces the true shard path even at B = n_dev; the
    # delta vs the single-device engine on the same batch is the pure
    # shard_map dispatch cost the B < devices x min_shard fallback saves
    b_small = n_dev
    ext_s = ext[:b_small]
    shard_forced = ShardedRunner(program, min_shard=0)
    t_sh = _timed(lambda: shard_forced.run(ext_s), repeats)
    t_si = _timed(lambda: program.run(ext_s), repeats)
    rows.append(("serve.sharded.dispatch_us", (t_sh - t_si) * 1e6,
                 f"shard_map minus single-device at B={b_small}; "
                 f"ShardedRunner routes smaller batches single-device"))

    # -- cold start: AOT bucket precompile ----------------------------------
    # each JaxMappedEngine below is a FRESH engine on the same artifact
    # (built outside Program's cache), AOT-warmed via precompile — the
    # timed call is that engine's genuinely-first request. A single
    # first request is one sample, so take the median over a few
    # independent cold engines to keep scheduler noise out of the row.
    from repro.core import JaxMappedEngine
    cold = ExecutionSpec(donate=True).resolve()
    policy = BatchPolicy(max_batch=8)
    req = ext[:policy.max_batch]
    firsts, eng = [], None
    for _ in range(3):
        eng = JaxMappedEngine(program.graph, program.lowered, cold)
        eng.precompile(policy.buckets, t_steps)
        t0 = time.perf_counter()
        eng.run(req)
        firsts.append((time.perf_counter() - t0) * 1e3)
    first_ms = float(np.median(firsts))
    steady = []
    for _ in range(10 if quick else 20):
        t0 = time.perf_counter()
        eng.run(req)
        steady.append((time.perf_counter() - t0) * 1e3)
    steady_p50 = float(np.percentile(steady, 50))
    rows.append(("bench.first_request_ms", first_ms,
                 f"median first request over 3 cold AOT-precompiled "
                 f"engines, B={policy.max_batch} T={t_steps}"))
    rows.append(("bench.steady_p50_ms", steady_p50,
                 f"p50 of subsequent identical requests; acceptance: "
                 f"first <= 2x steady"))

    # -- micro-batcher: deterministic drain ---------------------------------
    n_req = 64 if quick else 256
    def drain():
        r = np.random.default_rng(1)
        arrivals = np.cumsum(r.exponential(300.0, n_req))
        # pure queue simulation: with a service model set, engine calls
        # would add nothing to the p50/p99 rows but wall clock
        batcher = MicroBatcher(BatchPolicy(max_batch=8),
                               service_model=linear_service_model())
        return batcher.drain(arrivals)
    res_a, res_b = drain(), drain()
    m = res_a.metrics()
    det = np.array_equal(res_a.latencies_us, res_b.latencies_us)
    rows.append(("serve.batcher.p50_ms", m["p50_ms"],
                 f"{n_req} Poisson requests, linear service model"))
    rows.append(("serve.batcher.p99_ms", m["p99_ms"],
                 f"buckets {dict(sorted(m['buckets'].items()))}"))
    rows.append(("serve.batcher.deterministic", float(det),
                 "two same-seed drains, identical latencies"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv
    rows = _measure(quick)
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
    if "--emit-json" in sys.argv:
        print(_ROWS_TAG + json.dumps(rows))


if __name__ == "__main__":
    main()
