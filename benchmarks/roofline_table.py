"""§Roofline aggregation: reads results/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all``) and emits the per-cell roofline
terms. This is a REPORT benchmark — it fails (rows=0) if the dry-run has
not been executed."""
from __future__ import annotations

import glob
import json
import os


def run(quick: bool = False, out_dir: str = "results/dryrun") -> list[tuple]:
    rows = []
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    worst = (None, 1e9)
    for f in files:
        d = json.load(open(f))
        if "__" in os.path.basename(f):
            continue                        # perf-iteration variants
        r = d["roofline"]
        tag = f"{d['arch']}|{d['shape']}|{d['mesh']}"
        rows.append((f"roofline.fraction[{tag}]",
                     round(r["roofline_fraction"], 4),
                     f"dom={r['dominant']},useful={r['useful_flop_ratio']:.2f}"))
        if d["mesh"] == "single" and r["roofline_fraction"] < worst[1]:
            worst = (tag, r["roofline_fraction"])
    rows.append(("roofline.cells", len(rows), "expect 64 (32 x 2 meshes)"))
    if worst[0]:
        rows.append(("roofline.worst_cell", worst[1], worst[0]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]}")
