"""Scheduler throughput: the vectorized array core vs the legacy loop.

Two claims of the scheduling-subsystem refactor are measured here:

1. **Bit-exact speedup.** ``repro.core.scheduling.schedule_vectorized``
   reproduces the legacy pure-Python loop
   (``repro.core.scheduling.legacy``) bit-for-bit — tables,
   ``send_slot``/``send_order`` — on the same (graph, assignment, hw)
   while running ≥10x faster on the paper's fig13 SHD instance shape
   (700-in/300-hidden SRNN + readout, 9-bit weights, ~33k synapses,
   16 SPUs).

2. **Joint co-optimization.** ``compile(search=SearchConfig(...))``
   schedules every feasible candidate mapping under every registered
   schedule strategy and selects the joint (mapping, strategy) pair —
   on the benchmarked config it beats the best candidate under the
   default 'slack' strategy alone.

Timing is best-of-N with the GC paused — standard practice to cut
container noise; parity is asserted, not sampled.
"""
from __future__ import annotations

import gc
import time

import numpy as np

from benchmarks.partitioner_throughput import fig13_shd_instance
from repro.core import (SearchConfig, compile as compile_program,
                        random_graph, synapse_round_robin)
from repro.core.memory_model import HardwareConfig
from repro.core.scheduling import schedule_legacy, schedule_vectorized


def _timed(fn, repeats: int) -> tuple[float, object]:
    """Best-of-N wall time with the GC paused during each run."""
    best, out = float("inf"), None
    for _ in range(repeats):
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        gc.enable()
        best = min(best, dt)
    return best, out


def run(quick: bool = False) -> list[tuple]:
    g, hw = fig13_shd_instance()    # quick shortens repeats, not the shape
    repeats = 3 if quick else 5     # best-of-N: min is the robust estimator
    # a deterministic, balanced paper-scale assignment (the round-robin
    # baseline) so both sides schedule the identical instance every run
    assign = synapse_round_robin(g, hw).assign

    legacy_s, legacy = _timed(lambda: schedule_legacy(g, assign, hw), repeats)
    vec_s, vec = _timed(lambda: schedule_vectorized(g, assign, hw), repeats)
    parity = (legacy.depth == vec.depth
              and all(np.array_equal(getattr(legacy, f), getattr(vec, f))
                      for f in ("pre", "post", "weight", "pre_end",
                                "post_end"))
              and legacy.send_slot == vec.send_slot
              and legacy.send_order == vec.send_order)
    assert parity, "vectorized scheduler diverged from the legacy loop"

    rows = [
        ("scheduler.instance.synapses", g.n_synapses, "fig13 SHD shape"),
        ("scheduler.instance.ot_depth", legacy.depth, "scheduled depth"),
        ("scheduler.parity", float(parity), "bit-exact tables + send order"),
        ("scheduler.legacy.seconds", legacy_s, ""),
        ("scheduler.vectorized.seconds", vec_s, ""),
        ("scheduler.speedup", legacy_s / vec_s, "acceptance: >= 10x"),
    ]

    # joint co-optimization: a config where the strategies disagree, so
    # the portfolio's joint (mapping, strategy) selection lands strictly
    # below the best candidate scheduled with the default 'slack' order
    gj = random_graph(24, 48, 2000, seed=0)
    hwj = HardwareConfig(n_spus=8, unified_mem_depth=40, concentration=3,
                         max_neurons=128, max_post_neurons=64)
    t0 = time.perf_counter()
    prog = compile_program(gj, hwj, search=SearchConfig(
        restarts=4, max_iters=20000, early_exit=False))
    joint_s = time.perf_counter() - t0
    trace = prog.report.search
    slack_depths = [c.schedule_depths["slack"] for c in trace.candidates
                    if c.schedule_depths]
    best_slack = min(slack_depths)
    sel = trace.selected
    rows += [
        ("scheduler.joint.candidates", prog.report.candidates_tried, ""),
        ("scheduler.joint.best_slack_depth", best_slack,
         "best mapping under the default strategy alone"),
        ("scheduler.joint.ot_depth", prog.ot_depth,
         f"joint winner: {sel.strategy} + {prog.report.schedule_method}"),
        ("scheduler.joint.beats_single_strategy",
         float(prog.ot_depth < best_slack), "acceptance: 1.0"),
        ("scheduler.joint.compile_seconds", joint_s, ""),
    ]
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(f"{r[0]},{r[1]},{r[2]}")
