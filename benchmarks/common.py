"""Shared helpers for the per-table benchmarks."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import compile as compile_program
from repro.snn import QuantConfig, SNNConfig, quantize
from repro.snn.models import forward
from repro.snn.train import train
from repro.data import mnist_batches, synthetic_mnist, synthetic_shd, shd_batches


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat * 1e6  # us


def trained_mnist_snn(steps: int = 60, seed: int = 0):
    """Short synthetic-MNIST training run for the hardware benchmarks."""
    from repro.snn import MNIST_CONFIG
    xtr, ytr, xte, yte = synthetic_mnist(n_train=512, n_test=128, seed=seed)
    data = mnist_batches(xtr, ytr, batch=64, seed=seed)
    res = train(MNIST_CONFIG, data, steps=steps, lr=5e-4,
                key=jax.random.PRNGKey(seed), encode=True)
    return MNIST_CONFIG, res.params, (xte, yte)


def trained_shd_snn(sparsity: float, steps: int = 60, hidden: int = 128,
                    timesteps: int = 40, seed: int = 0):
    """Short synthetic-SHD SRNN training run at a given sparsity."""
    from repro.snn import LIFParams
    cfg = SNNConfig(layer_sizes=(700, hidden, 20), recurrent=True,
                    sparsity=sparsity, lif=LIFParams(alpha=0.03125),
                    surrogate="sigmoid", timesteps=timesteps)
    xtr, ytr, xte, yte = synthetic_shd(n_train=256, n_test=128,
                                       timesteps=timesteps, seed=seed)
    data = shd_batches(xtr, ytr, batch=32, seed=seed)
    res = train(cfg, data, steps=steps, lr=2e-3, key=jax.random.PRNGKey(seed),
                encode=False)
    return cfg, res.params, (xte, yte)


def accuracy(cfg, params, xte, yte, encode: bool, key=None):
    import jax.numpy as jnp
    from repro.snn.train import rate_encode
    key = key if key is not None else jax.random.PRNGKey(1)
    fwd = jax.jit(lambda p, s: jnp.argmax(forward(p, s, cfg)[0], -1))
    correct = 0
    for i in range(0, len(xte), 64):
        x = xte[i:i + 64]
        if encode:
            s = rate_encode(jnp.asarray(x), cfg.timesteps,
                            jax.random.fold_in(key, i))
        else:
            s = jnp.asarray(x.transpose(1, 0, 2).astype(np.float32))
        correct += int((np.asarray(fwd(params, s)) == yte[i:i + 64]).sum())
    return correct / len(xte)


def simulate_inference(cfg, params, hw, qc: QuantConfig, sample,
                       encode: bool, key=None, method="framework",
                       max_iters: int = 40000):
    """quantize -> compile to a Program artifact -> run -> profile.

    Returns ``(q, program, cycle_report)``; graph/tables/compile report
    hang off the artifact (``program.graph`` / ``.tables`` / ``.report``).
    """
    import jax.numpy as jnp
    from repro.snn.train import rate_encode
    q = quantize(params, cfg, qc)
    program = compile_program(q, hw, method=method, seed=0,
                              max_iters=max_iters)
    key = key if key is not None else jax.random.PRNGKey(2)
    if encode:
        spikes = np.asarray(rate_encode(jnp.asarray(sample[None]),
                                        cfg.timesteps, key))[:, 0]
    else:
        spikes = sample.astype(np.int32)
    _, _, stats = program.run(spikes.astype(np.int32), "python")
    prof = program.profile(stats, n_synapses=q.n_total_synapses)
    return q, program, prof.cycle
