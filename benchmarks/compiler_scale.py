"""Compiler-scale benchmark: hypergraph mapping quality + multilevel
compile cost (DESIGN.md §11).

Two claim groups:

* ``mapping.*`` — the ``hypergraph`` strategy vs the paper's framework
  heuristic on the fig13 SHD shape (the ROADMAP acceptance bar): OT
  depth under the best registered schedule strategy, and the static
  multicast packet cost of the mapping (total destination-SPU count
  over all fan-out hyperedges — the MC-tree deliveries one spike of
  every source costs). ``mapping.hypergraph.beats_paper`` is 1.0 when
  the hypergraph mapping wins on OT depth OR packets.

* ``compiler_scale.*`` — wall-clock compile seconds and peak RSS of a
  ``method="multilevel"`` + ``compile(n_chips=4)`` compile at a PINNED
  10⁵-synapse synthetic shape (``repro.core.scale``), measured in a
  fresh subprocess so ``ru_maxrss`` reflects this compile alone, not
  whatever benchmark ran before in the smoke process. Full (non-quick)
  mode sweeps additional sizes.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

_ROWS_TAG = "COMPILER_SCALE_ROWS_JSON:"
PINNED = dict(n_synapses=100_000, topology="mixed", skew=1.0, seed=0,
              n_chips=4, spus_per_chip=16)
#: (n_synapses, n_chips) sweep for full (non-quick) mode; the last entry
#: is the §12 million-synapse 4x4-mesh acceptance point
FULL_SWEEP = ((100_000, 4), (300_000, 4), (1_000_000, 16))

# generous soft regression pins for the PINNED 10^5 shape (the tracked
# trajectory point): §12 landed it at ~1.5 s / ~260 MB, so a breach
# means a real regression, not noise
PIN_100K_COMPILE_S = 6.0
PIN_100K_RSS_MB = 900.0
# §12 acceptance envelope for the million-synapse 16-chip compile
PIN_1M_COMPILE_S = 600.0
PIN_1M_RSS_MB = 2048.0


# ---------------------------------------------------------------------------
# Paper-scale mapping quality (in-process; no RSS involved).
# ---------------------------------------------------------------------------

def _best_depth(g, hw, assign) -> int:
    from repro.core.scheduling import (SCHEDULE_STRATEGIES, group_info,
                                       schedule)
    info = group_info(g, assign)
    return min(int(schedule(g, assign, hw, method=name, info=info).depth)
               for name in SCHEDULE_STRATEGIES)


def _quality_rows(quick: bool) -> list[tuple]:
    from benchmarks.partitioner_throughput import fig13_shd_instance
    from repro.core.mapping.hypergraph import (hypergraph_partition,
                                               mapping_traffic)
    from repro.core.mapping.search import framework_partition

    g, hw = fig13_shd_instance()
    iters = 3000 if quick else 20000
    t0 = time.perf_counter()
    fw, _, _ = framework_partition(g, hw, seed=0, restarts=1,
                                   max_iters=iters)
    fw_s = time.perf_counter() - t0
    # before/after the §12 load-balance pass: traffic-first greedy +
    # refinement concentrate fan-in groups onto few SPUs, which blows up
    # the OT depth (the busiest SPU's op count); balance_loads spreads
    # whole fan-in groups within each chip under Eq. (9)
    raw = hypergraph_partition(g, hw, balance=False)
    t0 = time.perf_counter()
    hg = hypergraph_partition(g, hw)
    hg_s = time.perf_counter() - t0

    fw_ot = _best_depth(g, hw, fw.assign)
    raw_ot = _best_depth(g, hw, raw.assign)
    hg_ot = _best_depth(g, hw, hg.assign)
    fw_pk = mapping_traffic(g, fw.assign, hw)["dests_total"]
    raw_pk = mapping_traffic(g, raw.assign, hw)["dests_total"]
    hg_pk = mapping_traffic(g, hg.assign, hw)["dests_total"]
    beats = float(hg_ot < fw_ot or hg_pk < fw_pk)
    return [
        ("mapping.instance.synapses", g.n_synapses, "fig13 SHD shape"),
        ("mapping.framework.ot_depth", fw_ot,
         f"best schedule strategy, {iters} iters"),
        ("mapping.framework.packets", fw_pk,
         "multicast destination-SPU total"),
        ("mapping.framework.seconds", fw_s, ""),
        ("mapping.hypergraph.unbalanced.ot_depth", raw_ot,
         "balance=False: the pre-§12 depth blowup"),
        ("mapping.hypergraph.unbalanced.packets", raw_pk,
         "multicast destination-SPU total"),
        ("mapping.hypergraph.ot_depth", hg_ot,
         "best schedule strategy, after balance_loads"),
        ("mapping.hypergraph.packets", hg_pk,
         "multicast destination-SPU total (depth-vs-packets tradeoff)"),
        ("mapping.hypergraph.seconds", hg_s, ""),
        ("mapping.hypergraph.beats_paper", beats,
         "acceptance: wins OT depth OR packets vs framework"),
    ]


# ---------------------------------------------------------------------------
# Scale compile (child measures; parent re-execs for a clean ru_maxrss).
# ---------------------------------------------------------------------------

def _scale_tag(n_synapses: int) -> str:
    return ("compiler_scale.1m" if n_synapses == 1_000_000
            else f"compiler_scale.{n_synapses // 1000}k")


def _measure_scale(n_synapses: int, topology: str, skew: float, seed: int,
                   n_chips: int, spus_per_chip: int) -> list[tuple]:
    import dataclasses
    import resource

    from repro.core import compile as compile_program
    from repro.core.mapping.hypergraph import mapping_traffic
    from repro.core.mapping.multilevel import multilevel_partition
    from repro.core.scale import scale_hw, synthetic_graph

    g = synthetic_graph(n_synapses, topology=topology, skew=skew, seed=seed)
    hw_all = scale_hw(g, n_chips=n_chips, spus_per_chip=spus_per_chip)
    # per-chip description; compile(n_chips=) replicates it (the API the
    # subsystem ships — exercise it rather than a pre-flattened config)
    hw1 = dataclasses.replace(hw_all, n_spus=hw_all.spus_per_chip, n_chips=1)
    t0 = time.perf_counter()
    prog = compile_program(g, hw1, method="multilevel", n_chips=n_chips,
                           validate=True)
    compile_s = time.perf_counter() - t0
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    traffic = mapping_traffic(g, prog.tables.assign, prog.hw)
    hop = prog.hw.inter_chip_hop_cycles
    tag = _scale_tag(n_synapses)
    mx, my = prog.hw.mesh_dims
    rows = [
        (f"{tag}.synapses", g.n_synapses, f"{topology}, skew={skew}"),
        (f"{tag}.compile_s", compile_s,
         f"multilevel, n_chips={n_chips}, validated schedule"),
        (f"{tag}.peak_rss_mb", peak_mb, "subprocess ru_maxrss"),
        (f"{tag}.feasible", float(prog.feasible), "Eq. (9) on every SPU"),
        (f"{tag}.ot_depth", int(prog.ot_depth), ""),
        (f"{tag}.packets", traffic["dests_total"],
         "multicast destination-SPU total"),
        (f"{tag}.inter_chip_total", traffic["inter_chip_total"],
         "forwarded packets if every source fired once"),
        (f"{tag}.mesh_hops_total", traffic["mesh_hops_total"],
         f"XY bounding-box hops on the {mx}x{my} mesh (DESIGN.md §12)"),
    ]
    # per-phase compile profile (§12): where the wall time went
    for name, secs in (prog.report.phase_seconds or {}).items():
        rows.append((f"{tag}.phase_s.{name}", secs, "compile-phase profiler"))
    # regression pins: generous soft thresholds on the tracked shapes
    if n_synapses == PINNED["n_synapses"]:
        assert compile_s < PIN_100K_COMPILE_S, \
            f"100k compile regressed: {compile_s:.2f}s >= {PIN_100K_COMPILE_S}"
        assert peak_mb < PIN_100K_RSS_MB, \
            f"100k compile RSS regressed: {peak_mb:.0f}MB >= {PIN_100K_RSS_MB}"
    if n_synapses == 1_000_000:
        assert prog.feasible, "1m acceptance shape went infeasible"
        assert compile_s < PIN_1M_COMPILE_S and peak_mb < PIN_1M_RSS_MB, \
            f"1m envelope breached: {compile_s:.1f}s / {peak_mb:.0f}MB"
    # mesh-vs-chain counterfactual at the acceptance shape: the same
    # pipeline with the placement stage disabled (§11 consecutive-id
    # chain overlay), compared on hop-weighted static traffic
    if n_synapses == PINNED["n_synapses"]:
        chain = multilevel_partition(g, prog.hw, chip_placement=False)
        tc = mapping_traffic(g, chain.assign, prog.hw)
        placed_cost = traffic["dests_total"] + hop * traffic["mesh_hops_total"]
        chain_cost = tc["dests_total"] + hop * tc["mesh_hops_total"]
        rows += [
            (f"{tag}.hopweighted.placed", placed_cost,
             "dests + hop_cycles * mesh hops, placement on"),
            (f"{tag}.hopweighted.chain", chain_cost,
             "chip_placement=False counterfactual"),
            (f"{tag}.mesh_beats_chain", float(placed_cost <= chain_cost),
             "acceptance: placement never loses to the chain overlay"),
        ]
    return rows


def _scale_rows_subprocess(n_synapses: int, n_chips: int) -> list[tuple]:
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [str(root / "src"), env.get("PYTHONPATH")] if p)
    cmd = [sys.executable, "-m", "benchmarks.compiler_scale", "--emit-json",
           "--synapses", str(n_synapses), "--chips", str(n_chips)]
    proc = subprocess.run(cmd, cwd=root, env=env, capture_output=True,
                          text=True, timeout=1800)
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(_ROWS_TAG):
            payload = json.loads(line[len(_ROWS_TAG):])
    if proc.returncode != 0 or payload is None:
        raise RuntimeError(
            f"compiler_scale subprocess failed (rc={proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    return [tuple(row) for row in payload]


def run(quick: bool = False) -> list[tuple]:
    rows = _quality_rows(quick)
    # the pinned 1e5 shape always runs (the tracked trajectory point);
    # full mode sweeps the larger sizes up to the 10^6 acceptance point
    sweep = (((PINNED["n_synapses"], PINNED["n_chips"]),) if quick
             else FULL_SWEEP)
    for n, chips in sweep:
        rows += _scale_rows_subprocess(n, chips)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-json", action="store_true")
    ap.add_argument("--synapses", type=int,
                    default=PINNED["n_synapses"])
    ap.add_argument("--chips", type=int, default=PINNED["n_chips"])
    args = ap.parse_args()
    out = _measure_scale(args.synapses, PINNED["topology"], PINNED["skew"],
                         PINNED["seed"], args.chips,
                         PINNED["spus_per_chip"])
    if args.emit_json:
        print(_ROWS_TAG + json.dumps(out))
    else:
        for name, value, derived in out:
            print(f"{name},{value},{derived}")
