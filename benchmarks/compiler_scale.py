"""Compiler-scale benchmark: hypergraph mapping quality + multilevel
compile cost (DESIGN.md §11).

Two claim groups:

* ``mapping.*`` — the ``hypergraph`` strategy vs the paper's framework
  heuristic on the fig13 SHD shape (the ROADMAP acceptance bar): OT
  depth under the best registered schedule strategy, and the static
  multicast packet cost of the mapping (total destination-SPU count
  over all fan-out hyperedges — the MC-tree deliveries one spike of
  every source costs). ``mapping.hypergraph.beats_paper`` is 1.0 when
  the hypergraph mapping wins on OT depth OR packets.

* ``compiler_scale.*`` — wall-clock compile seconds and peak RSS of a
  ``method="multilevel"`` + ``compile(n_chips=4)`` compile at a PINNED
  10⁵-synapse synthetic shape (``repro.core.scale``), measured in a
  fresh subprocess so ``ru_maxrss`` reflects this compile alone, not
  whatever benchmark ran before in the smoke process. Full (non-quick)
  mode sweeps additional sizes.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

_ROWS_TAG = "COMPILER_SCALE_ROWS_JSON:"
PINNED = dict(n_synapses=100_000, topology="mixed", skew=1.0, seed=0,
              n_chips=4, spus_per_chip=16)
FULL_SWEEP = (100_000, 300_000)


# ---------------------------------------------------------------------------
# Paper-scale mapping quality (in-process; no RSS involved).
# ---------------------------------------------------------------------------

def _best_depth(g, hw, assign) -> int:
    from repro.core.scheduling import (SCHEDULE_STRATEGIES, group_info,
                                       schedule)
    info = group_info(g, assign)
    return min(int(schedule(g, assign, hw, method=name, info=info).depth)
               for name in SCHEDULE_STRATEGIES)


def _quality_rows(quick: bool) -> list[tuple]:
    from benchmarks.partitioner_throughput import fig13_shd_instance
    from repro.core.mapping.hypergraph import (hypergraph_partition,
                                               mapping_traffic)
    from repro.core.mapping.search import framework_partition

    g, hw = fig13_shd_instance()
    iters = 3000 if quick else 20000
    t0 = time.perf_counter()
    fw, _, _ = framework_partition(g, hw, seed=0, restarts=1,
                                   max_iters=iters)
    fw_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    hg = hypergraph_partition(g, hw)
    hg_s = time.perf_counter() - t0

    fw_ot = _best_depth(g, hw, fw.assign)
    hg_ot = _best_depth(g, hw, hg.assign)
    fw_pk = mapping_traffic(g, fw.assign, hw)["dests_total"]
    hg_pk = mapping_traffic(g, hg.assign, hw)["dests_total"]
    beats = float(hg_ot < fw_ot or hg_pk < fw_pk)
    return [
        ("mapping.instance.synapses", g.n_synapses, "fig13 SHD shape"),
        ("mapping.framework.ot_depth", fw_ot,
         f"best schedule strategy, {iters} iters"),
        ("mapping.framework.packets", fw_pk,
         "multicast destination-SPU total"),
        ("mapping.framework.seconds", fw_s, ""),
        ("mapping.hypergraph.ot_depth", hg_ot, "best schedule strategy"),
        ("mapping.hypergraph.packets", hg_pk,
         "multicast destination-SPU total"),
        ("mapping.hypergraph.seconds", hg_s, ""),
        ("mapping.hypergraph.beats_paper", beats,
         "acceptance: wins OT depth OR packets vs framework"),
    ]


# ---------------------------------------------------------------------------
# Scale compile (child measures; parent re-execs for a clean ru_maxrss).
# ---------------------------------------------------------------------------

def _measure_scale(n_synapses: int, topology: str, skew: float, seed: int,
                   n_chips: int, spus_per_chip: int) -> list[tuple]:
    import dataclasses
    import resource

    from repro.core import compile as compile_program
    from repro.core.mapping.hypergraph import mapping_traffic
    from repro.core.scale import scale_hw, synthetic_graph

    g = synthetic_graph(n_synapses, topology=topology, skew=skew, seed=seed)
    hw_all = scale_hw(g, n_chips=n_chips, spus_per_chip=spus_per_chip)
    # per-chip description; compile(n_chips=) replicates it (the API the
    # subsystem ships — exercise it rather than a pre-flattened config)
    hw1 = dataclasses.replace(hw_all, n_spus=hw_all.spus_per_chip, n_chips=1)
    t0 = time.perf_counter()
    prog = compile_program(g, hw1, method="multilevel", n_chips=n_chips,
                           validate=True)
    compile_s = time.perf_counter() - t0
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    traffic = mapping_traffic(g, prog.tables.assign, prog.hw)
    tag = f"compiler_scale.{n_synapses // 1000}k"
    return [
        (f"{tag}.synapses", g.n_synapses, f"{topology}, skew={skew}"),
        (f"{tag}.compile_s", compile_s,
         f"multilevel, n_chips={n_chips}, validated schedule"),
        (f"{tag}.peak_rss_mb", peak_mb, "subprocess ru_maxrss"),
        (f"{tag}.feasible", float(prog.feasible), "Eq. (9) on every SPU"),
        (f"{tag}.ot_depth", int(prog.ot_depth), ""),
        (f"{tag}.packets", traffic["dests_total"],
         "multicast destination-SPU total"),
        (f"{tag}.inter_chip_total", traffic["inter_chip_total"],
         "forwarded packets if every source fired once"),
    ]


def _scale_rows_subprocess(n_synapses: int) -> list[tuple]:
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [str(root / "src"), env.get("PYTHONPATH")] if p)
    cmd = [sys.executable, "-m", "benchmarks.compiler_scale", "--emit-json",
           "--synapses", str(n_synapses)]
    proc = subprocess.run(cmd, cwd=root, env=env, capture_output=True,
                          text=True, timeout=1800)
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(_ROWS_TAG):
            payload = json.loads(line[len(_ROWS_TAG):])
    if proc.returncode != 0 or payload is None:
        raise RuntimeError(
            f"compiler_scale subprocess failed (rc={proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    return [tuple(row) for row in payload]


def run(quick: bool = False) -> list[tuple]:
    rows = _quality_rows(quick)
    # the pinned 1e5 shape always runs (the tracked trajectory point);
    # full mode sweeps the larger sizes on top
    for n in (PINNED["n_synapses"],) if quick else FULL_SWEEP:
        rows += _scale_rows_subprocess(n)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-json", action="store_true")
    ap.add_argument("--synapses", type=int,
                    default=PINNED["n_synapses"])
    args = ap.parse_args()
    out = _measure_scale(args.synapses, PINNED["topology"], PINNED["skew"],
                         PINNED["seed"], PINNED["n_chips"],
                         PINNED["spus_per_chip"])
    if args.emit_json:
        print(_ROWS_TAG + json.dumps(out))
    else:
        for name, value, derived in out:
            print(f"{name},{value},{derived}")
