"""LM pretraining through the fault-tolerant launcher — checkpointing,
journal, straggler watchdog, resume. Defaults to a CPU-sized reduced
config; ``--arch qwen2-1.5b`` (no --reduced on real hardware) runs the
full assigned architecture on the production mesh.

    PYTHONPATH=src python examples/lm_pretrain.py --steps 40
    # kill it mid-run, then:
    PYTHONPATH=src python examples/lm_pretrain.py --steps 40 --resume
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_pretrain")
    ap.add_argument("--resume", action="store_true")
    a = ap.parse_args()
    args = ["--arch", a.arch, "--reduced", "--steps", str(a.steps),
            "--batch", str(a.batch), "--seq", str(a.seq),
            "--ckpt-dir", a.ckpt_dir, "--ckpt-every", "10"]
    if a.resume:
        args.append("--resume")
    train_main(args)
