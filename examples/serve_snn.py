"""Micro-batching SNN serving loop over a loaded `Program` artifact —
the save-once / serve-many flow the artifact API exists for.

    PYTHONPATH=src python examples/serve_snn.py [--artifact PATH]
        [--requests 64] [--batch-max 8] [--arrival-us 300]

One process compiles (partition + schedule, the expensive stochastic
part) and saves the artifact; every serving process just `Program.load`s
it — no re-partitioning — and drives the compiled batched engine:

  1. requests (single spike trains, Poisson arrivals) land in a queue;
  2. the server drains up to --batch-max of them, PADS the batch up to
     the next power-of-two bucket (so XLA compiles one program per
     bucket, not per batch size), and runs them in one engine call;
  3. per-request latency = queue wait + batch service time.

Service times are real wall-clock engine calls; arrivals advance a
simulated clock so the demo is deterministic and sleep-free. Reports
p50/p99 latency, throughput, and the bucket histogram.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np

from repro.core import HardwareConfig, Program, compile, random_graph


def build_artifact(path: Path) -> Path:
    """Compile-once step: a toy-MNIST-shaped graph onto 16 SPUs."""
    g = random_graph(n_inputs=64, n_internal=48, n_synapses=900, seed=0)
    hw = HardwareConfig(n_spus=16, unified_mem_depth=64, concentration=3,
                        max_neurons=g.n_neurons,
                        max_post_neurons=g.n_internal)
    program = compile(g, hw, max_iters=20000)
    print(f"compiled: feasible={program.feasible} "
          f"OT depth={program.ot_depth} "
          f"({program.report.compile_seconds:.1f}s partition+schedule)")
    return program.save(path)


def bucket_of(n: int, batch_max: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, batch_max)


def serve(program: Program, requests: np.ndarray, arrivals: np.ndarray,
          batch_max: int) -> tuple[np.ndarray, dict[int, int]]:
    """Drain the arrival queue in micro-batches; return latencies (us)."""
    t_steps, n_in = requests.shape[1], requests.shape[2]
    # warm up one engine compilation per reachable bucket size:
    # powers of two below batch_max, plus batch_max itself (bucket_of
    # caps there, so a non-power-of-two max is its own bucket)
    sizes = {b for k in range(batch_max.bit_length())
             if (b := 2 ** k) < batch_max} | {batch_max}
    for b in sorted(sizes):
        program.run(np.zeros((b, t_steps, n_in), np.int32))

    latencies = np.zeros(len(requests))
    buckets: dict[int, int] = {}
    clock = 0.0                       # simulated us
    i = 0
    while i < len(requests):
        clock = max(clock, arrivals[i])          # wait for work
        n = 1                                    # drain what has arrived
        while (i + n < len(requests) and n < batch_max
               and arrivals[i + n] <= clock):
            n += 1
        bucket = bucket_of(n, batch_max)
        batch = requests[i:i + n]
        if len(batch) < bucket:                  # pad to the bucket shape
            pad = np.zeros((bucket - len(batch), t_steps, n_in), np.int32)
            batch = np.concatenate([batch, pad])
        t0 = time.perf_counter()
        program.run(batch)
        service_us = (time.perf_counter() - t0) * 1e6
        clock += service_us
        latencies[i:i + n] = clock - arrivals[i:i + n]
        buckets[bucket] = buckets.get(bucket, 0) + 1
        i += n
    return latencies, buckets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default="/tmp/suprasnn_serve_demo.npz")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-max", type=int, default=8)
    ap.add_argument("--timesteps", type=int, default=20)
    ap.add_argument("--arrival-us", type=float, default=300.0,
                    help="mean Poisson inter-arrival time")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    path = Path(args.artifact)
    if path.suffix != ".npz":          # Program.save appends .npz
        path = path.with_name(path.name + ".npz")
    if not path.exists():
        path = build_artifact(path)
    program = Program.load(path)      # no re-partitioning here
    print(f"loaded {path.name}: {program.n_synapses} synapses on "
          f"{program.hw.n_spus} SPUs, OT depth {program.ot_depth}")

    rng = np.random.default_rng(args.seed)
    reqs = (rng.random((args.requests, args.timesteps, program.n_inputs))
            < 0.25).astype(np.int32)
    arrivals = np.cumsum(rng.exponential(args.arrival_us, args.requests))

    lat, buckets = serve(program, reqs, arrivals, args.batch_max)
    p50, p99 = np.percentile(lat, [50, 99])
    span_s = (arrivals[-1] + lat[-1]) / 1e6
    print(f"served {args.requests} requests, batch buckets "
          f"{dict(sorted(buckets.items()))}")
    print(f"latency p50 {p50 / 1e3:.2f} ms  p99 {p99 / 1e3:.2f} ms  "
          f"throughput {args.requests / span_s:.0f} req/s")


if __name__ == "__main__":
    main()
