"""Micro-batching SNN serving CLI — a thin driver over `repro.serve`.

    PYTHONPATH=src python examples/serve_snn.py [--artifact PATH]
        [--requests 64] [--batch-max 8] [--max-wait-us 0]
        [--max-queue 0] [--deadline-us 0] [--shed reject]
        [--trace PATH.npz] [--arrival-us 300] [--seed 0]
        [--sharded] [--measured]

One process compiles (partition + schedule, the expensive stochastic
part) and saves the artifact; every serving process just `Program.load`s
it — no re-partitioning — registers it, and drains a Poisson request
stream through the library micro-batcher
(`repro.serve.batcher.MicroBatcher`): FIFO queue, power-of-two batch
buckets, pad-and-mask, per-request latency accounting on a simulated
microsecond clock.

Request spike trains AND Poisson arrivals come from ONE
`np.random.Generator(--seed)`, and service times default to the
deterministic linear model — so two runs with the same seed report
identical p50/p99 (asserted in tests/test_serving.py). `--measured`
swaps in real wall-clock engine times; `--sharded` runs each batch
data-parallel over every jax device (`repro.serve.sharded`).

Overload knobs map straight onto `BatchPolicy`: `--max-queue` bounds
the waiting queue, `--deadline-us` sets the per-request dispatch
deadline, `--shed` picks reject / drop-oldest /
degrade-to-smaller-bucket. `--trace` replays a recorded
`repro.serve.replay.ArrivalTrace` (.npz) instead of the synthetic
Poisson arrivals; shed and per-stage accounting are printed whenever
a policy can shed.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import numpy as np

from repro.core import (ExecutionSpec, HardwareConfig, Program, compile,
                        random_graph)
from repro.serve import (ArrivalTrace, BatchPolicy, MicroBatcher,
                         ProgramRegistry, linear_service_model)


def build_artifact(path: Path) -> Path:
    """Compile-once step: a toy-MNIST-shaped graph onto 16 SPUs."""
    g = random_graph(n_inputs=64, n_internal=48, n_synapses=900, seed=0)
    hw = HardwareConfig(n_spus=16, unified_mem_depth=64, concentration=3,
                        max_neurons=g.n_neurons,
                        max_post_neurons=g.n_internal)
    program = compile(g, hw, max_iters=20000)
    print(f"compiled: feasible={program.feasible} "
          f"OT depth={program.ot_depth} "
          f"({program.report.compile_seconds:.1f}s partition+schedule)")
    return program.save(path)


def run_demo(args) -> dict:
    """Load -> register -> drain the seeded stream; return the metrics."""
    path = Path(args.artifact)
    if path.suffix != ".npz":          # Program.save appends .npz
        path = path.with_name(path.name + ".npz")
    if not path.exists():
        path = build_artifact(path)
    registry = ProgramRegistry()
    program: Program = registry.load("demo", path)  # no re-partitioning
    print(f"loaded {path.name}: {program.n_synapses} synapses on "
          f"{program.hw.n_spus} SPUs, OT depth {program.ot_depth}")

    # ONE generator drives both the spike trains and the arrival process
    rng = np.random.default_rng(args.seed)
    if args.trace:
        trace = ArrivalTrace.load(args.trace)
        arrivals = trace.arrivals_us
        n_req = trace.n_requests
        print(f"replaying {trace.kind} trace: {n_req} requests over "
              f"{trace.duration_s:.1f}s ({trace.offered_qps:.0f} qps)")
    else:
        n_req = args.requests
        arrivals = np.cumsum(rng.exponential(args.arrival_us, n_req))
    reqs = (rng.random((n_req, args.timesteps, program.n_inputs))
            < 0.25).astype(np.int32)

    policy = BatchPolicy(max_batch=args.batch_max,
                         max_wait_us=args.max_wait_us,
                         max_queue=args.max_queue,
                         deadline_us=args.deadline_us,
                         shed=args.shed)
    spec = ExecutionSpec(mesh="auto") if args.sharded else None
    runner = registry.runner("demo", spec)
    batcher = MicroBatcher(
        policy, runner=runner,
        service_model=None if args.measured else linear_service_model())
    res = batcher.drain(arrivals, reqs)
    m = res.metrics()
    print(f"served {m['requests']} requests in {m['batches']} batches, "
          f"buckets {dict(sorted(m['buckets'].items()))}")
    print(f"latency p50 {m['p50_ms']:.2f} ms  p99 {m['p99_ms']:.2f} ms  "
          f"throughput {m['throughput_rps']:.0f} req/s")
    if policy.max_queue or policy.deadline_us:
        st = m["stages_us"]
        print(f"shed {m['shed']} ({m['shed_frac']:.1%}), "
              f"{m['degraded_batches']} degraded batches")
        print(f"stages (us): queue {st['queue_wait']:.1f}  "
              f"fill {st['batch_fill']:.1f}  pad {st['pad']:.1f}  "
              f"compute {st['compute']:.1f}")
    return m


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default="/tmp/suprasnn_serve_demo.npz")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-max", type=int, default=8)
    ap.add_argument("--max-wait-us", type=float, default=0.0)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the waiting queue (0 = unbounded); "
                         "overflow is handled by --shed")
    ap.add_argument("--deadline-us", type=float, default=0.0,
                    help="per-request dispatch deadline from arrival "
                         "(0 = none); late requests are shed, not late")
    ap.add_argument("--shed", default="reject",
                    choices=["reject", "drop-oldest", "degrade",
                             "degrade-to-smaller-bucket"],
                    help="overload policy when the queue is full")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a saved ArrivalTrace .npz instead of "
                         "synthetic Poisson arrivals")
    ap.add_argument("--timesteps", type=int, default=20)
    ap.add_argument("--arrival-us", type=float, default=300.0,
                    help="mean Poisson inter-arrival time")
    ap.add_argument("--seed", type=int, default=0,
                    help="one np.random.Generator seed for spike trains "
                         "AND arrivals: same seed, same p50/p99")
    ap.add_argument("--sharded", action="store_true",
                    help="run batches data-parallel over all jax devices")
    ap.add_argument("--measured", action="store_true",
                    help="use wall-clock engine times instead of the "
                         "deterministic linear service model")
    return run_demo(ap.parse_args(argv))


if __name__ == "__main__":
    main()
