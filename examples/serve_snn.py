"""Micro-batching SNN serving CLI — a thin driver over `repro.serve`.

    PYTHONPATH=src python examples/serve_snn.py [--artifact PATH]
        [--requests 64] [--batch-max 8] [--max-wait-us 0]
        [--arrival-us 300] [--seed 0] [--sharded] [--measured]

One process compiles (partition + schedule, the expensive stochastic
part) and saves the artifact; every serving process just `Program.load`s
it — no re-partitioning — registers it, and drains a Poisson request
stream through the library micro-batcher
(`repro.serve.batcher.MicroBatcher`): FIFO queue, power-of-two batch
buckets, pad-and-mask, per-request latency accounting on a simulated
microsecond clock.

Request spike trains AND Poisson arrivals come from ONE
`np.random.Generator(--seed)`, and service times default to the
deterministic linear model — so two runs with the same seed report
identical p50/p99 (asserted in tests/test_serving.py). `--measured`
swaps in real wall-clock engine times; `--sharded` runs each batch
data-parallel over every jax device (`repro.serve.sharded`).
"""
from __future__ import annotations

import argparse
from pathlib import Path

import numpy as np

from repro.core import (ExecutionSpec, HardwareConfig, Program, compile,
                        random_graph)
from repro.serve import (BatchPolicy, MicroBatcher, ProgramRegistry,
                         linear_service_model)


def build_artifact(path: Path) -> Path:
    """Compile-once step: a toy-MNIST-shaped graph onto 16 SPUs."""
    g = random_graph(n_inputs=64, n_internal=48, n_synapses=900, seed=0)
    hw = HardwareConfig(n_spus=16, unified_mem_depth=64, concentration=3,
                        max_neurons=g.n_neurons,
                        max_post_neurons=g.n_internal)
    program = compile(g, hw, max_iters=20000)
    print(f"compiled: feasible={program.feasible} "
          f"OT depth={program.ot_depth} "
          f"({program.report.compile_seconds:.1f}s partition+schedule)")
    return program.save(path)


def run_demo(args) -> dict:
    """Load -> register -> drain the seeded stream; return the metrics."""
    path = Path(args.artifact)
    if path.suffix != ".npz":          # Program.save appends .npz
        path = path.with_name(path.name + ".npz")
    if not path.exists():
        path = build_artifact(path)
    registry = ProgramRegistry()
    program: Program = registry.load("demo", path)  # no re-partitioning
    print(f"loaded {path.name}: {program.n_synapses} synapses on "
          f"{program.hw.n_spus} SPUs, OT depth {program.ot_depth}")

    # ONE generator drives both the spike trains and the arrival process
    rng = np.random.default_rng(args.seed)
    reqs = (rng.random((args.requests, args.timesteps, program.n_inputs))
            < 0.25).astype(np.int32)
    arrivals = np.cumsum(rng.exponential(args.arrival_us, args.requests))

    policy = BatchPolicy(max_batch=args.batch_max,
                         max_wait_us=args.max_wait_us)
    spec = ExecutionSpec(mesh="auto") if args.sharded else None
    runner = registry.runner("demo", spec)
    batcher = MicroBatcher(
        policy, runner=runner,
        service_model=None if args.measured else linear_service_model())
    res = batcher.drain(arrivals, reqs)
    m = res.metrics()
    print(f"served {m['requests']} requests in {m['batches']} batches, "
          f"buckets {dict(sorted(m['buckets'].items()))}")
    print(f"latency p50 {m['p50_ms']:.2f} ms  p99 {m['p99_ms']:.2f} ms  "
          f"throughput {m['throughput_rps']:.0f} req/s")
    return m


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default="/tmp/suprasnn_serve_demo.npz")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-max", type=int, default=8)
    ap.add_argument("--max-wait-us", type=float, default=0.0)
    ap.add_argument("--timesteps", type=int, default=20)
    ap.add_argument("--arrival-us", type=float, default=300.0,
                    help="mean Poisson inter-arrival time")
    ap.add_argument("--seed", type=int, default=0,
                    help="one np.random.Generator seed for spike trains "
                         "AND arrivals: same seed, same p50/p99")
    ap.add_argument("--sharded", action="store_true",
                    help="run batches data-parallel over all jax devices")
    ap.add_argument("--measured", action="store_true",
                    help="use wall-clock engine times instead of the "
                         "deterministic linear service model")
    return run_demo(ap.parse_args(argv))


if __name__ == "__main__":
    main()
