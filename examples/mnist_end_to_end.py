"""End-to-end driver (paper §7.1/§7.2): train the 784-116-10 SFNN with
surrogate-gradient BPTT, quantize to the 4-bit hardware format, compile
it into a `Program` artifact on the Table-2 hardware (16 SPUs), run
cycle-accurate mapped inference, and report the full Table-3 metric row
INCLUDING mapped-engine accuracy (the engine is bit-exact wrt the
integer oracle, so quantized accuracy == deployed accuracy).

    PYTHONPATH=src python examples/mnist_end_to_end.py [--steps 300]
        [--engine {python,jax}] [--save PATH]

``--engine python`` (default) runs the per-image reference executor;
``--engine jax`` runs the compiled batched executor — all test images
in ONE XLA call, bit-exact with the python engine and with identical
packet counts, so the profile rows are unchanged. ``--save`` persists
the compiled artifact for later serving (see examples/serve_snn.py).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.snn_paper import MNIST_HW
from repro.core import compile, from_quantized
from repro.data import load_mnist, mnist_batches
from repro.snn import MNIST_CONFIG, QuantConfig, quantize
from repro.snn.train import evaluate, rate_encode, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--test-images", type=int, default=20)
    ap.add_argument("--engine", choices=("python", "jax"), default="python",
                    help="mapped executor: per-image reference loop or "
                         "compiled batched engine")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="persist the compiled Program artifact to PATH")
    args = ap.parse_args()

    print("== 1. data (real MNIST if present, else synthetic) ==")
    xtr, ytr, xte, yte = load_mnist(n_train=2048, n_test=512)

    print(f"== 2. BPTT training, {args.steps} steps "
          f"(paper: 20 epochs, Adam, lr 5e-4, ReLU surrogate) ==")
    res = train(MNIST_CONFIG, mnist_batches(xtr, ytr, 64), args.steps,
                lr=5e-4, key=jax.random.PRNGKey(0), encode=True,
                verbose=True, log_every=100)
    acc_float = evaluate(res.params, MNIST_CONFIG, xte[:256], yte[:256],
                         jax.random.PRNGKey(1), encode=True)
    print(f"float accuracy: {acc_float:.4f}")

    print("== 3. quantize to 4-bit weights / 5-bit potential ==")
    q = quantize(res.params, MNIST_CONFIG, QuantConfig(4, 5))
    g = from_quantized(q)
    print(f"nonzero synapses: {g.n_synapses} "
          f"(post-quantization sparsity {q.sparsity:.4f})")

    print("== 4. compile to a Program artifact (16 SPUs, UM 128) ==")
    program = compile(g, MNIST_HW, engine=args.engine, max_iters=40000)
    rep = program.report
    print(f"feasible={program.feasible} iters={rep.iterations} "
          f"OT depth={program.ot_depth} (paper: 661) "
          f"BRAMs={rep.resources.brams} (paper: 33.5)")
    if args.save:
        print(f"saved artifact: {program.save(args.save)}")

    print(f"== 5. cycle-accurate mapped inference (engine={args.engine}) ==")
    n_img = args.test_images
    ext = np.stack([np.asarray(rate_encode(
        jnp.asarray(xte[i][None]), MNIST_CONFIG.timesteps,
        jax.random.fold_in(jax.random.PRNGKey(2), i)))[:, 0]
        for i in range(n_img)]).astype(np.int32)      # [B, T, 784]
    s_all, _, stats = program.run(ext)
    prof = program.profile(stats, n_synapses=q.n_total_synapses)

    out_lo = g.output_slice[0] - g.n_inputs
    correct = sum(
        int(np.argmax(s_all[i].sum(0)[out_lo:out_lo + 10]) == yte[i])
        for i in range(n_img))
    lat = [r.latency_us for r in prof.per_sample]
    en = [r.energy_mj for r in prof.per_sample]
    print(f"mapped-engine accuracy: {correct / n_img:.3f} "
          f"over {n_img} images")
    print(f"latency: {np.mean(lat):.1f} us/image   (paper: 149 us)")
    print(f"energy : {np.mean(en):.5f} mJ/image (paper: 0.02563 mJ)")
    print(f"        {np.mean(en) * 1e6 / q.n_total_synapses:.4f} nJ/synapse "
          f"(paper: 0.27675)")


if __name__ == "__main__":
    main()
