"""Recurrent SNN on (synthetic) SHD — the paper's second benchmark: a
700-300-20 SRNN at 87% sparsity compiled into a `Program` artifact on
the 64-SPU XC7Z030 config.

    PYTHONPATH=src python examples/shd_srnn.py [--steps 200] [--hidden 300]
"""
import argparse

import jax
import numpy as np

from repro.configs.snn_paper import SHD_HW
from repro.core import compile
from repro.data import shd_batches, synthetic_shd
from repro.snn import LIFParams, QuantConfig, SNNConfig, quantize
from repro.snn.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hidden", type=int, default=300)
    ap.add_argument("--timesteps", type=int, default=100)
    args = ap.parse_args()

    cfg = SNNConfig(layer_sizes=(700, args.hidden, 20), recurrent=True,
                    sparsity=0.8704, lif=LIFParams(alpha=0.03125),
                    surrogate="sigmoid", timesteps=args.timesteps)
    xtr, ytr, xte, yte = synthetic_shd(n_train=512, n_test=128,
                                       timesteps=args.timesteps)
    print(f"== training SRNN {cfg.layer_sizes}, sparsity {cfg.sparsity} ==")
    res = train(cfg, shd_batches(xtr, ytr, 32), args.steps, lr=1e-3,
                key=jax.random.PRNGKey(0), encode=False, verbose=True,
                log_every=50)

    print("== quantize (7-bit weights / 12-bit potential, Table 2) ==")
    q = quantize(res.params, cfg, QuantConfig(7, 12))
    print(f"nonzero synapses: {q.n_nonzero_synapses}")

    print("== compile onto the 64-SPU XC7Z030 config ==")
    program = compile(q, SHD_HW, max_iters=60000)
    print(f"feasible={program.feasible} OT depth={program.ot_depth} "
          f"(paper: 742)")

    print("== mapped inference on one sample ==")
    _, _, stats = program.run(xte[0].astype(np.int32), "python")
    prof = program.profile(stats, n_synapses=q.n_total_synapses)
    print(f"latency {prof.latency_us / 1e3:.3f} ms/sample (paper: 1.41 ms), "
          f"energy {prof.energy_mj:.3f} mJ (paper: 0.77)")


if __name__ == "__main__":
    main()
