"""Quickstart: the whole SupraSNN flow on a toy network in ~30 lines,
ending with the compiled batched executor (the ``--engine jax`` path of
examples/mnist_end_to_end.py).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CycleModel, HardwareConfig, compile_snn,
                        random_graph, run_mapped, run_mapped_batched,
                        run_oracle)

# 1. an irregular spiking network: 16 inputs, 32 internal neurons,
#    300 nonzero synapses (paper Fig. 2b style)
g = random_graph(n_inputs=16, n_internal=32, n_synapses=300, seed=0)

# 2. a SupraSNN hardware instance: 8 SPUs, 48 Unified-Memory lines each,
#    K=3 weights packed per line (paper Table 2 block)
hw = HardwareConfig(n_spus=8, unified_mem_depth=48, concentration=3,
                    max_neurons=64, max_post_neurons=32)

# 3. co-optimized mapping + scheduling (paper §6: probabilistic
#    partitioning + heuristic scheduling)
tables, report, part = compile_snn(g, hw)
print(f"feasible={report.feasible}  operation-table depth={report.ot_depth}"
      f"  SPU loads={report.spu_synapse_counts.tolist()}")

# 4. execute 20 timesteps; the mapped engine must match the dense
#    integer-LIF oracle BIT-EXACTLY (deterministic commit, paper §4.3)
ext = (np.random.default_rng(0).random((20, 16)) < 0.3).astype(np.int32)
s_oracle, _ = run_oracle(g, ext)
s_mapped, _, stats = run_mapped(g, tables, ext)
assert np.array_equal(s_oracle, s_mapped), "determinism violated!"
print(f"bit-exact over {s_oracle.size} neuron-timesteps "
      f"({int(s_oracle.sum())} spikes)")

# 5. cycle-accurate latency/energy (paper Table 3 metrics)
rep = CycleModel(hw).run(stats["packet_counts"], tables.depth, g.n_synapses)
print(f"latency={rep.latency_us:.1f} us  energy={rep.energy_mj * 1e3:.3f} uJ"
      f"  ({rep.energy_per_synapse_nj:.3f} nJ/synapse)")

# 6. the same program, compiled + batched (lax.scan + Pallas Neuron Unit):
#    8 spike trains through one XLA call, still bit-exact per sample
ext_b = (np.random.default_rng(1).random((8, 20, 16)) < 0.3).astype(np.int32)
s_b, _, stats_b = run_mapped_batched(g, tables, ext_b)
for i in range(8):
    assert np.array_equal(s_b[i], run_oracle(g, ext_b[i])[0])
print(f"batched engine: {s_b.shape[0]} samples in one call, bit-exact; "
      f"mean packets/step={stats_b['mean_packets_per_step']:.1f}")
