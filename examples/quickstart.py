"""Quickstart: the whole SupraSNN flow on a toy network in ~40 lines —
compile ONCE into a `Program` artifact, then run / profile / save / load
it (the deployment flow of examples/serve_snn.py).

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.core import (ExecutionSpec, HardwareConfig, Program, compile,
                        random_graph)

# 1. an irregular spiking network: 16 inputs, 32 internal neurons,
#    300 nonzero synapses (paper Fig. 2b style)
g = random_graph(n_inputs=16, n_internal=32, n_synapses=300, seed=0)

# 2. a SupraSNN hardware instance: 8 SPUs, 48 Unified-Memory lines each,
#    K=3 weights packed per line (paper Table 2 block)
hw = HardwareConfig(n_spus=8, unified_mem_depth=48, concentration=3,
                    max_neurons=64, max_post_neurons=32)

# 3. compile = the explicit pass pipeline (partition -> schedule ->
#    validate -> lower, paper §6 / Fig. 8) producing ONE artifact
program = compile(g, hw)
rep = program.report
print(f"feasible={program.feasible}  operation-table depth={program.ot_depth}"
      f"  SPU loads={rep.spu_synapse_counts.tolist()}")

# 4. execute 20 timesteps on all three engines through the SAME surface
#    — program.run(ext, spec) where spec is an ExecutionSpec or an
#    engine-name shorthand; the mapped program must match the dense
#    integer-LIF oracle BIT-EXACTLY (deterministic commit, paper §4.3)
ext = (np.random.default_rng(0).random((20, 16)) < 0.3).astype(np.int32)
s_oracle, _, _ = program.run(ext, "oracle")
s_mapped, _, stats = program.run(ext, "python")
assert np.array_equal(s_oracle, s_mapped), "determinism violated!"
print(f"bit-exact over {s_oracle.size} neuron-timesteps "
      f"({int(s_oracle.sum())} spikes)")

# 5. cycle-accurate latency/energy + FPGA resources in one call
prof = program.profile(stats)
print(f"latency={prof.latency_us:.1f} us  "
      f"energy={prof.energy_mj * 1e3:.3f} uJ"
      f"  ({prof.energy_per_synapse_nj:.3f} nJ/synapse)"
      f"  BRAMs={prof.resources.brams}")

# 6. the compiled batched engine is the default: 8 spike trains through
#    one XLA call per scan — the whole timestep (routing + per-SPU
#    accumulation + Neuron Unit) runs as ONE fused Pallas megakernel
#    (ExecutionSpec(kernel="fused"), the platform default); every tier
#    is bit-exact, so the spec only moves the speed point
ext_b = (np.random.default_rng(1).random((8, 20, 16)) < 0.3).astype(np.int32)
s_b, _, stats_b = program.run(ext_b)          # ExecutionSpec() default
s_lif, _, _ = program.run(ext_b, ExecutionSpec(kernel="lif"))
assert np.array_equal(s_b, s_lif), "kernel tiers must be bit-exact"
for i in range(8):
    assert np.array_equal(s_b[i], program.run(ext_b[i], "oracle")[0])
print(f"batched engine: {s_b.shape[0]} samples in one call, bit-exact "
      f"across kernel tiers; "
      f"mean packets/step={stats_b['mean_packets_per_step']:.1f}")

# 7. persist the artifact: save once, serve anywhere — load never
#    re-runs the stochastic partitioner and round-trips bit-exactly;
#    precompile= AOT-compiles the serving batch buckets at load time
#    so the first request never pays XLA
path = program.save(Path(tempfile.mkdtemp()) / "toy_program")
loaded = Program.load(path, precompile=[8], timesteps=20)
s_l, _, _ = loaded.run(ext_b)                 # hits the AOT executable
assert np.array_equal(s_l, s_b), "artifact round-trip must be bit-exact"
print(f"saved+loaded {path.name}: outputs identical, "
      f"{len(loaded.init_packets())} init packets")

# 8. scheduling is pluggable (paper §6.3): schedule_method= picks the
#    post transmit-order strategy, and compile(search=...) co-optimizes
#    the JOINT (mapping, schedule strategy) pair — every candidate
#    mapping is scored under every registered strategy
from repro.core import SCHEDULE_STRATEGIES, SearchConfig
depths = {name: compile(g, hw, schedule_method=name).ot_depth
          for name in SCHEDULE_STRATEGIES}
joint = compile(g, hw, search=SearchConfig(restarts=4, early_exit=False))
print(f"per-strategy OT depths={depths}  joint pick="
      f"{joint.report.search.selected.strategy}+"
      f"{joint.report.schedule_method} at depth {joint.ot_depth}")
