"""Batched LM serving through the framework's serve path: prefill a prompt
batch, decode with donated in-place caches (this is the program the
``decode_32k`` / ``long_500k`` dry-run cells lower at production scale).

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-3b
    PYTHONPATH=src python examples/serve_batched.py --arch glm4-9b --gen 32
"""
import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    a = ap.parse_args()
    serve_main(["--arch", a.arch, "--reduced", "--batch", str(a.batch),
                "--prompt-len", str(a.prompt_len), "--gen", str(a.gen)])
