"""The deprecated pre-Program wrappers must WARN, not silently delegate."""
import numpy as np
import pytest

from repro.core import (HardwareConfig, compile_snn, compile_quantized,
                        random_graph, run_mapped_batched,
                        compile as compile_program)
from repro.snn import MNIST_CONFIG, QuantConfig, quantize
from repro.snn.models import init_params
import jax


HW = HardwareConfig(n_spus=4, unified_mem_depth=256, concentration=3,
                    max_neurons=64, max_post_neurons=32)


def test_compile_snn_warns_and_delegates():
    g = random_graph(8, 12, 80, seed=0)
    with pytest.warns(DeprecationWarning, match="compile_snn is deprecated"):
        tables, report, part = compile_snn(g, HW, seed=0, max_iters=2000)
    fresh = compile_program(g, HW, seed=0, max_iters=2000)
    np.testing.assert_array_equal(tables.pre, fresh.tables.pre)
    np.testing.assert_array_equal(part.assign, fresh.part.assign)


def test_compile_quantized_warns():
    params = init_params(MNIST_CONFIG, jax.random.PRNGKey(0))
    q = quantize(params, MNIST_CONFIG,
                 QuantConfig(weight_bits=4, potential_bits=8))
    hw = HardwareConfig(n_spus=4, unified_mem_depth=10 ** 6, concentration=3,
                        max_neurons=2048, max_post_neurons=1024)
    with pytest.warns(DeprecationWarning,
                      match="compile_quantized is deprecated"):
        tables, report, part = compile_quantized(q, hw, max_iters=100)
    assert tables.depth > 0


def test_run_mapped_batched_warns():
    g = random_graph(8, 12, 80, seed=1)
    program = compile_program(g, HW, seed=0, max_iters=2000)
    ext = (np.random.default_rng(0).random((4, 8)) < 0.3).astype(np.int32)
    with pytest.warns(DeprecationWarning,
                      match="run_mapped_batched is deprecated"):
        s, v, _ = run_mapped_batched(g, program.tables, ext)
    s2, v2, _ = program.run(ext)
    np.testing.assert_array_equal(s, s2)
    np.testing.assert_array_equal(v, v2)
