"""The static artifact verifier (DESIGN.md §13).

Three layers of coverage:

* golden / compile-matrix cleanliness — ``Program.verify()`` emits
  ZERO diagnostics on the pinned golden artifact and on every
  ``compile()`` output across graph shapes, mapping strategies, and
  schedule strategies (plus a hypothesis property over random graphs);
* the mutation self-test — each class of verified field is corrupted
  on a fresh golden load and the expected diagnostic code must fire
  (the checkers prove they actually check something);
* the range analysis — the int8 MNIST-flavored / int16 SHD-flavored
  dense-plane dtype choices are confirmed STATICALLY (no engine, no
  densification) and pinned against what ``pack_dense`` then does.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (CHECKERS, CODES, Diagnostic, Severity,
                            register_checker, register_code, verify)
from repro.analysis.ranges import (dense_plane_bounds, min_safe_dtype,
                                   signed_bits)
from repro.analysis.schedule import check_schedule
from repro.core import HardwareConfig, Program, compile, random_graph
from repro.core.passes import lower_pass
from repro.serve.registry import ProgramRegistry
from repro.snn.lif import LIFIntParams

from conftest import make_feedforward, make_hw

GOLDEN = Path(__file__).parent / "golden" / "tiny_program_v1.npz"
NOP = -1


def golden() -> Program:
    return Program.load(GOLDEN)


# -- cleanliness ------------------------------------------------------------

def test_golden_artifact_is_clean():
    rep = golden().verify()
    assert rep.ok and not rep.diagnostics, rep.summary()
    assert rep.checkers == ["artifact", "schedule", "ranges", "memory"]
    assert rep.wall_ms > 0 and set(rep.checker_wall_ms) == set(rep.checkers)
    assert rep.summary().startswith("clean: 0 diagnostics")


@pytest.mark.parametrize("method", ["framework", "synapse_rr", "hypergraph"])
@pytest.mark.parametrize("recurrent", [False, True])
def test_every_compile_output_is_clean(method, recurrent):
    g = (random_graph(10, 12, 120, seed=3) if recurrent
         else make_feedforward())
    p = compile(g, make_hw(g), method=method)
    rep = p.verify()
    assert rep.ok and not rep.diagnostics, rep.summary()


@pytest.mark.parametrize("schedule_method",
                         ["slack", "consecutive", "load_balance"])
def test_every_schedule_strategy_is_clean(schedule_method):
    g = random_graph(8, 10, 90, seed=11)
    p = compile(g, make_hw(g), schedule_method=schedule_method)
    assert p.verify().ok


def test_leak_shift_zero_is_clean():
    lif = LIFIntParams(leak_shift=0, v_threshold=9, v_reset=-2)
    g = random_graph(6, 8, 40, seed=7, lif=lif)
    p = compile(g, make_hw(g))
    rep = p.verify()
    assert rep.ok, rep.summary()
    # with a full leak the carried state contributes nothing upward and
    # the lower fixpoint degenerates to the one-step sums
    r = rep.stats["ranges"]
    assert r["membrane_hi"] == r["current_hi"]
    assert r["membrane_lo"] == min(0, -2, r["current_lo"])


# -- the mutation self-test --------------------------------------------------

def _mutate_sched001(p):      # truncated op row
    t = p.tables
    s, slot = map(int, np.argwhere(t.pre != NOP)[0])
    t.pre[s, slot] = NOP
    t.post[s, slot] = NOP
    t.weight[s, slot] = 0
    t.pre_end[s, slot] = False
    t.post_end[s, slot] = False


def _mutate_sched003(p):      # Post-End flag drifts off the send slot
    t = p.tables
    s, slot = map(int, np.argwhere(t.post_end)[0])
    post = int(t.post[s, slot])
    t.send_slot[post] = slot + 1


def _mutate_sched004(p):      # duplicate Post-End in one SPU
    t = p.tables
    s, slot = map(int, np.argwhere(t.post_end)[0])
    post = int(t.post[s, slot])
    others = np.argwhere((t.post == post) & (t.pre != NOP) & ~t.post_end)
    others = [o for o in others if int(o[0]) == s]
    assert others, "golden graph needs >= 2 ops per (spu, post)"
    t.post_end[int(others[0][0]), int(others[0][1])] = True


def _mutate_sched005(p):      # missing Post-End
    t = p.tables
    s, slot = map(int, np.argwhere(t.post_end)[0])
    t.post_end[s, slot] = False


def _mutate_sched006(p):      # op lands after its send slot
    t = p.tables
    post = max(t.send_slot, key=t.send_slot.__getitem__)
    assert t.send_slot[post] > 0
    t.send_slot[post] = 0


def _mutate_sched008(p):      # two posts share one send slot
    t = p.tables
    p1, p2 = sorted(t.send_slot)[:2]
    t.send_slot[p2] = t.send_slot[p1]


def _mutate_sched009(p):      # NOP slot carries payload
    t = p.tables
    nops = np.argwhere(t.pre == NOP)
    assert len(nops), "golden tables need at least one NOP slot"
    t.post[int(nops[0][0]), int(nops[0][1])] = 5


def _widen_weight(p, value):  # consistently in graph AND tables
    g, t = p.graph, p.tables
    pre, post = int(g.pre[0]), int(g.post[0])
    g.weight[0] = value
    hits = np.argwhere((t.pre == pre) & (t.post == post))
    assert len(hits) == 1
    t.weight[int(hits[0][0]), int(hits[0][1])] = value


def _mutate_range001(p):      # weight outside the 4-bit UM field
    _widen_weight(p, 100)


def _mutate_range002(p):      # accumulator interval past int32
    _widen_weight(p, 2**31 - 1)


def _mutate_mem001(p):        # Eq. 9 overflow on a feasible-claimed artifact
    p.hw = dataclasses.replace(p.hw, unified_mem_depth=2)


def _mutate_mem002(p):
    p.report.scores[0] += 7


def _mutate_mem003(p):
    p.report.spu_post_counts[0] += 1


def _mutate_mem004(p):
    p.report.ot_depth += 1


def _mutate_mem005(p):        # shrunk memory stat
    p.report.resources.memory_kb *= 0.5


def _mutate_mem006(p):
    p.report.n_init_packets += 3


def _mutate_mem007(p):
    p.hw = dataclasses.replace(p.hw, max_neurons=p.graph.n_neurons - 1)


def _mutate_mem008(p):
    p.hw = dataclasses.replace(p.hw, max_post_neurons=1)


def _mutate_art001(p):        # torn arrays: assignment lost a synapse
    p.tables.assign = p.tables.assign[:-1]


def _mutate_art002(p):        # graph invariant: zero-weight synapse
    p.graph.weight[0] = 0


def _mutate_art003(p):        # partition names a nonexistent SPU
    p.tables.assign[0] = 99


MUTATIONS = [
    ("SCHED001", _mutate_sched001),
    ("SCHED003", _mutate_sched003),
    ("SCHED004", _mutate_sched004),
    ("SCHED005", _mutate_sched005),
    ("SCHED006", _mutate_sched006),
    ("SCHED008", _mutate_sched008),
    ("SCHED009", _mutate_sched009),
    ("RANGE001", _mutate_range001),
    ("RANGE002", _mutate_range002),
    ("MEM001", _mutate_mem001),
    ("MEM002", _mutate_mem002),
    ("MEM003", _mutate_mem003),
    ("MEM004", _mutate_mem004),
    ("MEM005", _mutate_mem005),
    ("MEM006", _mutate_mem006),
    ("MEM007", _mutate_mem007),
    ("MEM008", _mutate_mem008),
    ("ART001", _mutate_art001),
    ("ART002", _mutate_art002),
    ("ART003", _mutate_art003),
]


@pytest.mark.parametrize("code,mutate", MUTATIONS,
                         ids=[c for c, _ in MUTATIONS])
def test_mutation_fires_expected_code(code, mutate):
    p = golden()
    mutate(p)
    rep = p.verify()
    assert code in rep.codes(), \
        f"expected {code}; got {sorted(rep.codes())}\n{rep.summary()}"
    assert not rep.ok
    for d in rep.diagnostics:           # every code is a registered one
        assert d.code in CODES


def test_mutation_matrix_covers_enough_codes():
    # the acceptance floor: the self-test must prove >= 8 distinct
    # diagnostic codes actually fire
    assert len({c for c, _ in MUTATIONS}) >= 8


def test_art001_gates_the_other_checkers():
    p = golden()
    _mutate_art001(p)
    rep = p.verify()
    assert rep.checkers == ["artifact"] and not rep.ok


def test_sched001_wins_legacy_priority():
    # the legacy count assert fired before the multiset assert; the shim
    # must keep that order even though both diagnostics are emitted
    p = golden()
    _mutate_sched001(p)
    diags = check_schedule(p.graph, p.tables)
    codes = {d.code for d in diags}
    assert {"SCHED001", "SCHED002"} <= codes
    with pytest.raises(AssertionError, match=r"ops != \d+ synapses"):
        from repro.core.scheduling import validate_schedule
        validate_schedule(p.graph, p.tables)


def test_diagnostics_carry_location_and_hint():
    p = golden()
    _mutate_sched006(p)
    d = next(x for x in p.verify().diagnostics if x.code == "SCHED006")
    assert d.severity is Severity.ERROR
    assert d.location.post is not None and d.location.spu is not None
    assert d.hint
    assert "SCHED006" in str(d) and "post" in str(d)


# -- the range analysis (static dtype proofs, no engine execution) ----------

def test_range_proof_int8_mnist_flavor():
    # the paper's MNIST net quantizes to 4-bit weights -> int8 plane
    g = make_feedforward()                       # weights in [-7, 7]
    p = compile(g, make_hw(g))
    rep = p.verify()
    r = rep.stats["ranges"]
    assert r["dense_dtype"] == "int8" and r["int32_safe"]
    dense = __import__("repro.kernels.fused_step",
                       fromlist=["pack_dense"]).pack_dense(p.lowered)
    assert dense.dtype == np.int8
    assert (dense.value_min, dense.value_max) == (r["dense_lo"],
                                                  r["dense_hi"])
    assert (int(dense.weight.min()), int(dense.weight.max())) == \
        (r["dense_lo"], r["dense_hi"]) or 0 in (r["dense_lo"], r["dense_hi"])


def test_range_proof_int16_shd_flavor():
    # the paper's SHD net quantizes to 9-bit weights -> int16 plane
    g = random_graph(12, 10, 110, seed=2, weight_lo=-255, weight_hi=255)
    hw = dataclasses.replace(make_hw(g), weight_bits=9, potential_bits=18)
    p = compile(g, hw)
    rep = p.verify()
    assert rep.ok, rep.summary()
    r = rep.stats["ranges"]
    assert r["dense_dtype"] == "int16" and r["int32_safe"]
    from repro.kernels.fused_step import pack_dense
    assert pack_dense(p.lowered).dtype == np.int16


def test_range_bounds_are_sound_for_actual_runs():
    # the proven interval must contain every membrane value an engine
    # actually produces (checked with the pure-numpy oracle)
    from repro.core.engine import run_oracle
    from conftest import make_ext
    g = random_graph(8, 10, 80, seed=4)
    p = compile(g, make_hw(g))
    r = p.verify().stats["ranges"]
    ext = make_ext(g, 1, 24, rate=0.9)[0]
    _, v = run_oracle(g, ext)
    assert r["membrane_lo"] <= int(v.min()) and \
        int(v.max()) <= r["membrane_hi"]


def test_dense_plane_bounds_folds_duplicates():
    pre = np.array([0, 0, 1], np.int32)
    post = np.array([0, 0, 1], np.int32)
    w = np.array([100, 100, -3], np.int32)
    lo, hi = dense_plane_bounds(pre, post, w, 2, 2)
    assert (lo, hi) == (-3, 200)                 # 100+100 folds past int8
    assert min_safe_dtype(lo, hi) == "int16"


def test_min_safe_dtype_ladder():
    assert min_safe_dtype(-128, 127) == "int8"
    assert min_safe_dtype(-129, 0) == "int16"
    assert min_safe_dtype(0, 2**31 - 1) == "int32"
    assert min_safe_dtype(0, 2**31) == "int64"
    assert signed_bits(-8, 7) == 4
    assert signed_bits(0, 0) == 1


def test_pack_dense_guard_names_safe_dtype(monkeypatch):
    import repro.kernels.fused_step as fs
    g = make_feedforward()
    p = compile(g, make_hw(g))
    monkeypatch.setattr(fs, "MAX_DENSE_BYTES", 1)
    with pytest.raises(ValueError, match="minimal safe dtype int8"):
        fs.pack_dense(p.lowered)


def test_empty_style_edges():
    assert dense_plane_bounds(np.array([], np.int32), np.array([], np.int32),
                              np.array([], np.int32), 4, 2) == (0, 0)


# -- driver / registry plumbing ---------------------------------------------

def test_unknown_checker_name_rejected():
    with pytest.raises(KeyError, match="unknown checker"):
        verify(golden(), checkers=["nope"])


def test_unregistered_code_is_refused():
    def rogue(program):
        return [Diagnostic(code="BOGUS99", severity=Severity.ERROR,
                           message="x")], {}
    register_checker("rogue-test", rogue)
    try:
        with pytest.raises(ValueError, match="unregistered code"):
            verify(golden())
        with pytest.raises(ValueError, match="already registered"):
            register_checker("rogue-test", rogue)
    finally:
        CHECKERS.pop("rogue-test")


def test_register_code_title_is_a_contract():
    assert register_code("SCHED001", CODES["SCHED001"]) == "SCHED001"
    with pytest.raises(ValueError, match="already registered"):
        register_code("SCHED001", "something else")


def test_registry_verify_gate(tmp_path):
    reg = ProgramRegistry()
    reg.register("good", golden(), verify=True)
    bad = golden()
    _mutate_mem005(bad)
    with pytest.raises(ValueError, match="failed static verification"):
        reg.register("bad", bad, verify=True)
    assert "bad" not in reg
    # and the load() path forwards the gate
    p = golden()
    p.report.n_init_packets += 1
    path = p.save(tmp_path / "stale.npz")
    with pytest.raises(ValueError, match="MEM006"):
        reg.load("stale", path, verify=True)


# -- CLI --------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ)
    src = str(Path(__file__).parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.verify", *args],
        capture_output=True, text=True, env=env)


def test_cli_clean_artifact():
    r = _run_cli(str(GOLDEN), "--strict")
    assert r.returncode == 0, r.stderr
    assert "clean: 0 diagnostics" in r.stdout
    assert "RuntimeWarning" not in r.stderr     # no double-import of the CLI


def test_cli_json_and_failure_exit(tmp_path):
    p = golden()
    _mutate_mem004(p)
    path = p.save(tmp_path / "stale.npz")
    r = _run_cli(str(path), "--json")
    assert r.returncode == 1
    payload = json.loads(r.stdout)
    rep = payload[str(path)]
    assert rep["ok"] is False
    assert any(d["code"] == "MEM004" for d in rep["diagnostics"])


def test_cli_unreadable_artifact(tmp_path):
    bogus = tmp_path / "nope.npz"
    bogus.write_bytes(b"not an npz")
    r = _run_cli(str(bogus))
    assert r.returncode == 2 and "cannot load" in r.stderr


