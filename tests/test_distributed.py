"""Distributed substrate: checkpointing (atomic, sharded, verifiable,
reshardable), gradient compression with error feedback, elastic re-mesh
planning, straggler detection, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed.checkpoint import (CheckpointManager, latest_step,
                                          load_checkpoint, save_checkpoint)
from repro.distributed.compression import (compress_error_feedback,
                                           compress_int8, decompress_int8,
                                           init_error)
from repro.distributed.sharding import (LOGICAL_RULES_1POD, MeshRules,
                                        logical_constraint, mesh_rules,
                                        param_pspec)
from repro.distributed.straggler import StepJournal, StragglerMonitor


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layers": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.zeros((8,))},
            "step_count": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, n_shards=2, extra={"loss": 1.5})
    assert latest_step(str(tmp_path)) == 3
    like = jax.tree.map(jnp.zeros_like, t)
    restored, extra = load_checkpoint(str(tmp_path), None, like)
    assert extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    d = save_checkpoint(str(tmp_path), 1, t)
    shard = os.path.join(d, "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(AssertionError, match="hash mismatch"):
        load_checkpoint(str(tmp_path), 1, jax.tree.map(jnp.zeros_like, t))


def test_checkpoint_uncommitted_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crash mid-save: step dir without COMMITTED
    os.makedirs(tmp_path / "step_000000005")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t, blocking=True)
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("4")
    restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(restored["layers"]["w"]),
                                  np.asarray(t["layers"]["w"]))


# ---------------------------------------------------------------------------
# gradient compression + error feedback
# ---------------------------------------------------------------------------


def test_int8_roundtrip_accuracy():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (1000,)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (33, 77)) * 5.0}
    c = compress_int8(g, block=128)
    d = decompress_int8(c, g)
    for k in g:
        err = np.abs(np.asarray(d[k]) - np.asarray(g[k])).max()
        scale = np.abs(np.asarray(g[k])).max()
        assert err <= scale / 127.0 + 1e-6


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_error_feedback_unbiased_over_time(seed):
    """Sum of dequantized grads + final residual == sum of true grads —
    error feedback never loses mass (EF-SGD telescoping identity)."""
    rng = np.random.default_rng(seed)
    g_true = [jnp.asarray(rng.normal(size=(256,)), jnp.float32)
              for _ in range(5)]
    err = init_error({"g": g_true[0]})
    total_deq = jnp.zeros((256,))
    for g in g_true:
        comp, deq, err = compress_error_feedback({"g": g}, err, block=64)
        total_deq = total_deq + deq["g"]
    total_true = sum(np.asarray(g) for g in g_true)
    np.testing.assert_allclose(np.asarray(total_deq + err["g"]),
                               total_true, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# straggler monitor + journal
# ---------------------------------------------------------------------------


def test_straggler_monitor_flags_persistent_slowdowns():
    mon = StragglerMonitor(window=8, threshold=2.0, hysteresis=2)
    import time
    fired = []
    for i in range(12):
        mon.start_step()
        mon._t0 -= 0.01                 # simulate 10 ms steps
        if i >= 10:
            mon._t0 -= 0.05             # 6x slowdown
        fired.append(mon.end_step(i))
    assert fired[11] and not any(fired[:10])
    assert mon.summary()["straggler_events"] >= 2


def test_journal_replay(tmp_path):
    j = StepJournal(str(tmp_path / "j.jsonl"))
    for s in range(5):
        j.record(s, data_offset=s * 128, seed=0, checkpoint_step=s - s % 2)
    rp = j.replay_point()
    assert rp["step"] == 4 and rp["data_offset"] == 512
    # torn tail write must not break replay
    with open(tmp_path / "j.jsonl", "a") as f:
        f.write('{"step": 5, "data_off')
    assert j.replay_point()["step"] == 4


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def _rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return MeshRules(mesh, LOGICAL_RULES_1POD)


def test_param_rules_match_paths():
    r = _rules()
    # shardable shapes: every dim divisible by 1 on the (1,1) test mesh
    assert param_pspec("layers/attn/wq", (4, 64, 64), r) == \
        jax.sharding.PartitionSpec(None, "data", "model")
    assert param_pspec("embed", (1024, 64), r) == \
        jax.sharding.PartitionSpec("model", "data")
    assert param_pspec("layers/moe/w_gate", (4, 8, 64, 32), r) == \
        jax.sharding.PartitionSpec(None, "model", "data", None)
    # norm scales fall through to replication
    assert param_pspec("layers/ln1/scale", (64,), r) == \
        jax.sharding.PartitionSpec()


def test_logical_constraint_noop_without_context():
    x = jnp.ones((4, 8))
    y = logical_constraint(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_logical_constraint_skips_indivisible():
    r = _rules()
    with mesh_rules(r):
        x = jnp.ones((3, 5))        # nothing divides -> still legal
        y = logical_constraint(x, "batch", "tensor")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_replan_shapes():
    from repro.distributed.elastic import replan_mesh
    mesh = replan_mesh(1, model_parallel=1)
    assert mesh.devices.size == 1
    assert "model" in mesh.axis_names
