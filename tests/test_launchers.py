"""Launcher integration: real (reduced) training with checkpoint/resume and
batched serving run end-to-end on CPU."""
import numpy as np
import pytest


@pytest.mark.slow
def test_train_launcher_with_checkpoint_and_resume(tmp_path):
    from repro.launch.train import main
    d = str(tmp_path / "run")
    losses = main(["--arch", "qwen2-1.5b", "--reduced", "--steps", "8",
                   "--batch", "2", "--seq", "32", "--ckpt-dir", d,
                   "--ckpt-every", "4"])
    assert len(losses) == 8 and np.isfinite(losses).all()
    # loss should drop on a learnable synthetic stream... at least not blow up
    assert losses[-1] < losses[0] * 1.5

    # resume continues from the journaled step
    more = main(["--arch", "qwen2-1.5b", "--reduced", "--steps", "12",
                 "--batch", "2", "--seq", "32", "--ckpt-dir", d,
                 "--ckpt-every", "4", "--resume"])
    assert len(more) == 12 - 8


@pytest.mark.slow
def test_train_launcher_microbatched(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "qwen2-1.5b", "--reduced", "--steps", "3",
                   "--batch", "4", "--seq", "16", "--micro", "2"])
    assert len(losses) == 3 and np.isfinite(losses).all()


@pytest.mark.slow
def test_serve_launcher():
    from repro.launch.serve import main
    toks = main(["--arch", "rwkv6-3b", "--reduced", "--batch", "2",
                 "--prompt-len", "16", "--gen", "4"])
    assert toks.shape[0] == 2 and toks.shape[1] == 4
