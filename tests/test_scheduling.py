"""Tests for the scheduling subsystem (core/scheduling/).

The load-bearing suite is PARITY: the vectorized array core must
reproduce the preserved legacy loop bit-for-bit — tables,
``send_slot``/``send_order``, and infeasibility assertion messages —
across feedforward and recurrent graphs, partitioned and adversarial
assignments, and injected send orders. On top ride the strategy
registry, the joint (mapping, schedule) portfolio selection, its
save/load round-trip, and the satellite fixes of this PR (memory-model
Eq. 11 Spike Memory term, validator error paths, vectorized
CycleModel/oracle packet counts).
"""
import numpy as np
import pytest

from conftest import make_ext
from repro.core import (BASELINES, CycleModel, HardwareConfig, Program,
                        SCHEDULE_STRATEGIES, SearchConfig,
                        compile as compile_program, get_schedule_strategy,
                        oracle_packet_counts, partition, random_graph,
                        register_schedule_strategy, run_oracle, schedule,
                        validate_schedule)
from repro.core.memory_model import bram_count, total_memory_bits
from repro.core.scheduling import (group_info, schedule_legacy,
                                   schedule_vectorized)
from repro.core.scheduling.strategies import SlackStrategy

HW = HardwareConfig(n_spus=8, unified_mem_depth=64, concentration=3,
                    max_neurons=256, max_post_neurons=128)


def assert_tables_equal(a, b):
    assert a.depth == b.depth
    for f in ("pre", "post", "weight", "pre_end", "post_end", "assign"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
    assert a.send_slot == b.send_slot
    assert list(a.send_slot) == list(b.send_slot)      # insertion order too
    assert a.send_order == b.send_order


# ---------------------------------------------------------------------------
# Parity: vectorized core vs the preserved legacy loop.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 11])
def test_parity_recurrent_partitioned(seed):
    g = random_graph(20, 40, 700, seed=seed)
    res = partition(g, HW, seed=0, max_iters=20000)
    a = schedule_legacy(g, res.assign, HW)
    b = schedule_vectorized(g, res.assign, HW)
    assert_tables_equal(a, b)
    validate_schedule(g, b)


@pytest.mark.parametrize("seed", [0, 7])
def test_parity_random_assignments(seed):
    """Adversarial (unsearched) assignments hit imbalanced group shapes
    the partitioner never produces."""
    g = random_graph(16, 32, 600, seed=2)
    rng = np.random.default_rng(seed)
    for m in (2, 4, 8):
        hw = HardwareConfig(n_spus=m, unified_mem_depth=4096,
                            concentration=3, max_neurons=64,
                            max_post_neurons=32)
        assign = rng.integers(0, m, g.n_synapses).astype(np.int32)
        a = schedule_legacy(g, assign, hw)
        b = schedule_vectorized(g, assign, hw)
        assert_tables_equal(a, b)
        validate_schedule(g, b)


def test_parity_feedforward_and_skewed():
    """All synapses on few SPUs: deep tables, long backward fills."""
    g = random_graph(24, 16, 380, seed=4)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    rng = np.random.default_rng(0)
    assign = rng.choice([0, 5], g.n_synapses, p=[0.9, 0.1]).astype(np.int32)
    a = schedule_legacy(g, assign, hw)
    b = schedule_vectorized(g, assign, hw)
    assert_tables_equal(a, b)
    validate_schedule(g, b)


def test_parity_under_injected_send_orders():
    """Any permutation is feasible under the slot recurrence; the fill
    must stay bit-exact for arbitrary strategy outputs."""
    g = random_graph(12, 24, 400, seed=5)
    rng = np.random.default_rng(1)
    assign = rng.integers(0, HW.n_spus, g.n_synapses).astype(np.int32)
    gi = group_info(g, assign)
    for _ in range(4):
        order = rng.permutation(gi.posts)
        a = schedule_legacy(g, assign, HW, send_order=order)
        b = schedule_vectorized(g, assign, HW, send_order=order)
        assert_tables_equal(a, b)
        validate_schedule(g, b)


def test_parity_empty_graph():
    g = random_graph(4, 4, 5, seed=0)
    empty = type(g)(g.n_inputs, g.n_neurons, g.pre[:0], g.post[:0],
                    g.weight[:0], g.lif, g.output_slice)
    assign = np.zeros(0, np.int32)
    a = schedule_legacy(empty, assign, HW)
    b = schedule_vectorized(empty, assign, HW)
    assert_tables_equal(a, b)
    assert a.depth == 0


def test_infeasibility_assertion_messages_match():
    """Externally-injected (too tight) send slots overflow the backward
    fill in BOTH implementations with the identical message."""
    g = random_graph(10, 20, 150, seed=5)
    hw = HardwareConfig(n_spus=4, unified_mem_depth=512, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    rng = np.random.default_rng(0)
    assign = rng.integers(0, 4, g.n_synapses).astype(np.int32)
    posts = group_info(g, assign).posts
    slots = {int(q): i for i, q in enumerate(posts)}   # consecutive: too few
    msgs = []
    for fn in (schedule_legacy, schedule_vectorized):
        with pytest.raises(AssertionError, match="schedule infeasible"):
            try:
                fn(g, assign, hw, send_slots=slots)
            except AssertionError as exc:
                msgs.append(str(exc))
                raise
    assert len(msgs) == 2 and msgs[0] == msgs[1]


def test_vectorized_rejects_partial_send_order():
    g = random_graph(8, 12, 80, seed=6)
    rng = np.random.default_rng(0)
    assign = rng.integers(0, HW.n_spus, g.n_synapses).astype(np.int32)
    posts = group_info(g, assign).posts
    with pytest.raises(ValueError, match="permutation"):
        schedule_vectorized(g, assign, HW, send_order=posts[:-1])


# ---------------------------------------------------------------------------
# Strategy registry + compile(schedule_method=...).
# ---------------------------------------------------------------------------

def test_registry_has_builtins_slack_first():
    assert list(SCHEDULE_STRATEGIES)[0] == "slack"   # wins joint ties
    assert set(SCHEDULE_STRATEGIES) >= {"slack", "consecutive",
                                        "load_balance"}


def test_registry_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown schedule_method 'nope'"):
        get_schedule_strategy("nope")
    g = random_graph(8, 8, 40, seed=0)
    with pytest.raises(ValueError, match="unknown schedule_method"):
        compile_program(g, HW, schedule_method="nope")


def test_register_schedule_strategy_replace_semantics():
    with pytest.raises(ValueError, match="already registered"):
        register_schedule_strategy(SlackStrategy())
    custom = SlackStrategy(name="test_custom_order")
    try:
        register_schedule_strategy(custom)
        assert get_schedule_strategy("test_custom_order") is custom
    finally:
        SCHEDULE_STRATEGIES.pop("test_custom_order", None)


def test_custom_strategy_reaches_compile_and_stays_correct():
    """A registered custom ordering flows through compile() and still
    produces a valid, bit-exact-vs-oracle program."""
    class ReverseStrategy:
        name = "test_reverse"

        def send_order(self, info):
            return info.posts[::-1].copy()

    g = random_graph(12, 16, 200, seed=7)
    hw = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    try:
        register_schedule_strategy(ReverseStrategy())
        p = compile_program(g, hw, schedule_method="test_reverse",
                            max_iters=3000)
        assert p.report.schedule_method == "test_reverse"
        assert p.tables.send_order == sorted(p.tables.send_order,
                                             reverse=True)
        ext = make_ext(g, 1, 8, seed=1)[0]
        s, v, _ = p.run(ext, "python")
        s_ref, v_ref = run_oracle(g, ext)
        np.testing.assert_array_equal(s, s_ref)
        np.testing.assert_array_equal(v, v_ref)
    finally:
        SCHEDULE_STRATEGIES.pop("test_reverse", None)


@pytest.mark.parametrize("method", ["slack", "consecutive", "load_balance"])
def test_compile_reaches_every_schedule_strategy(method):
    g = random_graph(12, 16, 200, seed=7)
    hw = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    p = compile_program(g, hw, schedule_method=method, max_iters=3000)
    assert p.report.schedule_method == method
    assert p.report.schedule_depths == {method: p.ot_depth}
    validate_schedule(g, p.tables)
    # every strategy executes bit-exactly (order changes slots, not math)
    ext = make_ext(g, 1, 6, seed=2)[0]
    s, _, _ = p.run(ext, "python")
    np.testing.assert_array_equal(s, run_oracle(g, ext)[0])


def test_slack_strategy_is_the_legacy_order():
    g = random_graph(16, 32, 500, seed=7)
    res = partition(g, HW, seed=0)
    assert_tables_equal(schedule(g, res.assign, HW, method="slack"),
                        schedule_legacy(g, res.assign, HW))


def test_compile_rejects_schedule_method_alongside_search():
    g = random_graph(12, 24, 300, seed=3)
    with pytest.raises(ValueError, match="SearchConfig"):
        compile_program(g, HW, schedule_method="consecutive",
                        search=SearchConfig(restarts=2))


# ---------------------------------------------------------------------------
# Joint (mapping, schedule strategy) portfolio selection.
# ---------------------------------------------------------------------------

def _joint_instance():
    """A config where the strategies disagree on the best candidate, so
    joint selection strictly beats slack-only selection (the benchmark's
    acceptance scenario, pinned here as a regression)."""
    g = random_graph(24, 48, 2000, seed=0)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=40, concentration=3,
                        max_neurons=128, max_post_neurons=64)
    return g, hw


@pytest.fixture(scope="module")
def joint_program():
    g, hw = _joint_instance()
    return g, hw, compile_program(g, hw, search=SearchConfig(
        restarts=4, max_iters=20000, early_exit=False))


def test_joint_selection_beats_best_single_strategy(joint_program):
    g, hw, p = joint_program
    trace = p.report.search
    feas = [c for c in trace.candidates if c.feasible]
    assert feas
    # every feasible candidate was scored under every registered strategy
    for c in feas:
        assert set(c.schedule_depths) == set(SCHEDULE_STRATEGIES)
        assert c.ot_depth == min(c.schedule_depths.values())
        assert c.schedule_depths[c.schedule_method] == c.ot_depth
    best_slack = min(c.schedule_depths["slack"] for c in feas)
    assert p.ot_depth < best_slack, \
        "joint (mapping, strategy) selection must beat slack-only here"
    assert p.report.schedule_method != "slack"
    assert p.report.schedule_depths == trace.selected.schedule_depths
    validate_schedule(g, p.tables)


def test_joint_winner_minimizes_over_pairs(joint_program):
    _, _, p = joint_program
    trace = p.report.search
    feas = [c for c in trace.candidates if c.feasible]
    assert p.ot_depth == min(min(c.schedule_depths.values()) for c in feas)
    sel = trace.selected
    assert sel.feasible and sel.ot_depth == p.ot_depth


def test_joint_choice_roundtrips_through_artifact(tmp_path, joint_program):
    _, _, p = joint_program
    loaded = Program.load(p.save(tmp_path / "joint"))
    assert loaded.report.schedule_method == p.report.schedule_method
    assert loaded.report.schedule_depths == p.report.schedule_depths
    a, b = p.report.search, loaded.report.search
    assert [c.schedule_method for c in a.candidates] == \
           [c.schedule_method for c in b.candidates]
    assert [c.schedule_depths for c in a.candidates] == \
           [c.schedule_depths for c in b.candidates]
    assert b.selected.schedule_method == a.selected.schedule_method
    np.testing.assert_array_equal(loaded.tables.pre, p.tables.pre)


def test_plain_compile_records_schedule_choice_roundtrip(tmp_path):
    g = random_graph(12, 16, 200, seed=7)
    hw = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    p = compile_program(g, hw, schedule_method="load_balance",
                        max_iters=3000)
    loaded = Program.load(p.save(tmp_path / "lb"))
    assert loaded.report.schedule_method == "load_balance"
    assert loaded.report.schedule_depths == {"load_balance": p.ot_depth}


# ---------------------------------------------------------------------------
# Satellite: memory model Eq. (11) Spike Memory reconciliation.
# ---------------------------------------------------------------------------

def test_total_memory_bits_includes_spike_memory():
    """Eq. (11) and the BRAM packing model must agree about what memory
    exists: both count routing, M x (OT + UM + Spike Memory), and the
    Neuron State SRAM. Pinned at the Table 2 MNIST point and a second
    (SHD-flavored) point."""
    mnist = HardwareConfig(n_spus=16, unified_mem_depth=128, concentration=3,
                           weight_bits=4, potential_bits=5, max_neurons=910,
                           max_post_neurons=126)
    # by hand: ot_entry = 2*7 + 2 + 10 + 2 = 28; routing = 910*16
    # per SPU: OT 661*28 + UM 3*4*128 + spike 910; NU 126*(10+12-7+1)
    expect = 910 * 16 + 16 * (661 * 28 + 1536 + 910) + 126 * 16
    assert total_memory_bits(mnist, 661) == expect
    shd = HardwareConfig(n_spus=16, unified_mem_depth=120, concentration=3,
                         weight_bits=9, potential_bits=18, max_neurons=1020,
                         max_post_neurons=320)
    # ot_entry = 2*7 + 2 + 10 + 2 = 28; UM = 3*9*120; NU = 320*(10+27-9+1)
    expect = 1020 * 16 + 16 * (2000 * 28 + 3240 + 1020) + 320 * 29
    assert total_memory_bits(shd, 2000) == expect


def test_memory_and_bram_models_cover_same_structures():
    """Growing max_neurons by one 18Kb-BRAM's worth of spike bits moves
    BOTH reports — before the fix only bram_count saw Spike Memory."""
    base = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                          max_neurons=600, max_post_neurons=126)
    # +300 neurons within one log2 bucket (no entry-width change):
    # routing grows 300*M bits and Spike Memory grows M*300 bits
    big = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                         max_neurons=900, max_post_neurons=126)
    d_bits = total_memory_bits(big, 100) - total_memory_bits(base, 100)
    assert d_bits == 300 * 4 + 4 * 300   # routing growth + spike growth
    assert bram_count(big, 100) >= bram_count(base, 100)


# ---------------------------------------------------------------------------
# Satellite: validator error paths.
# ---------------------------------------------------------------------------

def _valid_tables():
    g = random_graph(16, 32, 400, seed=9)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    res = BASELINES["synapse_rr"](g, hw)
    return g, schedule(g, res.assign, hw)


def test_validator_post_missing_from_send_slot_is_assertion():
    """Invariant (b) with a post absent from send_slot must raise the
    intended AssertionError (expected slot -1), not a KeyError from
    inside the message formatting."""
    g, tables = _valid_tables()
    pq = tables.send_order[0]
    del tables.send_slot[pq]
    with pytest.raises(AssertionError,
                       match=f"post {pq} sent at \\d+ != slot -1"):
        validate_schedule(g, tables)


def test_validator_send_slot_mismatch_message():
    g, tables = _valid_tables()
    pq = tables.send_order[0]
    tables.send_slot[pq] += 1
    with pytest.raises(AssertionError, match=f"post {pq} sent at"):
        validate_schedule(g, tables)


def test_validator_late_op_message():
    """Invariant (c) now names the offending (post, SPU, slot)."""
    g, tables = _valid_tables()
    # move a non-Post-End op of the FIRST-sending post to a free later
    # slot: multiset (a) and alignment (b) stay intact, (c) trips
    moved = False
    for pq in tables.send_order:
        t_p = tables.send_slot[pq]
        for spu in range(tables.n_spus):
            ops = np.flatnonzero((tables.post[spu] == pq)
                                 & ~tables.post_end[spu])
            free = np.flatnonzero(tables.pre[spu] == -1)
            free = free[free > t_p]
            if len(ops) and len(free):
                a, b = int(ops[0]), int(free[0])
                for arr in (tables.pre, tables.post, tables.weight):
                    arr[spu, b] = arr[spu, a]
                    arr[spu, a] = -1 if arr is not tables.weight else 0
                tables.pre_end[spu, b] = tables.pre_end[spu, a]
                tables.pre_end[spu, a] = False
                moved = True
                break
        if moved:
            break
    assert moved, "instance left no room to build the violation"
    with pytest.raises(AssertionError, match="after its send slot"):
        validate_schedule(g, tables)


# ---------------------------------------------------------------------------
# Satellite: vectorized CycleModel + oracle packet counts.
# ---------------------------------------------------------------------------

def _loop_cycle_report(cm, packet_counts, ot_depth, n_syn):
    """The pre-vectorization per-timestep loop, kept as the reference."""
    dist = syn = over = 0
    for n in packet_counts:
        a, b, c = cm.timestep_cycles(int(n), ot_depth)
        dist += a
        syn += b
        over += c
    total = dist + syn + over
    lat_us = total / cm.hw.clock_mhz
    p = cm.power.total_w(cm.hw)
    e_mj = p * lat_us * 1e-3
    return total, dist, syn, over, lat_us, p, e_mj, e_mj * 1e6 / n_syn


def test_cycle_model_bit_identical_to_loop():
    hw = HardwareConfig(n_spus=16, unified_mem_depth=128, concentration=3,
                        max_neurons=910, max_post_neurons=126)
    cm = CycleModel(hw)
    rng = np.random.default_rng(0)
    for t_steps in (1, 7, 50):
        pkts = rng.integers(0, 300, t_steps)
        rep = cm.run(pkts, 661, 92604)
        ref = _loop_cycle_report(cm, pkts, 661, 92604)
        assert (rep.cycles_total, rep.cycles_distribution,
                rep.cycles_synaptic, rep.cycles_overhead) == ref[:4]
        assert rep.latency_us == ref[4] and rep.energy_mj == ref[6]
        assert rep.energy_per_synapse_nj == ref[7]


def test_cycle_model_rejects_batched_counts():
    hw = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    with pytest.raises(ValueError, match=r"1-D \[T\]"):
        CycleModel(hw).run(np.ones((3, 10), np.int64), 50, 100)


def test_oracle_packet_counts_match_loop_and_batch():
    g = random_graph(10, 14, 120, seed=1)
    ext = make_ext(g, 3, 9, seed=2)
    singles = []
    for b in range(3):
        s, _ = run_oracle(g, ext[b])
        # reference loop (the pre-vectorization implementation)
        ref = np.zeros(ext.shape[1], np.int64)
        for t in range(ext.shape[1]):
            prev = np.count_nonzero(s[t - 1]) if t else 0
            ref[t] = np.count_nonzero(ext[b, t]) + prev
        got = oracle_packet_counts(ext[b], s)
        np.testing.assert_array_equal(got, ref)
        singles.append((s, got))
    batched = oracle_packet_counts(ext, np.stack([s for s, _ in singles]))
    assert batched.shape == (3, 9)
    for b in range(3):
        np.testing.assert_array_equal(batched[b], singles[b][1])
    with pytest.raises(ValueError, match="matching"):
        oracle_packet_counts(ext[0, 0], np.zeros(3))
