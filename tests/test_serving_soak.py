"""Soak-harness tests: trace generation, replay determinism, SLO bars.

Pure simulation — nothing here imports jax — so this file runs
identically on a laptop and in the 8-virtual-device CI serving lane.
The acceptance criteria pinned here:

* the harness replays >= 60 *simulated* seconds at target QPS, and the
  same seed reproduces identical p50/p99/shed counts (bit-level
  fingerprints over per-request latencies);
* per-request stage latencies sum bit-exactly to ``latencies_us``
  through the replay path;
* ``SoakReport.check``/``assert_slo`` enforce p99 + shed-rate bounds;
* deadline misses are monotone in offered load for a seeded QPS sweep
  with real deadline shedding (the general-policy regression that
  complements the provable max_batch=1 hypothesis property in
  test_serving.py).
"""
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.serve import (ArrivalTrace, BatchPolicy, MicroBatcher,
                         SoakReport, linear_service_model, replay)

sys.path.insert(0, str(Path(__file__).parent.parent))  # for benchmarks.*

SERVICE = linear_service_model(200.0, 25.0)   # bucket 8 => 50 us/request


# ---------------------------------------------------------------------------
# ArrivalTrace generators
# ---------------------------------------------------------------------------

def test_poisson_trace_is_seed_deterministic():
    a = ArrivalTrace.poisson(1000.0, 2.0, seed=42, n_streams=4)
    b = ArrivalTrace.poisson(1000.0, 2.0, seed=42, n_streams=4)
    np.testing.assert_array_equal(a.arrivals_us, b.arrivals_us)
    np.testing.assert_array_equal(a.streams, b.streams)
    c = ArrivalTrace.poisson(1000.0, 2.0, seed=43, n_streams=4)
    assert not np.array_equal(a.arrivals_us, c.arrivals_us)


def test_poisson_trace_hits_target_rate():
    tr = ArrivalTrace.poisson(5000.0, 10.0, seed=0)
    assert tr.kind == "poisson" and tr.duration_s == 10.0
    assert tr.offered_qps == pytest.approx(5000.0, rel=0.05)
    assert np.all(np.diff(tr.arrivals_us) >= 0)
    assert tr.arrivals_us[-1] < tr.duration_us


def test_bursty_trace_modulates_but_keeps_mean_rate():
    tr = ArrivalTrace.bursty(4000.0, 10.0, seed=1, burst_factor=6.0,
                             period_s=0.5, duty=0.15)
    assert tr.kind == "bursty"
    assert tr.offered_qps == pytest.approx(4000.0, rel=0.15)
    assert np.all(np.diff(tr.arrivals_us) >= 0)
    # the on-windows really are denser: most arrivals land in the
    # duty fraction of each period
    phase = np.mod(tr.arrivals_us, 0.5e6)
    on_frac = float((phase < 0.15 * 0.5e6).mean())
    assert on_frac > 0.5


def test_trace_validation():
    with pytest.raises(ValueError, match="nondecreasing"):
        ArrivalTrace(np.array([1.0, 0.5]), np.zeros(2), 10.0)
    with pytest.raises(ValueError, match="streams shape"):
        ArrivalTrace(np.array([0.0, 1.0]), np.zeros(3), 10.0)
    with pytest.raises(ValueError, match="kind"):
        ArrivalTrace(np.zeros(1), np.zeros(1), 10.0, kind="mystery")
    with pytest.raises(ValueError):
        ArrivalTrace.poisson(0.0, 1.0)
    with pytest.raises(ValueError, match="duty"):
        ArrivalTrace.bursty(100.0, 1.0, duty=1.5)
    with pytest.raises(ValueError, match="burst_factor"):
        ArrivalTrace.bursty(100.0, 1.0, burst_factor=0.5)


def test_trace_save_load_roundtrip(tmp_path):
    tr = ArrivalTrace.bursty(500.0, 3.0, seed=9, n_streams=3)
    tr.save(tmp_path / "trace.npz")
    back = ArrivalTrace.load(tmp_path / "trace.npz")
    np.testing.assert_array_equal(back.arrivals_us, tr.arrivals_us)
    np.testing.assert_array_equal(back.streams, tr.streams)
    assert back.duration_us == tr.duration_us
    assert back.kind == "bursty" and back.seed == 9


# ---------------------------------------------------------------------------
# replay(): the >= 60-simulated-seconds determinism acceptance bar
# ---------------------------------------------------------------------------

OVERLOAD = BatchPolicy(max_batch=8, max_wait_us=200.0, max_queue=64,
                       deadline_us=20_000.0, shed="reject")


def _soak_once(seed: int) -> SoakReport:
    trace = ArrivalTrace.bursty(3000.0, 60.0, seed=seed, n_streams=8,
                                burst_factor=8.0, period_s=0.5, duty=0.15)
    return replay(trace, OVERLOAD, SERVICE)


def test_replay_60s_soak_is_deterministic_and_stage_exact():
    rep = _soak_once(7)
    assert rep.sim_seconds >= 60.0                 # acceptance floor
    assert rep.requests > 100_000                  # sustained target QPS
    assert rep.shed_frac > 0.0                     # overload really bites
    assert rep.stage_sum_exact                     # bit-exact stages
    rep2 = _soak_once(7)                           # same seed, same bits
    assert rep2.fingerprint() == rep.fingerprint()
    assert (rep2.p50_ms, rep2.p99_ms) == (rep.p50_ms, rep.p99_ms)
    assert rep2.shed == rep.shed and rep2.served == rep.served
    other = _soak_once(8)                          # different seed differs
    assert other.fingerprint() != rep.fingerprint()


def test_replay_multi_model_and_validation():
    traces = {"a": ArrivalTrace.poisson(500.0, 2.0, seed=1),
              "b": ArrivalTrace.poisson(500.0, 2.0, seed=2)}
    rep = replay(traces, BatchPolicy(max_batch=4, max_wait_us=300.0),
                 SERVICE)
    assert set(rep.results) == {"a", "b"}
    assert rep.requests == sum(r.n_requests for r in rep.results.values())
    assert rep.stage_sum_exact
    with pytest.raises(ValueError, match="service_model"):
        replay(traces["a"], BatchPolicy())
    with pytest.raises(ValueError, match="no policy"):
        replay(traces, {"a": BatchPolicy()}, SERVICE)
    with pytest.raises(ValueError, match="at least one"):
        replay({}, BatchPolicy(), SERVICE)


def test_soak_report_slo_bars():
    rep = _soak_once(3)
    assert rep.check(slo_p99_ms=1e9, max_shed_frac=1.0) == []
    rep.assert_slo(slo_p99_ms=1e9, max_shed_frac=1.0)
    bad = rep.check(slo_p99_ms=1e-6, max_shed_frac=0.0,
                    max_deadline_miss_frac=0.0)
    assert len(bad) == 2                 # p99 + shed (no deadline sheds:
    assert any("p99" in b for b in bad)  # queue_full fires first here)
    with pytest.raises(AssertionError, match="soak SLO violated"):
        rep.assert_slo(max_shed_frac=0.0)


def test_replay_shed_semantics_match_drain():
    """replay() is the same simulation MicroBatcher.drain runs — one
    trace, both paths, identical per-request accounting."""
    trace = ArrivalTrace.bursty(2000.0, 5.0, seed=11, burst_factor=8.0,
                                period_s=0.25, duty=0.2)
    rep = replay(trace, OVERLOAD, SERVICE)
    direct = MicroBatcher(OVERLOAD, service_model=SERVICE).drain(
        trace.arrivals_us)
    res = rep.results["model"]
    np.testing.assert_array_equal(res.served, direct.served)
    np.testing.assert_array_equal(
        res.latencies_us[res.served], direct.latencies_us[direct.served])
    np.testing.assert_array_equal(res.shed_reason, direct.shed_reason)


def test_deadline_misses_monotone_over_qps_sweep():
    """Seeded regression for the general batching policy: offered load
    up, deadline misses never down (the provable serial-queue case is
    a hypothesis property in test_serving.py)."""
    pol = BatchPolicy(max_batch=8, max_wait_us=200.0,
                      deadline_us=3000.0)
    misses = []
    for qps in (5_000, 10_000, 20_000, 30_000):
        tr = ArrivalTrace.poisson(qps, 5.0, seed=11)
        res = MicroBatcher(pol, service_model=SERVICE).drain(
            tr.arrivals_us)
        misses.append(res.shed_counts()["deadline"])
    assert misses == sorted(misses)
    assert misses[-1] > 0                # the sweep reaches overload


# ---------------------------------------------------------------------------
# The CI soak benchmark rows
# ---------------------------------------------------------------------------

def test_soak_benchmark_rows():
    from benchmarks import serving_soak
    rows = {name: value for name, value, _ in serving_soak.run(quick=True)}
    assert rows["serve.soak.sim_seconds"] >= 60.0
    assert rows["serve.soak.deterministic"] == 1.0
    assert rows["serve.soak.slo_ok"] == 1.0
    assert rows["serve.stage.sum_exact"] == 1.0
    assert 0.0 < rows["serve.soak.shed_frac"] < 0.25
    assert rows["serve.soak.p99_ms"] > 0.0
    stage_sum = (rows["serve.stage.queue_us"] + rows["serve.stage.fill_us"]
                 + rows["serve.stage.pad_us"]
                 + rows["serve.stage.compute_us"])
    # mean stages reassemble the mean latency (rounded rows, loose tol)
    assert stage_sum == pytest.approx(
        rows["serve.soak.p50_ms"] * 1e3, rel=2.0)
