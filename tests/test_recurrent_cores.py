"""RWKV-6 / Mamba-2 core equivalence: the chunked (train/prefill) form and
the single-step (decode) recurrence must compute the same function."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.mamba2 import ssd_chunked, ssd_step
from repro.models.rwkv import wkv6_chunked, wkv6_step


# heavy chunked-vs-stepwise parity suite: full-suite CI job only
pytestmark = pytest.mark.slow


def test_wkv6_chunked_equals_stepwise():
    B, S, H, N = 2, 37, 3, 8          # S deliberately not chunk-aligned
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) - 1.0)
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    st0 = jnp.zeros((B, H, N, N), jnp.float32)

    y_c, st_c = wkv6_chunked(r, k, v, w_log, u, st0, chunk=16)

    st = st0
    ys = []
    for t in range(S):
        y, st = wkv6_step(r[:, t], k[:, t], v[:, t], w_log[:, t], u, st)
        ys.append(y)
    y_s = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_state_carries_across_calls():
    """Splitting a sequence across two chunked calls == one call."""
    B, S, H, N = 1, 24, 2, 4
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)))
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    st0 = jnp.zeros((B, H, N, N), jnp.float32)
    y_all, st_all = wkv6_chunked(r, k, v, w_log, u, st0, chunk=8)
    y1, st1 = wkv6_chunked(r[:, :10], k[:, :10], v[:, :10], w_log[:, :10],
                           u, st0, chunk=8)
    y2, st2 = wkv6_chunked(r[:, 10:], k[:, 10:], v[:, 10:], w_log[:, 10:],
                           u, st1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_all),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_equals_stepwise():
    B, S, H, P, N = 2, 29, 3, 4, 8
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    b = jax.random.normal(ks[2], (B, S, N))
    c = jax.random.normal(ks[3], (B, S, N))
    st0 = jnp.zeros((B, H, P, N), jnp.float32)

    y_c, st_c = ssd_chunked(x, dt, a_log, b, c, st0, chunk=8)

    st = st0
    ys = []
    for t in range(S):
        y, st = ssd_step(x[:, t], dt[:, t], a_log, b[:, t], c[:, t], st)
        ys.append(y)
    y_s = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_matches_dense():
    """Flash-pattern online softmax == naive dense attention."""
    from repro.models.layers import chunked_attention
    B, S, H, D = 2, 50, 4, 16
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    out = chunked_attention(q, k, v, causal=True, chunk=16)
    # dense reference
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_grouped_decode_matches_dense():
    """The grouped-einsum decode path (no KV repeat) == dense GQA."""
    from repro.configs import get_reduced
    from repro.models.layers import attention, init_attention
    cfg = get_reduced("glm4-9b")
    p = init_attention(cfg, jax.random.PRNGKey(0))
    B, S = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    pos = jnp.arange(S)
    full, kv = attention(p, x, cfg, positions=pos, return_kv=True)
    cap = S
    k = jnp.zeros((B, cap, cfg.n_kv_heads, cfg.resolved_head_dim),
                  jnp.bfloat16).at[:, :S - 1].set(kv[0][:, :S - 1])
    v = jnp.zeros_like(k).at[:, :S - 1].set(kv[1][:, :S - 1])
    dec, _ = attention(p, x[:, -1:], cfg, positions=pos[-1:],
                       kv_cache=(k, v),
                       cache_len=jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)
