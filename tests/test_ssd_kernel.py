"""Mamba-2 SSD Pallas kernel vs the model's exact recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ssd
from repro.models.mamba2 import ssd_chunked, ssd_step


def _case(b, s, h, p, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    bb = jax.random.normal(ks[2], (b, s, n))
    cc = jax.random.normal(ks[3], (b, s, n))
    st = jnp.zeros((b, h, p, n), jnp.float32)
    return x, dt, a_log, bb, cc, st


# heavy chunked-vs-stepwise parity suite: full-suite CI job only
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("b,s,h,p,n", [(1, 8, 1, 4, 8), (2, 29, 3, 4, 8),
                                       (1, 64, 2, 16, 16)])
def test_ssd_kernel_matches_stepwise(b, s, h, p, n):
    x, dt, a_log, bb, cc, st0 = _case(b, s, h, p, n)
    y_k, st_k = ssd(x, dt, a_log, bb, cc, st0, chunk=8, interpret=True)
    st = st0
    ys = []
    for t in range(s):
        y, st = ssd_step(x[:, t], dt[:, t], a_log, bb[:, t], cc[:, t], st)
        ys.append(y)
    y_s = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_s),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_kernel_chunk_invariant(chunk):
    x, dt, a_log, bb, cc, st0 = _case(2, 24, 2, 4, 8, seed=3)
    y_k, st_k = ssd(x, dt, a_log, bb, cc, st0, chunk=chunk, interpret=True)
    y_c, st_c = ssd_chunked(x, dt, a_log, bb, cc, st0, chunk=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_c),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_nonzero_state():
    x, dt, a_log, bb, cc, _ = _case(2, 12, 2, 4, 8, seed=7)
    st0 = jax.random.normal(jax.random.PRNGKey(11), (2, 2, 4, 8))
    y_k, st_k = ssd(x, dt, a_log, bb, cc, st0, chunk=4, interpret=True)
    y_c, st_c = ssd_chunked(x, dt, a_log, bb, cc, st0, chunk=6)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=2e-4, atol=2e-4)
