"""The dry-run's HLO cost instrument: trip-count-aware flops/bytes/
collective accounting (launch/hlo_analysis.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze


def _flops(fn, *specs):
    return analyze(jax.jit(fn).lower(*specs).compile().as_text())["flops"]


W = jax.ShapeDtypeStruct((256, 256), jnp.float32)
X = jax.ShapeDtypeStruct((256, 256), jnp.float32)
MM = 2 * 256 ** 3


def test_single_dot():
    got = _flops(lambda w, x: x @ w, W, X)
    assert abs(got - MM) < 0.01 * MM


def test_scan_multiplies_by_trip_count():
    def f(w, x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=9)
        return y
    got = _flops(f, W, X)
    assert abs(got - 9 * MM) < 0.01 * 9 * MM


def test_nested_scan():
    def f(w, x):
        def outer(c, _):
            c, _ = jax.lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                                length=4)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    got = _flops(f, W, X)
    assert abs(got - 12 * MM) < 0.01 * 12 * MM


def test_backward_flops_exceed_forward():
    """grad(loss) carries the ~3x fwd+bwd dot flops (NOTE: naive remat
    recompute at this scale is CSE'd away by XLA — which is why the
    analyzer must be run on the post-optimization module, not on jaxprs)."""
    def plain_loss(w, x):
        return ((jnp.tanh(x @ w) @ w) ** 2).sum()
    fwd = _flops(plain_loss, W, X)
    # grad wrt x only: fwd (2 dots) + 2 transpose-product dots = 2x fwd
    bwd = _flops(lambda w, x: jax.grad(plain_loss, argnums=1)(w, x), W, X)
    assert bwd >= 1.9 * fwd


def test_bytes_scale_with_trip_count():
    def f(w, x):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=7)
        return y
    a1 = analyze(jax.jit(lambda w, x: jnp.tanh(x @ w)).lower(W, X)
                 .compile().as_text())
    a7 = analyze(jax.jit(f).lower(W, X).compile().as_text())
    assert a7["bytes"] > 4 * a1["bytes"]
