"""SNN substrate: LIF dynamics, surrogate-gradient BPTT training on the
synthetic datasets, quantization pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import mnist_batches, synthetic_mnist, synthetic_shd, shd_batches
from repro.snn import (LIFParams, MNIST_CONFIG, QuantConfig, SNNConfig,
                       init_params, lif_step, quantize)
from repro.snn.lif import LIFIntParams, alpha_to_shift, lif_step_int
from repro.snn.train import evaluate, rate_encode, train


def test_lif_step_eqs_2_4_5():
    p = LIFParams(alpha=0.25, v_threshold=1.0, v_reset=0.0)
    v = jnp.array([0.8, 0.8, 0.0])
    i = jnp.array([0.5, 0.0, 1.2])
    v_next, s = lif_step(v, i, p)
    # V_upd = 0.75*0.8 + I
    np.testing.assert_allclose(np.asarray(s), [1.0, 0.0, 1.0])
    np.testing.assert_allclose(np.asarray(v_next), [0.0, 0.6, 0.0],
                               atol=1e-6)


def test_integer_lif_matches_float_shape():
    p = LIFIntParams(leak_shift=2, v_threshold=10, v_reset=0)
    v = np.array([8, -5, 12], np.int32)
    i = np.array([4, 1, 0], np.int32)
    v_next, s = lif_step_int(v, i, p)
    # leak: v - (v >> 2): 8-2=6, -5-(-2)=-3, 12-3=9
    np.testing.assert_array_equal(s, [1, 0, 0])
    np.testing.assert_array_equal(v_next, [0, -2, 9])
    # numpy and jnp paths identical
    vj, sj = lif_step_int(jnp.asarray(v), jnp.asarray(i), p)
    np.testing.assert_array_equal(np.asarray(vj), v_next)


def test_alpha_to_shift():
    assert alpha_to_shift(0.25) == 2
    assert alpha_to_shift(0.03125) == 5


def test_surrogate_gradients_nonzero():
    for surr in ("relu", "sigmoid", "fast_sigmoid"):
        g = jax.grad(lambda v: lif_step(jnp.array([0.9]),
                                        jnp.array([v]),
                                        LIFParams(), surr)[1].sum())(0.2)
        assert np.isfinite(g) and g != 0.0, surr


def test_rate_encode_statistics():
    img = jnp.full((4, 10), 0.3)
    spikes = rate_encode(img, 200, jax.random.PRNGKey(0))
    assert spikes.shape == (200, 4, 10)
    assert abs(float(spikes.mean()) - 0.3) < 0.03


@pytest.mark.slow
def test_mnist_sfnn_trains_above_chance():
    """Paper §7.1 pipeline at reduced scale: the 784-116-10 SFNN with the
    Table 2 recipe learns the (synthetic) digit task well above chance."""
    xtr, ytr, xte, yte = synthetic_mnist(n_train=512, n_test=256, seed=0)
    data = mnist_batches(xtr, ytr, batch=64, seed=0)
    res = train(MNIST_CONFIG, data, steps=120, lr=5e-4,
                key=jax.random.PRNGKey(0), encode=True)
    acc = evaluate(res.params, MNIST_CONFIG, xte, yte,
                   jax.random.PRNGKey(1), encode=True)
    assert acc > 0.5, acc    # 10 classes, chance = 0.1


@pytest.mark.slow
def test_shd_srnn_trains_above_chance():
    cfg = SNNConfig(layer_sizes=(700, 64, 20), recurrent=True,
                    sparsity=0.8, lif=LIFParams(alpha=0.03125),
                    surrogate="sigmoid", timesteps=40)
    xtr, ytr, xte, yte = synthetic_shd(n_train=256, n_test=128,
                                       timesteps=40, seed=0)
    data = shd_batches(xtr, ytr, batch=32, seed=0)
    res = train(cfg, data, steps=150, lr=2e-3, key=jax.random.PRNGKey(0),
                encode=False)
    correct = 0
    from repro.snn.models import forward
    fwd = jax.jit(lambda p, s: jnp.argmax(forward(p, s, cfg)[0], -1))
    for i in range(0, len(xte), 64):
        pred = fwd(res.params, jnp.asarray(
            xte[i:i + 64].transpose(1, 0, 2).astype(np.float32)))
        correct += int((np.asarray(pred) == yte[i:i + 64]).sum())
    acc = correct / len(xte)
    assert acc > 0.2, acc    # 20 classes, chance = 0.05


def test_quantize_drops_zeros_and_scales():
    cfg = MNIST_CONFIG
    params = init_params(cfg, jax.random.PRNGKey(0))
    q = quantize(params, cfg, QuantConfig(weight_bits=4))
    qmax = 2 ** 3 - 1
    for w in q.weights:
        assert w.dtype == np.int32
        assert np.abs(w).max() <= qmax + 1
    assert q.sparsity >= cfg.sparsity - 0.01
    assert q.lif.v_threshold >= 1
    assert q.n_unique_weights <= 2 * qmax + 2
