"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp
oracle (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (lif_update, lif_update_int, lif_update_ref,
                           spike_accum, spike_accum_ref)
from repro.snn.lif import LIFIntParams, lif_step_int


SHAPES = [(1, 7, 5), (3, 128, 128), (5, 300, 70), (8, 513, 257),
          (16, 1024, 116), (2, 784, 116)]


@pytest.mark.parametrize("b,n_pre,n_post", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
def test_spike_accum_matches_ref(b, n_pre, n_post, dtype):
    key = jax.random.PRNGKey(b * 1000 + n_pre)
    k1, k2 = jax.random.split(key)
    spikes = (jax.random.uniform(k1, (b, n_pre)) < 0.25)
    if dtype == "int32":
        s = spikes.astype(jnp.int32)
        w = jax.random.randint(k2, (n_pre, n_post), -7, 8, jnp.int32)
    else:
        s = spikes.astype(dtype)
        w = jax.random.normal(k2, (n_pre, n_post), jnp.float32).astype(dtype)
    out = spike_accum(s, w, interpret=True)
    ref = spike_accum_ref(s, w)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    if dtype == "int32":
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2 if dtype == "bfloat16" else 1e-5,
                                   atol=1e-2 if dtype == "bfloat16" else 1e-5)


@pytest.mark.parametrize("block", [(8, 128), (16, 256)])
def test_spike_accum_block_shapes(block):
    """Block-shape sweep: results must be block-size independent."""
    key = jax.random.PRNGKey(0)
    s = (jax.random.uniform(key, (9, 391)) < 0.3).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (391, 203))
    out = spike_accum(s, w, block_b=block[0], block_pre=block[1],
                      block_post=block[1], interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(spike_accum_ref(s, w)),
                               rtol=1e-5, atol=1e-5)


def test_spike_accum_zero_tile_skip_correct():
    """All-zero pre-tiles must contribute exactly nothing (the MC-tree
    block-skip cannot change results)."""
    s = jnp.zeros((8, 512), jnp.float32)
    s = s.at[0, 300].set(1.0)          # single live tile
    w = jax.random.normal(jax.random.PRNGKey(2), (512, 128))
    out = spike_accum(s, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(w[300]),
                               rtol=1e-6)
    assert float(jnp.abs(out[1:]).max()) == 0.0


@pytest.mark.parametrize("shape", [(7,), (1, 5), (3, 200), (8, 1024),
                                   (13, 300)])
@pytest.mark.parametrize("alpha", [0.25, 0.03125, 0.5])
def test_lif_update_matches_ref(shape, alpha):
    k1, k2 = jax.random.split(jax.random.PRNGKey(17))
    v = jax.random.normal(k1, shape)
    cur = jax.random.normal(k2, shape) * 2.0
    v_out, s_out = lif_update(v, cur, alpha=alpha, v_th=1.0, v_reset=0.0,
                              interpret=True)
    v_ref, s_ref = lif_update_ref(v, cur, alpha, 1.0, 0.0)
    np.testing.assert_allclose(np.asarray(v_out), np.asarray(v_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s_out), np.asarray(s_ref))


def test_lif_update_reset_semantics():
    v = jnp.array([[0.5, 2.0, -1.0, 0.999]])
    cur = jnp.zeros_like(v)
    v_out, s_out = lif_update(v, cur, alpha=0.0, v_th=1.0, v_reset=-0.25,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(v_out[0]),
                               [0.5, -0.25, -1.0, 0.999], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(s_out[0]), [0, 1, 0, 0])


@pytest.mark.parametrize("shape", [(9,), (1, 5), (3, 200), (16, 126)])
@pytest.mark.parametrize("leak_shift", [1, 2, 4])
def test_lif_update_int_matches_int_oracle(shape, leak_shift):
    """The integer Neuron-Unit kernel must be BIT-EXACT with lif_step_int
    (the deterministic-commit reference), including negative potentials
    (arithmetic shift)."""
    p = LIFIntParams(leak_shift=leak_shift, v_threshold=15, v_reset=0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(23))
    v = jax.random.randint(k1, shape, -50, 50, jnp.int32)
    cur = jax.random.randint(k2, shape, -30, 30, jnp.int32)
    v_out, s_out = lif_update_int(v, cur, p, interpret=True)
    v_ref, s_ref = lif_step_int(v, cur, p)
    assert v_out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(v_out), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(s_out), np.asarray(s_ref))
