"""End-to-end behaviour of the paper's system: train -> quantize -> map ->
schedule -> execute, plus cycle/energy model sanity against Table 2/3."""
import numpy as np
import pytest

from repro.core import (CycleModel, HardwareConfig,
                        compile as compile_program, from_quantized,
                        random_graph, run_mapped, run_oracle)
from repro.configs.snn_paper import MNIST_HW
from repro.snn import MNIST_CONFIG, QuantConfig, init_params, quantize

import jax


def test_end_to_end_random_graph():
    g = random_graph(24, 48, 400, seed=3)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=48, concentration=3,
                        max_neurons=128, max_post_neurons=64)
    program = compile_program(g, hw, seed=1)
    tables, report = program.tables, program.report
    assert report.feasible
    rng = np.random.default_rng(0)
    ext = (rng.random((20, g.n_inputs)) < 0.25).astype(np.int32)
    s_ref, v_ref = run_oracle(g, ext)
    s_map, v_map, stats = run_mapped(g, tables, ext)
    np.testing.assert_array_equal(s_ref, s_map)
    np.testing.assert_array_equal(v_ref, v_map)


def test_mnist_network_maps_onto_paper_hardware():
    """The paper's own MNIST config (Table 2) must produce a feasible
    mapping on the published hardware parameters."""
    cfg = MNIST_CONFIG
    params = init_params(cfg, jax.random.PRNGKey(0))
    q = quantize(params, cfg, QuantConfig(weight_bits=4, potential_bits=5))
    g = from_quantized(q)
    # post-quantization sparsity should exceed the pre-quantization level
    assert q.sparsity > 0.5
    program = compile_program(g, MNIST_HW, seed=0, max_iters=30000)
    tables, report = program.tables, program.report
    assert report.feasible, f"scores {report.scores.min()}"
    # schedule depth within the same order as the paper's 661
    assert report.ot_depth < 5 * 661

    # run a few timesteps mapped vs oracle
    rng = np.random.default_rng(1)
    ext = (rng.random((5, 784)) < 0.1).astype(np.int32)
    s_ref, v_ref = run_oracle(g, ext)
    s_map, v_map, stats = run_mapped(g, tables, ext)
    np.testing.assert_array_equal(s_ref, s_map)

    cm = CycleModel(MNIST_HW)
    rep = cm.run(stats["packet_counts"], tables.depth, g.n_synapses)
    assert rep.latency_us > 0 and rep.energy_mj > 0


def test_cycle_model_matches_paper_numbers():
    """Table 2/3 MNIST point: OT depth 661, 10 timesteps, ~130 MC
    packets/step (rate-coded MNIST at ~15% activity over 910 neurons).
    Paper: 149 us, 0.172 W, 0.02563 mJ/image, 0.27675 nJ/synapse (the
    per-synapse metric divides by ALL 92,604 synapses)."""
    cm = CycleModel(MNIST_HW)
    pkts = np.full(10, 130)
    rep = cm.run(pkts, 661, 92604)
    assert abs(rep.latency_us - 149) / 149 < 0.05, rep.latency_us
    assert abs(rep.power_w - 0.172) / 0.172 < 0.05, rep.power_w
    assert abs(rep.energy_mj - 0.02563) / 0.02563 < 0.10, rep.energy_mj
    assert abs(rep.energy_per_synapse_nj - 0.27675) / 0.27675 < 0.10, \
        rep.energy_per_synapse_nj


def test_merge_alignment_violation_detected():
    """Corrupting the schedule must trip the ME-tree alignment check or
    change the result — the deterministic-commit property is protective."""
    from repro.core.engine import MergeAlignmentError
    g = random_graph(10, 20, 150, seed=5)
    hw = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    tables = compile_program(g, hw, seed=0).tables
    m, depth = tables.pre.shape
    moved = False
    for spu in range(m):
        slots = np.flatnonzero(tables.post_end[spu])
        if len(slots) >= 2:
            a = int(slots[0])
            free = np.flatnonzero(tables.pre[spu] == -1)
            free = free[free != a]
            if len(free):
                t = int(free[0])
                for arr in (tables.pre, tables.post, tables.weight,
                            tables.pre_end, tables.post_end):
                    arr[spu, t] = arr[spu, a]
                    arr[spu, a] = -1 if arr is tables.pre else 0
                moved = True
                break
    if not moved:
        pytest.skip("no movable op in this schedule")
    ext = np.ones((2, g.n_inputs), np.int32)
    try:
        s_map, _, _ = run_mapped(g, tables, ext)
    except (MergeAlignmentError, AssertionError):
        return  # detected — good
    s_ref, _ = run_oracle(g, ext)
    assert not np.array_equal(s_ref, s_map), \
        "corrupted schedule silently produced oracle results"
