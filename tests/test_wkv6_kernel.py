"""WKV-6 Pallas kernel vs oracle: shape/dtype/chunk sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import wkv6, wkv6_ref


def _case(b, s, h, n, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, s, h, n)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, n)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, n)).astype(dtype)
    w = (-jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) - 1.0)) \
        .astype(jnp.float32)
    u = (jax.random.normal(ks[4], (h, n)) * 0.1).astype(jnp.float32)
    st = jnp.zeros((b, h, n, n), jnp.float32)
    return r, k, v, w, u, st


# heavy chunked-vs-stepwise parity suite: full-suite CI job only
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("b,s,h,n", [(1, 8, 1, 8), (2, 37, 3, 8),
                                     (2, 64, 2, 16), (1, 129, 4, 32)])
def test_wkv6_matches_ref(b, s, h, n):
    r, k, v, w, u, st = _case(b, s, h, n)
    y_k, st_k = wkv6(r, k, v, w, u, st, chunk=16, interpret=True)
    y_r, st_r = wkv6_ref(r, k, v, w, u, st)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [8, 32, 64])
def test_wkv6_chunk_invariant(chunk):
    r, k, v, w, u, st = _case(2, 48, 2, 8, jnp.float32, seed=3)
    y_k, st_k = wkv6(r, k, v, w, u, st, chunk=chunk, interpret=True)
    y_r, st_r = wkv6_ref(r, k, v, w, u, st)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)


def test_wkv6_bf16_inputs():
    r, k, v, w, u, st = _case(1, 16, 2, 8, jnp.bfloat16, seed=5)
    y_k, st_k = wkv6(r, k, v, w, u, st, chunk=8, interpret=True)
    y_r, st_r = wkv6_ref(r, k, v, w, u, st)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_wkv6_nonzero_initial_state():
    r, k, v, w, u, _ = _case(2, 20, 2, 8, jnp.float32, seed=7)
    st = jax.random.normal(jax.random.PRNGKey(9), (2, 2, 8, 8))
    y_k, st_k = wkv6(r, k, v, w, u, st, chunk=8, interpret=True)
    y_r, st_r = wkv6_ref(r, k, v, w, u, st)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=2e-5, atol=2e-5)


def test_wkv6_matches_model_chunked_form():
    """Kernel == the model's chunked-einsum path (same function, two
    implementations — kernel for TPU, einsum for the dry-run/backward)."""
    from repro.models.rwkv import wkv6_chunked
    r, k, v, w, u, st = _case(2, 40, 2, 8, jnp.float32, seed=11)
    y_k, st_k = wkv6(r, k, v, w, u, st, chunk=8, interpret=True)
    y_c, st_c = wkv6_chunked(r, k, v, w, u, st, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_c),
                               rtol=2e-4, atol=2e-4)
