"""Tests for the mapping search subsystem (core/mapping/).

The load-bearing suite is PARITY: the vectorized population core must
reproduce the preserved legacy loop bit-for-bit on the same
(graph, hw, seed) — assignment, scores, iteration count, perturbation
count, and score history — across feedforward and recurrent graphs,
both move modes, sampled and full member scans, and runs that cross
perturbation events.
"""
import numpy as np
import pytest

from repro.core import (BASELINES, HardwareConfig, SearchConfig, STRATEGIES,
                        compile as compile_program, get_strategy, partition,
                        random_graph, register_strategy, schedule,
                        validate_schedule)
from repro.core.mapping import (Books, FrameworkStrategy, framework_partition,
                                partition_legacy, portfolio_search, walk)
from repro.core.mapping.strategies import BaselineStrategy
from repro.snn.lif import LIFIntParams


def feedforward_graph(seed=0, n_in=24, n_out=16, n_syn=300):
    """Pure feedforward: every pre is an input neuron."""
    rng = np.random.default_rng(seed)
    flat = rng.choice(n_in * n_out, size=n_syn, replace=False)
    pre = (flat // n_out).astype(np.int32)
    post = (flat % n_out + n_in).astype(np.int32)
    w = rng.integers(1, 8, n_syn).astype(np.int32) * \
        rng.choice([-1, 1], n_syn).astype(np.int32)
    from repro.core.graph import SNNGraph
    g = SNNGraph(n_in, n_in + n_out, pre, post, w,
                 LIFIntParams(leak_shift=2, v_threshold=15, v_reset=0),
                 output_slice=(n_in, n_in + n_out))
    g.validate()
    return g


HW8 = HardwareConfig(n_spus=8, unified_mem_depth=24, concentration=3,
                     max_neurons=256, max_post_neurons=128)


def assert_parity(a, b):
    np.testing.assert_array_equal(a.assign, b.assign)
    np.testing.assert_array_equal(a.scores, b.scores)
    assert a.feasible == b.feasible
    assert a.iterations == b.iterations
    assert a.perturbations == b.perturbations
    assert a.score_history == b.score_history


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_parity_recurrent(seed):
    """random_graph mixes input->internal and internal->internal edges."""
    g = random_graph(16, 32, 900, seed=2)
    kw = dict(max_iters=20000)
    assert_parity(partition_legacy(g, HW8, seed=seed, **kw),
                  partition(g, HW8, seed=seed, **kw))


@pytest.mark.parametrize("seed", [0, 7])
def test_parity_feedforward(seed):
    g = feedforward_graph(seed=1)
    hw = HardwareConfig(n_spus=4, unified_mem_depth=16, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    assert_parity(partition_legacy(g, hw, seed=seed, max_iters=20000),
                  partition(g, hw, seed=seed, max_iters=20000))


def test_parity_with_sampling_and_perturbations():
    """Tight memory + tiny scan_cap forces the sampled-scan and the
    stagnation/perturbation paths through the identical RNG stream."""
    g = random_graph(12, 24, 800, seed=3)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=11, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    kw = dict(max_iters=60000, scan_cap=24, stagnation_window=120)
    a = partition_legacy(g, hw, seed=0, **kw)
    b = partition(g, hw, seed=0, **kw)
    assert a.perturbations > 0, "config too loose to exercise perturbation"
    assert_parity(a, b)


def test_parity_nudge_mode():
    g = random_graph(16, 32, 600, seed=2)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=30, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    kw = dict(move_mode="nudge", max_iters=8000)
    assert_parity(partition_legacy(g, hw, seed=0, **kw),
                  partition(g, hw, seed=0, **kw))


def test_parity_infeasible_budget_exhaustion():
    """Both sides must return the identical best-seen state when the
    iteration budget runs out without feasibility."""
    g = random_graph(12, 24, 800, seed=3)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=11, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    a = partition_legacy(g, hw, seed=0, max_iters=300)
    b = partition(g, hw, seed=0, max_iters=300)
    assert not a.feasible
    assert_parity(a, b)


# -- the batched tree / occupancy primitives --------------------------------

def test_walk_batched_matches_single():
    rng = np.random.default_rng(0)
    m, e, r_n = 8, 200, 5
    depth = 3
    p = rng.random((r_n, m - 1, e))
    r = rng.random((r_n, m - 1, e))
    batched = walk(p, r, depth)
    for k in range(r_n):
        np.testing.assert_array_equal(batched[k], walk(p[k], r[k], depth))


def test_books_match_ground_truth_after_search():
    g = random_graph(16, 32, 700, seed=4)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=26, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    res = partition(g, hw, seed=0, max_iters=10000)
    books = Books(g, hw, res.assign[None])
    w_id = books.w_id
    for i in range(hw.n_spus):
        sel = res.assign == i
        assert books.n_posts[0, i] == len(np.unique(g.post[sel]))
        assert books.n_weights[0, i] == len(np.unique(w_id[sel]))
    np.testing.assert_array_equal(books.scores_r(0), res.scores)
    # presence counters match the occupancy planes
    np.testing.assert_array_equal(books.np_post[0],
                                  (books.cnt_post[0] > 0).sum(0))
    np.testing.assert_array_equal(books.np_w[0],
                                  (books.cnt_w[0] > 0).sum(0))


def test_restart_population_matches_serial_runs():
    """Restart k of the lockstep population is bit-identical to a fresh
    single run with seed base+k."""
    g = random_graph(12, 24, 700, seed=5)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=13, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    _, results, _ = framework_partition(g, hw, seed=10, restarts=3,
                                        max_iters=4000, early_exit=False)
    for k, res in enumerate(results):
        assert_parity(res, partition(g, hw, seed=10 + k, max_iters=4000))


# -- baselines + strategy registry ------------------------------------------

@pytest.mark.parametrize("name", sorted(BASELINES))
def test_baseline_full_valid_assignment(name):
    g = random_graph(16, 32, 500, seed=6)
    res = BASELINES[name](g, HW8)
    assert res.assign.shape == (g.n_synapses,)
    assert res.assign.min() >= 0 and res.assign.max() < HW8.n_spus
    assert res.scores.shape == (HW8.n_spus,)
    tables = schedule(g, res.assign, HW8)
    validate_schedule(g, tables)


def test_registry_has_framework_and_all_baselines():
    assert set(STRATEGIES) == \
        {"framework", "hypergraph", "multilevel"} | set(BASELINES)
    assert isinstance(STRATEGIES["framework"], FrameworkStrategy)


def test_registry_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown method 'does_not_exist'"):
        get_strategy("does_not_exist")
    g = random_graph(8, 8, 40, seed=0)
    with pytest.raises(ValueError, match="unknown method"):
        compile_program(g, HW8, method="does_not_exist")


def test_register_strategy_replace_semantics():
    dummy = BaselineStrategy("synapse_rr", BASELINES["synapse_rr"])
    with pytest.raises(ValueError, match="already registered"):
        register_strategy(dummy)
    custom = BaselineStrategy("test_custom_rr", BASELINES["synapse_rr"])
    try:
        register_strategy(custom)
        assert get_strategy("test_custom_rr") is custom
    finally:
        STRATEGIES.pop("test_custom_rr", None)


@pytest.mark.parametrize("name", ["framework", "post_neuron_rr",
                                  "synapse_rr", "weight_rr"])
def test_compile_reaches_every_strategy(name):
    g = random_graph(12, 16, 200, seed=7)
    hw = HardwareConfig(n_spus=4, unified_mem_depth=64, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    program = compile_program(g, hw, method=name, max_iters=3000)
    assert program.report.method == name
    assert program.feasible
    assert program.report.search is None           # no portfolio used


# -- portfolio search -------------------------------------------------------

def _tight_instance():
    g = random_graph(12, 24, 800, seed=3)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=14, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    return g, hw


def test_portfolio_beats_single_seed_budget():
    """The acceptance scenario: a tight config where the single-seed
    compile exhausts max_iters infeasible, but the restart portfolio
    returns a feasible mapping — with the trace on the report."""
    g, hw = _tight_instance()
    single = compile_program(g, hw, seed=0, max_iters=60)
    assert not single.feasible
    program = compile_program(g, hw,
                              search=SearchConfig(restarts=8,
                                                  max_iters=60000))
    assert program.feasible
    rep = program.report
    assert rep.method == "portfolio"
    assert rep.search is not None
    assert rep.candidates_tried == len(rep.search.candidates) > 1
    sel = rep.search.selected
    assert sel.feasible and sel.ot_depth == program.ot_depth


def test_compile_rejects_partition_args_alongside_search():
    g, hw = _tight_instance()
    with pytest.raises(ValueError, match="SearchConfig"):
        compile_program(g, hw, seed=7, search=SearchConfig(restarts=2))
    with pytest.raises(ValueError, match="SearchConfig"):
        compile_program(g, hw, max_iters=50,
                        search=SearchConfig(restarts=2))


def test_portfolio_trace_contents_and_ranking():
    g = random_graph(16, 32, 500, seed=8)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    part, trace, tables = portfolio_search(
        g, hw, SearchConfig(restarts=2, max_iters=2000, early_exit=False))
    names = {c.strategy for c in trace.candidates}
    assert names == {"framework", "hypergraph"} | set(BASELINES)
    feas = [c for c in trace.candidates if c.feasible]
    assert feas, "relaxed memory: everything should be feasible"
    # winner minimizes (OT depth, memory-line usage) over the feasible
    sel = trace.selected
    assert sel.ot_depth == min(c.ot_depth for c in feas)
    assert all(c.memory_lines is not None for c in feas)
    best_depth = [c for c in feas if c.ot_depth == sel.ot_depth]
    assert sel.memory_lines == min(c.memory_lines for c in best_depth)
    assert tables is not None and tables.depth == sel.ot_depth
    assert part.feasible


def test_portfolio_budget_exhaustion_flag():
    g = random_graph(12, 24, 800, seed=3)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=5, concentration=3,
                        max_neurons=64, max_post_neurons=32)   # unsatisfiable
    _, trace, _ = portfolio_search(
        g, hw, SearchConfig(restarts=2, max_iters=10 ** 8,
                            include_baselines=False,
                            budget_seconds=0.2))
    assert trace.budget_exhausted
    assert trace.seconds < 5.0
    assert not trace.n_feasible


def test_portfolio_trace_roundtrips_through_artifact(tmp_path):
    g, hw = _tight_instance()
    program = compile_program(g, hw, search=SearchConfig(restarts=4,
                                                         max_iters=30000))
    path = program.save(tmp_path / "with_trace")
    from repro.core import Program
    loaded = Program.load(path)
    a, b = program.report.search, loaded.report.search
    assert b is not None
    assert [c.strategy for c in a.candidates] == \
           [c.strategy for c in b.candidates]
    assert [c.feasible for c in a.candidates] == \
           [c.feasible for c in b.candidates]
    assert a.selected.strategy == b.selected.strategy
    assert loaded.report.candidates_tried == program.report.candidates_tried


# -- vectorized validate_schedule keeps its messages ------------------------

def _valid_tables():
    g = random_graph(16, 32, 400, seed=9)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    res = BASELINES["synapse_rr"](g, hw)
    return g, schedule(g, res.assign, hw)


def test_validate_schedule_passes_on_valid():
    g, tables = _valid_tables()
    validate_schedule(g, tables)


def test_validate_schedule_multiset_message():
    g, tables = _valid_tables()
    spu, slot = np.argwhere(tables.pre != -1)[0]
    tables.weight[spu, slot] += 1
    with pytest.raises(AssertionError,
                       match="op multiset != synapse multiset"):
        validate_schedule(g, tables)


def test_validate_schedule_count_message():
    g, tables = _valid_tables()
    spu, slot = np.argwhere(tables.pre != -1)[0]
    tables.pre[spu, slot] = -1
    with pytest.raises(AssertionError, match="ops != .* synapses"):
        validate_schedule(g, tables)


def test_validate_schedule_send_slot_message():
    g, tables = _valid_tables()
    pq = tables.send_order[0]
    tables.send_slot[pq] += 1
    with pytest.raises(AssertionError, match=f"post {pq} sent at"):
        validate_schedule(g, tables)


def test_validate_schedule_missing_post_end_message():
    g, tables = _valid_tables()
    spu, slot = np.argwhere(tables.post_end)[0]
    tables.post_end[spu, slot] = False
    with pytest.raises(AssertionError, match="missing post_end"):
        validate_schedule(g, tables)


def test_validate_schedule_pre_end_message():
    g, tables = _valid_tables()
    spu, slot = np.argwhere(tables.pre_end)[0]
    tables.pre_end[spu, slot] = False
    with pytest.raises(AssertionError, match="pre_end flags wrong"):
        validate_schedule(g, tables)
