import numpy as np

from repro.core import HardwareConfig, random_graph
from repro.core.graph import SNNGraph


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


# -- shared graph/hardware fixtures (test_engine_jax, test_program) ---------

def make_hw(g, m=4, k=2):
    """A comfortably-feasible HardwareConfig for graph ``g``."""
    return HardwareConfig(
        n_spus=m, unified_mem_depth=4 * (g.n_synapses // m + g.n_internal),
        concentration=k, max_neurons=g.n_neurons,
        max_post_neurons=g.n_internal)


def make_feedforward(n_inputs=16, n_internal=12, n_synapses=150, seed=5):
    """Random graph restricted to input->internal synapses only."""
    g = random_graph(n_inputs, n_internal, n_synapses, seed=seed)
    ff = g.pre < n_inputs
    assert ff.sum() >= 8
    return SNNGraph(g.n_inputs, g.n_neurons, g.pre[ff], g.post[ff],
                    g.weight[ff], g.lif, g.output_slice)


def make_ext(g, b, t, rate=0.3, seed=0):
    """Binary [B, T, n_inputs] spike train for graph ``g``."""
    rng = np.random.default_rng(seed)
    return (rng.random((b, t, g.n_inputs)) < rate).astype(np.int32)
