"""Bit-exactness pins for the fused step megakernel (kernels/fused_step).

The fused tier collapses routing + per-SPU accumulation + Neuron Unit
into one pallas_call; the deterministic-commit property (paper §4.2)
says it must be BIT-identical — spikes, final potentials AND per-step
MC packet counts — to the unfused tiers and the dense oracle. Pinned
here over feedforward + recurrent graphs at ragged batch sizes
(1, D-1, D, 3D+1), random quantized nets (hypothesis), and the golden
artifact re-run through the fused tier.
"""
from pathlib import Path

import jax
import numpy as np
import pytest

from conftest import make_ext, make_feedforward, make_hw
from repro.core import ExecutionSpec, JaxMappedEngine, Program, compile, \
    lower_tables, random_graph, run_mapped, run_oracle
from repro.kernels.fused_step import (DEFAULT_BLOCK, pack_dense,
                                      fused_step)
from repro.snn.lif import LIFIntParams

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # CI installs hypothesis; bare envs skip
    HAVE_HYPOTHESIS = False

GOLDEN = Path(__file__).parent / "golden"


def _ragged_sizes():
    d = len(jax.devices())
    return sorted({1, max(1, d - 1), d, 3 * d + 1})


def _recurrent(seed=3):
    g = random_graph(12, 20, 160, seed=seed)
    assert (g.pre >= g.n_inputs).any(), "graph must contain recurrence"
    return g


@pytest.fixture(scope="module")
def ff_program():
    g = make_feedforward()
    return compile(g, make_hw(g), max_iters=4000)


@pytest.fixture(scope="module")
def rec_program():
    g = _recurrent()
    return compile(g, make_hw(g), max_iters=4000)


# ---------------------------------------------------------------------------
# Fused vs unfused tiers: spikes, potentials, packet counts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["feedforward", "recurrent"])
def test_fused_bit_exact_vs_unfused_ragged_batches(kind, ff_program,
                                                   rec_program):
    program = ff_program if kind == "feedforward" else rec_program
    g = program.graph
    fused = ExecutionSpec(kernel="fused")
    for b in _ragged_sizes():
        ext = make_ext(g, b, 11, seed=b)
        s_f, v_f, st_f = program.run(ext, fused)
        for tier in ("lif", "reference"):
            s_u, v_u, st_u = program.run(ext, ExecutionSpec(kernel=tier))
            assert s_f.tobytes() == s_u.tobytes(), (tier, b)
            assert v_f.tobytes() == v_u.tobytes(), (tier, b)
            assert st_f["packet_counts"].tobytes() == \
                st_u["packet_counts"].tobytes(), (tier, b)
        # and vs the dense oracle + python reference executor
        for i in range(b):
            s_ref, v_ref = run_oracle(g, ext[i])
            np.testing.assert_array_equal(s_f[i], s_ref)
            np.testing.assert_array_equal(v_f[i], v_ref)
            _, _, ref = run_mapped(g, program.tables, ext[i])
            np.testing.assert_array_equal(st_f["packet_counts"][i],
                                          ref["packet_counts"])


def test_fused_is_the_default_tier(rec_program):
    ext = make_ext(rec_program.graph, 2, 7, seed=0)
    s_d, v_d, st_d = rec_program.run(ext)
    s_f, v_f, st_f = rec_program.run(ext, ExecutionSpec(kernel="fused"))
    assert rec_program.engine() is rec_program.engine(
        ExecutionSpec(kernel="fused"))
    assert s_d.tobytes() == s_f.tobytes()
    assert v_d.tobytes() == v_f.tobytes()
    np.testing.assert_array_equal(st_d["packet_counts"],
                                  st_f["packet_counts"])


def test_fused_step_handles_non_tile_multiples():
    """Shapes straddling the (8, 128, 128) tile must pad-and-slice."""
    g = random_graph(120, 140, 2500, seed=11)     # n_neurons=260 > 2 tiles
    tables = compile(g, make_hw(g, m=8), max_iters=6000).tables
    ext = make_ext(g, b=9, t=5, seed=2)           # 9 = one tile + 1
    s_f, v_f, st_f = JaxMappedEngine(
        g, tables, ExecutionSpec(kernel="fused")).run(ext)
    s_u, v_u, st_u = JaxMappedEngine(
        g, tables, ExecutionSpec(kernel="lif")).run(ext)
    assert s_f.tobytes() == s_u.tobytes()
    assert v_f.tobytes() == v_u.tobytes()
    np.testing.assert_array_equal(st_f["packet_counts"],
                                  st_u["packet_counts"])


def test_fused_step_tiled_grid_matches_single_tile():
    """The TPU (8, 128, 128) tiling (multi-step reduction grid, VMEM
    scratch carries) must be bit-identical to the one-tile CPU path —
    tiling only reorders an associative int32 reduction."""
    rng = np.random.default_rng(0)
    b, n_all, n_int = 9, 260, 140                 # straddles every axis
    s_all = (rng.random((b, n_all)) < 0.4).astype(np.int32)
    v = rng.integers(-40, 40, (b, n_int)).astype(np.int32)
    w = rng.integers(-7, 8, (n_all, n_int)).astype(np.int8)
    p = LIFIntParams(leak_shift=3, v_threshold=30, v_reset=0)
    one = fused_step(np.asarray(s_all), np.asarray(v), np.asarray(w), p,
                     interpret=True)              # single full-array tile
    tiled = fused_step(np.asarray(s_all), np.asarray(v), np.asarray(w), p,
                       block=DEFAULT_BLOCK, interpret=True)
    for a, t in zip(one, tiled):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(t))


# ---------------------------------------------------------------------------
# pack_dense: exact densification + narrowest-dtype packing
# ---------------------------------------------------------------------------

def test_pack_dense_sums_duplicates_and_narrows(rec_program):
    g = rec_program.graph
    lw = lower_tables(g, rec_program.tables)
    d = pack_dense(lw)
    assert d.weight.shape == (g.n_neurons, g.n_internal)
    w_ref = np.zeros((g.n_neurons, g.n_internal), np.int64)
    np.add.at(w_ref, (lw.op_pre, lw.op_post_local), lw.op_weight)
    np.testing.assert_array_equal(d.weight.astype(np.int64), w_ref)
    # narrowest signed dtype holding every SUMMED entry
    lo, hi = int(w_ref.min()), int(w_ref.max())
    want = next(dt for dt in (np.int8, np.int16, np.int32)
                if np.iinfo(dt).min <= lo and hi <= np.iinfo(dt).max)
    assert d.dtype == np.dtype(want)


def test_pack_dense_size_guard(monkeypatch, rec_program):
    from repro.kernels import fused_step as fs
    monkeypatch.setattr(fs, "MAX_DENSE_BYTES", 16)
    lw = lower_tables(rec_program.graph, rec_program.tables)
    with pytest.raises(ValueError, match="kernel='lif'"):
        fs.pack_dense(lw)


def test_fused_step_packet_counts_count_all_senders():
    """Packets = every nonzero spike-plane entry (external ‖ internal)."""
    p = LIFIntParams(leak_shift=3, v_threshold=100, v_reset=0)
    s_all = np.array([[1, 0, 1, 0, 1], [0, 0, 0, 0, 0]], np.int32)
    v = np.zeros((2, 2), np.int32)
    w = np.zeros((5, 2), np.int8)
    _, _, pkt = fused_step(np.asarray(s_all), np.asarray(v),
                           np.asarray(w), p, interpret=True)
    np.testing.assert_array_equal(np.asarray(pkt), [3, 0])


# ---------------------------------------------------------------------------
# Hypothesis: random quantized nets stay bit-exact across tiers
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           n_inputs=st.integers(4, 24),
           n_internal=st.integers(4, 24),
           rate=st.floats(0.05, 0.9))
    def test_fused_bit_exact_random_quantized_nets(seed, n_inputs,
                                                   n_internal, rate):
        rng = np.random.default_rng(seed)
        n_syn = int(rng.integers(n_internal, 4 * (n_inputs + n_internal)))
        g = random_graph(n_inputs, n_internal, n_syn, seed=seed)
        tables = compile(g, make_hw(g), max_iters=2500).tables
        ext = make_ext(g, b=int(rng.integers(1, 5)),
                       t=int(rng.integers(2, 9)), rate=rate, seed=seed)
        s_f, v_f, st_f = JaxMappedEngine(
            g, tables, ExecutionSpec(kernel="fused")).run(ext)
        s_u, v_u, st_u = JaxMappedEngine(
            g, tables, ExecutionSpec(kernel="reference")).run(ext)
        assert s_f.tobytes() == s_u.tobytes()
        assert v_f.tobytes() == v_u.tobytes()
        assert st_f["packet_counts"].tobytes() == \
            st_u["packet_counts"].tobytes()


# ---------------------------------------------------------------------------
# Golden artifact through the fused tier
# ---------------------------------------------------------------------------

def test_golden_artifact_fused_tier_bit_exact():
    program = Program.load(GOLDEN / "tiny_program_v1.npz")
    with np.load(GOLDEN / "tiny_program_v1_io.npz") as io:
        s, v, stats = program.run(io["ext"], ExecutionSpec(kernel="fused"))
        np.testing.assert_array_equal(s, io["spikes"])
        np.testing.assert_array_equal(v, io["v_final"])
        np.testing.assert_array_equal(stats["packet_counts"],
                                      io["packet_counts"])
