"""Conformance/property tests for the serving subsystem (repro.serve).

Covers: (a) micro-batcher queue semantics — FIFO order per stream,
every request served exactly once, buckets always from the policy's
pow2 set, deterministic simulated-clock accounting (exact expected
latencies plus hypothesis properties); (b) overload semantics —
bounded queues, reject / drop-oldest / degrade shedding, dispatch
deadlines, and the bit-exact four-stage latency decomposition;
(c) sharded-vs-single-device bit-exactness over feedforward +
recurrent graphs and ragged batch sizes (1, D-1, D, 3D+1) — spikes,
potentials AND packet counts byte-identical; (d) registry semantics
(duplicate-name rejection, lazy per-model engine ownership, attached
policies); (e) the server's explicit shared / per-engine timeline
accounting; (f) the asyncio front-end (backpressure as exceptions,
real-clock stages); (g) the golden-artifact format pin; and (h) the
seeded serving example reporting identical p50/p99 twice.

Runs on single-device CPU and on the 8-virtual-device CI ``serving``
lane (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the
device count is read from jax, never assumed.
"""
import asyncio
import importlib.util
import json
import sys
import zipfile
from pathlib import Path

import jax
import numpy as np
import pytest

from conftest import make_ext, make_feedforward, make_hw
from repro.core import ExecutionSpec, Program, compile, random_graph
from repro.launch.mesh import make_serving_mesh
from repro.serve import (AsyncServer, BatchPolicy, DeadlineMissError,
                         MicroBatcher, ProgramRegistry, QueueFullError,
                         Request, SHED_DEADLINE, SHED_QUEUE_FULL, Server,
                         ShardedRunner, ShedError, linear_service_model)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # CI installs hypothesis; bare envs skip
    HAVE_HYPOTHESIS = False

GOLDEN = Path(__file__).parent / "golden"


def _recurrent(seed=3):
    g = random_graph(12, 20, 160, seed=seed)
    assert (g.pre >= g.n_inputs).any(), "graph must contain recurrence"
    return g


@pytest.fixture(scope="module")
def ff_program():
    g = make_feedforward()
    return compile(g, make_hw(g), max_iters=4000)


@pytest.fixture(scope="module")
def rec_program():
    g = _recurrent()
    return compile(g, make_hw(g), max_iters=4000)


def ragged_sizes() -> list[int]:
    """1, D-1, D, 3D+1 for the actual device count D (deduplicated)."""
    d = len(jax.devices())
    return sorted({1, max(1, d - 1), d, 3 * d + 1})


# ---------------------------------------------------------------------------
# BatchPolicy
# ---------------------------------------------------------------------------

def test_policy_default_buckets_are_pow2_capped():
    assert BatchPolicy(max_batch=8).buckets == (1, 2, 4, 8)
    # a non-power-of-two max is its own (largest) bucket
    assert BatchPolicy(max_batch=6).buckets == (1, 2, 4, 6)
    assert BatchPolicy(max_batch=1).buckets == (1,)


def test_policy_bucket_of_rounds_up():
    pol = BatchPolicy(max_batch=8)
    assert [pol.bucket_of(n) for n in range(1, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 8]
    with pytest.raises(ValueError):
        pol.bucket_of(9)
    with pytest.raises(ValueError):
        pol.bucket_of(0)


def test_policy_validation():
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=0)
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=4, max_wait_us=-1.0)
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=4, buckets=(2, 1, 4))       # not ascending
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=8, buckets=(1, 2, 4))       # can't hold 8
    assert BatchPolicy(max_batch=3, buckets=(1, 3)).bucket_of(2) == 3


# ---------------------------------------------------------------------------
# MicroBatcher: deterministic simulated-clock semantics (no engine)
# ---------------------------------------------------------------------------

ARR = np.array([0.0, 10.0, 20.0, 1000.0, 1001.0])
LINEAR = linear_service_model(100.0, 10.0)      # service(b) = 100 + 10 b


def test_batcher_drain_immediate_semantics():
    """max_wait=0: serve what has arrived; engine serially busy."""
    res = MicroBatcher(BatchPolicy(max_batch=2),
                       service_model=LINEAR).drain(ARR)
    # batch 1: only request 0 has arrived at t=0 -> bucket 1, done 110;
    # batch 2: requests 1+2 (both arrived by 110) -> bucket 2, done 230;
    # requests 3, 4 each alone (arrivals 1000, 1001 vs busy-until times)
    np.testing.assert_allclose(res.latencies_us,
                               [110.0, 220.0, 210.0, 110.0, 219.0])
    assert [(b.first, b.size, b.bucket) for b in res.batches] == \
        [(0, 1, 1), (1, 2, 2), (3, 1, 1), (4, 1, 1)]


def test_batcher_max_wait_holds_partial_batches():
    """A partial batch dispatches when the oldest waited max_wait_us."""
    res = MicroBatcher(BatchPolicy(max_batch=4, max_wait_us=50.0),
                       service_model=LINEAR).drain(ARR)
    # requests 0-2 arrive within the 50us window -> dispatch at 50,
    # bucket 4, done 190; requests 3-4 dispatch at 1000+50
    np.testing.assert_allclose(res.latencies_us,
                               [190.0, 180.0, 170.0, 170.0, 169.0])
    assert [(b.first, b.size, b.dispatch_us) for b in res.batches] == \
        [(0, 3, 50.0), (3, 2, 1050.0)]


def test_batcher_full_batch_dispatches_before_deadline():
    arr = np.array([0.0, 1.0, 2.0, 3.0])
    res = MicroBatcher(BatchPolicy(max_batch=4, max_wait_us=1000.0),
                       service_model=LINEAR).drain(arr)
    assert len(res.batches) == 1
    assert res.batches[0].dispatch_us == 3.0     # full at 4th arrival
    np.testing.assert_allclose(res.completion_us, 3.0 + 140.0)


def test_batcher_accounting_identity():
    res = MicroBatcher(BatchPolicy(max_batch=3, max_wait_us=25.0),
                       service_model=LINEAR).drain(ARR)
    np.testing.assert_allclose(res.completion_us - ARR, res.latencies_us)
    assert np.all(res.dispatch_us >= ARR)            # causal dispatch
    assert np.all(np.diff(res.completion_us) >= 0)   # FIFO completions
    sizes = [b.size for b in res.batches]
    assert sum(sizes) == len(ARR)                    # served exactly once
    assert res.metrics()["requests"] == len(ARR)


def test_batcher_input_validation():
    with pytest.raises(ValueError):                  # nothing to simulate
        MicroBatcher(BatchPolicy())
    b = MicroBatcher(BatchPolicy(), service_model=LINEAR)
    with pytest.raises(ValueError):                  # arrivals went back
        b.drain(np.array([0.0, 5.0, 4.0]))
    with pytest.raises(ValueError):                  # 2-D arrivals
        b.drain(np.zeros((2, 2)))
    with pytest.raises(ValueError):                  # runner, no requests
        MicroBatcher(BatchPolicy(), runner=lambda x: x,
                     service_model=LINEAR).drain(np.array([0.0]))


def test_batcher_empty_queue():
    res = MicroBatcher(BatchPolicy(), service_model=LINEAR).drain(
        np.array([], np.float64))
    assert res.n_requests == 0 and res.batches == []
    m = res.metrics()
    assert m["requests"] == 0 and m["batches"] == 0
    # the key set is schema-stable even with nothing served
    assert {"p50_ms", "p99_ms", "mean_ms", "throughput_rps",
            "buckets", "shed", "shed_frac", "stages_us"} <= set(m)


# ---------------------------------------------------------------------------
# Overload semantics: bounded queues, shedding, deadlines, degrade
# ---------------------------------------------------------------------------

def test_policy_overload_validation():
    with pytest.raises(ValueError):
        BatchPolicy(max_queue=-1)
    with pytest.raises(ValueError):
        BatchPolicy(deadline_us=-1.0)
    with pytest.raises(ValueError):
        BatchPolicy(shed="panic")
    # the long-form alias normalizes to the canonical name
    assert BatchPolicy(shed="degrade-to-smaller-bucket").shed == "degrade"
    assert BatchPolicy().shed == "reject"


def test_batcher_reject_sheds_arrivals():
    """shed='reject': an arrival finding the queue full is shed at its
    arrival time; everyone already queued is untouched."""
    pol = BatchPolicy(max_batch=1, max_queue=1, shed="reject")
    res = MicroBatcher(pol, service_model=LINEAR).drain(
        np.array([0.0, 10.0, 20.0, 30.0]))
    # r0 dispatches at 0 (engine busy to 110); r1 waits; r2, r3 find
    # the one waiting slot taken and are rejected on arrival
    np.testing.assert_array_equal(res.served, [True, True, False, False])
    np.testing.assert_array_equal(
        res.shed_reason, [0, 0, SHED_QUEUE_FULL, SHED_QUEUE_FULL])
    np.testing.assert_allclose(res.shed_time_us[2:], [20.0, 30.0])
    np.testing.assert_allclose(res.latencies_us[:2], [110.0, 210.0])
    assert np.isnan(res.latencies_us[2:]).all()
    assert np.isnan(res.completion_us[2:]).all()
    assert list(res.batch_index[2:]) == [-1, -1]
    assert res.metrics()["shed"] == {"queue_full": 2, "deadline": 0}
    assert res.metrics()["shed_frac"] == 0.5


def test_batcher_drop_oldest_shed_head():
    """shed='drop-oldest': the queue head is shed to admit the
    arrival, so the freshest requests survive overload."""
    pol = BatchPolicy(max_batch=1, max_queue=1, shed="drop-oldest")
    res = MicroBatcher(pol, service_model=LINEAR).drain(
        np.array([0.0, 10.0, 20.0, 30.0]))
    np.testing.assert_array_equal(res.served, [True, False, False, True])
    np.testing.assert_allclose(res.shed_time_us[1:3], [20.0, 30.0])
    # r3 dispatches when the engine frees at 110 -> latency 190
    np.testing.assert_allclose(res.latencies_us[[0, 3]], [110.0, 190.0])


def test_batcher_deadline_sheds_unreachable_requests():
    """A request still queued past arrival + deadline_us is shed with
    reason 'deadline' at its expiry time."""
    pol = BatchPolicy(max_batch=1, deadline_us=50.0)
    res = MicroBatcher(pol, service_model=LINEAR).drain(
        np.array([0.0, 10.0, 20.0]))
    # engine busy with r0 until 110; r1 expires at 60, r2 at 70
    np.testing.assert_array_equal(res.served, [True, False, False])
    np.testing.assert_array_equal(
        res.shed_reason, [0, SHED_DEADLINE, SHED_DEADLINE])
    np.testing.assert_allclose(res.shed_time_us[1:], [60.0, 70.0])
    assert res.metrics()["deadline_misses"] == 2


def test_batcher_deadline_aware_hold_window():
    """The batch hold window is clipped to the head's deadline: the
    partial batch dispatches exactly at the deadline and is served."""
    pol = BatchPolicy(max_batch=4, max_wait_us=100.0, deadline_us=40.0)
    res = MicroBatcher(pol, service_model=LINEAR).drain(
        np.array([0.0, 5.0]))
    assert len(res.batches) == 1
    assert res.batches[0].dispatch_us == 40.0     # deadline, not 100
    np.testing.assert_array_equal(res.served, [True, True])
    np.testing.assert_allclose(res.latencies_us, [160.0, 155.0])


def test_batcher_degrade_dispatches_exact_buckets():
    """shed='degrade' never sheds: over max_queue the batcher skips
    the hold window and serves the largest exact bucket (zero pad)."""
    pol = BatchPolicy(max_batch=8, max_queue=2, max_wait_us=1000.0,
                      shed="degrade")
    res = MicroBatcher(pol, service_model=LINEAR).drain(np.zeros(6))
    assert res.n_shed == 0
    # backlog 6 > 2: degraded dispatch of exactly 4 at t=0 (no pad);
    # backlog 2 <= 2: normal held dispatch at the 1000us horizon
    assert [(b.size, b.bucket, b.degraded, b.dispatch_us)
            for b in res.batches] == [(4, 4, True, 0.0),
                                      (2, 2, False, 1000.0)]
    assert np.all(res.pad_us == 0.0)              # exact buckets only
    assert res.metrics()["degraded_batches"] == 1


def test_stage_decomposition_sums_bit_exactly():
    """queue_wait + fill_wait + pad + compute == latencies_us, to the
    bit, served requests only; shed rows carry zero stages."""
    rng = np.random.default_rng(5)
    arr = np.cumsum(rng.exponential(30.0, 400))
    pol = BatchPolicy(max_batch=8, max_wait_us=40.0, max_queue=6,
                      deadline_us=900.0, shed="reject")
    res = MicroBatcher(pol, service_model=LINEAR).drain(arr)
    assert 0 < res.n_served < res.n_requests      # both populations
    s = res.served
    np.testing.assert_array_equal(res.stage_sum()[s], res.latencies_us[s])
    # the wall-clock identity holds to float rounding
    np.testing.assert_allclose(res.completion_us[s] - arr[s],
                               res.latencies_us[s])
    for stage in (res.queue_wait_us, res.fill_wait_us, res.pad_us,
                  res.compute_us):
        assert np.all(stage >= 0.0)
        assert np.all(stage[~s] == 0.0)
    m = res.metrics()
    assert set(m["stages_us"]) == {"queue_wait", "batch_fill", "pad",
                                   "compute"}
    assert sum(m["stages_us"].values()) == pytest.approx(
        res.latencies_us[s].mean())


def test_default_policy_has_no_overload_behavior():
    """max_queue=0 / deadline_us=0 reproduces the original unbounded
    queue bit-exactly: nothing shed, same pinned latencies."""
    res = MicroBatcher(BatchPolicy(max_batch=2),
                       service_model=LINEAR).drain(ARR)
    assert res.n_shed == 0 and np.all(res.served)
    np.testing.assert_allclose(res.latencies_us,
                               [110.0, 220.0, 210.0, 110.0, 219.0])


# ---------------------------------------------------------------------------
# MicroBatcher: hypothesis properties
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    policies = st.builds(
        BatchPolicy,
        max_batch=st.integers(min_value=1, max_value=16),
        max_wait_us=st.sampled_from([0.0, 30.0, 500.0]))
    arrival_gaps = st.lists(
        st.floats(min_value=0.0, max_value=800.0,
                  allow_nan=False, allow_infinity=False),
        min_size=1, max_size=64)

    @given(policies, arrival_gaps)
    @settings(max_examples=80, deadline=None)
    def test_property_served_exactly_once(policy, gaps):
        arr = np.cumsum(np.asarray(gaps))
        res = MicroBatcher(policy, service_model=LINEAR).drain(arr)
        # batches tile [0, N) contiguously: everything served once
        firsts = [b.first for b in res.batches]
        sizes = [b.size for b in res.batches]
        assert firsts[0] == 0 and sum(sizes) == len(arr)
        assert all(f + s == nf for f, s, nf
                   in zip(firsts, sizes, firsts[1:] + [len(arr)]))
        assert np.all(res.latencies_us > 0)

    @given(policies, arrival_gaps)
    @settings(max_examples=80, deadline=None)
    def test_property_buckets_always_in_policy_set(policy, gaps):
        arr = np.cumsum(np.asarray(gaps))
        res = MicroBatcher(policy, service_model=LINEAR).drain(arr)
        for b in res.batches:
            assert b.bucket in policy.buckets
            assert 1 <= b.size <= policy.max_batch <= max(policy.buckets)
            assert b.bucket >= b.size

    @given(policies, arrival_gaps,
           st.integers(min_value=1, max_value=5))
    @settings(max_examples=80, deadline=None)
    def test_property_fifo_preserved_per_stream(policy, gaps, n_streams):
        arr = np.cumsum(np.asarray(gaps))
        streams = np.arange(len(arr)) % n_streams   # interleaved clients
        res = MicroBatcher(policy, service_model=LINEAR).drain(arr)
        for s in range(n_streams):
            comp = res.completion_us[streams == s]
            assert np.all(np.diff(comp) >= 0)       # arrival order kept

    @given(policies, arrival_gaps)
    @settings(max_examples=80, deadline=None)
    def test_property_simulated_clock_monotone(policy, gaps):
        arr = np.cumsum(np.asarray(gaps))
        res = MicroBatcher(policy, service_model=LINEAR).drain(arr)
        # completions monotone in arrival order; dispatch causal and
        # serialized (engine busy until the previous batch finished)
        assert np.all(np.diff(res.completion_us) >= 0)
        assert np.all(res.dispatch_us >= arr)
        for prev, nxt in zip(res.batches, res.batches[1:]):
            assert nxt.dispatch_us >= prev.completion_us

    # overload policies: every shed mode, bounded queues, deadlines
    overload_policies = st.builds(
        BatchPolicy,
        max_batch=st.integers(min_value=1, max_value=8),
        max_wait_us=st.sampled_from([0.0, 30.0, 500.0]),
        max_queue=st.integers(min_value=0, max_value=4),
        deadline_us=st.sampled_from([0.0, 150.0, 2000.0]),
        shed=st.sampled_from(["reject", "drop-oldest", "degrade"]))

    @given(overload_policies, arrival_gaps)
    @settings(max_examples=100, deadline=None)
    def test_property_shed_requests_never_complete(policy, gaps):
        arr = np.cumsum(np.asarray(gaps))
        res = MicroBatcher(policy, service_model=LINEAR).drain(arr)
        assert res.n_served + res.n_shed == len(arr)
        shed = ~res.served
        # a shed request has no completion, no batch, a recorded
        # reason + time; a served one has all three and no reason
        assert np.isnan(res.completion_us[shed]).all()
        assert np.isnan(res.latencies_us[shed]).all()
        assert np.all(res.batch_index[shed] == -1)
        assert np.all(res.shed_reason[shed] != 0)
        assert not np.isnan(res.shed_time_us[shed]).any()
        assert not np.isnan(res.completion_us[res.served]).any()
        assert np.all(res.shed_reason[res.served] == 0)
        served_members = [r for b in res.batches for r in b.members]
        assert sorted(served_members) == \
            sorted(np.flatnonzero(res.served))
        if policy.shed == "degrade":    # degrade never sheds for
            assert res.shed_counts()["queue_full"] == 0   # queue-full

    @given(overload_policies, arrival_gaps)
    @settings(max_examples=100, deadline=None)
    def test_property_stage_sum_is_latency_bit_exact(policy, gaps):
        arr = np.cumsum(np.asarray(gaps))
        res = MicroBatcher(policy, service_model=LINEAR).drain(arr)
        s = res.served
        assert np.array_equal(res.stage_sum()[s], res.latencies_us[s])
        for stage in (res.queue_wait_us, res.fill_wait_us, res.pad_us,
                      res.compute_us):
            assert np.all(stage[~s] == 0.0) and np.all(stage >= 0.0)

    @given(overload_policies, arrival_gaps,
           st.integers(min_value=1, max_value=5))
    @settings(max_examples=100, deadline=None)
    def test_property_fifo_per_stream_survives_backpressure(
            policy, gaps, n_streams):
        arr = np.cumsum(np.asarray(gaps))
        streams = np.arange(len(arr)) % n_streams
        res = MicroBatcher(policy, service_model=LINEAR).drain(arr)
        for s in range(n_streams):
            comp = res.completion_us[(streams == s) & res.served]
            assert np.all(np.diff(comp) >= 0)   # survivors stay FIFO

    @given(st.lists(st.integers(min_value=0, max_value=800),
                    min_size=1, max_size=64),
           st.sampled_from([0.25, 0.5]),
           st.sampled_from([120.0, 400.0, 1500.0]))
    @settings(max_examples=100, deadline=None)
    def test_property_deadline_misses_monotone_in_offered_load(
            gaps, scale, deadline):
        """Compressing every inter-arrival gap (raising offered load)
        never decreases any request's queue wait — the Lindley
        recursion for the serial max_batch=1 queue — so the count of
        would-be deadline misses is monotone in offered load.
        Integer gaps + a power-of-two scale keep every simulated
        quantity exact in float64, so the comparison is bit-level."""
        arr = np.cumsum(np.asarray(gaps, np.float64))
        pol = BatchPolicy(max_batch=1)       # serial queue, no hold
        base = MicroBatcher(pol, service_model=LINEAR).drain(arr)
        loaded = MicroBatcher(pol, service_model=LINEAR).drain(
            arr * scale)
        assert np.all(loaded.queue_wait_us >= base.queue_wait_us)
        assert (loaded.queue_wait_us > deadline).sum() >= \
            (base.queue_wait_us > deadline).sum()
else:                                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_batcher_suite():
        pass


# ---------------------------------------------------------------------------
# MicroBatcher over the real engine: outputs bit-exact per request
# ---------------------------------------------------------------------------

def test_batcher_outputs_match_unbatched_runs(ff_program):
    g = ff_program.graph
    n = 10
    reqs = make_ext(g, n, 8, seed=2)
    arr = np.cumsum(np.full(n, 40.0))
    batcher = MicroBatcher(BatchPolicy(max_batch=4, max_wait_us=100.0),
                           runner=ff_program.run, service_model=LINEAR)
    res = batcher.drain(arr, reqs)
    assert res.outputs is not None
    spikes, v, pkts = res.outputs
    assert spikes.shape[0] == v.shape[0] == pkts.shape[0] == n
    for i in range(n):                   # padding never leaks into rows
        s1, v1, st1 = ff_program.run(reqs[i])
        assert spikes[i].tobytes() == s1.tobytes()
        assert v[i].tobytes() == v1.tobytes()
        np.testing.assert_array_equal(pkts[i], st1["packet_counts"])


def test_batcher_measured_mode_warms_buckets(ff_program):
    """service_model=None: real wall-clock service times, with one
    warm-up call per bucket so jit compile never lands in a latency."""
    g = ff_program.graph
    calls = []

    def runner(batch):
        calls.append(len(batch))
        return ff_program.run(batch)

    n = 5
    reqs = make_ext(g, n, 6, seed=9)
    arr = np.zeros(n)                    # all arrive at once
    res = MicroBatcher(BatchPolicy(max_batch=4),
                       runner=runner).drain(arr, reqs)
    # warm-up hit every bucket (1, 2, 4) before any timed batch
    assert calls[:3] == [1, 2, 4]
    assert np.all(res.latencies_us > 0)
    np.testing.assert_allclose(res.completion_us - arr, res.latencies_us)
    assert [b.service_us > 0 for b in res.batches] == [True, True]


def test_batcher_warm_cache_skips_repeat_drains(ff_program):
    """Warming is cached per (bucket, T, dtype): a second drain on the
    same shapes issues only real batch calls, no warm-up calls."""
    g = ff_program.graph
    calls = []

    def runner(batch):                   # plain function: no precompile
        calls.append(len(batch))         # hook, so warming is observable
        return ff_program.run(batch)

    batcher = MicroBatcher(BatchPolicy(max_batch=4), runner=runner)
    reqs = make_ext(g, 5, 6, seed=9)
    batcher.drain(np.zeros(5), reqs)
    # 3 warm calls (buckets 1, 2, 4) + 2 batch calls (sizes 4, 1)
    assert len(calls) == 5
    batcher.drain(np.zeros(5), reqs)     # same shapes: cache hit
    assert len(calls) == 7
    assert calls[5:] == [4, 1]           # batch dispatches only
    # a new T axis is a new compilation: warming runs again
    batcher.drain(np.zeros(5), make_ext(g, 5, 7, seed=9))
    assert calls[7:10] == [1, 2, 4]


# ---------------------------------------------------------------------------
# Sharded execution: bit-exact vs the single-device engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["feedforward", "recurrent"])
def test_sharded_bit_exact_ragged_batches(kind, ff_program, rec_program):
    program = ff_program if kind == "feedforward" else rec_program
    g = program.graph
    forced = ShardedRunner(program, min_shard=0)       # no fallback: every
    for b in ragged_sizes():                           # size pads-and-masks
        ext = make_ext(g, b, 12, seed=b)
        s1, v1, st1 = program.run(ext)                 # single-device jax
        for s2, v2, st2 in (program.run(ext, ExecutionSpec(mesh="auto")),
                            forced.run(ext)):
            assert s2.tobytes() == s1.tobytes(), f"spikes differ at B={b}"
            assert v2.tobytes() == v1.tobytes(), f"v_final differs at B={b}"
            assert st2["packet_counts"].tobytes() == \
                st1["packet_counts"].tobytes(), f"packets differ at B={b}"
            assert st2["mean_packets_per_step"] == \
                st1["mean_packets_per_step"]


def test_sharded_unbatched_input_squeezes(rec_program):
    g = rec_program.graph
    ext = make_ext(g, 1, 9, seed=1)[0]                 # [T, n_in]
    s1, v1, st1 = rec_program.run(ext)
    s2, v2, st2 = rec_program.run(ext, ExecutionSpec(mesh="auto"))
    assert s2.shape == s1.shape and v2.shape == v1.shape
    assert s2.tobytes() == s1.tobytes()
    np.testing.assert_array_equal(st2["packet_counts"],
                                  st1["packet_counts"])


def test_sharded_runner_owned_and_cached(rec_program):
    r1 = rec_program.sharded_runner()
    assert rec_program.sharded_runner() is r1          # cached like engines
    mesh = make_serving_mesh()
    assert rec_program.sharded_runner(mesh) is \
        rec_program.sharded_runner(mesh)
    assert r1.n_shards == int(mesh.shape["data"])
    assert r1.padded_size(1) == r1.n_shards            # pad-and-mask rule
    assert r1.padded_size(3 * r1.n_shards + 1) == 4 * r1.n_shards


def test_sharded_rejects_bad_requests(rec_program):
    with pytest.raises(ValueError, match="mesh= shards the jax"):
        ExecutionSpec(engine="python", mesh="auto")
    # the deprecated kwargs shim keeps its exact historical error
    with pytest.deprecated_call(), \
            pytest.raises(ValueError, match="sharded=True runs the jax"):
        rec_program.run(make_ext(rec_program.graph, 1, 4), sharded=True,
                        engine="python")
    with pytest.raises(ValueError, match="lack 'data'"):
        ShardedRunner(rec_program, jax.make_mesh((1,), ("model",)))
    with pytest.raises(ValueError, match="ext_spikes shape"):
        rec_program.sharded_runner().run(np.zeros((4, 5), np.int32))


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------

def test_registry_rejects_duplicate_names(ff_program, rec_program):
    reg = ProgramRegistry()
    reg.register("m", ff_program)
    with pytest.raises(ValueError, match="already registered"):
        reg.register("m", rec_program)
    with pytest.raises(ValueError):
        reg.register("", ff_program)
    assert reg.names() == ("m",) and "m" in reg and len(reg) == 1


def test_registry_lookup_and_unregister(ff_program):
    reg = ProgramRegistry()
    with pytest.raises(KeyError, match="not registered"):
        reg.get("missing")
    reg.register("m", ff_program)
    assert reg.get("m") is ff_program
    assert reg.unregister("m") is ff_program
    with pytest.raises(KeyError):
        reg.unregister("m")
    reg.register("m", ff_program)                      # re-register ok


def test_registry_engine_ownership_per_model(ff_program, rec_program):
    reg = ProgramRegistry()
    reg.register("a", ff_program)
    reg.register("b", rec_program)
    # engines are lazy, owned by each Program, reused across lookups
    assert reg.get("a").engine() is reg.get("a").engine()
    assert reg.get("a").engine() is not reg.get("b").engine()
    sharded_spec = ExecutionSpec(mesh="auto")
    assert reg.runner("a", sharded_spec).__self__ is \
        reg.runner("a", sharded_spec).__self__         # one ShardedRunner
    ext = make_ext(ff_program.graph, 2, 6, seed=0)
    s1, _, _ = reg.runner("a")(ext)
    s2, _, _ = ff_program.run(ext)
    np.testing.assert_array_equal(s1, s2)


def test_registry_load_from_artifact(ff_program, tmp_path):
    path = ff_program.save(tmp_path / "m.npz")
    reg = ProgramRegistry()
    p = reg.load("m", path)
    assert p.ot_depth == ff_program.ot_depth
    ext = make_ext(ff_program.graph, 2, 6, seed=3)
    np.testing.assert_array_equal(p.run(ext)[0], ff_program.run(ext)[0])


# ---------------------------------------------------------------------------
# Server loop
# ---------------------------------------------------------------------------

def _stream(ff_program, rec_program, seed=4, n=12):
    rng = np.random.default_rng(seed)
    stream, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(150.0))
        name = "ff" if i % 3 else "rec"
        g = (ff_program if name == "ff" else rec_program).graph
        ext = (rng.random((8, g.n_inputs)) < 0.3).astype(np.int32)
        stream.append(Request(name, ext, t, stream=i % 2))
    return stream


def test_server_metrics_dict(ff_program, rec_program):
    reg = ProgramRegistry()
    reg.register("ff", ff_program)
    reg.register("rec", rec_program)
    srv = Server(reg, policy=BatchPolicy(max_batch=4, max_wait_us=60.0),
                 service_model=LINEAR)
    metrics = srv.serve(_stream(ff_program, rec_program))
    assert set(metrics) == {"models", "total"}
    assert set(metrics["models"]) == {"ff", "rec"}
    for m in metrics["models"].values():
        assert {"p50_ms", "p99_ms", "throughput_rps",
                "buckets"} <= set(m)
        assert all(b in (1, 2, 4) for b in m["buckets"])
    assert metrics["total"]["requests"] == 12
    assert metrics["total"]["models"] == 2
    # deterministic: same stream, same metrics (simulated clock)
    assert srv.serve(_stream(ff_program, rec_program)) == metrics


def test_server_rejects_unknown_model(ff_program):
    reg = ProgramRegistry()
    reg.register("ff", ff_program)
    srv = Server(reg, service_model=LINEAR)
    bad = [Request("nope", np.zeros((4, 16), np.int32), 0.0)]
    with pytest.raises(KeyError, match="nope"):
        srv.serve(bad)


def test_server_per_model_policy_override(ff_program, rec_program):
    reg = ProgramRegistry()
    reg.register("ff", ff_program)
    reg.register("rec", rec_program)
    srv = Server(reg, policy=BatchPolicy(max_batch=4, max_wait_us=1e6),
                 policies={"rec": BatchPolicy(max_batch=1)},
                 service_model=LINEAR)
    metrics = srv.serve(_stream(ff_program, rec_program))
    assert set(metrics["models"]["rec"]["buckets"]) == {1}   # no batching
    assert max(metrics["models"]["ff"]["buckets"]) > 1       # held + batched


def test_server_two_model_shared_timeline_regression(ff_program,
                                                     rec_program):
    """Totals regression: two models, one request each at t=0, on ONE
    engine. The pre-timeline server reported both models completing at
    110us as if they ran concurrently; on the shared timeline the
    second dispatch waits for the first, so the corrected span is
    220us and throughput exactly halves."""
    reg = ProgramRegistry()
    reg.register("ff", ff_program)
    reg.register("rec", rec_program)
    mk = lambda name, p: Request(
        name, np.zeros((8, p.graph.n_inputs), np.int32), 0.0)
    stream = [mk("ff", ff_program), mk("rec", rec_program)]

    shared = Server(reg, policy=BatchPolicy(max_batch=1),
                    service_model=LINEAR).serve(stream)
    t = shared["total"]
    assert t["timeline"] == "shared" and t["requests"] == 2
    # queue order is sorted model names: ff at [0, 110], rec [110, 220]
    assert t["p50_ms"] == pytest.approx(0.165)          # (110+220)/2 us
    assert t["throughput_rps"] == pytest.approx(2 / 220e-6)

    per = Server(reg, policy=BatchPolicy(max_batch=1),
                 service_model=LINEAR,
                 timeline="per-engine").serve(stream)
    # dedicated engines: both complete at 110us, double the throughput
    assert per["total"]["timeline"] == "per-engine"
    assert per["total"]["p50_ms"] == pytest.approx(0.110)
    assert per["total"]["throughput_rps"] == pytest.approx(2 / 110e-6)

    with pytest.raises(ValueError, match="timeline"):
        Server(reg, timeline="concurrent-ish", service_model=LINEAR)


def test_server_shared_timeline_interleaves_engine(ff_program,
                                                   rec_program):
    """Per-model completions on the shared timeline reflect the one
    serially-busy engine, not per-model clocks from zero."""
    reg = ProgramRegistry()
    reg.register("a", ff_program)
    reg.register("b", rec_program)
    ext = {n: np.zeros((8, p.graph.n_inputs), np.int32)
           for n, p in (("a", ff_program), ("b", rec_program))}
    srv = Server(reg, policy=BatchPolicy(max_batch=1),
                 service_model=LINEAR)
    srv.serve([Request("a", ext["a"], 0.0), Request("b", ext["b"], 0.0)])
    np.testing.assert_allclose(
        srv.last_results["a"].completion_us, [110.0])
    np.testing.assert_allclose(
        srv.last_results["b"].completion_us, [220.0])


def test_server_ragged_shapes_raise_named_valueerror(ff_program):
    reg = ProgramRegistry()
    reg.register("ff", ff_program)
    srv = Server(reg, service_model=LINEAR)
    n_in = ff_program.graph.n_inputs
    good = Request("ff", np.zeros((8, n_in), np.int32), 0.0, stream=0)
    ragged = Request("ff", np.zeros((9, n_in), np.int32), 1.0, stream=3)
    with pytest.raises(ValueError, match=r"request #1 for model 'ff' "
                                         r"\(stream 3\)"):
        srv.serve([good, ragged])
    flat = Request("ff", np.zeros(n_in, np.int32), 0.0, stream=1)
    with pytest.raises(ValueError, match="2-D"):
        srv.serve([flat])


def test_server_resolves_registry_attached_policy(ff_program):
    reg = ProgramRegistry()
    reg.register("ff", ff_program, policy=BatchPolicy(max_batch=1))
    assert reg.policy("ff").max_batch == 1
    with pytest.raises(KeyError):
        reg.policy("missing")
    srv = Server(reg, policy=BatchPolicy(max_batch=8),
                 service_model=LINEAR)
    assert srv.policy_for("ff").max_batch == 1     # registry wins default
    srv2 = Server(reg, policies={"ff": BatchPolicy(max_batch=4)},
                  service_model=LINEAR)
    assert srv2.policy_for("ff").max_batch == 4    # explicit wins registry
    reg.unregister("ff")
    reg.register("ff", ff_program)                 # policy was dropped too
    assert reg.policy("ff") is None
    assert srv.policy_for("ff").max_batch == 8     # falls back to default


def test_server_metrics_carry_shed_and_stage_accounting(ff_program):
    reg = ProgramRegistry()
    reg.register("ff", ff_program)
    n_in = ff_program.graph.n_inputs
    stream = [Request("ff", np.zeros((8, n_in), np.int32), 10.0 * i)
              for i in range(4)]
    srv = Server(reg, policy=BatchPolicy(max_batch=1, max_queue=1,
                                         shed="reject"),
                 service_model=LINEAR)
    m = srv.serve(stream)
    assert m["models"]["ff"]["shed"] == {"queue_full": 2, "deadline": 0}
    assert m["total"]["shed"] == {"queue_full": 2, "deadline": 0}
    assert m["total"]["shed_frac"] == 0.5
    assert m["total"]["deadline_misses"] == 0
    assert set(m["total"]["stages_us"]) == {"queue_wait", "batch_fill",
                                            "pad", "compute"}
    res = srv.last_results["ff"]
    s = res.served
    np.testing.assert_array_equal(res.stage_sum()[s],
                                  res.latencies_us[s])


# ---------------------------------------------------------------------------
# AsyncServer: real-clock backpressure as exceptions
# ---------------------------------------------------------------------------

SLOW_50MS = linear_service_model(50_000.0, 0.0)


async def _eventually(pred, timeout=5.0):
    """Poll until ``pred()`` — bounds timing races without sleeps
    tuned to scheduler luck."""
    loop = asyncio.get_running_loop()
    end = loop.time() + timeout
    while not pred():
        if loop.time() > end:
            raise AssertionError("condition not reached in time")
        await asyncio.sleep(0.005)


def _async_registry(program):
    reg = ProgramRegistry()
    reg.register("m", program)
    return reg


def _req(program, seed=0):
    g = program.graph
    rng = np.random.default_rng(seed)
    return Request("m", (rng.random((6, g.n_inputs)) < 0.3)
                   .astype(np.int32), 0.0, stream=seed)


def test_async_server_serves_with_bit_exact_stages(ff_program):
    async def main():
        srv = AsyncServer(
            _async_registry(ff_program),
            policy=BatchPolicy(max_batch=4, max_wait_us=3000.0),
            service_model=linear_service_model(2000.0, 100.0))
        async with srv:
            done = await asyncio.gather(
                *[srv.submit(_req(ff_program, i)) for i in range(8)])
        for c in done:
            total = ((c.queue_wait_us + c.fill_wait_us)
                     + c.pad_us) + c.compute_us
            assert total == c.latency_us            # bit-exact, real clock
            assert c.model == "m" and c.bucket in (1, 2, 4)
            assert 1 <= c.batch_size <= 4 and not c.degraded
        assert sorted(c.stream for c in done) == list(range(8))
        m = srv.metrics()
        assert m["total"]["requests"] == 8
        assert m["total"]["timeline"] == "real"
        assert m["total"]["shed"] == {"queue_full": 0, "deadline": 0}
        assert set(m["total"]["stages_us"]) == {"queue_wait", "batch_fill",
                                                "pad", "compute"}
    asyncio.run(main())


def test_async_server_lifecycle_and_unknown_model(ff_program):
    async def main():
        srv = AsyncServer(_async_registry(ff_program),
                          service_model=SLOW_50MS)
        with pytest.raises(RuntimeError, match="not started"):
            await srv.submit(_req(ff_program))
        async with srv:
            with pytest.raises(KeyError, match="nope"):
                await srv.submit(Request("nope", np.zeros((4, 2),
                                                          np.int32), 0.0))
            with pytest.raises(RuntimeError, match="already started"):
                await srv.start()
    asyncio.run(main())


def test_async_server_reject_backpressure(ff_program):
    async def main():
        srv = AsyncServer(
            _async_registry(ff_program),
            policy=BatchPolicy(max_batch=1, max_queue=1, shed="reject"),
            service_model=SLOW_50MS)
        async with srv:
            t1 = asyncio.create_task(srv.submit(_req(ff_program, 1)))
            await _eventually(lambda: srv._dequeued["m"] == 1)
            t2 = asyncio.create_task(srv.submit(_req(ff_program, 2)))
            await _eventually(lambda: len(srv._queues["m"]) == 1)
            with pytest.raises(QueueFullError, match="queue full"):
                await srv.submit(_req(ff_program, 3))
            done = await asyncio.gather(t1, t2)
        assert [c.stream for c in done] == [1, 2]   # FIFO survivors
        m = srv.metrics()
        assert m["total"]["shed"] == {"queue_full": 1, "deadline": 0}
        assert m["total"]["shed_frac"] == pytest.approx(1 / 3)
    asyncio.run(main())


def test_async_server_drop_oldest_fails_the_old_await(ff_program):
    async def main():
        srv = AsyncServer(
            _async_registry(ff_program),
            policy=BatchPolicy(max_batch=1, max_queue=1,
                               shed="drop-oldest"),
            service_model=SLOW_50MS)
        async with srv:
            t1 = asyncio.create_task(srv.submit(_req(ff_program, 1)))
            await _eventually(lambda: srv._dequeued["m"] == 1)
            t2 = asyncio.create_task(srv.submit(_req(ff_program, 2)))
            await _eventually(lambda: len(srv._queues["m"]) == 1)
            t3 = asyncio.create_task(srv.submit(_req(ff_program, 3)))
            r1, r2, r3 = await asyncio.gather(t1, t2, t3,
                                              return_exceptions=True)
        assert r1.stream == 1 and r3.stream == 3    # newest survived
        assert isinstance(r2, QueueFullError)       # oldest was shed
        assert "drop-oldest" in str(r2)
    asyncio.run(main())


def test_async_server_deadline_miss_raises(ff_program):
    async def main():
        srv = AsyncServer(
            _async_registry(ff_program),
            policy=BatchPolicy(max_batch=1, deadline_us=10_000.0),
            service_model=linear_service_model(60_000.0, 0.0))
        async with srv:
            t1 = asyncio.create_task(srv.submit(_req(ff_program, 1)))
            await _eventually(lambda: srv._dequeued["m"] == 1)
            t2 = asyncio.create_task(srv.submit(_req(ff_program, 2)))
            r1, r2 = await asyncio.gather(t1, t2, return_exceptions=True)
        assert r1.stream == 1
        assert isinstance(r2, DeadlineMissError)
        assert srv.metrics()["total"]["deadline_misses"] == 1
    asyncio.run(main())


def test_async_server_stop_without_drain_sheds_pending(ff_program):
    async def main():
        srv = AsyncServer(
            _async_registry(ff_program),
            policy=BatchPolicy(max_batch=1),
            service_model=SLOW_50MS)
        await srv.start()
        t1 = asyncio.create_task(srv.submit(_req(ff_program, 1)))
        await _eventually(lambda: srv._dequeued["m"] == 1)
        t2 = asyncio.create_task(srv.submit(_req(ff_program, 2)))
        await _eventually(lambda: len(srv._queues["m"]) == 1)
        await srv.stop(drain=False)
        r1, r2 = await asyncio.gather(t1, t2, return_exceptions=True)
        assert r1.stream == 1                       # in flight: finished
        assert isinstance(r2, ShedError)            # queued: shed
        assert not isinstance(r2, (QueueFullError, DeadlineMissError))
    asyncio.run(main())


def test_async_server_engine_mode_outputs_bit_exact(ff_program):
    async def main():
        srv = AsyncServer(_async_registry(ff_program),
                          policy=BatchPolicy(max_batch=2,
                                             max_wait_us=5000.0))
        reqs = [_req(ff_program, i) for i in range(3)]
        async with srv:
            done = await asyncio.gather(*[srv.submit(r) for r in reqs])
        by_stream = {c.stream: c for c in done}
        for i, r in enumerate(reqs):
            c = by_stream[i]
            s1, v1, st1 = ff_program.run(r.ext)
            assert c.outputs[0].tobytes() == s1.tobytes()
            assert c.outputs[1].tobytes() == v1.tobytes()
            np.testing.assert_array_equal(c.outputs[2],
                                          st1["packet_counts"])
    asyncio.run(main())


# ---------------------------------------------------------------------------
# Golden artifact: the save/load format pin
# ---------------------------------------------------------------------------

def test_golden_artifact_loads_and_runs_bit_exact():
    program = Program.load(GOLDEN / "tiny_program_v1.npz")
    assert program.feasible
    with np.load(GOLDEN / "tiny_program_v1_io.npz") as io:
        for engine in ("python", "jax", "oracle"):
            s, v, stats = program.run(io["ext"], engine)
            np.testing.assert_array_equal(s, io["spikes"], err_msg=engine)
            np.testing.assert_array_equal(v, io["v_final"], err_msg=engine)
            np.testing.assert_array_equal(stats["packet_counts"],
                                          io["packet_counts"],
                                          err_msg=engine)


def test_golden_artifact_roundtrips_byte_exact(tmp_path):
    program = Program.load(GOLDEN / "tiny_program_v1.npz")
    resaved = program.save(tmp_path / "resaved.npz")
    with np.load(GOLDEN / "tiny_program_v1.npz") as a, \
            np.load(resaved) as b:
        assert set(a.files) == set(b.files)
        assert json.loads(str(a["header"][()])) == \
            json.loads(str(b["header"][()]))
        for k in a.files:
            if k != "header":
                assert a[k].tobytes() == b[k].tobytes(), k
                assert a[k].dtype == b[k].dtype, k


def _rewrite_header(src: Path, dst: Path, mutate) -> Path:
    """Copy an artifact npz with a mutated JSON header."""
    with np.load(src) as z:
        arrays = {k: z[k] for k in z.files}
    header = json.loads(str(arrays["header"][()]))
    mutate(header)
    arrays["header"] = np.asarray(json.dumps(header))
    np.savez_compressed(dst, **arrays)
    return dst


def test_golden_artifact_wrong_version_rejected(tmp_path):
    bad = _rewrite_header(
        GOLDEN / "tiny_program_v1.npz", tmp_path / "bad_version.npz",
        lambda h: h.update(version=h["version"] + 1))
    with pytest.raises(ValueError, match="version"):
        Program.load(bad)
    worse = _rewrite_header(
        GOLDEN / "tiny_program_v1.npz", tmp_path / "bad_format.npz",
        lambda h: h.update(format="not-a-program"))
    with pytest.raises(ValueError, match="format"):
        Program.load(worse)
    # not-an-artifact npz
    np.savez_compressed(tmp_path / "junk.npz", x=np.arange(3))
    with pytest.raises(ValueError, match="artifact"):
        Program.load(tmp_path / "junk.npz")
    with zipfile.ZipFile(GOLDEN / "tiny_program_v1.npz") as z:
        assert "header.npy" in z.namelist()            # format layout pin


# ---------------------------------------------------------------------------
# Example seeding: two runs, identical p50/p99
# ---------------------------------------------------------------------------

def _load_example():
    path = Path(__file__).parent.parent / "examples" / "serve_snn.py"
    spec = importlib.util.spec_from_file_location("serve_snn_example", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def test_example_seed_determinism(tmp_path):
    mod = _load_example()
    argv = ["--artifact", str(tmp_path / "demo.npz"),
            "--requests", "24", "--timesteps", "8", "--seed", "7"]
    m1 = mod.main(argv)
    m2 = mod.main(argv)                 # artifact reloaded, not recompiled
    assert m1["p50_ms"] == m2["p50_ms"]
    assert m1["p99_ms"] == m2["p99_ms"]
    assert m1["buckets"] == m2["buckets"]
    m3 = mod.main(argv[:-1] + ["8"])    # different seed, different stream
    assert (m3["p50_ms"], m3["p99_ms"]) != (m1["p50_ms"], m1["p99_ms"])
