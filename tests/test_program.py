"""Tests for the `Program` artifact API (repro.core.program).

Covers: the compile() pass pipeline, uniform run() shapes across the
three engines, save/load bit-exact round-trips WITHOUT re-partitioning,
format-version rejection, init-packet determinism, owned-engine caching,
profile(), and the deprecated wrappers' delegation.
"""
import json

import numpy as np
import pytest

from conftest import make_ext, make_feedforward, make_hw
from repro.core import (ENGINES, CycleModel, ExecutionSpec, Program, compile,
                        compile_snn, random_graph, run_mapped, run_oracle)
from repro.kernels.ops import _default_interpret

_hw, _feedforward, _ext = make_hw, make_feedforward, make_ext


def _recurrent(seed=3):
    g = random_graph(12, 20, 160, seed=seed)
    assert (g.pre >= g.n_inputs).any(), "graph must contain recurrence"
    return g


@pytest.fixture(scope="module")
def recurrent_program():
    g = _recurrent()
    return compile(g, _hw(g), max_iters=4000)


# ---------------------------------------------------------------------------
# compile() and the artifact's parts.
# ---------------------------------------------------------------------------

def test_compile_owns_all_parts(recurrent_program):
    p = recurrent_program
    assert p.feasible and p.report.feasible
    assert p.ot_depth == p.tables.depth == p.report.ot_depth
    assert p.lowered.n_ops == p.graph.n_synapses
    assert p.part.assign.shape == (p.graph.n_synapses,)
    assert len(p.init_packets()) == p.report.n_init_packets


def test_compile_matches_deprecated_wrapper():
    g = _recurrent(seed=21)
    p = compile(g, _hw(g), seed=4, max_iters=4000)
    with pytest.deprecated_call():
        tables, report, part = compile_snn(g, _hw(g), seed=4,
                                           max_iters=4000)
    np.testing.assert_array_equal(p.tables.pre, tables.pre)
    np.testing.assert_array_equal(p.tables.weight, tables.weight)
    np.testing.assert_array_equal(p.part.assign, part.assign)
    assert p.report.ot_depth == report.ot_depth


def test_compile_rejects_unknown_engine_and_method():
    g = _recurrent(seed=23)
    with pytest.raises(ValueError, match="engine"):
        compile(g, _hw(g), engine="verilog")
    with pytest.raises(ValueError, match="method"):
        compile(g, _hw(g), method="astrology")


# ---------------------------------------------------------------------------
# Uniform run() surface.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_run_uniform_shapes_and_bits(recurrent_program, engine):
    p = recurrent_program
    ext_b = _ext(p.graph, b=3, t=7, seed=1)
    s, v, st = p.run(ext_b, engine)
    assert s.shape == (3, 7, p.graph.n_internal)
    assert v.shape == (3, p.graph.n_internal)
    assert st["packet_counts"].shape == (3, 7)
    s1, v1, st1 = p.run(ext_b[0], engine)           # unbatched
    assert s1.shape == (7, p.graph.n_internal)
    assert st1["packet_counts"].shape == (7,)
    np.testing.assert_array_equal(s1, s[0])
    np.testing.assert_array_equal(v1, v[0])
    # every engine bit-exact vs the dense oracle, incl. packet counts
    for b in range(3):
        s_ref, v_ref = run_oracle(p.graph, ext_b[b])
        np.testing.assert_array_equal(s[b], s_ref)
        np.testing.assert_array_equal(v[b], v_ref)
        _, _, ref = run_mapped(p.graph, p.tables, ext_b[b])
        np.testing.assert_array_equal(st["packet_counts"][b],
                                      ref["packet_counts"])


def test_run_rejects_bad_engine_and_shape(recurrent_program):
    p = recurrent_program
    with pytest.raises(ValueError, match="engine"):
        p.run(_ext(p.graph, 1, 4), "fpga")
    with pytest.raises(ValueError, match="shape"):
        p.run(np.zeros((4, p.graph.n_inputs + 1), np.int32))


# ---------------------------------------------------------------------------
# save() / load() round trip.
# ---------------------------------------------------------------------------

def _no_repartition(monkeypatch):
    import importlib
    import repro.core.passes as passes_mod
    # the package re-exports the `partition` FUNCTION, shadowing the
    # submodule attribute — resolve the modules via importlib
    part_mod = importlib.import_module("repro.core.partition")
    search_mod = importlib.import_module("repro.core.mapping.search")

    def boom(*a, **kw):
        raise AssertionError("partitioner must not run on load")
    monkeypatch.setattr(part_mod, "partition", boom)
    monkeypatch.setattr(search_mod, "framework_partition", boom)
    monkeypatch.setattr(search_mod, "_Population", boom)
    monkeypatch.setattr(passes_mod, "partition_pass", boom)
    monkeypatch.setattr(passes_mod, "search_pass", boom)


@pytest.mark.parametrize("kind", ["feedforward", "recurrent"])
def test_save_load_bit_exact_no_repartition(tmp_path, monkeypatch, kind):
    g = _feedforward() if kind == "feedforward" else _recurrent()
    p = compile(g, _hw(g), max_iters=4000)
    path = p.save(tmp_path / f"{kind}.npz")
    assert path.exists()

    _no_repartition(monkeypatch)
    p2 = Program.load(path)
    for f in ("pre", "post", "weight", "pre_end", "post_end", "assign"):
        np.testing.assert_array_equal(getattr(p2.tables, f),
                                      getattr(p.tables, f))
    assert p2.tables.send_slot == p.tables.send_slot
    assert p2.tables.send_order == p.tables.send_order
    np.testing.assert_array_equal(p2.part.assign, p.part.assign)
    assert p2.hw == p.hw

    ext = _ext(g, b=3, t=9, seed=2)
    s, v, st = p2.run(ext, "jax")
    for b in range(3):
        s_ref, v_ref = run_oracle(g, ext[b])
        np.testing.assert_array_equal(s[b], s_ref)
        np.testing.assert_array_equal(v[b], v_ref)
        _, _, ref = run_mapped(g, p.tables, ext[b])
        np.testing.assert_array_equal(st["packet_counts"][b],
                                      ref["packet_counts"])


def test_save_appends_npz_suffix(tmp_path, recurrent_program):
    path = recurrent_program.save(tmp_path / "artifact")
    assert path.name == "artifact.npz" and path.exists()


def test_load_rejects_version_mismatch(tmp_path, recurrent_program):
    path = recurrent_program.save(tmp_path / "versioned.npz")
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    header = json.loads(str(arrays["header"][()]))
    header["version"] += 1
    arrays["header"] = np.asarray(json.dumps(header))
    np.savez(tmp_path / "future.npz", **arrays)
    with pytest.raises(ValueError, match="version"):
        Program.load(tmp_path / "future.npz")


def test_load_rejects_foreign_npz(tmp_path):
    np.savez(tmp_path / "foreign.npz", weights=np.zeros(3))
    with pytest.raises(ValueError, match="artifact"):
        Program.load(tmp_path / "foreign.npz")


def test_init_packets_deterministic_across_save_load(tmp_path,
                                                     recurrent_program):
    p = recurrent_program
    p2 = Program.load(p.save(tmp_path / "pkts.npz"))
    pkts, pkts2 = p.init_packets(), p2.init_packets()
    assert pkts == pkts2
    assert len(pkts2) == p2.report.n_init_packets == p.report.n_init_packets


# ---------------------------------------------------------------------------
# profile().
# ---------------------------------------------------------------------------

def test_profile_matches_cycle_model(recurrent_program):
    p = recurrent_program
    ext = _ext(p.graph, b=2, t=8, seed=3)
    _, _, st = p.run(ext, "python")
    prof = p.profile(st)
    assert len(prof.per_sample) == 2
    cm = CycleModel(p.hw)
    for b in range(2):
        ref = cm.run(st["packet_counts"][b], p.tables.depth,
                     p.graph.n_synapses)
        assert prof.per_sample[b] == ref
    assert prof.latency_us == pytest.approx(
        np.mean([r.latency_us for r in prof.per_sample]))
    assert prof.resources == p.report.resources
    # unbatched stats -> aggregate IS the single sample
    _, _, st1 = p.run(ext[0], "python")
    prof1 = p.profile(st1)
    assert prof1.cycle == prof1.per_sample[0]
    # n_synapses override changes only the per-synapse denominator
    prof_q = p.profile(st1, n_synapses=2 * p.graph.n_synapses)
    assert prof_q.energy_per_synapse_nj == pytest.approx(
        prof1.energy_per_synapse_nj / 2)


# ---------------------------------------------------------------------------
# Owned engines.
# ---------------------------------------------------------------------------

def test_engines_are_owned_and_keyed_on_resolved_spec(recurrent_program):
    p = recurrent_program
    assert p.engine() is p.engine()
    # unset fields resolve to platform defaults before keying, so every
    # spelling of the default spec maps to the same engine instance
    assert p.engine() is p.engine(ExecutionSpec())
    assert p.engine() is p.engine(
        ExecutionSpec(kernel="fused", interpret=_default_interpret()))
    assert p.engine(ExecutionSpec(kernel="reference")) is not p.engine()
    # legacy kwargs still reach the same cache, through a warning shim
    with pytest.deprecated_call():
        legacy = p.engine(nu_kernel=True)
    assert legacy is p.engine(ExecutionSpec(kernel="lif"))
    # no module-level cache left behind
    from repro.core import engine_jax
    assert not hasattr(engine_jax, "_ENGINE_CACHE")
