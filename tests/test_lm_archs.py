"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates at REDUCED scale, runs forward/train/prefill/decode on
CPU, and produces finite outputs of the right shape. The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.models import model as M


def _tokens(cfg, b, s, key):
    shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


def _positions(cfg, b, s):
    if cfg.mrope_sections:
        return jnp.broadcast_to(jnp.arange(s), (3, b, s))
    return None


@pytest.mark.slow
@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_forward_and_train_step(name):
    cfg = get_reduced(name)
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    B, S = 2, 16
    toks = _tokens(cfg, B, S, key)
    pos = _positions(cfg, B, S)

    logits, aux = M.full_logits(params, cfg, toks, positions=pos)
    want = (B, S, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks \
        else (B, S, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    batch = {"tokens": toks, "labels": toks}
    if pos is not None:
        batch["positions"] = pos
    loss, metrics = M.loss_fn(params, cfg, batch, loss_chunk=8)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch, loss_chunk=8)[0])(
        params)
    gsum = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0.0


@pytest.mark.slow
@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_prefill_decode_consistency(name):
    """prefill(tokens[:N]) + step-by-step decode of the rest must agree
    with the full teacher-forced forward — the KV/recurrent caches carry
    exactly the information the parallel path uses."""
    cfg = get_reduced(name)
    key = jax.random.PRNGKey(1)
    params = M.init_model(cfg, key)
    B, S, NP = 2, 12, 8
    toks = _tokens(cfg, B, S, key)
    pos = _positions(cfg, B, S)

    full, _ = M.full_logits(params, cfg, toks, positions=pos)

    ppos = pos[:, :, :NP] if pos is not None else None
    lg, st = M.prefill(params, cfg, toks[:, :NP], positions=ppos)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32), np.asarray(full[:, NP - 1],
                                                     np.float32),
        rtol=5e-2, atol=5e-2)

    # decode caches have capacity == prompt; regrow to S
    st = _grow(cfg, st, B, S)
    for t in range(NP, S):
        tok = toks[:, t:t + 1]
        dpos = (jnp.broadcast_to(jnp.asarray(t), (3, B, 1))
                if pos is not None else None)
        lg, st = M.decode_step(params, cfg, tok, st, positions=dpos)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full[:, t], np.float32), rtol=5e-2, atol=5e-2)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["glm4-9b", "deepseek-v3-671b"])
def test_unrolled_decode_matches_scanned(name):
    """§Perf decode iteration 2: the unrolled-layer decode (per-layer
    cache leaves) computes the same function as the scanned decode."""
    cfg = get_reduced(name)
    key = jax.random.PRNGKey(2)
    params = M.init_model(cfg, key)
    B, S = 2, 10
    toks = _tokens(cfg, B, S, key)
    _, st = M.prefill(params, cfg, toks[:, :S - 1])
    st = _grow(cfg, st, B, S)
    lg_scan, _ = M.decode_step(params, cfg, toks[:, -1:], st)
    # convert stacked caches to per-layer lists
    st_ur = {"len": st["len"]}
    for part in ("dense", "main"):
        if part in st:
            st_ur[part] = {k: [v[i] for i in range(v.shape[0])]
                           for k, v in st[part].items()}
    lg_ur, new_ur = M.decode_step(params, cfg, toks[:, -1:], st_ur,
                                  unroll=True)
    np.testing.assert_allclose(np.asarray(lg_ur, np.float32),
                               np.asarray(lg_scan, np.float32),
                               rtol=4e-2, atol=4e-2)
    assert isinstance(new_ur["main"]["k" if not cfg.mla else "latent"],
                      list)


def _grow(cfg, state, b, cap):
    fresh = M.init_decode_state(cfg, b, cap)

    def graft(f, s):
        if f.shape != s.shape:
            pad = [(0, fi - si) for fi, si in zip(f.shape, s.shape)]
            return jnp.pad(s.astype(f.dtype), pad)
        return s.astype(f.dtype)
    out = jax.tree.map(graft, fresh, state)
    out["len"] = state["len"]
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    """The FULL configs carry the exact public numbers of the assignment."""
    cfg = get_config(name)
    expect = {
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, (got, expect)


def test_param_counts_sane():
    """n_params() should land near the nameplate sizes."""
    for name, lo, hi in [
        ("qwen2-1.5b", 1.2e9, 2.2e9),
        ("glm4-9b", 8e9, 11e9),
        ("stablelm-12b", 10e9, 14e9),
        ("deepseek-v3-671b", 600e9, 740e9),
        ("qwen3-moe-30b-a3b", 25e9, 36e9),
        ("rwkv6-3b", 2.2e9, 4e9),
        ("zamba2-7b", 5.5e9, 9e9),
    ]:
        n = get_config(name).n_params()
        assert lo < n < hi, (name, n)
    dsv = get_config("deepseek-v3-671b")
    assert 30e9 < dsv.n_active_params() < 45e9   # ~37B active


def test_moe_long_context_skips():
    from repro.configs import all_cells
    cells = all_cells()
    longs = [a for a, s in cells if s == "long_500k"]
    assert sorted(longs) == ["rwkv6-3b", "zamba2-7b"]
    assert len(cells) == 8 * 3 + 2 * 4
