"""Hypothesis property: ``Program.verify()`` is clean on EVERY
``compile()`` output — any graph shape, any mapping strategy, any
schedule strategy. A diagnostic on a freshly-compiled program would be
a false positive of the static verifier (or a real compiler bug);
either way the property must fail."""
from __future__ import annotations

import pytest

from repro.core import compile, random_graph
from repro.core.graph import SNNGraph

from conftest import make_hw

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_inputs=st.integers(2, 12), n_internal=st.integers(4, 14),
       density=st.floats(0.2, 0.9),
       method=st.sampled_from(["framework", "synapse_rr", "hypergraph"]),
       schedule_method=st.sampled_from(["slack", "consecutive",
                                        "load_balance"]),
       feedforward=st.booleans())
def test_verify_clean_on_random_compiles(seed, n_inputs, n_internal,
                                         density, method, schedule_method,
                                         feedforward):
    n_syn = max(4, int(density * (n_inputs + n_internal) * n_internal))
    g = random_graph(n_inputs, n_internal, n_syn, seed=seed)
    if feedforward:
        ff = g.pre < n_inputs
        if ff.sum() < 2:
            return
        g = SNNGraph(g.n_inputs, g.n_neurons, g.pre[ff], g.post[ff],
                     g.weight[ff], g.lif, g.output_slice)
    p = compile(g, make_hw(g), method=method,
                schedule_method=schedule_method)
    rep = p.verify()
    assert rep.ok and not rep.diagnostics, rep.summary()
