"""Property tests (hypothesis): the deterministic-commit property.

For ANY random graph, ANY feasible hardware config, and ANY input spike
train, the mapped+scheduled engine must reproduce the dense integer-LIF
oracle BIT-EXACTLY — this is the paper's central correctness claim for the
bufferless ME tree (§4.3) and the schedule alignment (§6.3).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (HardwareConfig, compile as compile_program,
                        random_graph, run_mapped, run_oracle)
from repro.snn.lif import LIFIntParams


@st.composite
def graph_and_hw(draw):
    n_in = draw(st.integers(2, 24))
    n_int = draw(st.integers(4, 40))
    max_e = (n_in + n_int) * n_int
    n_syn = draw(st.integers(min(8, max_e), min(400, max_e)))
    seed = draw(st.integers(0, 2 ** 16))
    m = draw(st.sampled_from([2, 4, 8]))
    k = draw(st.integers(1, 4))
    leak = draw(st.integers(1, 4))
    vth = draw(st.integers(3, 40))
    g = random_graph(n_in, n_int, n_syn, seed=seed,
                     lif=LIFIntParams(leak_shift=leak, v_threshold=vth,
                                      v_reset=0))
    # generous memory so compile always succeeds; tight-memory feasibility
    # is covered separately in test_partition_schedule
    hw = HardwareConfig(n_spus=m, unified_mem_depth=4 * (n_syn // m + n_int),
                        concentration=k, max_neurons=n_in + n_int,
                        max_post_neurons=n_int)
    t = draw(st.integers(1, 12))
    rate = draw(st.floats(0.05, 0.9))
    ext_seed = draw(st.integers(0, 2 ** 16))
    return g, hw, t, rate, ext_seed


@given(graph_and_hw())
@settings(max_examples=25, deadline=None)
def test_mapped_execution_bit_exact(case):
    g, hw, t, rate, ext_seed = case
    tables = compile_program(g, hw, seed=0, max_iters=4000).tables
    rng = np.random.default_rng(ext_seed)
    ext = (rng.random((t, g.n_inputs)) < rate).astype(np.int32)
    s_ref, v_ref = run_oracle(g, ext)
    s_map, v_map, _ = run_mapped(g, tables, ext)
    np.testing.assert_array_equal(s_ref, s_map)
    np.testing.assert_array_equal(v_ref, v_map)


@given(graph_and_hw(), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_determinism_across_partition_seeds(case, pseed):
    """Different (valid) partitions of the same network must produce the
    SAME spikes — determinism is a property of the architecture, not of
    the mapping (paper: 'strict mathematical determinism')."""
    g, hw, t, rate, ext_seed = case
    rng = np.random.default_rng(ext_seed)
    ext = (rng.random((t, g.n_inputs)) < rate).astype(np.int32)
    t1 = compile_program(g, hw, seed=0, max_iters=4000).tables
    t2 = compile_program(g, hw, seed=17 + pseed, max_iters=4000).tables
    s1, v1, _ = run_mapped(g, t1, ext)
    s2, v2, _ = run_mapped(g, t2, ext)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(v1, v2)
