"""Parity tests for the compiled batched executor (engine_jax).

The deterministic-commit property must survive the lowering: for any
mapped program, ``run_mapped_batched`` must equal ``run_oracle`` (and
hence ``run_mapped``) BIT-EXACTLY, and its per-timestep MC packet counts
must equal ``run_mapped``'s stats so CycleModel reports are unchanged.
"""
import numpy as np
import pytest

from conftest import make_ext, make_feedforward, make_hw
from repro.configs.snn_paper import mnist_scale_random_graph
from repro.core import compile as program_compile
from repro.core import (ExecutionSpec, JaxMappedEngine, KERNELS,
                        lower_tables, random_graph, run_mapped,
                        run_mapped_batched, run_oracle)


_hw, _feedforward, _ext = make_hw, make_feedforward, make_ext


@pytest.mark.parametrize("kernel", KERNELS)
def test_recurrent_batched_bit_exact_vs_oracle(kernel):
    g = random_graph(12, 20, 160, seed=3)   # pre spans inputs AND internal
    assert (g.pre >= g.n_inputs).any(), "graph must contain recurrence"
    tables = program_compile(g, _hw(g), max_iters=4000).tables
    ext = _ext(g, b=4, t=9, seed=1)
    s, v, _ = JaxMappedEngine(g, tables,
                              ExecutionSpec(kernel=kernel)).run(ext)
    for b in range(ext.shape[0]):
        s_ref, v_ref = run_oracle(g, ext[b])
        np.testing.assert_array_equal(s[b], s_ref)
        np.testing.assert_array_equal(v[b], v_ref)


def test_feedforward_batched_bit_exact_vs_oracle():
    g = _feedforward()
    tables = program_compile(g, _hw(g), max_iters=4000).tables
    ext = _ext(g, b=3, t=12, rate=0.5, seed=2)
    s, v, _ = JaxMappedEngine(g, tables).run(ext)
    for b in range(ext.shape[0]):
        s_ref, v_ref = run_oracle(g, ext[b])
        np.testing.assert_array_equal(s[b], s_ref)
        np.testing.assert_array_equal(v[b], v_ref)


def test_packet_counts_match_run_mapped_stats():
    g = random_graph(10, 14, 100, seed=7)
    tables = program_compile(g, _hw(g), max_iters=4000).tables
    ext = _ext(g, b=3, t=8, seed=4)
    _, _, stats = JaxMappedEngine(g, tables).run(ext)
    assert stats["packet_counts"].shape == (3, 8)
    for b in range(3):
        _, _, ref = run_mapped(g, tables, ext[b])
        np.testing.assert_array_equal(stats["packet_counts"][b],
                                      ref["packet_counts"])
    assert stats["mean_packets_per_step"] == pytest.approx(
        float(stats["packet_counts"].mean()))


def test_unbatched_input_matches_run_mapped_shapes():
    g = random_graph(8, 10, 60, seed=9)
    tables = program_compile(g, _hw(g), max_iters=4000).tables
    ext = _ext(g, b=1, t=6, seed=5)[0]
    s_j, v_j, st_j = JaxMappedEngine(g, tables).run(ext)
    s_p, v_p, st_p = run_mapped(g, tables, ext)
    assert s_j.shape == s_p.shape and v_j.shape == v_p.shape
    np.testing.assert_array_equal(s_j, s_p)
    np.testing.assert_array_equal(v_j, v_p)
    np.testing.assert_array_equal(st_j["packet_counts"],
                                  st_p["packet_counts"])


def test_mnist_scale_graph_bit_exact():
    """Acceptance: bit-exact on the MNIST-scale graph (784-126, 16 SPUs)."""
    g, hw = mnist_scale_random_graph()
    program = program_compile(g, hw, max_iters=40000)
    tables = program.tables
    assert program.report.feasible
    ext = _ext(g, b=2, t=10, rate=0.2, seed=0)
    s, v, stats = JaxMappedEngine(g, tables).run(ext)
    for b in range(2):
        s_ref, v_ref = run_oracle(g, ext[b])
        np.testing.assert_array_equal(s[b], s_ref)
        np.testing.assert_array_equal(v[b], v_ref)
    _, _, ref = run_mapped(g, tables, ext[0])
    np.testing.assert_array_equal(stats["packet_counts"][0],
                                  ref["packet_counts"])


def test_engine_reuse_and_ownership():
    g = random_graph(8, 10, 60, seed=11)
    tables = program_compile(g, _hw(g), max_iters=4000).tables
    eng = JaxMappedEngine(g, tables)
    a = eng.run(_ext(g, 2, 5, seed=1))
    b = eng.run(_ext(g, 2, 5, seed=1))          # same input, same engine
    np.testing.assert_array_equal(a[0], b[0])
    # engines are owned by the Program artifact now; the fragile
    # id()-keyed module cache is gone and the wrapper warns
    from repro.core import engine_jax
    assert not hasattr(engine_jax, "_ENGINE_CACHE")
    with pytest.deprecated_call():
        c = run_mapped_batched(g, tables, _ext(g, 2, 5, seed=1))
    np.testing.assert_array_equal(a[0], c[0])
    prog = program_compile(g, _hw(g), max_iters=4000)
    assert prog.engine() is prog.engine()       # reused across run() calls


def test_lower_tables_covers_all_synapses():
    g = random_graph(10, 12, 90, seed=13)
    tables = program_compile(g, _hw(g), max_iters=4000).tables
    lw = lower_tables(g, tables)
    assert lw.n_ops == g.n_synapses
    got = sorted(zip(lw.op_pre.tolist(),
                     (lw.op_post_local + g.n_inputs).tolist(),
                     lw.op_weight.tolist()))
    want = sorted(zip(g.pre.tolist(), g.post.tolist(), g.weight.tolist()))
    assert got == want
    # slot-major commit order
    assert (np.diff(lw.op_slot) >= 0).all()
    # routing bitmap: SPU i flagged for q iff q has a synapse mapped there
    for q in range(g.n_neurons):
        spus = set(tables.assign[g.pre == q].tolist())
        assert set(np.flatnonzero(lw.routing[q]).tolist()) == spus
