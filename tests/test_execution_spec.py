"""ExecutionSpec surface + AOT precompile layer (core/execution, core/aot).

Covers: (a) spec validation and resolution — unknown engines/kernels
rejected at construction, non-jax specs reject jax-only knobs, resolve()
is idempotent and the resolved spec keys the engine cache; (b) as_spec
coercion (None / engine-name string / spec); (c) the deprecated-kwarg
shim — exact nu_kernel/sharded/mesh semantics behind a
DeprecationWarning; (d) AOT bucket precompile on engines, Programs,
registries and the sharded runner, all bit-exact vs the jit path;
(e) the sharded small-batch fallback (min_shard); (f) the batcher's
measured-mode warmup reusing the AOT path; (g) normalize_buckets /
content_hash / enable_persistent_cache.
"""
import numpy as np
import pytest

from conftest import make_ext, make_feedforward, make_hw
from repro.core import (ExecutionSpec, KERNELS, Program, compile,
                        default_kernel, random_graph)
from repro.core.aot import content_hash, enable_persistent_cache, \
    normalize_buckets
from repro.core.execution import as_spec, spec_from_legacy_kwargs
from repro.kernels.ops import _default_interpret
from repro.serve import (BatchPolicy, MicroBatcher, ProgramRegistry,
                         ShardedRunner)


@pytest.fixture(scope="module")
def program():
    g = make_feedforward()
    return compile(g, make_hw(g), max_iters=4000)


# ---------------------------------------------------------------------------
# Validation + resolution
# ---------------------------------------------------------------------------

def test_spec_rejects_unknown_engine_and_kernel():
    with pytest.raises(ValueError, match="unknown engine"):
        ExecutionSpec(engine="fpga")
    with pytest.raises(ValueError, match="unknown kernel"):
        ExecutionSpec(kernel="cuda")


@pytest.mark.parametrize("bad", [dict(kernel="fused"), dict(interpret=True),
                                 dict(donate=True)])
def test_spec_rejects_jax_knobs_on_other_engines(bad):
    with pytest.raises(ValueError, match="jax-engine build options"):
        ExecutionSpec(engine="python", **bad)


def test_spec_rejects_mesh_on_other_engines():
    with pytest.raises(ValueError, match="mesh= shards the jax"):
        ExecutionSpec(engine="oracle", mesh="auto")


def test_resolve_folds_platform_defaults_and_is_idempotent():
    r = ExecutionSpec().resolve()
    assert r.resolved and not ExecutionSpec().resolved
    assert r.kernel == default_kernel()
    assert r.interpret == _default_interpret()
    assert r.resolve() == r                        # idempotent
    # every explicit spelling of the defaults resolves identically
    assert ExecutionSpec(kernel=default_kernel()).resolve() == r
    # non-jax specs are already resolved (no jax knobs to fold)
    assert ExecutionSpec(engine="python").resolved


def test_resolve_expands_auto_mesh_and_rejects_other_strings():
    r = ExecutionSpec(mesh="auto").resolve()
    assert r.sharded and not isinstance(r.mesh, str)
    assert r.single_device().mesh is None
    assert r.single_device().kernel == r.kernel    # only the mesh drops
    with pytest.raises(ValueError, match="only string form"):
        ExecutionSpec(mesh="ring").resolve()


def test_specs_key_the_engine_cache(program):
    assert program.engine(ExecutionSpec()) is \
        program.engine(ExecutionSpec(interpret=_default_interpret()))
    e = {k: program.engine(ExecutionSpec(kernel=k)) for k in KERNELS}
    assert len(set(map(id, e.values()))) == len(KERNELS)


# ---------------------------------------------------------------------------
# as_spec coercion
# ---------------------------------------------------------------------------

def test_as_spec_coercion():
    assert as_spec(None) == ExecutionSpec()
    assert as_spec(None, default_engine="python").engine == "python"
    assert as_spec("oracle") == ExecutionSpec(engine="oracle")
    s = ExecutionSpec(kernel="lif")
    assert as_spec(s) is s
    with pytest.raises(TypeError, match="ExecutionSpec"):
        as_spec(42)
    with pytest.raises(ValueError, match="unknown engine"):
        as_spec("fpga")


# ---------------------------------------------------------------------------
# Deprecated-kwarg shim
# ---------------------------------------------------------------------------

def test_legacy_kwargs_map_onto_specs():
    with pytest.deprecated_call(match="Migration to ExecutionSpec"):
        assert spec_from_legacy_kwargs(nu_kernel=True).kernel == "lif"
    with pytest.deprecated_call():
        assert spec_from_legacy_kwargs(nu_kernel=False).kernel == "reference"
    with pytest.deprecated_call():                 # sharded=True -> auto mesh
        assert spec_from_legacy_kwargs(sharded=True).mesh == "auto"
    with pytest.deprecated_call():                 # old API: mesh needs sharded
        assert spec_from_legacy_kwargs(mesh=object()).mesh is None
    with pytest.deprecated_call():
        assert spec_from_legacy_kwargs(engine="python") == \
            ExecutionSpec(engine="python")
    with pytest.deprecated_call(), \
            pytest.raises(ValueError, match="sharded=True runs the jax"):
        spec_from_legacy_kwargs(sharded=True, engine="oracle")


def test_legacy_run_kwargs_delegate_bit_exact(program):
    ext = make_ext(program.graph, 2, 6, seed=0)
    s_new, v_new, _ = program.run(ext, ExecutionSpec(kernel="lif"))
    with pytest.deprecated_call():
        s_old, v_old, _ = program.run(ext, nu_kernel=True)
    assert s_old.tobytes() == s_new.tobytes()
    assert v_old.tobytes() == v_new.tobytes()
    with pytest.raises(TypeError, match="both"):
        program.run(ext, ExecutionSpec(), engine="jax")


# ---------------------------------------------------------------------------
# AOT precompile
# ---------------------------------------------------------------------------

def test_engine_precompile_is_idempotent_and_bit_exact(program):
    eng = program.engine(ExecutionSpec(donate=False))
    new = eng.precompile([2, 4], timesteps=6)
    assert set(new) == {(2, 6), (4, 6)}
    assert eng.precompile([2, 4], timesteps=6) == []   # already compiled
    ext = make_ext(program.graph, 4, 6, seed=1)
    s_aot, v_aot, st_aot = eng.run(ext)                # hits the executable
    s_jit, _, _ = program.run(ext, ExecutionSpec(kernel="lif"))
    assert s_aot.tobytes() == s_jit.tobytes()
    # non-matching shapes still fall back to the jitted path
    ext5 = make_ext(program.graph, 5, 6, seed=1)
    assert eng.run(ext5)[0].shape == (5, 6, program.graph.n_internal)


def test_program_precompile_accepts_policy_and_ints(program):
    assert isinstance(program.precompile(BatchPolicy(max_batch=4),
                                         timesteps=5), list)
    assert isinstance(program.precompile(8, timesteps=5), list)
    ext = make_ext(program.graph, 8, 5, seed=4)        # served by the AOT exe
    np.testing.assert_array_equal(
        program.run(ext)[0],
        program.run(ext, ExecutionSpec(kernel="lif"))[0])
    with pytest.raises(TypeError):                     # timesteps required
        program.precompile([2])


def test_load_precompile_requires_timesteps(tmp_path, program):
    path = program.save(tmp_path / "m.npz")
    with pytest.raises(ValueError, match="timesteps"):
        Program.load(path, precompile=[4])
    p = Program.load(path, precompile=[4], timesteps=6)
    ext = make_ext(p.graph, 4, 6, seed=2)
    np.testing.assert_array_equal(p.run(ext)[0], program.run(ext)[0])


def test_registry_register_precompile(tmp_path, program):
    reg = ProgramRegistry()
    with pytest.raises(ValueError, match="timesteps"):
        reg.register("m", program, precompile=[2])
    reg.register("m", program, precompile=[2], timesteps=6)
    assert reg.get("m") is program


def test_normalize_buckets():
    assert normalize_buckets([4, 2, 2, 8]) == (2, 4, 8)
    assert normalize_buckets(3) == (3,)
    assert normalize_buckets(BatchPolicy(max_batch=4)) == (1, 2, 4)
    with pytest.raises(ValueError, match="positive"):
        normalize_buckets([0, 2])
    with pytest.raises(ValueError, match="positive"):
        normalize_buckets([])


def test_content_hash_tracks_the_computation(program):
    h = content_hash(program)
    assert isinstance(h, str) and len(h) == 64
    assert content_hash(program) == h              # deterministic
    g2 = make_feedforward(seed=7)
    other = compile(g2, make_hw(g2), max_iters=4000)
    assert content_hash(other) != h


def test_enable_persistent_cache_idempotent(tmp_path):
    d = enable_persistent_cache(str(tmp_path / "xla"))
    if d is None:                                  # jax without the knobs
        pytest.skip("jax build lacks compilation-cache config")
    assert enable_persistent_cache() == d          # sticky afterwards


# ---------------------------------------------------------------------------
# Sharded small-batch fallback + batcher warmup
# ---------------------------------------------------------------------------

def test_sharded_small_batch_fallback_bit_exact(program):
    r = ShardedRunner(program, min_shard=4)        # fallback below 4/shard
    b_small = max(1, r.n_shards * r.min_shard - 1)
    ext = make_ext(program.graph, b_small, 6, seed=3)
    assert r._use_fallback(b_small)
    s, v, st = r.run(ext)
    s1, v1, st1 = program.run(ext)
    assert s.tobytes() == s1.tobytes()
    assert v.tobytes() == v1.tobytes()
    np.testing.assert_array_equal(st["packet_counts"],
                                  st1["packet_counts"])
    # min_shard=0 disables the fallback even at B=1
    assert not ShardedRunner(program, min_shard=0)._use_fallback(1)
    # precompile warms fallback buckets on the single-device engine
    warmed = r.precompile([1, 8 * max(1, r.n_shards)], timesteps=6)
    assert warmed is not None


def test_batcher_measured_warmup_uses_aot_precompile(program):
    reg = ProgramRegistry()
    reg.register("m", program)
    runner = reg.runner("m", ExecutionSpec())
    called = []
    orig = runner.precompile
    runner.precompile = lambda buckets, t: (called.append((tuple(buckets),
                                                           t)),
                                            orig(buckets, t))[1]
    g = program.graph
    reqs = make_ext(g, 5, 6, seed=9)
    res = MicroBatcher(BatchPolicy(max_batch=4),
                       runner=runner).drain(np.zeros(5), reqs)
    assert called == [((1, 2, 4), 6)]              # AOT path, not throwaway
    assert res.n_requests == 5
    # non-jax runners expose no precompile hook (nothing to AOT-warm)
    py_runner = reg.runner("m", ExecutionSpec(engine="python"))
    assert not hasattr(py_runner, "precompile")
