"""MoE layer properties — the MC/ME-tree analogue (DESIGN.md §4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.models.moe import _pick_group_size, init_moe, moe_mlp, route_topk


def test_route_topk_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    w, idx = route_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 8
    # indices are the true top-k
    ref = np.argsort(-np.asarray(logits), axis=-1)[:, :3]
    np.testing.assert_array_equal(np.sort(np.asarray(idx), -1),
                                  np.sort(ref, -1))


@given(st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_pick_group_size_divides(t):
    g = _pick_group_size(t)
    assert t % g == 0 and 1 <= g <= 2048


def test_moe_grouping_invariance_when_capacity_ample():
    """With no-drop capacity, the group decomposition must not change the
    result (the MC-tree multicast is exact)."""
    cfg = get_reduced("qwen3-moe-30b-a3b")
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y1, aux1 = moe_mlp(p, x, cfg, group_size=32)
    y2, aux2 = moe_mlp(p, x, cfg, group_size=8)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_moe_deterministic_merge():
    """Two identical calls produce bit-identical outputs (the ME-tree
    deterministic-commit analogue: fixed-order einsum reduction)."""
    cfg = get_reduced("qwen3-moe-30b-a3b")
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    f = jax.jit(lambda: moe_mlp(p, x, cfg)[0])
    a, b = f(), f()
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most expert slots vanish: the output
    must shrink in norm (dropped tokens get zero update), not error out."""
    cfg = get_reduced("qwen3-moe-30b-a3b")
    tight = dataclasses.replace(
        cfg, moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                           capacity_factor=4.0))
    p = init_moe(tight, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y_full, _ = moe_mlp(p, x, tight)
    squeezed = dataclasses.replace(
        tight, moe=dataclasses.replace(tight.moe, capacity_factor=0.1))
    y_drop, _ = moe_mlp(p, x, squeezed)
    assert float(jnp.abs(y_drop).sum()) < float(jnp.abs(y_full).sum())


def test_moe_aux_loss_balanced_vs_collapsed():
    """The load-balance loss must penalize a collapsed router."""
    cfg = get_reduced("qwen3-moe-30b-a3b")
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    _, aux_balanced = moe_mlp(p, x, cfg)
    p_collapsed = dict(p)
    router = np.zeros(p["router"].shape, np.float32)
    router[:, 0] = 50.0                      # everything to expert 0
    p_collapsed["router"] = jnp.asarray(router)
    _, aux_collapsed = moe_mlp(p_collapsed, x, cfg)
    assert float(aux_collapsed) > float(aux_balanced)
