"""Compiler-scale mapping subsystem (DESIGN.md §11): hypergraph/
multilevel strategies, FM refinement properties, multi-chip accounting,
the synthetic-scale generator, and the portfolio-search satellites
(process workers, in-sweep deadline)."""
import dataclasses
import time

import numpy as np
import pytest

from conftest import make_ext, make_feedforward, make_hw
from repro.core import HardwareConfig, SearchConfig, compile, random_graph
from repro.core.engine import CycleModel
from repro.core.mapping.hypergraph import (balance_loads, chip_span,
                                           hyper_view, hypergraph_partition,
                                           inter_chip_hop_counts,
                                           inter_chip_packet_counts,
                                           mapping_traffic, mesh_hops,
                                           multicast_dests, refine_mapping)
from repro.core.mapping.multilevel import (coarsen_graph,
                                           multilevel_partition, place_chips)
from repro.core.mapping.search import framework_partition, portfolio_search
from repro.core.memory_model import (bram_count, scores_from_assignment,
                                     total_memory_bits)
from repro.core.scale import scale_hw, synthetic_graph
from repro.core.scheduling import schedule, validate_schedule


def _graphs():
    return [("ff", make_feedforward(16, 12, 150, seed=5)),
            ("recurrent", random_graph(16, 32, 900, seed=2)),
            ("recurrent2", random_graph(8, 24, 500, seed=11))]


# ---------------------------------------------------------------------------
# The hyperedge view.
# ---------------------------------------------------------------------------

def test_hyper_view_structure():
    g = random_graph(10, 20, 300, seed=0)
    hv = hyper_view(g)
    assert hv.fanin_ptr[0] == 0 and hv.fanin_ptr[-1] == g.n_synapses
    seen = np.concatenate([hv.fanin(j) for j in range(hv.n_posts)])
    assert np.array_equal(np.sort(seen), np.arange(g.n_synapses))
    for j in (0, hv.n_posts // 2, hv.n_posts - 1):
        assert (g.post[hv.fanin(j)] == hv.posts[j]).all()
    # fan-out CSR: each pre's hyperedge lists exactly its posts
    for q in (0, g.n_inputs, g.n_neurons - 1):
        mine = np.sort(g.post[g.pre == q])
        got = hv.fanout_post[hv.fanout_ptr[q]:hv.fanout_ptr[q + 1]]
        assert np.array_equal(np.sort(got), mine)


# ---------------------------------------------------------------------------
# Strategy validity: every mapping schedules + validates + scores right.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,g", _graphs())
@pytest.mark.parametrize("method", ["hypergraph", "multilevel"])
def test_strategies_valid_and_schedulable(kind, g, method):
    hw = make_hw(g, m=4, k=2)
    prog = compile(g, hw, method=method)
    assert prog.feasible, f"{method} infeasible on generous hw ({kind})"
    validate_schedule(g, prog.tables)
    assert np.array_equal(
        prog.part.scores,
        scores_from_assignment(g.weight, g.post, prog.part.assign, hw))
    assert total_memory_bits(hw, prog.ot_depth) > 0
    # mapped execution still matches the oracle bit-exactly
    ext = make_ext(g, 1, 8, seed=3)[0]
    s_m, v_m, _ = prog.run(ext, "python")
    s_o, v_o, _ = prog.run(ext, "oracle")
    assert np.array_equal(s_m, s_o) and np.array_equal(v_m, v_o)


def test_multilevel_coarsen_path_valid():
    # force the real coarsen->partition->refine path on a small graph
    g = random_graph(24, 48, 3000, seed=7)
    hw = make_hw(g, m=8, k=3)
    res = multilevel_partition(g, hw, seed=0, coarse_target=500,
                               max_iters=3000)
    assert res.feasible
    tables = schedule(g, res.assign, hw)
    validate_schedule(g, tables)


def test_coarsen_graph_maps_are_consistent():
    g = random_graph(24, 48, 3000, seed=7)
    hw = make_hw(g, m=8, k=3)
    cg = coarsen_graph(g, hw, coarse_target=500)
    gc = cg.graph
    gc.validate()
    assert cg.levels >= 1 and cg.n_clusters < g.n_internal
    assert gc.n_synapses < g.n_synapses
    # every fine synapse lands on the coarse synapse of its (pre, cluster)
    cl = cg.cluster[g.post.astype(np.int64) - g.n_inputs]
    assert np.array_equal(gc.pre[cg.syn_map], g.pre)
    assert np.array_equal(gc.post[cg.syn_map].astype(np.int64),
                          g.n_neurons + cl)
    # clusters partition the fine posts
    assert cg.cluster.shape == (g.n_internal,)
    assert set(np.unique(cg.cluster)) == set(range(cg.n_clusters))


# ---------------------------------------------------------------------------
# Refinement never worsens the extended objective (overflow, cut-traffic).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("n_chips", [1, 2])
def test_refinement_never_worsens(seed, n_chips):
    g = random_graph(20, 40, 1500, seed=seed)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=40, concentration=3,
                        max_neurons=128, max_post_neurons=64,
                        n_chips=n_chips)
    rng = np.random.default_rng(seed)
    a0 = rng.integers(0, hw.n_spus, g.n_synapses).astype(np.int32)
    a1, st = refine_mapping(g, hw, a0, passes=3)
    # strict-accept FM: the (overflow, traffic) objective is monotone
    assert (st.overflow_after, st.traffic_after) <= \
        (st.overflow_before, st.traffic_before)
    # the stats' incremental accounting matches ground truth
    hop = hw.inter_chip_hop_cycles if n_chips > 1 else 0
    for a, over, traf in ((a0, st.overflow_before, st.traffic_before),
                          (a1, st.overflow_after, st.traffic_after)):
        sc = scores_from_assignment(g.weight, g.post, a, hw)
        t = mapping_traffic(g, a, hw)
        assert over == int(np.maximum(-sc, 0).sum())
        assert traf == t["dests_total"] + hop * t["inter_chip_total"]


def test_refinement_repairs_projected_overflow():
    # the multilevel contract: refinement drives a messy projected
    # mapping to Eq. (9) feasibility on a satisfiable instance
    g = random_graph(20, 40, 1500, seed=1)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=40, concentration=3,
                        max_neurons=128, max_post_neurons=64)
    a0 = np.random.default_rng(0).integers(0, 8, g.n_synapses) \
        .astype(np.int32)
    _, st = refine_mapping(g, hw, a0, passes=4)
    assert st.overflow_before > 0 and st.overflow_after == 0


# ---------------------------------------------------------------------------
# Multi-chip accounting conserves the single-chip totals at n_chips=1.
# ---------------------------------------------------------------------------

def test_multichip_conservation_at_one_chip():
    g = random_graph(16, 32, 900, seed=2)
    hw1 = make_hw(g, m=8, k=2)
    assert hw1.n_chips == 1
    prog = compile(g, hw1, method="hypergraph")
    # no forwarded packets, ever
    assert (prog.chip_span() <= 1).all()
    ext = make_ext(g, 2, 10, seed=0)
    s, _, stats = prog.run(ext, "oracle")
    ic = prog.inter_chip_counts(ext, s)
    assert ic.shape == stats["packet_counts"].shape and (ic == 0).all()
    # the cycle model with explicit zero forwards is bit-identical
    r0 = prog.profile(stats)
    r1 = prog.profile(stats, inter_chip_counts=ic)
    assert r0.cycle == r1.cycle
    # compile(n_chips=1) is the identity
    prog1 = compile(g, hw1, method="hypergraph", n_chips=1)
    assert prog1.hw == hw1


def test_multichip_packet_accounting():
    g = random_graph(16, 32, 900, seed=2)
    hw1 = make_hw(g, m=8, k=2)
    hw4 = dataclasses.replace(hw1, n_chips=4)
    res = hypergraph_partition(g, hw1)
    # fabric deliveries are invariant under the chip grouping; chip
    # spans are bounded by the destination counts
    d = multicast_dests(g, res.assign, hw1.n_spus)
    sp1, sp4 = chip_span(g, res.assign, hw1), chip_span(g, res.assign, hw4)
    assert mapping_traffic(g, res.assign, hw1)["dests_total"] == \
        mapping_traffic(g, res.assign, hw4)["dests_total"]
    assert (sp1 <= 1).all() and (sp4 <= np.minimum(d, 4)).all()
    assert (sp4[d > 0] >= 1).all()
    # forwarded packets charge hop cycles in the distribution phase
    ext = make_ext(g, 1, 12, seed=1)[0]
    spikes = make_ext(g, 1, 12, seed=2)[0][:, :g.n_internal]
    ic = inter_chip_packet_counts(ext, spikes, sp4)
    pkts = np.arange(12, dtype=np.int64) + 1
    cm = CycleModel(hw4)
    base = cm.run(pkts, 10, g.n_synapses)
    multi = cm.run(pkts, 10, g.n_synapses, inter_chip_counts=ic)
    assert multi.cycles_distribution - base.cycles_distribution == \
        int(ic.sum()) * hw4.inter_chip_hop_cycles
    assert multi.cycles_synaptic == base.cycles_synaptic


def test_compile_n_chips_replicates_per_chip_config():
    g = random_graph(16, 32, 900, seed=2)
    hw1 = make_hw(g, m=4, k=2)
    prog = compile(g, hw1, method="hypergraph", n_chips=2)
    assert prog.hw.n_chips == 2 and prog.hw.n_spus == 2 * hw1.n_spus
    assert prog.hw.spus_per_chip == hw1.n_spus
    # mapping/scheduling run on the flattened tree; since the chip-aware
    # placement/balancing stage (DESIGN.md §12) the mapping may differ
    # from an explicitly flattened single-chip run — but only through the
    # chip grouping: with balancing scoped to the whole (single-chip)
    # fabric the two pipelines are identical
    flat = dataclasses.replace(hw1, n_spus=2 * hw1.n_spus)
    ref = compile(g, flat, method="hypergraph")
    assert prog.part.feasible and ref.part.feasible
    assert hypergraph_partition(g, prog.hw, balance=False).assign.tolist() \
        == hypergraph_partition(g, flat, balance=False).assign.tolist()
    # memory model counts per-chip structures replicated n_chips times
    assert total_memory_bits(prog.hw, prog.ot_depth) != \
        total_memory_bits(flat, prog.ot_depth)
    assert bram_count(prog.hw, prog.ot_depth) > 0
    with pytest.raises(ValueError, match="SINGLE-chip"):
        compile(g, prog.hw, n_chips=2)


def test_multichip_program_roundtrips(tmp_path):
    g = random_graph(16, 32, 900, seed=2)
    prog = compile(g, make_hw(g, m=4, k=2), method="hypergraph", n_chips=2)
    path = prog.save(tmp_path / "multichip")
    loaded = type(prog).load(path)
    assert loaded.hw == prog.hw
    assert np.array_equal(loaded.tables.pre, prog.tables.pre)
    assert np.array_equal(loaded.part.assign, prog.part.assign)


# ---------------------------------------------------------------------------
# 2D-mesh topology (DESIGN.md §12).
# ---------------------------------------------------------------------------

def test_mesh_dims_auto_and_explicit():
    g = random_graph(16, 32, 900, seed=2)
    base = make_hw(g, m=32, k=2)
    # auto factorization is near-square: 16 -> 4x4, 8 -> 4x2, 2 -> 2x1
    for n, dims in ((16, (4, 4)), (8, (4, 2)), (4, (2, 2)), (2, (2, 1)),
                    (1, (1, 1))):
        hw = dataclasses.replace(base, n_chips=n)
        assert hw.mesh_dims == dims
    hw = dataclasses.replace(base, n_chips=8, mesh_x=8, mesh_y=1)
    assert hw.mesh_dims == (8, 1)
    assert hw.chip_coords(5) == (5, 0)
    assert int(hw.chip_hops(0, 5)) == 5          # chain: pure X distance
    grid = dataclasses.replace(base, n_chips=8, mesh_x=4, mesh_y=2)
    assert grid.chip_coords(5) == (1, 1)
    assert int(grid.chip_hops(0, 5)) == 2        # XY Manhattan
    assert int(grid.chip_hops(5, 5)) == 0
    with pytest.raises(AssertionError):
        dataclasses.replace(base, n_chips=8, mesh_x=3, mesh_y=2)
    with pytest.raises(AssertionError):
        dataclasses.replace(base, n_chips=8, mesh_x=4)   # one-sided pin


def test_mesh_hops_accounting():
    g = random_graph(16, 32, 900, seed=2)
    hw1 = make_hw(g, m=8, k=2)
    res = hypergraph_partition(g, hw1)
    # on a 2-chip chain the multicast bounding box degenerates to
    # span - 1, so mesh hops and the §11 forward counts coincide
    hw2 = dataclasses.replace(hw1, n_chips=2)
    mh = mesh_hops(g, res.assign, hw2)
    sp = chip_span(g, res.assign, hw2)
    assert np.array_equal(mh, np.maximum(sp - 1, 0))
    t = mapping_traffic(g, res.assign, hw2)
    assert t["mesh_hops_total"] == int(mh.sum())
    # hop counts weight each spike by its pre's mesh extent
    ext = make_ext(g, 1, 12, seed=1)[0]
    spikes = make_ext(g, 1, 12, seed=2)[0][:, :g.n_internal]
    assert np.array_equal(inter_chip_hop_counts(ext, spikes, mh),
                          inter_chip_packet_counts(ext, spikes, sp))
    # 2x2 mesh: the bounding-box half-perimeter never exceeds the
    # chain's span-1 upper bound and is zero exactly on-chip
    hw4 = dataclasses.replace(hw1, n_chips=4)
    mh4 = mesh_hops(g, res.assign, hw4)
    sp4 = chip_span(g, res.assign, hw4)
    assert ((mh4 == 0) == (sp4 <= 1)).all()
    assert (mh4 <= np.maximum(sp4 - 1, 0) * 2).all()
    assert mesh_hops(g, res.assign, hw1).sum() == 0      # single chip


def test_place_chips_never_worsens_and_is_identity_on_one_chip():
    g = random_graph(24, 48, 3000, seed=7)
    hw1 = make_hw(g, m=16, k=2)
    res = hypergraph_partition(g, hw1)
    assert np.array_equal(place_chips(g, hw1, res.assign), res.assign)
    hw4 = dataclasses.replace(hw1, n_chips=4)
    placed = place_chips(g, hw4, res.assign)
    before = int(mesh_hops(g, res.assign, hw4).sum())
    after = int(mesh_hops(g, placed, hw4).sum())
    assert after <= before
    # placement is a pure SPU relabeling: per-SPU groups are preserved,
    # so Eq. (9)-(11) feasibility is untouched
    s_old = np.sort(scores_from_assignment(g.weight, g.post,
                                           res.assign, hw4))
    s_new = np.sort(scores_from_assignment(g.weight, g.post, placed, hw4))
    assert np.array_equal(s_old, s_new)


def test_balance_loads_reduces_max_load_within_chips():
    g = random_graph(16, 48, 3000, seed=3)
    hw = dataclasses.replace(make_hw(g, m=8, k=2), n_chips=4)
    res = hypergraph_partition(g, hw, balance=False)
    assign, stats = balance_loads(g, hw, res.assign)
    assert stats["max_load_after"] <= stats["max_load_before"]
    # Eq. (9) feasibility is never sacrificed for balance
    assert scores_from_assignment(g.weight, g.post, assign, hw).min() >= \
        min(0, int(res.scores.min()))
    # chip traffic is invariant: balancing moves never cross chips
    assert mesh_hops(g, assign, hw).sum() == \
        mesh_hops(g, res.assign, hw).sum()
    assert np.array_equal(assign // hw.spus_per_chip,
                          res.assign // hw.spus_per_chip)
    tables = schedule(g, assign, hw)
    validate_schedule(g, tables)


# ---------------------------------------------------------------------------
# The synthetic-scale generator.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["layered", "recurrent", "mixed"])
def test_synthetic_graph_shapes(topology):
    g = synthetic_graph(20_000, topology=topology, skew=1.0, seed=3)
    g.validate()
    assert g.n_synapses == 20_000
    assert g.n_inputs > 0 and g.n_internal > 0
    if topology != "layered":        # some recurrence: internal pres exist
        assert (g.pre >= g.n_inputs).any()


def test_synthetic_graph_deterministic_and_skewed():
    a = synthetic_graph(10_000, topology="mixed", skew=1.0, seed=9)
    b = synthetic_graph(10_000, topology="mixed", skew=1.0, seed=9)
    assert np.array_equal(a.pre, b.pre) and \
        np.array_equal(a.weight, b.weight)
    # sparse enough that fan-out isn't capped by layer saturation
    flat = synthetic_graph(10_000, topology="layered", skew=0.0, seed=9,
                           neurons_per_synapse=0.1)
    hub = synthetic_graph(10_000, topology="layered", skew=2.0, seed=9,
                          neurons_per_synapse=0.1)
    assert np.bincount(hub.pre).max() > np.bincount(flat.pre).max()
    hw = scale_hw(a, n_chips=2, spus_per_chip=8)
    assert hw.n_spus == 16 and hw.n_chips == 2


# ---------------------------------------------------------------------------
# Portfolio satellites: in-sweep deadline + process workers.
# ---------------------------------------------------------------------------

def _unsat_instance():
    g = random_graph(12, 24, 800, seed=3)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=5, concentration=3,
                        max_neurons=64, max_post_neurons=32)
    return g, hw


def test_deadline_enforced_inside_restart_sweep():
    g, hw = _unsat_instance()
    budget = 0.15
    t0 = time.perf_counter()
    _, _, exhausted = framework_partition(
        g, hw, seed=0, restarts=16, max_iters=10 ** 8,
        early_exit=False, deadline=t0 + budget)
    elapsed = time.perf_counter() - t0
    assert exhausted
    # a 16-restart sweep of an unbounded search must stop within a
    # step of the deadline, not a full sweep (regression: the check
    # used to run only between sweeps)
    assert elapsed < budget + 0.5, f"overshot the deadline: {elapsed:.2f}s"


def test_portfolio_workers_parity_with_inline():
    g = random_graph(16, 32, 500, seed=8)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    cfg = dict(restarts=2, max_iters=2000, early_exit=False)
    part1, trace1, tables1 = portfolio_search(
        g, hw, SearchConfig(**cfg, workers=1))
    part2, trace2, tables2 = portfolio_search(
        g, hw, SearchConfig(**cfg, workers=2))
    # deterministic reduction: same candidates, same winner, same bits
    assert [c.strategy for c in trace1.candidates] == \
        [c.strategy for c in trace2.candidates]
    s1, s2 = trace1.selected, trace2.selected
    assert (s1.strategy, s1.seed, s1.ot_depth, s1.memory_lines) == \
        (s2.strategy, s2.seed, s2.ot_depth, s2.memory_lines)
    assert np.array_equal(part1.assign, part2.assign)
    assert tables1.depth == tables2.depth


def test_portfolio_workers_budget_prefix():
    g, hw = _unsat_instance()
    t0 = time.perf_counter()
    part, trace, _ = portfolio_search(g, hw, SearchConfig(
        restarts=4, max_iters=10 ** 8, budget_seconds=1.0, workers=2))
    elapsed = time.perf_counter() - t0
    assert trace.budget_exhausted
    assert len(trace.candidates) >= 1      # first candidate always lands
    assert part is not None
    assert elapsed < 30.0                  # pool teardown slack


def test_portfolio_races_hypergraph_by_default():
    g = random_graph(16, 32, 500, seed=8)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    _, trace, _ = portfolio_search(g, hw, SearchConfig(restarts=1,
                                                       max_iters=2000))
    names = [c.strategy for c in trace.candidates]
    assert "hypergraph" in names and "multilevel" not in names
    _, trace0, _ = portfolio_search(g, hw, SearchConfig(
        restarts=1, max_iters=2000, extra_strategies=()))
    assert "hypergraph" not in [c.strategy for c in trace0.candidates]


# ---------------------------------------------------------------------------
# Compiler scale (slow lane).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multilevel_compiles_large_multichip_graph():
    g = synthetic_graph(100_000, topology="mixed", skew=1.0, seed=0)
    hw4 = scale_hw(g, n_chips=4, spus_per_chip=16)
    hw1 = dataclasses.replace(hw4, n_spus=hw4.spus_per_chip, n_chips=1)
    prog = compile(g, hw1, method="multilevel", n_chips=4)  # validates
    assert prog.feasible
    assert prog.hw.n_spus == 64 and prog.hw.n_chips == 4
    traffic = mapping_traffic(g, prog.tables.assign, prog.hw)
    assert traffic["inter_chip_total"] > 0
    ext = make_ext(g, 1, 5, seed=0)[0]
    s, _, stats = prog.run(ext, "oracle")
    rep = prog.profile(stats,
                       inter_chip_counts=prog.inter_chip_counts(ext, s))
    assert rep.cycle.cycles_total > 0


@pytest.mark.slow
def test_mesh_placement_beats_chain_at_scale():
    # the §12 acceptance property at the pinned 1e5 bench shape: the
    # chip-placement stage wins hop-weighted static traffic over the
    # consecutive-id chain overlay (chip_placement=False), at equal
    # feasibility (placement is a pure SPU relabeling)
    g = synthetic_graph(100_000, topology="mixed", skew=1.0, seed=0)
    hw = scale_hw(g, n_chips=4, spus_per_chip=16)
    placed = multilevel_partition(g, hw)
    chain = multilevel_partition(g, hw, chip_placement=False)
    assert np.array_equal(np.sort(placed.scores), np.sort(chain.scores))
    tp = mapping_traffic(g, placed.assign, hw)
    tc = mapping_traffic(g, chain.assign, hw)
    hop = hw.inter_chip_hop_cycles
    cost_p = tp["dests_total"] + hop * tp["mesh_hops_total"]
    cost_c = tc["dests_total"] + hop * tc["mesh_hops_total"]
    assert cost_p < cost_c


@pytest.mark.slow
def test_million_synapse_compile_envelope():
    # §12 acceptance point: 10^6 synapses on 16 chips (4x4 mesh)
    # compiles feasible inside the wall-clock envelope the bench pins
    g = synthetic_graph(1_000_000, topology="mixed", skew=1.0, seed=0)
    hw16 = scale_hw(g, n_chips=16, spus_per_chip=16)
    hw1 = dataclasses.replace(hw16, n_spus=hw16.spus_per_chip, n_chips=1)
    t0 = time.perf_counter()
    prog = compile(g, hw1, method="multilevel", n_chips=16)  # validates
    compile_s = time.perf_counter() - t0
    assert prog.feasible
    assert prog.hw.mesh_dims == (4, 4)
    assert compile_s < 600.0, f"1m compile blew the envelope: {compile_s:.0f}s"
    # the profiler covered the whole pipeline on the way
    assert prog.report.phase_seconds is not None
    assert sum(prog.report.phase_seconds.values()) > 0.0
