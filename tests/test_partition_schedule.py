"""Unit tests for the partitioning (§6.2) and scheduling (§6.3) framework."""
import math

import numpy as np
import pytest

from repro.core import (BASELINES, HardwareConfig, partition,
                        random_graph, schedule, scores_from_assignment,
                        spu_score, spu_usage, validate_schedule)
from repro.core.memory_model import bram_count, total_memory_kb


HW = HardwareConfig(n_spus=8, unified_mem_depth=64, concentration=3,
                    max_neurons=256, max_post_neurons=128)


def test_eq9_eq10_by_hand():
    # |Q|=5 unique weights, K=3 -> ceil(6/3)=2 lines; |P|=7 posts -> 9 lines
    assert spu_usage(5, 7, 3) == 9
    hw = HardwareConfig(n_spus=4, unified_mem_depth=10)
    assert spu_score(5, 7, hw) == 1
    assert spu_score(5, 9, hw) == -1          # violation -> negative


def test_scores_vectorized_matches_bookkeeping():
    g = random_graph(16, 32, 300, seed=0)
    rng = np.random.default_rng(0)
    assign = rng.integers(0, HW.n_spus, g.n_synapses).astype(np.int32)
    scores = scores_from_assignment(g.weight, g.post, assign, HW)
    for i in range(HW.n_spus):
        sel = assign == i
        expect = HW.unified_mem_depth - (
            math.ceil((len(np.unique(g.weight[sel])) + 1) / HW.concentration)
            + len(np.unique(g.post[sel])))
        assert scores[i] == expect


def test_partition_feasible_and_respects_constraint():
    g = random_graph(20, 40, 500, seed=1)
    res = partition(g, HW, seed=0, max_iters=20000)
    assert res.feasible
    scores = scores_from_assignment(g.weight, g.post, res.assign, HW)
    assert scores.min() >= 0
    np.testing.assert_array_equal(scores, res.scores)


def test_partition_balance_under_relaxed_constraint():
    """Fig 14: with relaxed memory the distribution converges to balanced."""
    g = random_graph(20, 40, 800, seed=2)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    res = partition(g, hw, seed=0, max_iters=2000)
    counts = np.bincount(res.assign, minlength=8)
    assert res.feasible
    # P=0.5 start => near-binomial balance; generous 3-sigma-ish bound
    assert counts.std() < 0.15 * counts.mean() + 10


def test_partition_tightens_with_memory_pressure():
    """Fig 13a regime (per-SPU load >> #posts): tighter Unified Memory is
    feasible only via post/weight consolidation, which unbalances the load
    and DEEPENS the Operation Table; relaxed memory converges back to the
    balanced (minimum-depth) mapping."""
    g = random_graph(12, 24, 800, seed=3)
    ot = {}
    for L in (14, 200):
        hw = HardwareConfig(n_spus=8, unified_mem_depth=L, concentration=3,
                            max_neurons=64, max_post_neurons=32)
        res = partition(g, hw, seed=0, max_iters=60000)
        assert res.feasible, f"L={L}: min score {res.scores.min()}"
        tables = schedule(g, res.assign, hw)
        validate_schedule(g, tables)
        ot[L] = tables.depth
    assert ot[200] <= ot[14], ot


@pytest.mark.parametrize("name", list(BASELINES))
def test_baselines_produce_valid_schedules(name):
    g = random_graph(16, 32, 400, seed=4)
    hw = HardwareConfig(n_spus=8, unified_mem_depth=4096, concentration=3,
                        max_neurons=256, max_post_neurons=128)
    res = BASELINES[name](g, hw)
    tables = schedule(g, res.assign, hw)
    validate_schedule(g, tables)


def test_synapse_rr_is_balanced_post_rr_never_duplicates():
    g = random_graph(16, 32, 400, seed=5)
    rr = BASELINES["synapse_rr"](g, HW)
    counts = np.bincount(rr.assign, minlength=HW.n_spus)
    assert counts.max() - counts.min() <= 1
    pn = BASELINES["post_neuron_rr"](g, HW)
    # every post-neuron lives on exactly one SPU
    for q in np.unique(g.post):
        assert len(np.unique(pn.assign[g.post == q])) == 1


def test_schedule_depth_lower_bound():
    """OT depth >= max per-SPU synapse count (each op takes one slot)."""
    g = random_graph(16, 32, 400, seed=6)
    res = partition(g, HW, seed=0)
    tables = schedule(g, res.assign, HW)
    per_spu = np.bincount(res.assign, minlength=HW.n_spus)
    assert tables.depth >= per_spu.max()
    validate_schedule(g, tables)


def test_high_fanin_posts_send_late():
    """§6.3: posts are sent in ascending max-synapses-per-SPU order."""
    g = random_graph(16, 32, 500, seed=7)
    res = partition(g, HW, seed=0)
    tables = schedule(g, res.assign, HW)
    cmax = {}
    for q in np.unique(g.post):
        per = np.bincount(res.assign[g.post == q], minlength=HW.n_spus)
        cmax[int(q)] = int(per.max())
    sent = [cmax[q] for q in tables.send_order]
    assert sent == sorted(sent)


def test_memory_model_eq11_paper_point():
    """Eq. (11) at the Table 2 MNIST hardware point lands in the BRAM
    ballpark the paper reports (33.5 36Kb BRAMs on XC7Z020)."""
    hw = HardwareConfig(n_spus=16, unified_mem_depth=128, concentration=3,
                        weight_bits=4, potential_bits=5, max_neurons=910,
                        max_post_neurons=126)
    kb = total_memory_kb(hw, op_table_depth=661)
    assert 30 < kb < 120, kb
    brams = bram_count(hw, 661)
    assert 16 <= brams <= 50, brams
