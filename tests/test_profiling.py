"""Compile-phase profiler tests (DESIGN.md §12).

Pins the three contracts the profiler ships with: the top-level pass
phases tile the whole compile (their sum approximates
``compile_seconds``), the per-phase breakdown survives
``Program.save``/``load``, and un-profiled code paths cost nothing
(``phase()`` without an active profiler is a shared no-op object).
"""
import numpy as np
import pytest

from conftest import make_hw
from repro.core import compile, random_graph
from repro.core.mapping.multilevel import multilevel_partition
from repro.core.profiling import (TOP_LEVEL_PHASES, PhaseProfiler,
                                  current_profiler, phase, profiled)
from repro.core.program import Program
from repro.core.scale import scale_hw, synthetic_graph


def test_phase_seconds_tile_compile_time():
    g = random_graph(24, 48, 3000, seed=7)
    prog = compile(g, make_hw(g, m=8))
    rep = prog.report
    assert rep.phase_seconds is not None
    assert set(rep.phase_seconds) <= set(TOP_LEVEL_PHASES)
    assert all(v >= 0.0 for v in rep.phase_seconds.values())
    total = sum(rep.phase_seconds[k] for k in TOP_LEVEL_PHASES
                if k in rep.phase_seconds)
    # the phases tile the pipeline: everything outside them (graph
    # conversion, report attach, phase bookkeeping) is microseconds, so
    # the sum lands within a loose envelope of compile_seconds (which
    # is stamped INSIDE the report phase, hence the two-sided slack)
    assert total == pytest.approx(rep.compile_seconds, rel=0.5, abs=0.05)


def test_multilevel_subphases_recorded():
    g = synthetic_graph(4000, topology="mixed", skew=1.0, seed=0)
    hw = scale_hw(g, n_chips=2, spus_per_chip=4)
    with profiled() as prof:
        res = multilevel_partition(g, hw, coarse_target=500)
    assert res.assign.shape == (g.n_synapses,)
    for name in ("coarsen", "coarse_search", "project", "refine"):
        assert name in prof.seconds, prof.seconds
    assert "place" in prof.seconds          # n_chips > 1: placement ran


def test_compile_reuses_installed_profiler_and_nests_subphases():
    # above COARSE_TARGET so the multilevel sub-phases actually run
    g = synthetic_graph(40_000, topology="mixed", skew=1.0, seed=0)
    hw = scale_hw(g, spus_per_chip=16)
    with profiled(PhaseProfiler()) as prof:
        prog = compile(g, hw, method="multilevel")
    # compile adopted the caller's profiler rather than installing its
    # own, so top-level pass phases and the partitioner sub-phases land
    # in ONE dict (sub-phases nest inside "partition" wall time)
    assert prog.report.phase_seconds == {
        k: pytest.approx(v) for k, v in prof.seconds.items()}
    assert "partition" in prof.seconds
    sub = [k for k in prof.seconds if k not in TOP_LEVEL_PHASES]
    assert sub, "expected multilevel sub-phases on the shared profiler"
    assert sum(prof.seconds[k] for k in sub) <= \
        prof.seconds["partition"] + 1e-6


def test_phase_report_roundtrips_through_save_load(tmp_path):
    g = random_graph(16, 32, 900, seed=2)
    prog = compile(g, make_hw(g, m=8))
    with profiled(PhaseProfiler(alloc=True)):
        prog_alloc = compile(g, make_hw(g, m=8))
    assert prog_alloc.report.phase_alloc_mb is not None
    for p, name in ((prog, "wall.npz"), (prog_alloc, "alloc.npz")):
        path = tmp_path / name
        p.save(path)
        back = Program.load(path)
        assert back.report.phase_seconds == \
            pytest.approx(p.report.phase_seconds)
        if p.report.phase_alloc_mb is None:
            assert back.report.phase_alloc_mb is None
        else:
            assert back.report.phase_alloc_mb == \
                pytest.approx(p.report.phase_alloc_mb)


def test_disabled_profiling_is_none_and_phase_is_noop():
    g = random_graph(10, 20, 300, seed=0)
    prog = compile(g, make_hw(g), profile_phases=False)
    assert prog.report.phase_seconds is None
    assert prog.report.phase_alloc_mb is None
    # identical artifact either way: profiling is observe-only
    ref = compile(g, make_hw(g))
    assert np.array_equal(prog.tables.pre, ref.tables.pre)
    assert prog.report.ot_depth == ref.report.ot_depth

    # no active profiler -> phase() returns the SHARED no-op context
    # manager (no per-call allocation, nothing recorded)
    assert current_profiler() is None
    cm1, cm2 = phase("anything"), phase("else")
    assert cm1 is cm2
    with cm1:
        pass
    with profiled() as prof:
        with phase("x"):
            pass
        with phase("x"):
            pass
    assert set(prof.seconds) == {"x"}       # repeats accumulate, one key
    assert current_profiler() is None       # reset on exit
