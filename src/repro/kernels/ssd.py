"""Mamba-2 SSD recurrence as a Pallas TPU kernel — zamba2's state-space
half, same design as kernels/wkv6.py (and the same roofline motivation:
the chunked einsum form materializes O(C^2 H) decay-ratio tensors in HBM;
zamba2 train_4k sits at 0.02-0.03 of roofline, memory-bound).

The per-head SSM state S [P, N] lives in VMEM scratch across the
sequential chunk grid; tokens update it rank-1:

    S_t = exp(-exp(a_log_h) * dt_t) * S_{t-1} + dt_t * x_t b_t^T
    y_t = S_t c_t

HBM traffic = stream x/dt/b/c once + write y once. Grid (B, H, S/C),
chunk axis minormost (sequential on TPU), state re-initialized from the
carried input when the chunk index wraps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, s0_ref,
            y_ref, s_out_ref, state, *, chunk: int):
    cc = pl.program_id(2)

    @pl.when(cc == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    neg_a = jnp.exp(alog_ref[0, 0].astype(jnp.float32))   # -A > 0, scalar

    def step(t, st):
        x = x_ref[0, 0, t].astype(jnp.float32)            # [P]
        dt = dt_ref[0, 0, t].astype(jnp.float32)          # scalar
        b = b_ref[0, t].astype(jnp.float32)               # [N]
        c = c_ref[0, t].astype(jnp.float32)               # [N]
        decay = jnp.exp(-neg_a * dt)
        st = decay * st + dt * x[:, None] * b[None, :]
        y_ref[0, 0, t] = (st @ c).astype(y_ref.dtype)     # y_t = S_t c_t
        return st

    state[...] = jax.lax.fori_loop(0, chunk, step, state[...])

    @pl.when(cc == pl.num_programs(2) - 1)
    def _flush():
        s_out_ref[0, 0] = state[...].astype(s_out_ref.dtype)


def ssd_pallas(x, dt, a_log, b, c, state0, *, chunk: int = DEFAULT_CHUNK,
               interpret: bool = True):
    """x [B, S, H, P]; dt [B, S, H] (softplus'd, >= 0); a_log [H];
    b/c [B, S, N]; state0 [B, H, P, N] f32.

    Returns (y [B, S, H, P], state [B, H, P, N]). Matches
    ``repro.models.mamba2.ssd_chunked`` / ``ssd_step`` (the D-skip and
    gating stay outside, as in the model). Padding is harmless: dt pad =
    0 -> decay 1 and zero state update.
    """
    bsz, s, h, p_dim = x.shape
    n = b.shape[-1]
    pad = -s % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad

    xh = x.transpose(0, 2, 1, 3)                   # [B, H, S, P]
    dth = dt.transpose(0, 2, 1)                    # [B, H, S]

    grid = (bsz, h, sp // chunk)
    y, s_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p_dim),
                         lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bb, hh, cc: (bb, hh, cc)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, 1), lambda bb, hh, cc: (0, hh)),
            pl.BlockSpec((1, 1, p_dim, n),
                         lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p_dim),
                         lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1, 1, p_dim, n),
                         lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bsz, h, sp, p_dim), x.dtype),
                   jax.ShapeDtypeStruct((bsz, h, p_dim, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p_dim, n), jnp.float32)],
        interpret=interpret,
    )(xh, dth, b, c, a_log[None, :], state0)
    return y.transpose(0, 2, 1, 3)[:, :s], s_out
