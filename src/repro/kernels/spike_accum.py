"""Block-sparse spike-accumulation Pallas kernel.

TPU-native adaptation of SupraSNN's synapse-level parallelism (DESIGN.md §3):

* the paper's per-event skip (operation tables only hold nonzero synapses,
  SPUs idle on non-spiking pres) becomes a per-BLOCK skip — the MXU is a
  dense 128x128 systolic array, so the profitable granularity of
  event-sparsity on TPU is a VMEM tile, not a scalar;
* the MC-tree routing bitstring becomes the block-occupancy predicate
  (`any spike in this pre-tile?`) evaluated inside the kernel; a dead tile
  skips the weight MAC entirely;
* the ME-tree deterministic merge is the sequential accumulation over the
  minormost grid dimension — a fixed-order reduction, bit-identical run
  to run, exactly the paper's deterministic-commit guarantee.

Grid: (batch_blocks, post_blocks, pre_blocks); pre is minormost so each
(i, j) output tile accumulates its pre-tiles in a fixed sequential order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_PRE = 128
DEFAULT_BLOCK_POST = 128


def _kernel(s_ref, w_ref, o_ref, *, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = s_ref[...]
    # MC-tree analogue: OR-reduce the spike tile; skip dead weight tiles.
    any_spike = jnp.any(s != 0)

    @pl.when(any_spike)
    def _mac():
        o_ref[...] += jnp.dot(s.astype(acc_dtype),
                              w_ref[...].astype(acc_dtype),
                              preferred_element_type=acc_dtype)


def spike_accum(spikes: jax.Array, weights: jax.Array, *,
                block_b: int = DEFAULT_BLOCK_B,
                block_pre: int = DEFAULT_BLOCK_PRE,
                block_post: int = DEFAULT_BLOCK_POST,
                interpret: bool = True) -> jax.Array:
    """I = S @ W with block-level spike sparsity skipping.

    spikes [B, N_pre], weights [N_pre, N_post] -> [B, N_post].
    Inputs are padded to block multiples; output unpadded. f32/bf16 inputs
    accumulate in f32; integer inputs accumulate in int32 (bit-exact with
    the quantized-hardware oracle).
    """
    b, n_pre = spikes.shape
    n_pre_w, n_post = weights.shape
    assert n_pre == n_pre_w, (spikes.shape, weights.shape)

    integer = jnp.issubdtype(weights.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32

    pb = -b % block_b
    pk = -n_pre % block_pre
    pn = -n_post % block_post
    s = jnp.pad(spikes, ((0, pb), (0, pk)))
    w = jnp.pad(weights, ((0, pk), (0, pn)))

    grid = (s.shape[0] // block_b, w.shape[1] // block_post,
            s.shape[1] // block_pre)
    out = pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_pre), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_pre, block_post), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_post), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s.shape[0], w.shape[1]), acc_dtype),
        interpret=interpret,
    )(s, w)
    return out[:b, :n_post]
