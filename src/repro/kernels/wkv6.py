"""WKV-6 (RWKV "Finch") recurrence as a Pallas TPU kernel.

WHY (roofline-driven, EXPERIMENTS.md §Perf rwkv6 iterations): the pure-JAX
chunked WKV materializes the intra-chunk decay-ratio tensor
[C, C, H, N] in HBM every chunk — at train_4k scale that one intermediate
makes rwkv6-3b the WORST roofline cell of the whole grid (memory term
~100x the compute term). The kernel keeps the running state S [N, N], the
chunk inputs, and every intermediate in VMEM: HBM traffic drops to
read r/k/v/w once + write y once — the arithmetic-intensity profile the
paper's Unified-Memory/SPU-local design achieves for synaptic sums.

Mapping (DESIGN.md §3/§4): the per-head state S is "neuronal" (small,
stateful, sequential — lives in VMEM scratch like membrane potentials in
the Neuron Unit); the r/k/v/w streams are "synaptic" (big, streamed).

Grid: (B, H, S/C) with the chunk axis minormost — TPU grids execute
sequentially, so VMEM scratch carries S across chunks of one (b, h) and
re-initializes when the chunk index wraps (same pattern as spike_accum's
accumulator).

Inside a chunk the recurrence is stepped token-by-token with rank-1
updates (fori_loop over C): O(C N^2) VPU work per head-chunk with ZERO
HBM intermediates. The matrix-form intra-chunk path (two MXU matmuls)
requires an exp(+cumsum) ratio factorization that overflows for long
chunks; the sequential form is unconditionally stable, and with every
operand VMEM-resident the kernel is bandwidth- not compute-bound anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            y_ref, s_out_ref, state, *, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                    # [N]

    def step(t, st):
        r = r_ref[0, 0, t].astype(jnp.float32)          # [N]
        k = k_ref[0, 0, t].astype(jnp.float32)
        v = v_ref[0, 0, t].astype(jnp.float32)
        w = w_ref[0, 0, t].astype(jnp.float32)          # log-decay <= 0
        # y_t = r . (S + (u*k) v^T)   (current-token bonus included)
        bonus = jnp.sum(r * u * k)
        y = r @ st + bonus * v
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        # S' = diag(exp(w)) S + k v^T
        return jnp.exp(w)[:, None] * st + k[:, None] * v[None, :]

    state[...] = jax.lax.fori_loop(0, chunk, step, state[...])

    @pl.when(c == pl.num_programs(2) - 1)
    def _flush():
        s_out_ref[0, 0] = state[...].astype(s_out_ref.dtype)


def wkv6_pallas(r, k, v, w_log, u, state0, *, chunk: int = DEFAULT_CHUNK,
                interpret: bool = True):
    """r/k/v/w_log [B, S, H, N]; u [H, N]; state0 [B, H, N, N] f32.

    Returns (y [B, S, H, N], state [B, H, N, N]). S is padded to a chunk
    multiple (padded slots have k = v = 0 and exp(0) = 1 decay: the state
    passes through unchanged, so results are pad-invariant).
    """
    b, s, h, n = r.shape
    pad = -s % chunk
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, w_log = zp(r), zp(k), zp(v), zp(w_log)
    sp = s + pad

    # [B, S, H, N] -> [B, H, S, N]: the streamed tile is (tokens, features)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    r, k, v, w_log = tr(r), tr(k), tr(v), tr(w_log)

    seq_spec = pl.BlockSpec((1, 1, chunk, n),
                            lambda bb, hh, cc: (bb, hh, cc, 0))
    state_spec = pl.BlockSpec((1, 1, n, n), lambda bb, hh, cc: (bb, hh, 0, 0))
    grid = (b, h, sp // chunk)
    y, s_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, n), lambda bb, hh, cc: (hh, 0)),
                  state_spec],
        out_specs=[seq_spec, state_spec],
        out_shape=[jax.ShapeDtypeStruct((b, h, sp, n), r.dtype),
                   jax.ShapeDtypeStruct((b, h, n, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u, state0)
    y = y.transpose(0, 2, 1, 3)[:, :s]
    return y, s_out
