"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU — the kernels
TARGET TPU (BlockSpec VMEM tiling, MXU-aligned tiles) and are validated in
interpret mode on CPU (see tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.lif_update import lif_update as _lif_update
from repro.kernels.spike_accum import spike_accum as _spike_accum


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_b", "block_pre",
                                             "block_post", "interpret"))
def spike_accum(spikes, weights, *, block_b=8, block_pre=128, block_post=128,
                interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _spike_accum(spikes, weights, block_b=block_b,
                        block_pre=block_pre, block_post=block_post,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("alpha", "v_th", "v_reset",
                                             "block", "interpret"))
def lif_update(v, current, *, alpha, v_th=1.0, v_reset=0.0, block=(8, 128),
               interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _lif_update(v, current, alpha=alpha, v_th=v_th, v_reset=v_reset,
                       block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("p", "block", "interpret"))
def lif_update_int(v, current, p, *, block=(8, 128), interpret=None):
    from repro.kernels.lif_update import lif_update_int as _lif_update_int
    interpret = _default_interpret() if interpret is None else interpret
    return _lif_update_int(v, current, p, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w_log, u, state0, *, chunk=64, interpret=None):
    from repro.kernels.wkv6 import wkv6_pallas
    interpret = _default_interpret() if interpret is None else interpret
    return wkv6_pallas(r, k, v, w_log, u, state0, chunk=chunk,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, state0, *, chunk=64, interpret=None):
    from repro.kernels.ssd import ssd_pallas
    interpret = _default_interpret() if interpret is None else interpret
    return ssd_pallas(x, dt, a_log, b, c, state0, chunk=chunk,
                      interpret=interpret)
