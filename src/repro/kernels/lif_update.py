"""Fused LIF membrane-update Pallas kernels — the centralized Neuron Unit.

Leak, integrate, threshold, and reset (paper Eqs. 2/4/5, Fig. 7 pipeline)
fused into one element-wise VMEM pass: one HBM read + one write per state
element instead of the four separate passes a naive implementation costs.

Two variants share the same tiling:

* ``lif_update``     — float path (training-side inference);
* ``lif_update_int`` — int32 path with the hardware's shift-based leak
  ``V - (V >> shift)``, bit-exact with :func:`repro.snn.lif.lif_step_int`.
  This is the Neuron Unit of the compiled mapped executor
  (:mod:`repro.core.engine_jax`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.snn.lif import LIFIntParams, leak_int


DEFAULT_BLOCK = (8, 128)


def _pad_call(kernel, v, current, block, interpret):
    """Shared pad-to-block / grid / unpad wrapper for both LIF variants."""
    squeeze = v.ndim == 1
    if squeeze:
        v, current = v[None, :], current[None, :]
    b, n = v.shape
    bb, bn = block
    pb, pn = -b % bb, -n % bn
    vp = jnp.pad(v, ((0, pb), (0, pn)))
    ip = jnp.pad(current, ((0, pb), (0, pn)))

    grid = (vp.shape[0] // bb, vp.shape[1] // bn)
    v_next, spikes = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct(vp.shape, v.dtype),
                   jax.ShapeDtypeStruct(vp.shape, v.dtype)],
        interpret=interpret,
    )(vp, ip)
    v_next, spikes = v_next[:b, :n], spikes[:b, :n]
    if squeeze:
        v_next, spikes = v_next[0], spikes[0]
    return v_next, spikes


def _kernel(v_ref, i_ref, v_out_ref, s_ref, *, alpha, v_th, v_reset):
    v = v_ref[...]
    v_upd = (1.0 - alpha) * v + i_ref[...]
    spike = v_upd >= v_th
    v_out_ref[...] = jnp.where(spike, jnp.asarray(v_reset, v.dtype), v_upd)
    s_ref[...] = spike.astype(v.dtype)


def lif_update(v: jax.Array, current: jax.Array, *, alpha: float,
               v_th: float = 1.0, v_reset: float = 0.0,
               block: tuple[int, int] = DEFAULT_BLOCK,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused LIF step on [B, N] (or [N], auto-promoted) state tensors."""
    kernel = functools.partial(_kernel, alpha=alpha, v_th=v_th,
                               v_reset=v_reset)
    return _pad_call(kernel, v, current, block, interpret)


def _kernel_int(v_ref, i_ref, v_out_ref, s_ref, *, leak_shift, v_th, v_reset):
    v = v_ref[...]
    v_upd = leak_int(v, leak_shift) + i_ref[...]
    spike = v_upd >= v_th
    v_out_ref[...] = jnp.where(spike, jnp.asarray(v_reset, v.dtype), v_upd)
    s_ref[...] = spike.astype(v.dtype)


def lif_update_int(v: jax.Array, current: jax.Array, p: LIFIntParams, *,
                   block: tuple[int, int] = DEFAULT_BLOCK,
                   interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused int32 LIF step, bit-exact with ``lif_step_int``.

    Pad lanes hold v == 0, current == 0; they are sliced off before
    return, so a non-positive threshold spiking the padding is harmless.
    """
    kernel = functools.partial(_kernel_int, leak_shift=p.leak_shift,
                               v_th=p.v_threshold, v_reset=p.v_reset)
    return _pad_call(kernel, v, current, block, interpret)
