"""Fused LIF membrane-update Pallas kernel — the centralized Neuron Unit.

Leak, integrate, threshold, and reset (paper Eqs. 2/4/5, Fig. 7 pipeline)
fused into one element-wise VMEM pass: one HBM read + one write per state
element instead of the four separate passes a naive implementation costs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (8, 128)


def _kernel(v_ref, i_ref, v_out_ref, s_ref, *, alpha, v_th, v_reset):
    v = v_ref[...]
    v_upd = (1.0 - alpha) * v + i_ref[...]
    spike = v_upd >= v_th
    v_out_ref[...] = jnp.where(spike, jnp.asarray(v_reset, v.dtype), v_upd)
    s_ref[...] = spike.astype(v.dtype)


def lif_update(v: jax.Array, current: jax.Array, *, alpha: float,
               v_th: float = 1.0, v_reset: float = 0.0,
               block: tuple[int, int] = DEFAULT_BLOCK,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused LIF step on [B, N] (or [N], auto-promoted) state tensors."""
    squeeze = v.ndim == 1
    if squeeze:
        v, current = v[None, :], current[None, :]
    b, n = v.shape
    bb, bn = block
    pb, pn = -b % bb, -n % bn
    vp = jnp.pad(v, ((0, pb), (0, pn)))
    ip = jnp.pad(current, ((0, pb), (0, pn)))

    grid = (vp.shape[0] // bb, vp.shape[1] // bn)
    v_next, spikes = pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, v_th=v_th, v_reset=v_reset),
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct(vp.shape, v.dtype),
                   jax.ShapeDtypeStruct(vp.shape, v.dtype)],
        interpret=interpret,
    )(vp, ip)
    v_next, spikes = v_next[:b, :n], spikes[:b, :n]
    if squeeze:
        v_next, spikes = v_next[0], spikes[0]
    return v_next, spikes
