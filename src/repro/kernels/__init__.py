# Pallas TPU kernels for the compute hot-spots: synaptic accumulation
# (spike_accum), the centralized Neuron Unit (lif_update), and the WKV-6
# recurrence (wkv6 — the rwkv6 roofline fix, see kernels/wkv6.py).
# ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles.
from repro.kernels.ops import (lif_update, lif_update_int, spike_accum, ssd,
                               wkv6)
from repro.kernels.ref import lif_update_ref, spike_accum_ref, wkv6_ref

__all__ = ["lif_update", "lif_update_int", "spike_accum", "ssd", "wkv6",
           "lif_update_ref", "spike_accum_ref", "wkv6_ref"]
