"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp


def spike_accum_ref(spikes: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Dense reference of the synaptic accumulation I = S @ W.

    spikes:  [B, N_pre]  (0/1, any numeric dtype)
    weights: [N_pre, N_post]
    returns: [B, N_post] in f32 (or int32 for integer inputs).
    """
    acc = jnp.int32 if jnp.issubdtype(weights.dtype, jnp.integer) else jnp.float32
    return jnp.dot(spikes.astype(acc), weights.astype(acc),
                   preferred_element_type=acc)


def lif_update_ref(v: jnp.ndarray, current: jnp.ndarray, alpha: float,
                   v_th: float, v_reset: float
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused LIF membrane update (paper Eqs. 2, 4, 5).

    v, current: [N] f32. Returns (v_next, spikes) with spikes in {0,1} f32.
    """
    v_upd = (1.0 - alpha) * v + current
    s = (v_upd >= v_th).astype(v.dtype)
    v_next = jnp.where(s > 0, jnp.asarray(v_reset, v.dtype), v_upd)
    return v_next, s


def wkv6_ref(r, k, v, w_log, u, state0):
    """Sequential WKV-6 oracle (token-by-token exact recurrence).

    r/k/v/w_log [B, S, H, N]; u [H, N]; state0 [B, H, N, N].
    """
    import jax

    def step(st, xs):
        rt, kt, vt, wt = xs
        y = jnp.einsum("bhk,bhkn->bhn", rt, st) \
            + jnp.einsum("bhk,hk,bhk->bh", rt, u, kt)[..., None] * vt
        st = st * jnp.exp(wt)[..., None] \
            + jnp.einsum("bhk,bhn->bhkn", kt, vt)
        return st, y

    tr = lambda x: x.transpose(1, 0, 2, 3)
    st, ys = jax.lax.scan(
        step, state0.astype(jnp.float32),
        (tr(r.astype(jnp.float32)), tr(k.astype(jnp.float32)),
         tr(v.astype(jnp.float32)), tr(w_log.astype(jnp.float32))))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), st
