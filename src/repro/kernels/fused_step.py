"""Fused per-timestep step megakernel — route + accumulate + Neuron Unit.

The ``"lif"`` engine tier executes every timestep as three
XLA-fused-but-distinct ops: a gather over the lowered op stream
(multicast routing), a segment-sum (per-SPU weight accumulation merged
by the ME tree), and the small Pallas LIF kernel (the centralized
Neuron Unit) — round-tripping the spike plane and synaptic currents
through HBM between each. This module collapses the whole timestep
into ONE ``pallas_call``, mirroring the decoupled-SPU / unified-NU
dataflow SupraSNN implements in hardware (Fig. 7): spikes stream in,
currents accumulate on-chip, membrane state updates in place.

Memory layout (DESIGN.md §10):

* the lowered op stream is **densified** once per engine into a weight
  plane ``W[n_neurons, n_internal]`` with ``W[q, p] = Σ weight`` over
  all (q -> p) synapses, packed to the narrowest signed dtype that
  holds every entry (int8 for the paper's 4-bit MNIST net, int16 for
  the 9-bit SHD net). The synaptic phase is then the exact int32
  contraction ``current = s_all @ W`` — identical bits to the
  segment-sum (int32 addition is associative; deterministic-commit
  property, paper §4.2);
* the grid is ``(batch blocks, post blocks, pre blocks)`` with the pre
  (reduction) axis innermost; spike and weight tiles stream through
  VMEM under Pallas's pipelined BlockSpec DMA (each next tile is
  fetched while the current one multiplies — the double-buffered spike
  plane of the hardware's distribution phase);
* partial currents live in an int32 VMEM scratch accumulator; on the
  LAST pre block the Neuron Unit epilogue runs in-register: shift-leak,
  integrate, threshold, reset — one HBM read and one write per state
  element for the whole timestep;
* the membrane-state input is aliased onto the ``v_next`` output
  (``input_output_aliases``), so the donated state buffer is updated
  in place rather than reallocated every step;
* MC packet counts (one packet per fired neuron, the distribution
  phase of the cycle model) are counted from the same streamed spike
  tiles at ``j == 0`` — the fused step emits them for free.

Bit-exactness (spikes, potentials AND packet counts) vs the unfused
tiers is pinned by ``tests/test_fused_kernel.py`` over feedforward +
recurrent graphs, ragged batch sizes, random quantized nets
(hypothesis) and the golden artifact.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.ranges import dense_plane_bounds, min_safe_dtype
from repro.snn.lif import LIFIntParams

DEFAULT_BLOCK = (8, 128, 128)           # (batch, post, pre) tile

# Densifying the op stream costs n_neurons * n_internal entries; past
# this many bytes the fused tier refuses and the caller should stay on
# the streaming "lif" tier (override via env for big-memory hosts).
MAX_DENSE_BYTES = int(os.environ.get("SUPRASNN_FUSED_MAX_BYTES",
                                     256 * 1024 * 1024))


@dataclasses.dataclass(frozen=True)
class DenseSynapses:
    """The lowered op stream as a packed dense weight plane.

    ``value_min``/``value_max`` are the PROVEN bounds of the folded
    plane (min/max after summing duplicate (pre, post) ops) — the
    facts the range analyzer (:mod:`repro.analysis.ranges`) consumes
    directly instead of re-scanning the dense array.
    """
    weight: np.ndarray                  # [n_neurons, n_internal], int8/16/32
    n_neurons: int
    n_internal: int
    value_min: int = 0                  # exact folded-plane bounds
    value_max: int = 0

    @property
    def dtype(self) -> np.dtype:
        return self.weight.dtype


def pack_dense(lowered) -> DenseSynapses:
    """Densify a :class:`~repro.core.scheduling.LoweredProgram`.

    Sums duplicate (pre, post) ops exactly (int32), then packs to the
    narrowest signed dtype holding every SUMMED entry — the packing
    check runs on the dense plane, not the raw weights, so two int8
    synapses folding into a >int8 entry still pack correctly wider.
    The folded bounds (and the dtype choice they imply) are computed
    by the static range analyzer BEFORE any densification, so the
    size-guard message can already name the dtype the plane would use.
    """
    n, m = lowered.n_neurons, lowered.n_internal
    lo, hi = dense_plane_bounds(lowered.op_pre, lowered.op_post_local,
                                lowered.op_weight, n, m)
    if n * m * 4 > MAX_DENSE_BYTES:
        raise ValueError(
            f"fused kernel tier would densify {n}x{m} weights "
            f"(> {MAX_DENSE_BYTES} bytes; plane values in [{lo}, {hi}], "
            f"minimal safe dtype {min_safe_dtype(lo, hi)}); use "
            f"kernel='lif' for this graph or raise "
            f"SUPRASNN_FUSED_MAX_BYTES")
    w = np.zeros((n, m), np.int32)
    np.add.at(w, (lowered.op_pre, lowered.op_post_local), lowered.op_weight)
    dt = np.dtype(min_safe_dtype(lo, hi))
    if dt.itemsize < 4:                 # int8/int16; int32 already holds it
        w = w.astype(dt)
    return DenseSynapses(weight=w, n_neurons=n, n_internal=m,
                         value_min=lo, value_max=hi)


# ---------------------------------------------------------------------------
# The kernel body.
# ---------------------------------------------------------------------------

def _kernel(s_ref, w_ref, v_ref, v_out_ref, s_out_ref, pkt_ref,
            acc_ref, pkt_acc_ref, *, leak_shift, v_th, v_reset, nk):
    j, k = pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((k == 0) & (j == 0))
    def _init_pkt():
        pkt_acc_ref[...] = jnp.zeros_like(pkt_acc_ref)

    # synaptic phase: exact int32 contraction of the streamed spike
    # tile with the packed weight tile (== segment-sum == ME tree)
    s_blk = s_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        s_blk, w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    # distribution phase: one MC packet per fired neuron; count once
    # per pre tile (j == 0 — the count is independent of the post tile)
    @pl.when(j == 0)
    def _count_packets():
        pkt_acc_ref[...] += jnp.sum((s_blk != 0).astype(jnp.int32),
                                    axis=1, keepdims=True)

    # Neuron Unit epilogue on the last pre tile: shift-leak, integrate,
    # threshold, reset — in-register, one state read + one write
    @pl.when(k == nk - 1)
    def _neuron_unit():
        v = v_ref[...]
        v_upd = (v - jax.lax.shift_right_arithmetic(
            v, jnp.int32(leak_shift))) + acc_ref[...]
        spike = v_upd >= v_th
        v_out_ref[...] = jnp.where(spike, jnp.asarray(v_reset, v.dtype),
                                   v_upd)
        s_out_ref[...] = spike.astype(jnp.int32)

    @pl.when((j == 0) & (k == nk - 1))
    def _emit_packets():
        pkt_ref[...] = pkt_acc_ref[...]


def fused_step(s_all: jax.Array, v: jax.Array, weight: jax.Array,
               p: LIFIntParams, *,
               block: tuple[int, int, int] | None = None,
               interpret: bool = True
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused timestep: ``(v_next, spikes, packet_counts)``.

    s_all:  [B, n_neurons] int32 spike plane (external ‖ internal t-1).
    v:      [B, n_internal] int32 membrane state — aliased onto the
            ``v_next`` output, so pass a donated/owned buffer.
    weight: [n_neurons, n_internal] packed dense plane
            (:func:`pack_dense`); any signed int dtype, accumulated
            in int32.

    ``block=None`` resolves per backend: the (8, 128, 128) VMEM tiling
    on real TPU, but ONE full-array tile (grid ``(1, 1, 1)``) under
    interpret mode — the interpreter walks the grid in Python, so on
    CPU the single-tile kernel lowers to one XLA dot + epilogue
    instead of hundreds of emulated DMA steps. Tiling only changes the
    visit order of an associative int32 reduction, so every block
    choice is bit-exact (pinned in tests/test_fused_kernel.py).

    Pad lanes are all-zero spikes / zero weights / zero potentials:
    they contribute nothing to real currents and are sliced off before
    return, so a non-positive threshold spiking the padding is
    harmless (same rule as ``lif_update_int``).
    """
    b, n_all = s_all.shape
    n_int = v.shape[1]
    if block is None:
        block = (b, n_int, n_all) if interpret else DEFAULT_BLOCK
    bb, bn, bk = block
    sp = jnp.pad(s_all, ((0, -b % bb), (0, -n_all % bk)))
    vp = jnp.pad(v, ((0, -b % bb), (0, -n_int % bn)))
    wp = jnp.pad(weight, ((0, -n_all % bk), (0, -n_int % bn)))
    nb, nj, nk = sp.shape[0] // bb, vp.shape[1] // bn, sp.shape[1] // bk
    kernel = functools.partial(_kernel, leak_shift=p.leak_shift,
                               v_th=p.v_threshold, v_reset=p.v_reset, nk=nk)
    v_next, s_out, pkt = pl.pallas_call(
        kernel,
        grid=(nb, nj, nk),              # pre (reduction) axis innermost
        in_specs=[pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                  pl.BlockSpec((bb, bn), lambda i, j, k: (i, j))],
        out_specs=[pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bb, 1), lambda i, j, k: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(vp.shape, jnp.int32),
                   jax.ShapeDtypeStruct(vp.shape, jnp.int32),
                   jax.ShapeDtypeStruct((sp.shape[0], 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.int32),
                        pltpu.VMEM((bb, 1), jnp.int32)],
        input_output_aliases={2: 0},    # v updates in place (donation)
        interpret=interpret,
    )(sp, wp, vp)
    return v_next[:b, :n_int], s_out[:b, :n_int], pkt[:b, 0]
