"""Static analysis over compiled SupraSNN artifacts (DESIGN.md §13).

``verify(program)`` proves the paper's architectural contract —
schedule legality, integer ranges, Eq. 9/11 memory bounds — on a
loaded :class:`~repro.core.program.Program` WITHOUT executing any
engine, and reports violations as structured
:class:`~repro.analysis.diagnostics.Diagnostic` records with stable
codes. Entry points: :meth:`repro.core.program.Program.verify`, the
``python -m repro.analysis.verify`` CLI, and the
``ProgramRegistry.register(verify=True)`` serving gate.
"""
from typing import Any

from repro.analysis.diagnostics import (CODES, Diagnostic, Location,
                                        Severity, VerifyReport,
                                        register_code)

__all__ = ["CODES", "CHECKERS", "Diagnostic", "Location", "Severity",
           "VerifyReport", "register_code", "register_checker", "verify"]

_DRIVER = {"verify", "register_checker", "CHECKERS"}


def __getattr__(name: str) -> Any:
    # the driver is loaded lazily (PEP 562) so `python -m
    # repro.analysis.verify` does not import it twice (once as part of
    # the package, once as __main__ — runpy warns about that). The
    # resolved attribute is pinned into the package namespace so
    # `repro.analysis.verify` stays the FUNCTION even though the
    # submodule import transiently bound the module object there.
    if name in _DRIVER:
        import importlib
        mod = importlib.import_module("repro.analysis.verify")
        for n in _DRIVER:
            globals()[n] = getattr(mod, n)
        return globals()[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
