"""Memory / capacity audit — Eq. 9/10/11 recomputed from raw arrays.

The persisted :class:`~repro.core.passes.CompileReport` header is a
CLAIM about the artifact (scores, occupancy, Eq. 11 memory, BRAM
count, init-packet count). This checker recomputes every one of those
claims from the raw graph + tables arrays — the ground truth an engine
would actually execute — and cross-checks the header, catching stale
or hand-edited artifacts that "compile succeeded" can never catch:

* MEM001  Eq. 9 per-SPU occupancy overflow on a feasible-claimed
          artifact (the hard hardware constraint);
* MEM002  persisted per-SPU scores != recomputed Eq. 10;
* MEM003  persisted per-SPU synapse/post/weight stats != recomputed;
* MEM004  header ``ot_depth`` != the actual table depth;
* MEM005  persisted :class:`~repro.core.cost.ResourceReport` != the
          Eq. 11 / BRAM / LUT / FF recompute at the actual depth;
* MEM006  header ``n_init_packets`` != the closed-form recompute;
* MEM007  graph exceeds the ``max_neurons`` addressing capacity;
* MEM008  internal neurons exceed the Neuron State SRAM capacity
          (``n_chips * max_post_neurons``);
* MEM009  header says infeasible but the recomputed scores are all
          non-negative (conservatively stale; WARNING).

Everything is recomputed from ``tables.assign`` — the mapping that
executes — so a partitioner result diverging from the shipped tables
surfaces as MEM002/MEM003 mismatches. ``repro.core`` is imported
lazily inside the checker to keep the analysis layer import-light.
"""
from __future__ import annotations

import math
from typing import TYPE_CHECKING, Any

import numpy as np

from repro.analysis.diagnostics import (Diagnostic, Location, Severity,
                                        register_code)

if TYPE_CHECKING:
    from repro.core.program import Program

MEM001 = register_code(
    "MEM001", "Eq. 9 Unified-Memory occupancy overflow on a feasible artifact")
MEM002 = register_code("MEM002", "persisted SPU scores != recomputed Eq. 10")
MEM003 = register_code(
    "MEM003", "persisted per-SPU stats != recomputed from arrays")
MEM004 = register_code("MEM004", "header ot_depth != actual table depth")
MEM005 = register_code(
    "MEM005", "persisted resource report != Eq. 11 recompute")
MEM006 = register_code(
    "MEM006", "header n_init_packets != closed-form recompute")
MEM007 = register_code("MEM007", "graph exceeds max_neurons addressing")
MEM008 = register_code(
    "MEM008", "internal neurons exceed Neuron State SRAM capacity")
MEM009 = register_code(
    "MEM009", "header says infeasible but recomputed scores are clean")


def _first_diff(a: Any, b: Any) -> int:
    d = np.flatnonzero(np.asarray(a) != np.asarray(b))
    return int(d[0]) if len(d) else -1


def check_memory(program: "Program") -> tuple[list[Diagnostic],
                                              dict[str, Any]]:
    """MEM diagnostics + recomputed memory facts for an artifact."""
    from repro.core.cost import resources
    from repro.core.memory_model import (scores_from_assignment,
                                         total_memory_bits,
                                         usage_from_assignment)
    from repro.core.passes import _spu_stats, n_initialization_packets

    g, hw, tables, rep = (program.graph, program.hw, program.tables,
                          program.report)
    out: list[Diagnostic] = []
    assign = tables.assign

    # -- Eq. 9/10 from the shipped mapping ----------------------------------
    scores = scores_from_assignment(g.weight, g.post, assign, hw)
    usage = usage_from_assignment(g.weight, g.post, assign, hw)
    worst = int(np.argmin(scores)) if len(scores) else 0
    if rep.feasible and len(scores) and int(scores[worst]) < 0:
        out.append(Diagnostic(
            code=MEM001, severity=Severity.ERROR,
            message=(f"SPU {worst} uses {int(usage[worst])} memory lines "
                     f"> depth {hw.unified_mem_depth} (Eq. 9 score "
                     f"{int(scores[worst])}) on a feasible-claimed artifact"),
            location=Location(spu=worst, field="report.feasible"),
            hint="the mapping overflows the Unified Memory; re-partition",
            count=int((scores < 0).sum())))
    if not rep.feasible and len(scores) and int(scores.min()) >= 0:
        out.append(Diagnostic(
            code=MEM009, severity=Severity.WARNING,
            message=("header says infeasible but every recomputed Eq. 10 "
                     f"score is >= 0 (min {int(scores.min())})"),
            location=Location(field="report.feasible"),
            hint="stale conservative header; recompile to refresh"))
    if not np.array_equal(np.asarray(rep.scores), scores):
        i = _first_diff(rep.scores, scores)
        out.append(Diagnostic(
            code=MEM002, severity=Severity.ERROR,
            message=(f"persisted score[{i}]={int(np.asarray(rep.scores)[i])}"
                     f" != recomputed Eq. 10 score {int(scores[i])}"),
            location=Location(spu=i, field="report.scores"),
            hint="stale header (or tables.assign diverged); recompile",
            count=int((np.asarray(rep.scores) != scores).sum())))

    # -- per-SPU stats ------------------------------------------------------
    syn, posts, weights = _spu_stats(g, assign, hw.n_spus)
    for name, have, want in (("spu_synapse_counts", rep.spu_synapse_counts,
                              syn),
                             ("spu_post_counts", rep.spu_post_counts, posts),
                             ("spu_weight_counts", rep.spu_weight_counts,
                              weights)):
        if not np.array_equal(np.asarray(have), want):
            i = _first_diff(have, want)
            out.append(Diagnostic(
                code=MEM003, severity=Severity.ERROR,
                message=(f"persisted {name}[{i}]="
                         f"{int(np.asarray(have)[i])} != recomputed "
                         f"{int(want[i])}"),
                location=Location(spu=i, field=f"report.{name}"),
                hint="stale header; recompile",
                count=int((np.asarray(have) != want).sum())))

    # -- OT depth -----------------------------------------------------------
    if int(rep.ot_depth) != int(tables.depth):
        out.append(Diagnostic(
            code=MEM004, severity=Severity.ERROR,
            message=(f"header ot_depth={int(rep.ot_depth)} != actual table "
                     f"depth {int(tables.depth)}"),
            location=Location(field="report.ot_depth"),
            hint="stale header; recompile"))

    # -- Eq. 11 / BRAM / LUT / FF at the ACTUAL depth -----------------------
    res = resources(hw, int(tables.depth))
    for fld, have, want in (("luts", rep.resources.luts, res.luts),
                            ("ffs", rep.resources.ffs, res.ffs),
                            ("brams", rep.resources.brams, res.brams),
                            ("memory_kb", rep.resources.memory_kb,
                             res.memory_kb)):
        if not math.isclose(float(have), float(want), rel_tol=1e-12,
                            abs_tol=1e-9):
            out.append(Diagnostic(
                code=MEM005, severity=Severity.ERROR,
                message=(f"persisted resources.{fld}={have} != Eq. 11 "
                         f"recompute {want} at depth {int(tables.depth)}"),
                location=Location(field=f"report.resources.{fld}"),
                hint="stale header; recompile"))

    # -- init-packet count --------------------------------------------------
    n_init = n_initialization_packets(g, tables)
    if int(rep.n_init_packets) != n_init:
        out.append(Diagnostic(
            code=MEM006, severity=Severity.ERROR,
            message=(f"header n_init_packets={int(rep.n_init_packets)} != "
                     f"recomputed stream length {n_init}"),
            location=Location(field="report.n_init_packets"),
            hint="stale header; recompile"))

    # -- per-chip capacity bounds -------------------------------------------
    if g.n_neurons > hw.max_neurons:
        out.append(Diagnostic(
            code=MEM007, severity=Severity.ERROR,
            message=(f"{g.n_neurons} neurons exceed the max_neurons="
                     f"{hw.max_neurons} addressing capacity"),
            location=Location(field="hw.max_neurons"),
            hint="raise max_neurons (wider routing words) or shrink the net"))
    nu_capacity = hw.n_chips * hw.max_post_neurons
    if g.n_internal > nu_capacity:
        out.append(Diagnostic(
            code=MEM008, severity=Severity.ERROR,
            message=(f"{g.n_internal} internal neurons exceed the Neuron "
                     f"State SRAM capacity {nu_capacity} "
                     f"({hw.n_chips} chip(s) x max_post_neurons="
                     f"{hw.max_post_neurons})"),
            location=Location(field="hw.max_post_neurons"),
            hint="raise max_post_neurons or scale out n_chips"))

    stats: dict[str, Any] = {
        "score_min": int(scores.min()) if len(scores) else 0,
        "usage_max": int(usage.max()) if len(usage) else 0,
        "unified_mem_depth": int(hw.unified_mem_depth),
        "ot_depth": int(tables.depth),
        "total_memory_bits": int(total_memory_bits(hw, int(tables.depth))),
        "memory_kb": float(res.memory_kb),
        "brams": float(res.brams),
        "n_init_packets": int(n_init),
        "feasible": bool(rep.feasible),
    }
    return out, stats
