"""Schedule hazard detector — static legality analysis of OpTables.

Re-derives send-slot occupancy from the raw ``[M, depth]`` tables and
proves the paper's scheduling contract without executing any engine:

* every synapse appears exactly once (SCHED001/002);
* Merge-Tree alignment — every Post-End op of post ``p`` sits in
  ``p``'s one global send slot (SCHED003/004/005);
* the send-slot deadline — no op of ``p`` after its send slot
  (SCHED006);
* Pre-End marks exactly the last reference per (SPU, pre) (SCHED007);
* one-send-per-slot — two posts sharing a send slot would merge into
  one Neuron-Unit commit (SCHED008, a hazard the legacy validator
  never checked);
* table well-formedness — NOP slots carry no payload, op indices are
  in range (SCHED009).

This module subsumes ``repro.core.scheduling.validate`` — that module
is now a compat shim calling :func:`check_schedule` and raising
``AssertionError`` with the exact legacy message via
:func:`raise_legacy` (tests/test_mapping.py and
tests/test_scheduling.py pin those messages). All checks are numpy
mask/lexsort expressions; one diagnostic is emitted per code, carrying
the FIRST violation (legacy ``np.argmax`` order) plus the total count.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np

from repro.analysis.diagnostics import (Diagnostic, Location, Severity,
                                        register_code)

if TYPE_CHECKING:                      # runtime import stays lazy/cheap
    from repro.core.graph import SNNGraph
    from repro.core.scheduling.tables import OpTables

NOP = -1                               # mirrors scheduling.tables.NOP

SCHED001 = register_code("SCHED001", "op count != synapse count")
SCHED002 = register_code("SCHED002", "op multiset != synapse multiset")
SCHED003 = register_code(
    "SCHED003", "Merge-Tree alignment: Post-End op outside its send slot")
SCHED004 = register_code("SCHED004", "duplicate Post-End per (SPU, post)")
SCHED005 = register_code("SCHED005", "missing Post-End for a (SPU, post)")
SCHED006 = register_code("SCHED006", "op scheduled after its send slot")
SCHED007 = register_code(
    "SCHED007", "Pre-End flag not on the last (SPU, pre) reference")
SCHED008 = register_code(
    "SCHED008", "send-slot collision: two posts share one slot")
SCHED009 = register_code(
    "SCHED009", "malformed op slot (NOP payload or out-of-range index)")

# the order the legacy validator checked invariants in; raise_legacy
# surfaces the first diagnostic under this priority so assertion
# messages stay pinned bit-for-bit
LEGACY_PRIORITY = [SCHED001, SCHED002, SCHED003, SCHED004, SCHED005,
                   SCHED006, SCHED007, SCHED008, SCHED009]


def _diag(code: str, message: str, count: int = 1,
          hint: str = "", **loc: Any) -> Diagnostic:
    return Diagnostic(code=code, severity=Severity.ERROR, message=message,
                      location=Location(**loc), hint=hint, count=count)


def check_schedule(g: "SNNGraph", tables: "OpTables") -> list[Diagnostic]:
    """All schedule-legality diagnostics for (graph, tables).

    Pure and total: never raises on corrupt inputs — malformed values
    become SCHED009 diagnostics and are masked out of the dependent
    checks. Returns ``[]`` exactly when the legacy validator accepted.
    """
    out: list[Diagnostic] = []
    n = int(g.n_neurons)
    valid = tables.pre != NOP
    spu_i, slot_i = np.nonzero(valid)           # row-major: (spu, t) order
    pre_v = tables.pre[spu_i, slot_i]
    post_v = tables.post[spu_i, slot_i]
    w_v = tables.weight[spu_i, slot_i]

    # -- SCHED009: well-formedness ------------------------------------------
    nop_payload = (~valid) & ((tables.post != NOP) | (tables.weight != 0)
                              | tables.pre_end | tables.post_end)
    bad_idx = ((pre_v < 0) | (pre_v >= n)
               | (post_v < g.n_inputs) | (post_v >= n))
    n_bad = int(nop_payload.sum()) + int(bad_idx.sum())
    if n_bad:
        if nop_payload.any():
            s, t = (int(x) for x in np.argwhere(nop_payload)[0])
            msg = f"NOP slot carries payload on SPU {s} at slot {t}"
        else:
            i = int(np.argmax(bad_idx))
            s, t = int(spu_i[i]), int(slot_i[i])
            msg = (f"op index out of range on SPU {s} at slot {t} "
                   f"(pre={int(pre_v[i])}, post={int(post_v[i])}, "
                   f"n_neurons={n})")
        out.append(_diag(SCHED009, msg, count=n_bad, spu=s, slot=t,
                         hint="artifact arrays are corrupt; recompile"))
    ok = ~bad_idx                                # mask for index-safe checks

    # -- SCHED001: every synapse appears exactly once -----------------------
    n_placed = int(valid.sum())
    if n_placed != g.n_synapses:
        out.append(_diag(
            SCHED001, f"{n_placed} ops != {g.n_synapses} synapses",
            hint="ops were dropped or invented; re-run schedule_pass"))

    # -- SCHED002: op multiset == synapse multiset --------------------------
    have = np.lexsort((w_v, post_v, pre_v))
    want = np.lexsort((g.weight, g.post, g.pre))
    if not (len(have) == len(want)
            and np.array_equal(pre_v[have], g.pre[want])
            and np.array_equal(post_v[have], g.post[want])
            and np.array_equal(w_v[have], g.weight[want])):
        msg = "op multiset != synapse multiset"
        kw: dict[str, int] = {}
        if len(have) == len(want) and len(have):
            d = ((pre_v[have] != g.pre[want]) | (post_v[have] != g.post[want])
                 | (w_v[have] != g.weight[want]))
            j = int(np.argmax(d))
            i = int(have[j])
            kw = {"spu": int(spu_i[i]), "slot": int(slot_i[i]),
                  "pre": int(pre_v[i]), "post": int(post_v[i])}
            msg += (f" (first diverging op pre={int(pre_v[i])} "
                    f"post={int(post_v[i])} weight={int(w_v[i])} on SPU "
                    f"{kw['spu']} slot {kw['slot']})")
        out.append(_diag(SCHED002, msg,
                         hint="table payload diverged from the graph; "
                              "recompile", **kw))

    # send slot per post as a dense lookup table (missing posts read -1)
    ss = np.full(n, -1, np.int64)
    for pq, t in tables.send_slot.items():
        if 0 <= int(pq) < n:
            ss[int(pq)] = int(t)

    # -- SCHED003: merge alignment ------------------------------------------
    pe_spu, pe_slot = np.nonzero(tables.post_end)
    pe_post = tables.post[pe_spu, pe_slot]
    pe_ok = (pe_post >= 0) & (pe_post < n)
    bad = np.zeros(len(pe_post), bool)
    bad[pe_ok] = ss[pe_post[pe_ok]] != pe_slot[pe_ok]
    if bad.any():
        i = int(np.argmax(bad))                  # first violation, (spu, t)
        out.append(_diag(
            SCHED003,
            f"post {int(pe_post[i])} sent at {int(pe_slot[i])} "
            f"!= slot {int(ss[int(pe_post[i])])}",
            count=int(bad.sum()), spu=int(pe_spu[i]),
            slot=int(pe_slot[i]), post=int(pe_post[i]),
            hint="send_slot and Post-End flags disagree; the Merge Tree "
                 "would commit this post in the wrong slot"))

    # -- SCHED004/005: exactly one Post-End per (spu, post with ops) --------
    pe_key = pe_spu[pe_ok] * n + pe_post[pe_ok]
    op_key = spu_i[ok] * n + post_v[ok]
    uniq_pe, pe_counts = np.unique(pe_key, return_counts=True)
    dup = pe_counts > 1
    if dup.any():
        k = int(uniq_pe[np.argmax(dup)])
        out.append(_diag(
            SCHED004,
            f"duplicate post_end in one SPU "
            f"(post {k % n} flagged {int(pe_counts[np.argmax(dup)])}x "
            f"on SPU {k // n})",
            count=int(dup.sum()), spu=k // n, post=k % n,
            hint="a post would be committed twice by one SPU"))
    uniq_op = np.unique(op_key)
    if not np.array_equal(uniq_pe, uniq_op):
        missing = np.setdiff1d(uniq_op, uniq_pe)
        extra = np.setdiff1d(uniq_pe, uniq_op)
        k = int(missing[0]) if len(missing) else int(extra[0])
        what = "no ops" if not len(missing) else "no Post-End"
        out.append(_diag(
            SCHED005,
            f"missing post_end (post {k % n} on SPU {k // n} has {what})",
            count=int(len(missing) + len(extra)), spu=k // n, post=k % n,
            hint="every (SPU, post) group must end in exactly one "
                 "Post-End op"))

    # -- SCHED006: all ops of (spu, post) at slots <= send slot -------------
    late = np.zeros(len(post_v), bool)
    late[ok] = slot_i[ok] > ss[post_v[ok]]
    if late.any():
        i = int(np.argmax(late))
        out.append(_diag(
            SCHED006,
            f"op of post {int(post_v[i])} on SPU {int(spu_i[i])} at slot "
            f"{int(slot_i[i])} after its send slot {int(ss[post_v[i]])}",
            count=int(late.sum()), spu=int(spu_i[i]), slot=int(slot_i[i]),
            post=int(post_v[i]),
            hint="the accumulated current would arrive after the Neuron "
                 "Unit already committed this post"))

    # -- SCHED007: pre_end exactly on last reference per (spu, pre) ---------
    key = spu_i[ok] * n + np.clip(pre_v[ok], 0, n - 1)
    order = np.lexsort((slot_i[ok], key))
    k_sorted, s_sorted = key[order], slot_i[ok][order]
    is_last = np.r_[k_sorted[1:] != k_sorted[:-1],
                    np.ones(min(len(key), 1), bool)]
    fe_spu, fe_slot = np.nonzero(tables.pre_end)
    fe_pre = tables.pre[fe_spu, fe_slot]
    fe_ok = (fe_pre >= 0) & (fe_pre < n)
    fkey = fe_spu[fe_ok] * n + fe_pre[fe_ok]
    forder = np.lexsort((fe_slot[fe_ok], fkey))
    fk, fs = fkey[forder], fe_slot[fe_ok][forder]
    f_last = np.r_[fk[1:] != fk[:-1], np.ones(min(len(fk), 1), bool)]
    if not (np.array_equal(fk[f_last], k_sorted[is_last])
            and np.array_equal(fs[f_last], s_sorted[is_last])):
        want_pairs = set(zip(k_sorted[is_last].tolist(),
                             s_sorted[is_last].tolist()))
        got_pairs = set(zip(fk[f_last].tolist(), fs[f_last].tolist()))
        diff = sorted(want_pairs ^ got_pairs)
        k2, t2 = (diff[0] if diff else (0, 0))
        out.append(_diag(
            SCHED007,
            f"pre_end flags wrong (pre {int(k2) % n} on SPU {int(k2) // n} "
            f"around slot {int(t2)})",
            count=max(len(diff), 1), spu=int(k2) // n, slot=int(t2),
            pre=int(k2) % n,
            hint="Pre-End must clear the Spike Memory bit exactly at the "
                 "last reference"))

    # -- SCHED008: one send per slot (Merge-Tree occupancy) -----------------
    slots = np.asarray(sorted(int(t) for t in tables.send_slot.values()),
                       np.int64)
    coll = np.flatnonzero(slots[1:] == slots[:-1]) if len(slots) else \
        np.zeros(0, np.int64)
    if len(coll):
        t = int(slots[int(coll[0])])
        posts = sorted(int(p) for p, tt in tables.send_slot.items()
                       if int(tt) == t)
        out.append(_diag(
            SCHED008,
            f"send-slot collision: posts {posts} all sent at slot {t}",
            count=int(len(coll)), slot=t, post=posts[0],
            hint="the Merge Tree would fold distinct posts into one "
                 "Neuron-Unit commit; reschedule"))

    return out


def raise_legacy(diags: list[Diagnostic]) -> None:
    """Compat shim: raise ``AssertionError`` for the highest-priority
    diagnostic under the legacy check order (message parity with the
    pre-framework ``validate_schedule`` asserts), or return silently."""
    if not diags:
        return
    rank = {c: i for i, c in enumerate(LEGACY_PRIORITY)}
    first = min(diags, key=lambda d: (rank.get(d.code, len(rank))))
    raise AssertionError(first.message)
