"""The structured-diagnostic model of the artifact verifier.

Every invariant the static analyzer proves (or refutes) about a
compiled :class:`~repro.core.program.Program` is reported as a
:class:`Diagnostic` — a stable error code (``SCHED003``), a severity,
a structured :class:`Location` naming the offending (post, SPU, slot,
header field), a human message, and a fix hint. The full code registry
lives in :data:`CODES` (DESIGN.md §13 documents each); checkers
register their codes at import time via :func:`register_code`, and the
driver (:mod:`repro.analysis.verify`) refuses diagnostics with
unregistered codes so the registry can never drift from what is
actually emitted.

A :class:`VerifyReport` is the collected output of one
:func:`repro.analysis.verify.verify` run: diagnostics, per-checker
facts (the range analyzer's proven bounds, the memory audit's
recomputed totals), and wall time. ``report.ok`` means "no
ERROR-severity diagnostics" — the gate
:meth:`repro.serve.registry.ProgramRegistry.register` enforces with
``verify=True``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any


class Severity(enum.IntEnum):
    """Diagnostic severity; ordering is by increasing gravity."""
    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Location:
    """Where in the artifact a diagnostic points.

    All fields are optional; ``spu``/``slot`` address the OpTables
    grid, ``post``/``pre`` are global neuron indices, and ``field``
    names a persisted header entry (for the stale-header audit).
    """
    spu: int | None = None
    slot: int | None = None
    post: int | None = None
    pre: int | None = None
    field: str | None = None

    def __str__(self) -> str:
        parts = [f"{k}={v}" for k, v in (
            ("spu", self.spu), ("slot", self.slot), ("post", self.post),
            ("pre", self.pre), ("field", self.field)) if v is not None]
        return ", ".join(parts) if parts else "-"

    def to_json(self) -> dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verified-invariant violation (or notice) in an artifact."""
    code: str                        # stable registry key, e.g. "SCHED003"
    severity: Severity
    message: str                     # human text; legacy-parity where pinned
    location: Location = Location()
    hint: str = ""                   # how to fix / what to re-run
    count: int = 1                   # total violations this diag summarizes

    def __str__(self) -> str:
        more = f" (+{self.count - 1} more)" if self.count > 1 else ""
        hint = f" [hint: {self.hint}]" if self.hint else ""
        return (f"{self.code} {self.severity}: {self.message}{more} "
                f"@ {self.location}{hint}")

    def to_json(self) -> dict[str, Any]:
        return {"code": self.code, "severity": str(self.severity),
                "message": self.message, "location": self.location.to_json(),
                "hint": self.hint, "count": self.count}


@dataclasses.dataclass
class VerifyReport:
    """The collected result of one static verification run."""
    diagnostics: list[Diagnostic]
    stats: dict[str, Any]            # checker name -> proven facts
    checkers: list[str]              # checkers that ran, in order
    wall_ms: float
    checker_wall_ms: dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def ok(self) -> bool:
        """True iff no ERROR-severity diagnostic was emitted."""
        return not self.errors

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity >= Severity.ERROR]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity == Severity.WARNING]

    def codes(self) -> set[str]:
        return {d.code for d in self.diagnostics}

    def to_json(self) -> dict[str, Any]:
        return {
            "ok": self.ok,
            "diagnostics": [d.to_json() for d in self.diagnostics],
            "stats": self.stats,
            "checkers": self.checkers,
            "wall_ms": self.wall_ms,
            "checker_wall_ms": self.checker_wall_ms,
        }

    def summary(self) -> str:
        """Human one-per-line rendering (the CLI's default output)."""
        head = (f"{len(self.diagnostics)} diagnostic(s), "
                f"{len(self.errors)} error(s) "
                f"[{', '.join(self.checkers)}; {self.wall_ms:.1f} ms]")
        if not self.diagnostics:
            return f"clean: 0 diagnostics {head[len('0 diagnostic(s), '):]}"
        return "\n".join([head] + [f"  {d}" for d in self.diagnostics])


# ---------------------------------------------------------------------------
# The stable diagnostic-code registry (DESIGN.md §13).
# ---------------------------------------------------------------------------

CODES: dict[str, str] = {}


def register_code(code: str, title: str) -> str:
    """Register a stable diagnostic code with its one-line meaning.

    Re-registering the same (code, title) pair is a no-op (modules may
    be reloaded); changing the title of an existing code is an error —
    codes are a public contract.
    """
    if code in CODES and CODES[code] != title:
        raise ValueError(f"diagnostic code {code} already registered as "
                         f"{CODES[code]!r}")
    CODES[code] = title
    return code
