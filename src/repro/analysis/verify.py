"""The verification driver: ``verify(program) -> VerifyReport`` + CLI.

Runs every registered checker over a compiled
:class:`~repro.core.program.Program` WITHOUT executing any engine and
collects structured :class:`~repro.analysis.diagnostics.Diagnostic`
records. The built-in pipeline is

1. ``artifact``  — well-formedness of the raw arrays (ART001-003);
   any ERROR here gates the remaining checkers, which index into
   those arrays;
2. ``schedule``  — the hazard detector of
   :mod:`repro.analysis.schedule` (SCHED001-009);
3. ``ranges``    — the integer range analysis of
   :mod:`repro.analysis.ranges` (RANGE001-002, proven bounds in
   ``report.stats['ranges']``);
4. ``memory``    — the Eq. 9/11 capacity audit of
   :mod:`repro.analysis.memory` (MEM001-009).

Third parties extend the pipeline with :func:`register_checker`; the
driver refuses diagnostics whose code is not in
:data:`~repro.analysis.diagnostics.CODES`, so the public registry can
never drift from what is emitted.

CLI (the CI gate for golden artifacts)::

    python -m repro.analysis.verify artifact.npz [more.npz ...] \
        [--json] [--strict]

Exit status: 0 clean, 1 on any ERROR diagnostic (``--strict``: on ANY
diagnostic), 2 on unreadable artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import TYPE_CHECKING, Any, Callable

from repro.analysis.diagnostics import (CODES, Diagnostic, Location,
                                        Severity, VerifyReport,
                                        register_code)

if TYPE_CHECKING:
    from repro.core.program import Program

Checker = Callable[["Program"], "tuple[list[Diagnostic], dict[str, Any]]"]

ART001 = register_code("ART001", "malformed artifact arrays")
ART002 = register_code("ART002", "graph invariant violation")
ART003 = register_code(
    "ART003", "hardware config inconsistent with the artifact")


def _art(code: str, message: str, hint: str = "", count: int = 1,
         **loc: Any) -> Diagnostic:
    return Diagnostic(code=code, severity=Severity.ERROR, message=message,
                      location=Location(**loc), hint=hint, count=count)


def check_artifact(program: "Program") -> tuple[list[Diagnostic],
                                                dict[str, Any]]:
    """ART diagnostics: raw-array well-formedness of the artifact."""
    import numpy as np

    g, hw, tables = program.graph, program.hw, program.tables
    out: list[Diagnostic] = []

    # -- ART001: table/graph array shapes ------------------------------------
    shape = tables.pre.shape
    for name, arr in (("post", tables.post), ("weight", tables.weight),
                      ("pre_end", tables.pre_end),
                      ("post_end", tables.post_end)):
        if arr.shape != shape:
            out.append(_art(
                ART001, f"tables.{name} shape {arr.shape} != tables.pre "
                        f"shape {shape}", field=f"tables.{name}",
                hint="artifact arrays are torn; re-save from compile()"))
    if len(shape) != 2 or int(tables.depth) != shape[1]:
        out.append(_art(
            ART001, f"tables.depth={int(tables.depth)} != array depth "
                    f"{shape[1] if len(shape) == 2 else shape}",
            field="tables.depth",
            hint="artifact arrays are torn; re-save from compile()"))
    if not (g.pre.shape == g.post.shape == g.weight.shape):
        out.append(_art(
            ART001, f"graph arrays disagree: pre {g.pre.shape}, post "
                    f"{g.post.shape}, weight {g.weight.shape}",
            field="graph", hint="re-save from compile()"))
    if tables.assign.shape != g.pre.shape:
        out.append(_art(
            ART001, f"tables.assign has {tables.assign.shape[0]} entries "
                    f"for {g.n_synapses} synapses", field="tables.assign",
            hint="the partition must assign every synapse exactly once"))

    # -- ART002: graph invariants (mirrors SNNGraph.validate) ----------------
    n, ni = int(g.n_neurons), int(g.n_inputs)
    checks = [
        ((g.weight == 0), "zero-weight synapse (must be dropped)"),
        ((g.pre < 0) | (g.pre >= n), f"pre index outside [0, {n})"),
        ((g.post < ni) | (g.post >= n),
         f"post index outside [{ni}, {n}) (must be internal)"),
    ]
    for bad, what in checks:
        if bad.any():
            i = int(np.argmax(bad))
            out.append(_art(
                ART002, f"synapse {i}: {what} (pre={int(g.pre[i])}, "
                        f"post={int(g.post[i])}, w={int(g.weight[i])})",
                count=int(bad.sum()), pre=int(g.pre[i]), post=int(g.post[i]),
                hint="the graph violates SNNGraph invariants; rebuild it"))
    key = g.pre.astype(np.int64) * n + g.post
    uniq, counts = np.unique(key, return_counts=True)
    if (counts > 1).any():
        k = int(uniq[np.argmax(counts > 1)])
        out.append(_art(
            ART002, f"duplicate synapse ({k // n} -> {k % n})",
            count=int((counts > 1).sum()), pre=k // n, post=k % n,
            hint="merge duplicate (pre, post) pairs before compiling"))

    # -- ART003: hw vs artifact ----------------------------------------------
    if tables.n_spus != hw.n_spus:
        out.append(_art(
            ART003, f"tables span {tables.n_spus} SPUs but hw.n_spus="
                    f"{hw.n_spus}", field="hw.n_spus",
            hint="the artifact was scheduled for a different fabric"))
    if len(tables.assign) and tables.assign.size and (
            (tables.assign < 0).any()
            or (tables.assign >= hw.n_spus).any()):
        i = int(np.argmax((tables.assign < 0)
                          | (tables.assign >= hw.n_spus)))
        out.append(_art(
            ART003, f"tables.assign[{i}]={int(tables.assign[i])} outside "
                    f"[0, {hw.n_spus})", field="tables.assign",
            hint="the partition names SPUs the hardware does not have"))

    stats = {"n_synapses": int(g.n_synapses), "n_neurons": n,
             "n_spus": int(tables.n_spus), "depth": int(tables.depth)}
    return out, stats


def _schedule_checker(program: "Program") -> tuple[list[Diagnostic],
                                                   dict[str, Any]]:
    from repro.analysis.schedule import check_schedule
    diags = check_schedule(program.graph, program.tables)
    return diags, {"n_sends": len(program.tables.send_slot)}


def _ranges_checker(program: "Program") -> tuple[list[Diagnostic],
                                                 dict[str, Any]]:
    from repro.analysis.ranges import check_ranges
    return check_ranges(program.graph, program.hw, program.tables)


def _memory_checker(program: "Program") -> tuple[list[Diagnostic],
                                                 dict[str, Any]]:
    from repro.analysis.memory import check_memory
    return check_memory(program)


# ordered registry; "artifact" gates the rest (its ERRORs mean the
# arrays cannot be safely indexed by the other checkers)
CHECKERS: dict[str, Checker] = {
    "artifact": check_artifact,
    "schedule": _schedule_checker,
    "ranges": _ranges_checker,
    "memory": _memory_checker,
}
_GATE = "artifact"


def register_checker(name: str, fn: Checker) -> None:
    """Add a checker to the verification pipeline (runs after the
    built-ins, in registration order). The checker must only emit
    diagnostics with :func:`register_code`-registered codes."""
    if name in CHECKERS:
        raise ValueError(f"checker {name!r} already registered")
    CHECKERS[name] = fn


def verify(program: "Program",
           checkers: "list[str] | None" = None) -> VerifyReport:
    """Statically verify a compiled artifact; never executes an engine.

    ``checkers`` restricts the run to a subset of registry names
    (default: all, in registry order). Raises ``KeyError`` on unknown
    names and ``ValueError`` if a checker emits an unregistered code.
    """
    names = list(CHECKERS) if checkers is None else list(checkers)
    for name in names:
        if name not in CHECKERS:
            raise KeyError(f"unknown checker {name!r}; registered: "
                           f"{sorted(CHECKERS)}")
    t0 = time.perf_counter()
    diags: list[Diagnostic] = []
    stats: dict[str, Any] = {}
    ran: list[str] = []
    per_ms: dict[str, float] = {}
    gated = False
    for name in names:
        if gated and name != _GATE:
            continue
        t1 = time.perf_counter()
        d, s = CHECKERS[name](program)
        per_ms[name] = (time.perf_counter() - t1) * 1e3
        for diag in d:
            if diag.code not in CODES:
                raise ValueError(
                    f"checker {name!r} emitted unregistered code "
                    f"{diag.code!r}; call analysis.register_code first")
        diags.extend(d)
        stats[name] = s
        ran.append(name)
        if name == _GATE and any(x.severity >= Severity.ERROR for x in d):
            gated = True                # arrays unsafe for the others
    return VerifyReport(diagnostics=diags, stats=stats, checkers=ran,
                        wall_ms=(time.perf_counter() - t0) * 1e3,
                        checker_wall_ms=per_ms)


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.verify",
        description="Statically verify compiled SupraSNN Program artifacts "
                    "(no engine execution).")
    ap.add_argument("paths", nargs="+", help="Program .npz artifact(s)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit one JSON object {path: report} to stdout")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on ANY diagnostic (default: errors only)")
    args = ap.parse_args(argv)

    from repro.core.program import Program
    reports: dict[str, VerifyReport] = {}
    status = 0
    for path in args.paths:
        try:
            program = Program.load(path)
        except Exception as e:           # unreadable beats unverifiable
            print(f"{path}: cannot load: {e}", file=sys.stderr)
            return 2
        rep = verify(program)
        reports[path] = rep
        bad = rep.diagnostics if args.strict else rep.errors
        if bad:
            status = 1
        if not args.as_json:
            print(f"{path}: {rep.summary()}")
    if args.as_json:
        print(json.dumps({p: r.to_json() for p, r in reports.items()},
                         indent=2, sort_keys=True))
    return status


if __name__ == "__main__":
    sys.exit(main())
