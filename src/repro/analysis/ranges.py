"""Integer range analysis over the scheduled op stream.

Computes, WITHOUT executing any engine, sound worst-case intervals for
every integer quantity the execution tiers manipulate:

* per-synapse weights vs the signed ``weight_bits`` Unified-Memory
  field (RANGE001);
* the folded dense weight plane ``W[q, p] = Σ weight`` that
  :func:`repro.kernels.fused_step.pack_dense` builds — proving the
  int8/int16 dtype choice (the paper's 4-bit MNIST / 9-bit SHD nets)
  before any densification happens;
* the per-post synaptic accumulator and membrane potential of the
  integer LIF (``v' = leak(v) + I``, spike iff ``v' >= th`` then
  reset), proving the int32 accumulation in every engine and in the
  fused megakernel cannot overflow — or naming the offending neuron
  and the minimal safe width (RANGE002).

The membrane bounds are a closed-form fixpoint of the reset dynamics
(DESIGN.md §13 derives both):

* upper: the carried (post-commit) state never exceeds
  ``carried_hi = max(v_reset, 0, v_threshold - 1)`` — a spiking step
  resets, a non-spiking one leaves ``v' <= th - 1``, and the initial
  state is 0 — so the pre-threshold peak is bounded by
  ``leak(carried_hi) + pos[p]`` with ``pos[p] = Σ max(w, 0)`` over
  ``p``'s in-synapses (all pres firing at once);
* lower: ``lo[p] = min(0, v_reset, neg[p] * 2**leak_shift)`` is an
  inductive invariant — the arithmetic-shift leak contracts a negative
  state by at least ``2**-leak_shift`` of itself, so
  ``leak(lo) + neg >= lo`` exactly when ``lo <= neg * 2**leak_shift``.
  At ``leak_shift = 0`` the leak zeroes the state and both collapse to
  one-step sums.

Extremes are finished in exact Python ints (numpy int64 only carries
the per-post partial sums, which are safe for any graph the pipeline
can represent). This module imports ONLY numpy at runtime —
``kernels/fused_step.py`` imports :func:`min_safe_dtype` from here for
its guard message, so this must stay below the jax layer.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np
import numpy.typing as npt

from repro.analysis.diagnostics import (Diagnostic, Location, Severity,
                                        register_code)

if TYPE_CHECKING:
    from repro.core.graph import SNNGraph
    from repro.core.memory_model import HardwareConfig
    from repro.core.scheduling.tables import OpTables

NOP = -1

RANGE001 = register_code(
    "RANGE001", "weight outside the signed weight_bits field")
RANGE002 = register_code(
    "RANGE002", "accumulator interval exceeds the int32 engine width")

INT32_LO, INT32_HI = -(2 ** 31), 2 ** 31 - 1


def signed_bits(lo: int, hi: int) -> int:
    """Smallest signed bit-width holding every value in [lo, hi]."""
    b = 1
    while not (-(1 << (b - 1)) <= lo and hi <= (1 << (b - 1)) - 1):
        b += 1
    return b


def min_safe_dtype(lo: int, hi: int) -> str:
    """Narrowest signed numpy dtype name holding [lo, hi] (the
    ``pack_dense`` ladder: int8 -> int16 -> int32 -> int64)."""
    b = signed_bits(int(lo), int(hi))
    for width in (8, 16, 32, 64):
        if b <= width:
            return f"int{width}"
    return f"int{b}"                     # unrepresentable in numpy; name it


def dense_plane_bounds(op_pre: npt.NDArray[Any], op_post_local: npt.NDArray[Any],
                       op_weight: npt.NDArray[Any], n_neurons: int,
                       n_internal: int) -> tuple[int, int]:
    """Exact (min, max) of the folded dense plane ``W[q, p] = Σ w``.

    Group-sums the op stream by (pre, post) WITHOUT allocating the
    ``n_neurons x n_internal`` plane, so the bound is computable for
    graphs far past ``SUPRASNN_FUSED_MAX_BYTES``. Cells with no
    synapse hold an implicit 0, included whenever the plane is not
    fully dense.
    """
    w = np.asarray(op_weight, np.int64)
    n_cells = int(n_neurons) * int(n_internal)
    if not len(w):
        return (0, 0)
    key = (np.asarray(op_pre, np.int64) * n_internal
           + np.asarray(op_post_local, np.int64))
    order = np.argsort(key, kind="stable")
    ks = key[order]
    starts = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1]])
    sums = np.add.reduceat(w[order], starts)
    lo, hi = int(sums.min()), int(sums.max())
    if len(starts) < n_cells:            # implicit zero cells exist
        lo, hi = min(lo, 0), max(hi, 0)
    return lo, hi


def _leak_hi(v: int, shift: int) -> int:
    """``leak(v) = v - (v >> shift)`` for a non-negative carried bound."""
    return v - (v >> shift)


def check_ranges(g: "SNNGraph", hw: "HardwareConfig", tables: "OpTables"
                 ) -> tuple[list[Diagnostic], dict[str, Any]]:
    """RANGE diagnostics + the proven interval facts for (g, hw, tables).

    Folds from the TABLES (not the lowered program), so hand-edited
    artifacts are analyzed as they would execute after re-lowering.
    """
    out: list[Diagnostic] = []
    n, n_int = int(g.n_neurons), int(g.n_internal)
    valid = tables.pre != NOP
    spu_i, slot_i = np.nonzero(valid)
    pre_v = tables.pre[spu_i, slot_i].astype(np.int64)
    post_v = tables.post[spu_i, slot_i].astype(np.int64)
    w_v = tables.weight[spu_i, slot_i].astype(np.int64)
    in_range = ((pre_v >= 0) & (pre_v < n)
                & (post_v >= g.n_inputs) & (post_v < n))
    pre_v, post_v, w_v = pre_v[in_range], post_v[in_range], w_v[in_range]
    idx = np.flatnonzero(valid.ravel())[in_range]

    # -- RANGE001: every weight representable in the signed UM field --------
    ww = int(hw.weight_bits)
    w_lo, w_hi = -(1 << (ww - 1)), (1 << (ww - 1)) - 1
    bad = (w_v < w_lo) | (w_v > w_hi)
    if bad.any():
        i = int(np.argmax(bad))
        s, t = divmod(int(idx[i]), tables.pre.shape[1])
        out.append(Diagnostic(
            code=RANGE001, severity=Severity.ERROR,
            message=(f"weight {int(w_v[i])} of synapse "
                     f"({int(pre_v[i])} -> {int(post_v[i])}) outside the "
                     f"signed {ww}-bit range [{w_lo}, {w_hi}]; needs "
                     f"{signed_bits(int(w_v.min()), int(w_v.max()))} bits"),
            location=Location(spu=s, slot=t, pre=int(pre_v[i]),
                              post=int(post_v[i]), field="hw.weight_bits"),
            hint="raise HardwareConfig.weight_bits or requantize",
            count=int(bad.sum())))

    # -- per-post one-step current interval [neg, pos] ----------------------
    pos = np.zeros(n_int, np.int64)
    neg = np.zeros(n_int, np.int64)
    pl = (post_v - g.n_inputs).astype(np.int64)
    np.add.at(pos, pl, np.maximum(w_v, 0))
    np.add.at(neg, pl, np.minimum(w_v, 0))

    # -- membrane fixpoint bounds (module docstring derives both) -----------
    ls = int(g.lif.leak_shift)
    th, reset = int(g.lif.v_threshold), int(g.lif.v_reset)
    carried_hi = max(reset, 0, th - 1)
    p_hi = int(np.argmax(pos)) if n_int else 0
    p_lo = int(np.argmin(neg)) if n_int else 0
    # exact Python ints from here: the shift by leak_shift could leave
    # int64 for adversarial (leak_shift, fan-in) combinations
    v_hi = _leak_hi(carried_hi, ls) + int(pos[p_hi]) if n_int else 0
    v_lo = min(0, reset, int(neg[p_lo]) << ls) if n_int else 0
    acc_lo = min(v_lo, int(neg[p_lo]) if n_int else 0)
    acc_hi = max(v_hi, int(pos[p_hi]) if n_int else 0)
    acc_bits = signed_bits(acc_lo, acc_hi)

    if acc_lo < INT32_LO or acc_hi > INT32_HI:
        p_bad = p_hi if acc_hi > INT32_HI else p_lo
        out.append(Diagnostic(
            code=RANGE002, severity=Severity.ERROR,
            message=(f"accumulator interval [{acc_lo}, {acc_hi}] of post "
                     f"{p_bad + g.n_inputs} exceeds int32; minimal safe "
                     f"width is {acc_bits} bits ({min_safe_dtype(acc_lo, acc_hi)})"),
            location=Location(post=p_bad + g.n_inputs),
            hint="shrink weights/fan-in or widen the engine accumulator",
            count=1))

    # -- dense-plane dtype proof (the pack_dense choice) --------------------
    d_lo, d_hi = dense_plane_bounds(pre_v, pl, w_v, n, n_int)
    stats: dict[str, Any] = {
        "weight_lo": int(w_v.min()) if len(w_v) else 0,
        "weight_hi": int(w_v.max()) if len(w_v) else 0,
        "weight_bits_needed": (signed_bits(int(w_v.min()), int(w_v.max()))
                               if len(w_v) else 1),
        "dense_lo": d_lo, "dense_hi": d_hi,
        "dense_dtype": min_safe_dtype(d_lo, d_hi),
        "current_lo": int(neg[p_lo]) if n_int else 0,
        "current_hi": int(pos[p_hi]) if n_int else 0,
        "membrane_lo": v_lo, "membrane_hi": v_hi,
        "acc_lo": acc_lo, "acc_hi": acc_hi, "acc_bits": acc_bits,
        "int32_safe": INT32_LO <= acc_lo and acc_hi <= INT32_HI,
    }
    return out, stats
