"""RWKV-6 "Finch" block (arXiv:2404.05892) — data-dependent decay linear
attention, the [ssm]-family member of the assigned pool.

SupraSNN mapping (DESIGN.md §4): the wide r/k/v/g projections are the
"synaptic" half (massive cheap matmuls, sharded over 'model'); the WKV
state recurrence is the "neuronal" half — a small stateful update per head,
exactly the paper's compute asymmetry. The chunked formulation below keeps
the synaptic half on the MXU and the state hop at O(S/C) sequential steps.

Two execution paths:

* ``wkv6_chunked``: parallel within chunks of C tokens (einsum form, causal
  decay ratios computed in log space), ``lax.scan`` across chunks carrying
  the [H, N, N] state — used for train/prefill;
* ``wkv6_step``: the exact recurrence for single-token decode (O(1) state,
  enabling the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, _dense_init, init_rmsnorm, rmsnorm


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_rwkv_block(cfg: ArchConfig, key: jax.Array) -> Params:
    d = cfg.d_model
    s = cfg.ssm
    lora = s.decay_lora
    ks = jax.random.split(key, 16)
    n_heads = d // s.head_dim
    return {
        "time_mix": {
            # base lerp coefficients for the 5 ddlerp streams (w,k,v,r,g)
            "mu_base": jnp.zeros((d,), jnp.float32),
            "mu": jnp.zeros((5, d), jnp.float32),
            # ddlerp LoRA: tanh(x W1) W2 per stream
            "lora_w1": _dense_init(ks[0], (d, 5 * 32), dtype=jnp.float32),
            "lora_w2": _dense_init(ks[1], (5, 32, d), dtype=jnp.float32),
            # data-dependent decay LoRA
            "w0": jnp.full((d,), -6.0, jnp.float32),   # exp(-exp(-6)) ~ .9975
            "w1": _dense_init(ks[2], (d, lora), dtype=jnp.float32),
            "w2": _dense_init(ks[3], (lora, d), dtype=jnp.float32),
            "wr": _dense_init(ks[4], (d, d)),
            "wk": _dense_init(ks[5], (d, d)),
            "wv": _dense_init(ks[6], (d, d)),
            "wg": _dense_init(ks[7], (d, d)),
            "u": (jax.random.normal(ks[8], (n_heads, s.head_dim),
                                    jnp.float32) * 0.1),
            "ln_x": {"scale": jnp.ones((d,), jnp.float32),
                     "bias": jnp.zeros((d,), jnp.float32)},
            "wo": _dense_init(ks[9], (d, d)),
        },
        "channel_mix": {
            "mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "wk": _dense_init(ks[10], (d, cfg.d_ff)),
            "wv": _dense_init(ks[11], (cfg.d_ff, d)),
            "wr": _dense_init(ks[12], (d, d)),
        },
        "ln1": init_rmsnorm(d),
        "ln2": init_rmsnorm(d),
    }


# ---------------------------------------------------------------------------
# WKV-6 core
# ---------------------------------------------------------------------------


def wkv6_chunked(r, k, v, w_log, u, state, chunk: int | None = None):
    """Chunked WKV-6.

    r/k/v [B, S, H, N]; w_log [B, S, H, N] = log(decay) <= 0;
    u [H, N] bonus; state [B, H, N, N] (key-major: S[k_dim, v_dim]).
    Returns (y [B, S, H, N], state').

    Per head: S_t = diag(w_t) S_{t-1} + k_t v_t^T,
              y_t = S_{t-1}^T r_t + (r_t . (u*k_t)) v_t.

    ``chunk`` (default env REPRO_WKV_CHUNK or 64) trades the O(S*C*H*N)
    intra-chunk ratio-tensor HBM traffic against O(S/C * H * N^2) state
    hops — the §Perf tuning knob for the rwkv6 train cells. On real TPU
    the Pallas kernel (kernels/wkv6.py) replaces this path entirely.
    """
    import os
    if chunk is None:
        chunk = int(os.environ.get("REPRO_WKV_CHUNK", "64"))
    b, s, h, n = r.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w_log = jnp.pad(w_log, ((0, 0), (0, pad), (0, 0), (0, 0)))
    c = chunk

    def split(x):  # [B, S, H, N] -> [NC, B, C, H, N]
        return x.reshape(b, nc, c, h, n).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = split(r), split(k), split(v), split(w_log)

    import os
    ratio_bf16 = bool(int(os.environ.get("REPRO_WKV_BF16", "0")))

    def body(st, inp):
        rb, kb, vb, wb = [x.astype(jnp.float32) for x in inp]  # [B,C,H,N]
        la = jnp.cumsum(wb, axis=1)                 # logA_t (inclusive)
        la_prev = la - wb                           # logA_{t-1} (exclusive)
        # inter-chunk: y_t += (r_t * A_{t-1})^T S_0
        q_dec = rb * jnp.exp(la_prev)
        y = jnp.einsum("bchk,bhkn->bchn", q_dec, st)
        # intra-chunk, strictly causal: ratio A_{t-1}/A_s, s < t, computed
        # in log space (diff <= 0 under the mask -> exp never overflows).
        # REPRO_WKV_BF16=1 stores the O(C^2 H N) ratio tensor in bf16
        # (f32 accumulation) — §Perf rwkv iteration 3: the ratio tensor is
        # the dominant HBM traffic of this formulation; decays in [0, 1]
        # lose ~3 significand bits, the same trade flash-attention makes.
        diff = la_prev[:, :, None] - la[:, None, :]   # [B, T, S, H, N]
        mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])
        ratio = jnp.exp(jnp.where(mask[None, :, :, None, None], diff, -1e30))
        if ratio_bf16:
            att = jnp.einsum("bthk,bshk,btshk->bths",
                             rb.astype(jnp.bfloat16),
                             kb.astype(jnp.bfloat16),
                             ratio.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)
        else:
            att = jnp.einsum("bthk,bshk,btshk->bths", rb, kb, ratio)
        y = y + jnp.einsum("bths,bshn->bthn", att, vb)
        # current-token bonus: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bchk,hk,bchk->bch", rb, u.astype(jnp.float32), kb)
        y = y + bonus[..., None] * vb
        # state update: S' = diag(A_C) S_0 + sum_s diag(A_C/A_s) k_s v_s^T
        la_end = la[:, -1][:, None]                  # [B, 1, H, N]
        k_dec = kb * jnp.exp(la_end - la)
        st = st * jnp.exp(la_end[:, 0])[..., None] \
            + jnp.einsum("bshk,bshn->bhkn", k_dec, vb)
        return st, y

    state, ys = jax.lax.scan(body, state.astype(jnp.float32),
                             (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, h, n)[:, :s]
    return y.astype(r.dtype), state


def wkv6_step(r, k, v, w_log, u, state):
    """Single-token recurrence. r/k/v/w_log [B, H, N]; state [B, H, N, N]."""
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    y = jnp.einsum("bhk,bhkn->bhn", rf, state) \
        + jnp.einsum("bhk,hk,bhk->bh", rf, u.astype(jnp.float32),
                     kf)[..., None] * vf
    state = state * jnp.exp(w_log.astype(jnp.float32))[..., None] \
        + jnp.einsum("bhk,bhn->bhkn", kf, vf)
    return y.astype(r.dtype), state


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def _ddlerp(p: Params, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift (5 streams: w, k, v, r, g)."""
    delta = x_prev - x
    base = x + delta * p["mu_base"].astype(x.dtype)
    lora = jnp.tanh(base.astype(jnp.float32) @ p["lora_w1"])
    lora = lora.reshape(*base.shape[:-1], 5, 32)
    mix = p["mu"] + jnp.einsum("...fk,fkd->...fd", lora, p["lora_w2"])
    return x[..., None, :] + delta[..., None, :] * mix.astype(x.dtype)


def rwkv_time_mix(p: Params, x: jax.Array, cfg: ArchConfig, *,
                  x_prev: jax.Array, state: jax.Array,
                  single_step: bool = False):
    """x [B, S, D] (train/prefill) or [B, 1, D] (decode).

    x_prev [B, D]: last token of the previous call (token shift across
    boundaries); state [B, H, N, N].
    """
    b, s, d = x.shape
    hd = cfg.ssm.head_dim
    h = d // hd

    shifted = jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]],
                              axis=1)
    streams = _ddlerp(p, x, shifted)                  # [B, S, 5, D]
    xw, xk, xv, xr, xg = [streams[:, :, i] for i in range(5)]

    w_log = -jnp.exp(p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["w1"])
                     @ p["w2"])                        # [B, S, D], <= 0
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w_log = w_log.reshape(b, s, h, hd)

    if single_step:
        y, state = wkv6_step(r[:, 0], k[:, 0], v[:, 0], w_log[:, 0],
                             p["u"], state)
        y = y[:, None]
    else:
        y, state = wkv6_chunked(r, k, v, w_log, p["u"], state)

    # per-head groupnorm (ln_x) then gate
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yf = yf.reshape(b, s, d) * p["ln_x"]["scale"] + p["ln_x"]["bias"]
    out = (yf.astype(x.dtype) * g) @ p["wo"]
    return out, x[:, -1], state


def rwkv_channel_mix(p: Params, x: jax.Array, x_prev: jax.Array):
    shifted = jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]],
                              axis=1)
    xk = x + (shifted - x) * p["mu_k"].astype(x.dtype)
    xr = x + (shifted - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1]


def rwkv_block(p: Params, x: jax.Array, cfg: ArchConfig, state: dict,
               single_step: bool = False) -> tuple[jax.Array, dict]:
    """One RWKV-6 block. state = {tm_x, cm_x [B,D], wkv [B,H,N,N]}."""
    a, tm_x, wkv = rwkv_time_mix(
        p["time_mix"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
        x_prev=state["tm_x"], state=state["wkv"], single_step=single_step)
    x = x + a
    c, cm_x = rwkv_channel_mix(
        p["channel_mix"], rmsnorm(p["ln2"], x, cfg.norm_eps),
        x_prev=state["cm_x"])
    x = x + c
    return x, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}


def init_rwkv_state(cfg: ArchConfig, batch: int,
                    dtype=jnp.float32) -> dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    return {"tm_x": jnp.zeros((batch, d), dtype),
            "cm_x": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32)}
