"""Mamba-2 SSD block (arXiv:2405.21060) — the state-space half of Zamba2
(arXiv:2411.15242), the [hybrid] member of the assigned pool.

SupraSNN mapping (DESIGN.md §4): the in/out projections and the chunked
SSD matmuls are the "synaptic" half (dense, MXU-bound, sharded over
'model'); the [H, P, N] recurrent state hop between chunks is the
"neuronal" half — small, stateful, sequential. Zamba2's *shared* attention
block (one physical block time-multiplexed across depth) mirrors the
paper's centralized Neuron Unit.

Two execution paths, like rwkv.py:

* ``ssd_chunked`` — matrix-form SSD within chunks (quadratic in the chunk,
  linear across chunks via a scanned state), used for train/prefill;
* ``ssd_step`` — exact single-token recurrence for decode (O(1) state,
  enabling the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, _dense_init, init_rmsnorm, rmsnorm


def init_mamba2_block(cfg: ArchConfig, key: jax.Array) -> Params:
    d = cfg.d_model
    s = cfg.ssm
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    ks = jax.random.split(key, 6)
    # single fused in-projection: [z, x, B, C, dt]
    d_in_proj = 2 * d_inner + 2 * s.d_state + n_heads
    return {
        "in_proj": _dense_init(ks[0], (d, d_in_proj)),
        # depthwise conv over the (x, B, C) channels
        "conv_w": (jax.random.normal(ks[1],
                                     (s.d_conv, d_inner + 2 * s.d_state),
                                     jnp.float32) * 0.1),
        "conv_b": jnp.zeros((d_inner + 2 * s.d_state,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        # A is per-head scalar (SSD restriction), stored as log
        "a_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": init_rmsnorm(d_inner),
        "out_proj": _dense_init(ks[2], (d_inner, d)),
        "ln": init_rmsnorm(d),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, a_log, b, c, state, chunk: int = 64):
    """Chunked SSD (Mamba-2 alg. 1, matrix form).

    x   [B, S, H, P]   inputs per head
    dt  [B, S, H]      softplus'd step sizes (>= 0)
    a_log [H]          log(-A) per head; decay = exp(-exp(a_log) * dt)
    b   [B, S, N]      input->state projection  (shared across heads, G=1)
    c   [B, S, N]      state->output projection
    state [B, H, P, N] carried SSM state.
    Returns (y [B, S, H, P], state').

    Discrete recurrence per head/channel:
      S_t = exp(a_t) S_{t-1} + dt_t * x_t b_t^T,   a_t = -exp(a_log) dt_t
      y_t = S_t c_t  (+ D x_t skip added by the caller)
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    cs = chunk

    def split(t, shape):
        return t.reshape(bsz, nc, cs, *shape).transpose(1, 0, 2,
                                                        *range(3, 3 + len(shape)))

    xc = split(x, (h, p))
    dtc = split(dt, (h,))
    bc = split(b, (n,))
    cc = split(c, (n,))
    neg_a = jnp.exp(a_log.astype(jnp.float32))          # [H] = -A > 0

    def body(st, inp):
        xb, dtb, bb, cb = [t.astype(jnp.float32) for t in inp]
        # log decays within the chunk
        la = -neg_a[None, None, :] * dtb                 # [B, C, H] (<= 0)
        cum = jnp.cumsum(la, axis=1)                     # inclusive logA_t
        # inter-chunk contribution: y_t += (exp(cum_t) * c_t) . S_0
        y = jnp.einsum("bch,bcn,bhpn->bchp", jnp.exp(cum), cb, st)
        # intra-chunk, causal (t >= s): ratio exp(cum_t - cum_s)
        diff = cum[:, :, None, :] - cum[:, None, :, :]   # [B, T, S, H]
        mask = jnp.arange(cs)[:, None] >= jnp.arange(cs)[None, :]
        ratio = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
        att = jnp.einsum("btn,bsn,btsh->btsh", cb, bb, ratio)
        y = y + jnp.einsum("btsh,bsh,bshp->bthp", att, dtb, xb)
        # state: S' = exp(cum_C) S_0 + sum_s exp(cum_C - cum_s) dt_s x_s b_s^T
        la_end = cum[:, -1]                              # [B, H]
        k_dec = jnp.exp(la_end[:, None] - cum) * dtb     # [B, C, H]
        st = st * jnp.exp(la_end)[..., None, None] \
            + jnp.einsum("bch,bchp,bcn->bhpn", k_dec, xb, bb)
        return st, y

    state, ys = jax.lax.scan(body, state.astype(jnp.float32),
                             (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * cs, h, p)[:, :s]
    return y.astype(x.dtype), state


def ssd_step(x, dt, a_log, b, c, state):
    """Single-token SSD recurrence.

    x [B, H, P]; dt [B, H]; b/c [B, N]; state [B, H, P, N].
    """
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)
    decay = jnp.exp(-jnp.exp(a_log.astype(jnp.float32))[None, :] * dtf)
    state = state * decay[..., None, None] \
        + jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, bf)
    y = jnp.einsum("bhpn,bn->bhp", state, cf)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv1d. x [B, S, C]; w [K, C]; cache [B, K-1, C].

    Returns (y [B, S, C], new_cache [B, K-1, C]).
    """
    k = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xe = jnp.concatenate([cache, x], axis=1)             # [B, S+K-1, C]
    y = sum(xe[:, i:i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
            for i in range(k))
    y = y + b.astype(x.dtype)
    new_cache = xe[:, -(k - 1):] if k > 1 else cache
    return y, new_cache


def mamba2_block(p: Params, x: jax.Array, cfg: ArchConfig, state: dict,
                 single_step: bool = False) -> tuple[jax.Array, dict]:
    """One Mamba-2 block (pre-norm residual).

    state = {"ssm": [B, H, P, N] f32, "conv": [B, K-1, C_conv]}.
    """
    s = cfg.ssm
    bsz, seq, d = x.shape
    d_inner = s.expand * d
    h = d_inner // s.head_dim

    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    zxbcdt = xn @ p["in_proj"]
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * s.d_state], axis=-1)
    xbc, conv_cache = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state["conv"])
    xbc = jax.nn.silu(xbc)
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                  # [B, S, H]
    xh = xs.reshape(bsz, seq, h, s.head_dim)

    if single_step:
        y, ssm = ssd_step(xh[:, 0], dt[:, 0], p["a_log"], b[:, 0], c[:, 0],
                          state["ssm"])
        y = y[:, None]
    else:
        y, ssm = ssd_chunked(xh, dt, p["a_log"], b, c, state["ssm"])
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, seq, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    return x + out, {"ssm": ssm, "conv": conv_cache}


def init_mamba2_state(cfg: ArchConfig, batch: int) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    h = d_inner // s.head_dim
    return {"ssm": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
            "conv": jnp.zeros((batch, s.d_conv - 1, d_inner + 2 * s.d_state),
                              jnp.bfloat16)}
