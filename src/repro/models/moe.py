"""Mixture-of-Experts layer in the SupraSNN vocabulary (DESIGN.md §4).

The structural mapping to the paper:

* the router's top-k ``dispatch`` tensor IS the MC-tree routing bitstring —
  one bit per (token, expert, slot) saying "this expert holds work for this
  token"; tokens are multicast only to the experts that need them
  (capacity-bounded all_to_all over the EP axis);
* the weighted ``combine`` of expert outputs IS the ME tree — a
  deterministic, fixed-order merge of partial results into the token's
  residual stream (an einsum reduction, bit-identical run to run);
* expert placement under the per-device HBM budget is the same
  parallelism-memory trade-off the paper's partitioner solves (Eq. 9):
  experts-per-device = n_experts / ep_size is our |P_i| analogue.

Implementation is GShard-style dense dispatch (einsum with a one-hot
dispatch tensor) — the idiomatic TPU formulation: no gather/scatter,
MXU-friendly, and the dispatch/combine einsums shard cleanly over
('data', groups) x ('model', experts).

SCALING NOTE: dispatch is computed PER GROUP of ``group_size`` tokens, so
the one-hot tensors are [G, T_g, E, C_g] with T_g ~ 2k, never the flat
[T, E, C] (at train_4k deepseek-v3 scale the flat tensor would hold 1e16
elements). Groups are an integer multiple of the data-shard count so a
group never crosses devices; capacity is enforced per (group, expert) —
this matches GShard/Switch semantics where capacity is local to a group.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, _dense_init


def init_moe(cfg: ArchConfig, key: jax.Array) -> Params:
    mo = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, mo.n_experts), dtype=jnp.float32),
        # stacked expert weights [E, d, d_ff] — shard E over 'model' (EP)
        "w_gate": _dense_init(ks[1], (mo.n_experts, d, mo.d_ff_expert)),
        "w_up": _dense_init(ks[2], (mo.n_experts, d, mo.d_ff_expert)),
        "w_down": _dense_init(ks[3], (mo.n_experts, mo.d_ff_expert, d)),
    }
    if mo.n_shared_experts:
        kss = jax.random.split(ks[4], 3)
        dff_sh = mo.d_ff_shared * mo.n_shared_experts
        p["shared"] = {"w_gate": _dense_init(kss[0], (d, dff_sh)),
                       "w_up": _dense_init(kss[1], (d, dff_sh)),
                       "w_down": _dense_init(kss[2], (dff_sh, d))}
    return p


def route_topk(logits: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k routing with normalized probabilities.

    logits [..., E] f32 -> (weights [..., k], indices [..., k]).
    DeepSeek-V3 style: softmax over the selected k (sigmoid variant omitted;
    the communication pattern — the part that matters for the systems
    reproduction — is identical).
    """
    vals, idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(vals, axis=-1)
    return weights, idx


def _pick_group_size(t: int, target: int = 2048) -> int:
    """Largest divisor of t that is <= target (>= 1)."""
    g = min(target, t)
    while t % g:
        g -= 1
    return g


def moe_mlp(p: Params, x: jax.Array, cfg: ArchConfig, *,
            group_size: Optional[int] = None) -> tuple[jax.Array, jax.Array]:
    """MoE MLP. x [B, S, D] -> (y [B, S, D], aux_loss scalar).

    Grouped dense-dispatch formulation (per group g of T_g tokens):
      dispatch [G, T_g, E, C] one-hot  (MC tree: token -> expert-slot multicast)
      expert compute [G, E, C, D]      (the parallel SPU array)
      combine  [G, T_g, E, C] weighted (ME tree: deterministic partial-sum merge)
    """
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    tg = group_size or _pick_group_size(t)
    g = t // tg
    xt = x.reshape(g, tg, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = route_topk(logits, mo.top_k)           # [G, T_g, k]

    # load-balancing aux loss (GShard/Switch): E * sum_e f_e * P_e
    one_hot = jax.nn.one_hot(idx, mo.n_experts, dtype=jnp.float32)
    f = one_hot.sum(axis=2).mean(axis=(0, 1))             # fraction per expert
    aux = mo.n_experts * jnp.sum(f * probs.mean(axis=(0, 1))) \
        * mo.router_aux_coef

    capacity = int(mo.capacity_factor * tg * mo.top_k / mo.n_experts)
    capacity = max(capacity, 4)

    # position of each (token, k) within its expert's per-group capacity
    flat_expert = idx.reshape(g, tg * mo.top_k)           # [G, T_g*k]
    flat_onehot = jax.nn.one_hot(flat_expert, mo.n_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(flat_onehot, axis=1) - 1)           # [G, T_g*k, E]
    pos = jnp.take_along_axis(pos, flat_expert[..., None],
                              axis=2)[..., 0].reshape(g, tg, mo.top_k)
    keep = pos < capacity                                 # overflow -> dropped
    pos_c = jnp.where(keep, pos, 0)

    expert_oh = jax.nn.one_hot(idx, mo.n_experts, dtype=jnp.bfloat16)
    slot_oh = jax.nn.one_hot(pos_c, capacity, dtype=jnp.bfloat16) \
        * keep[..., None].astype(jnp.bfloat16)
    # dispatch [G, T_g, E, C]: sum over the k selections
    disp = jnp.einsum("gtke,gtkc->gtec", expert_oh, slot_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", expert_oh.astype(jnp.float32),
                      slot_oh.astype(jnp.float32),
                      jnp.where(keep, weights, 0.0))

    # MC-tree multicast: gather token activations into expert buffers
    buf = jnp.einsum("gtd,gtec->gecd", xt, disp.astype(x.dtype))
    # parallel expert compute (the SPU array)
    hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    out = jnp.einsum("gecf,efd->gecd", hidden, p["w_down"])  # [G, E, C, D]
    # ME-tree merge: deterministic weighted combine back to tokens
    yt = jnp.einsum("gecd,gtec->gtd", out.astype(jnp.float32), comb)

    y = yt.astype(x.dtype)
    if mo.n_shared_experts:
        sh = p["shared"]
        xf = xt
        y = y + ((jax.nn.silu(xf @ sh["w_gate"]) * (xf @ sh["w_up"]))
                 @ sh["w_down"])
    return y.reshape(b, s, d), aux
