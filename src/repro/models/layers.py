"""Transformer building blocks for the assigned-architecture pool.

Pure JAX (no flax): parameters are plain dict pytrees created by ``init_*``
functions; every leaf carries a *logical sharding axis* spec in a parallel
pytree (see ``repro.distributed.sharding``) so the same model code runs on a
laptop CPU and a 512-chip mesh.

Design rules (they matter for the multi-pod dry-run):

* layers are STACKED on a leading axis and executed with ``lax.scan`` —
  a 61-layer model lowers to one scanned HLO body, keeping compile time
  and code size flat in depth;
* attention over long sequences is CHUNKED (online-softmax flash pattern,
  ``lax.scan`` over KV blocks) so a 32k-token prefill never materializes
  the [S, S] score matrix;
* everything computes in bf16 with f32 softmax/norm/accumulation islands.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

Params = dict  # nested dict pytree of jnp arrays

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, in_axis=-2, dtype=jnp.bfloat16):
    """Truncated-normal fan-in init (matches common LM pretraining setups)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def _embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def apply_norm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    return layernorm(p, x, eps) if "bias" in p else rmsnorm(p, x, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings: standard / partial / 2D (chatglm) / M-RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float) -> jax.Array:
    """inv_freq [dim//2] f32."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_dim: Optional[int] = None,
               mrope_sections: Optional[tuple] = None) -> jax.Array:
    """Rotate ``x`` [..., S, H, D] by ``positions``.

    positions: [..., S] int32 for 1-D RoPE, or [3, ..., S] for M-RoPE
    (t/h/w position triplets, qwen2-vl arXiv:2409.12191).
    rotary_dim: rotate only the first ``rotary_dim`` features (partial RoPE,
    stablelm/glm style); the remainder passes through unchanged.
    """
    d = x.shape[-1]
    rd = rotary_dim or d
    x_rot, x_pass = x[..., :rd], x[..., rd:]

    inv_freq = rope_frequencies(rd, theta)                     # [rd/2]
    if mrope_sections is not None:
        # M-RoPE: split the rd/2 frequency slots into (t, h, w) sections,
        # each driven by its own position stream.
        assert positions.shape[0] == 3, "M-RoPE needs [3, ...] positions"
        freqs = []
        start = 0
        for sec, pos in zip(mrope_sections, positions):
            f = pos[..., None].astype(jnp.float32) * inv_freq[start:start + sec]
            freqs.append(f)
            start += sec
        freqs = jnp.concatenate(freqs, axis=-1)                # [..., S, rd/2]
    else:
        freqs = positions[..., None].astype(jnp.float32) * inv_freq

    cos = jnp.cos(freqs)[..., None, :]                         # [..., S, 1, rd/2]
    sin = jnp.sin(freqs)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1).astype(x.dtype)
    return jnp.concatenate([rot, x_pass], axis=-1) if rd < d else rot


def sinusoidal_positions(seq_len: int, d: int) -> jax.Array:
    """MusicGen-style additive sinusoidal embedding [S, D] f32."""
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / np.power(10000.0, dim / d)
    out = np.zeros((seq_len, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure JAX oracle.
# The Pallas TPU kernel lives in repro.kernels.flash_attention; this is the
# reference path and also what the dry-run lowers (same memory behaviour:
# no [S, S] materialization).
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, chunk: int = 1024,
                      scale: Optional[float] = None,
                      q_offset: int = 0) -> jax.Array:
    """Online-softmax attention.

    q [B, Sq, H, Dh], k/v [B, Sk, Hkv, Dh] (GQA broadcast on the fly).
    Scans over KV chunks, carrying (m, l, acc) — the flash-attention
    recurrence — so peak memory is O(Sq * chunk), not O(Sq * Sk).
    q_offset: absolute position of q[0] (decode: Sk_cached).
    """
    b, sq, h, dh = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]                        # may differ from dh (MLA)
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, dv).transpose(1, 0, 2, 3, 4)

    qf = (q.astype(jnp.float32) * scale)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, idx = inp                       # [B, C, Hkv, Dh], chunk idx
        kb = jnp.repeat(kb, rep, axis=2).astype(jnp.float32)
        vb = jnp.repeat(vb, rep, axis=2).astype(jnp.float32)
        # scores [B, H, Sq, C]
        s = jnp.einsum("bqhd,bchd->bhqc", qf, kb)
        k_pos = idx * chunk + jnp.arange(chunk)
        valid = k_pos < sk                      # mask padding
        if causal:
            valid = valid[None, :] & (k_pos[None, :] <= q_pos[:, None])
            s = jnp.where(valid[None, None], s, NEG_INF)
        else:
            s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqc,bchd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)     # [B, Sq, H, Dh]


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_attention(cfg: ArchConfig, key: jax.Array) -> Params:
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * dh)),
        "wk": _dense_init(ks[1], (d, hkv * dh)),
        "wv": _dense_init(ks[2], (d, hkv * dh)),
        "wo": _dense_init(ks[3], (h * dh, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh)
        p["k_norm"] = init_rmsnorm(dh)
    return p


def attention(p: Params, x: jax.Array, cfg: ArchConfig, *,
              positions: jax.Array,
              kv_cache: Optional[tuple] = None,
              cache_len: Optional[jax.Array] = None,
              chunk: int = 1024,
              return_kv: bool = False) -> tuple[jax.Array, Optional[tuple]]:
    """GQA attention. x [B, S, D].

    Training/prefill: kv_cache None -> causal self-attention over x; with
    ``return_kv`` the rotated (k, v) are returned as a capacity-S cache.
    Decode: kv_cache (k [B, Smax, Hkv, Dh], v) with ``cache_len`` valid
    entries; x is the new token(s); returns the updated cache.
    """
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)

    rd = int(dh * cfg.partial_rotary)
    if rd > 0:
        q = apply_rope(q, positions, cfg.rope_theta, rd, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, rd, cfg.mrope_sections)

    if kv_cache is None:
        out = chunked_attention(q, k, v, causal=True, chunk=chunk)
        new_cache = ((k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
                     if return_kv else None)
    else:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_len, axis=1)
        # decode: grouped-query einsum — the KV cache is NEVER repeated to
        # full head count nor cast to f32 (at 32k x B=128 that repeat would
        # materialize hundreds of GB); the rep axis lives only on q/scores.
        smax = ck.shape[1]
        rep = h // hkv
        qg = q.reshape(b, s, hkv, rep, dh) * (1.0 / math.sqrt(dh))
        scores = jnp.einsum("bsgrd,bkgd->bgrsk", qg, ck,
                            preferred_element_type=jnp.float32)
        k_pos = jnp.arange(smax)
        valid = k_pos[None, :] <= (cache_len + jnp.arange(s))[:, None]
        scores = jnp.where(valid[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bgrsk,bkgd->bsgrd", probs, cv,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, s, h, dh).astype(x.dtype)
        new_cache = (ck, cv)

    out = out.reshape(b, s, h * dh) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437)
# ---------------------------------------------------------------------------


def init_mla(cfg: ArchConfig, key: jax.Array) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": _dense_init(ks[0], (d, m.q_lora_rank)),
        "q_a_norm": init_rmsnorm(m.q_lora_rank),
        "wq_b": _dense_init(ks[1], (m.q_lora_rank, h * qk_dim)),
        "wkv_a": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "kv_a_norm": init_rmsnorm(m.kv_lora_rank),
        "wkv_b": _dense_init(ks[3], (m.kv_lora_rank,
                                     h * (m.qk_nope_head_dim + m.v_head_dim))),
        "wo": _dense_init(ks[4], (h * m.v_head_dim, d)),
    }


def mla_attention(p: Params, x: jax.Array, cfg: ArchConfig, *,
                  positions: jax.Array,
                  kv_cache: Optional[tuple] = None,
                  cache_len: Optional[jax.Array] = None,
                  chunk: int = 1024,
                  return_kv: bool = False) -> tuple[jax.Array, Optional[tuple]]:
    """MLA: queries/keys/values through low-rank latents; the KV cache holds
    only the compressed latent (kv_lora_rank) + decoupled RoPE key — the
    paper's main KV-memory saving.
    """
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope_d, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = rmsnorm(p["q_a_norm"], x @ p["wq_a"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]                               # [B, S, r + rope_d]
    latent = rmsnorm(p["kv_a_norm"], kv_a[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., m.kv_lora_rank:][..., None, :],
                        positions, cfg.rope_theta)      # [B, S, 1, rope_d]

    if kv_cache is None:
        # train/prefill: expand latents to full K/V once (seq-parallel path)
        kv = latent @ p["wkv_b"]
        kv = kv.reshape(b, s, h, nope + vdim)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(k_rope.astype(k_nope.dtype),
                                              (b, s, h, rope_d))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(q_full, k, v, causal=True, chunk=chunk,
                                scale=1.0 / math.sqrt(nope + rope_d))
        out = out.reshape(b, s, h * vdim) @ p["wo"]
        new_cache = ((latent.astype(jnp.bfloat16),
                      k_rope[:, :, 0].astype(jnp.bfloat16))
                     if return_kv else None)
        return out, new_cache

    # decode: WEIGHT-ABSORBED attention over the compressed latent cache.
    # Never expands the cache to per-head K/V (at 32k x B=128 that would be
    # ~200 GB); instead absorbs wkv_b into the query/output sides:
    #   scores = (q_nope W_bk^T) . latent + q_rope . k_rope
    #   out    = (probs . latent) W_bv
    # This is the MLA decode identity from arXiv:2412.19437 §2.1.
    c_lat, c_kr = kv_cache
    c_lat = jax.lax.dynamic_update_slice_in_dim(
        c_lat, latent.astype(c_lat.dtype), cache_len, axis=1)
    c_kr = jax.lax.dynamic_update_slice_in_dim(
        c_kr, k_rope[:, :, 0].astype(c_kr.dtype), cache_len, axis=1)
    new_cache = (c_lat, c_kr)
    kv_len = c_lat.shape[1]

    w_b = p["wkv_b"].reshape(m.kv_lora_rank, h, nope + vdim)
    w_bk, w_bv = w_b[..., :nope], w_b[..., nope:]
    scale = 1.0 / math.sqrt(nope + rope_d)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_bk)       # [B,s,H,r]
    scores = (jnp.einsum("bshr,bkr->bhsk", q_abs, c_lat,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshd,bkd->bhsk", q_rope, c_kr,
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(kv_len)[None, :] <= \
        (cache_len + jnp.arange(s))[:, None]
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    lat_out = jnp.einsum("bhsk,bkr->bshr", probs, c_lat,
                         preferred_element_type=jnp.float32)
    out = jnp.einsum("bshr,rhv->bshv", lat_out.astype(x.dtype), w_bv)
    out = out.reshape(b, s, h * vdim) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(d: int, d_ff: int, style: str, key: jax.Array) -> Params:
    ks = jax.random.split(key, 3)
    if style == "swiglu":
        return {"w_gate": _dense_init(ks[0], (d, d_ff)),
                "w_up": _dense_init(ks[1], (d, d_ff)),
                "w_down": _dense_init(ks[2], (d_ff, d))}
    return {"w_up": _dense_init(ks[0], (d, d_ff)),
            "w_down": _dense_init(ks[1], (d_ff, d))}


def mlp(p: Params, x: jax.Array, style: str) -> jax.Array:
    if style == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# Embeddings / heads
# ---------------------------------------------------------------------------


def init_embedding(vocab: int, d: int, key: jax.Array) -> jax.Array:
    return _embed_init(key, (vocab, d))


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jax.Array, x: jax.Array, tied: bool) -> jax.Array:
    """Logits in f32 (loss stability)."""
    w = table_or_head.T if tied else table_or_head
    return (x.astype(jnp.float32) @ w.astype(jnp.float32))
