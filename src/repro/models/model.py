"""Unified CausalLM over the assigned-architecture pool.

One model definition, driven entirely by ``ArchConfig``:

  dense GQA transformers   stablelm-12b, glm4-9b, chatglm3-6b, qwen2-1.5b
  audio backbone           musicgen-medium (multi-codebook in/out heads)
  VLM backbone             qwen2-vl-7b (M-RoPE position streams)
  MoE                      qwen3-moe-30b-a3b, deepseek-v3-671b (MLA + shared)
  SSM                      rwkv6-3b
  hybrid                   zamba2-7b (Mamba2 + one shared attn block)

Execution structure (this is what keeps the 512-chip dry-run compilable):

* layers are STACKED on a leading axis and run with ``lax.scan`` — one HLO
  body regardless of depth (61-layer deepseek compiles as fast as 2-layer);
* every block body is ``jax.checkpoint``-wrapped in training (remat), so
  activation memory is O(1) in depth;
* three modes share the code: ``train`` (no caches), ``prefill`` (emit the
  decode state for the whole prompt), ``decode`` (single token, O(1) or
  O(S) state per family);
* the LM loss is CHUNKED over the sequence (``chunked_xent_loss``): logits
  for a few hundred tokens exist at a time, rematerialized in backward —
  full [B, S, V] logits for train_4k glm4 would be 635 GB in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical_constraint
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv as RW
from repro.models.layers import Params

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn_block(cfg: ArchConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    norm = (L.init_layernorm if cfg.norm_style == "layernorm"
            else L.init_rmsnorm)
    return {
        "ln1": norm(d),
        "attn": (L.init_mla(cfg, k1) if cfg.mla else L.init_attention(cfg, k1)),
        "ln2": norm(d),
    }


def _init_dense_layer(cfg: ArchConfig, key: jax.Array,
                      d_ff: Optional[int] = None) -> Params:
    k1, k2 = jax.random.split(key)
    p = _init_attn_block(cfg, k1)
    p["mlp"] = L.init_mlp(cfg.d_model, d_ff or cfg.d_ff, cfg.mlp_style, k2)
    return p


def _init_moe_layer(cfg: ArchConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    p = _init_attn_block(cfg, k1)
    p["moe"] = MOE.init_moe(cfg, k2)
    return p


def _stack_init(fn: Callable, n: int, key: jax.Array) -> Params:
    """Initialize n layers and stack every leaf on a leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_model(cfg: ArchConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    norm = (L.init_layernorm if cfg.norm_style == "layernorm"
            else L.init_rmsnorm)
    params: Params = {"final_norm": norm(d)}

    # embeddings / heads
    if cfg.n_codebooks:
        params["embed_codebooks"] = L._embed_init(
            ks[0], (cfg.n_codebooks, v, d))
        params["lm_heads"] = L._dense_init(ks[1], (cfg.n_codebooks, d, v))
    else:
        params["embed"] = L.init_embedding(v, d, ks[0])
        if not cfg.tie_embeddings:
            params["lm_head"] = L._dense_init(ks[1], (d, v))

    if cfg.family == "ssm":                       # rwkv6
        params["layers"] = _stack_init(
            lambda k: RW.init_rwkv_block(cfg, k), cfg.n_layers, ks[2])
    elif cfg.family == "hybrid":                  # zamba2
        params["mamba"] = _stack_init(
            lambda k: M2.init_mamba2_block(cfg, k), cfg.n_layers, ks[2])
        kk = jax.random.split(ks[3], 2)
        shared = _init_attn_block(cfg, kk[0])
        shared["mlp"] = L.init_mlp(d, cfg.d_ff, cfg.mlp_style, kk[1])
        # rename for the sharding rules (unstacked weights)
        params["shared_attn_block"] = {
            "ln1": shared["ln1"], "shared_attn": shared["attn"],
            "ln2": shared["ln2"], "shared_mlp": shared["mlp"]}
    elif cfg.moe is not None:                     # deepseek-v3 / qwen3-moe
        nd = cfg.moe.n_dense_layers
        if nd:
            dff = cfg.moe.d_ff_dense or cfg.d_ff
            params["dense_layers"] = _stack_init(
                lambda k: _init_dense_layer(cfg, k, dff), nd, ks[2])
        params["layers"] = _stack_init(
            lambda k: _init_moe_layer(cfg, k), cfg.n_layers - nd, ks[3])
    else:                                         # dense / audio / vlm
        params["layers"] = _stack_init(
            lambda k: _init_dense_layer(cfg, k), cfg.n_layers, ks[2])
    return params


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(params: Params, cfg: ArchConfig, tokens: jax.Array,
                 positions: Optional[jax.Array] = None) -> jax.Array:
    """tokens [B, S] (or [B, S, K] multi-codebook) -> x [B, S, D]."""
    if cfg.n_codebooks:
        tbl = params["embed_codebooks"]               # [K, V, D]
        x = sum(jnp.take(tbl[k], tokens[..., k], axis=0)
                for k in range(cfg.n_codebooks))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos_embed == "sinusoidal":
        s = x.shape[1]
        pos = (positions if positions is not None
               else jnp.arange(s))                    # [S] or [B, S]
        x = x + _sinusoidal(pos, cfg.d_model).astype(x.dtype)
    return logical_constraint(x, "batch", "seq", None)


def _sinusoidal(pos: jax.Array, d: int) -> jax.Array:
    """Dynamic sinusoidal embedding for int positions [..., S] -> [..., S, D]."""
    half = jnp.arange(0, d, 2, dtype=jnp.float32)
    angle = pos[..., None].astype(jnp.float32) / jnp.power(10000.0, half / d)
    out = jnp.zeros((*pos.shape, d), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(angle))
    out = out.at[..., 1::2].set(jnp.cos(angle))
    return out


def unembed_hidden(params: Params, cfg: ArchConfig, x: jax.Array
                   ) -> jax.Array:
    """x [B, S, D] -> logits f32 [B, S, V] (or [B, S, K, V])."""
    xf = x.astype(jnp.float32)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", xf,
                            params["lm_heads"].astype(jnp.float32))
        return logical_constraint(logits, "batch", "seq", None, "tensor")
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = xf @ w.astype(jnp.float32)
    return logical_constraint(logits, "batch", "seq", "tensor")


# ---------------------------------------------------------------------------
# Blocks (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _norm(p, x, cfg):
    return L.apply_norm(p, x, cfg.norm_eps)


def _attn(cfg):
    return L.mla_attention if cfg.mla else L.attention


def _attn_mlp_block(lp: Params, x, cfg, *, positions, kv=None, cache_len=None,
                    moe_layer=False, return_kv=False):
    """Pre-norm attn + (mlp|moe). Returns (x, aux, new_kv)."""
    h, new_kv = _attn(cfg)(lp["attn"], _norm(lp["ln1"], x, cfg), cfg,
                           positions=positions, kv_cache=kv,
                           cache_len=cache_len, return_kv=return_kv)
    x = x + h
    x = logical_constraint(x, "batch", "seq", None)
    aux = jnp.zeros((), jnp.float32)
    if moe_layer:
        y, aux = MOE.moe_mlp(lp["moe"], _norm(lp["ln2"], x, cfg), cfg)
    else:
        y = L.mlp(lp["mlp"], _norm(lp["ln2"], x, cfg), cfg.mlp_style)
    x = x + y
    x = logical_constraint(x, "batch", "seq", None)
    return x, aux, new_kv


# ---------------------------------------------------------------------------
# Forward — mode "train" | "prefill" | "decode"
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ForwardOut:
    hidden: jax.Array               # [B, S, D] final-normed hidden states
    aux: jax.Array                  # scalar aux loss (MoE balance)
    state: Optional[dict]           # decode state (prefill/decode modes)


def forward(params: Params, cfg: ArchConfig, tokens: jax.Array, *,
            positions: Optional[jax.Array] = None,
            mode: str = "train",
            state: Optional[dict] = None,
            remat: bool = True,
            unroll_decode: bool = False) -> ForwardOut:
    assert mode in ("train", "prefill", "decode")
    b = tokens.shape[0]
    s = tokens.shape[1]
    cache_len = state["len"] if (mode == "decode" and state is not None
                                 and "len" in state) else None

    if positions is None:
        if mode == "decode":
            base = cache_len + jnp.arange(s)
            positions = (jnp.broadcast_to(base, (3, b, s))
                         if cfg.mrope_sections else base)
        else:
            positions = (jnp.broadcast_to(jnp.arange(s), (3, b, s))
                         if cfg.mrope_sections else jnp.arange(s))

    emb_pos = positions if cfg.pos_embed == "sinusoidal" else None
    if mode == "decode" and cfg.pos_embed == "sinusoidal":
        emb_pos = cache_len + jnp.arange(s)
    x = embed_tokens(params, cfg, tokens, emb_pos)

    ck = functools.partial(jax.checkpoint) if (remat and mode == "train") \
        else (lambda f: f)

    if cfg.family == "ssm":
        out = _forward_rwkv(params, cfg, x, mode, state, ck)
    elif cfg.family == "hybrid":
        out = _forward_hybrid(params, cfg, x, positions, mode, state, ck)
    elif mode == "decode" and unroll_decode:
        out = _decode_transformer_unrolled(params, cfg, x, positions, state)
    else:
        out = _forward_transformer(params, cfg, x, positions, mode, state, ck)

    x, aux, new_state = out
    x = _norm(params["final_norm"], x, cfg)
    if new_state is not None and cache_len is not None:
        new_state["len"] = cache_len + s
    return ForwardOut(x, aux, new_state)


# -- transformer families ----------------------------------------------------


def _kv_zeros(cfg: ArchConfig, n_layers: int, batch: int, capacity: int):
    if cfg.mla:
        m = cfg.mla
        return {"latent": jnp.zeros((n_layers, batch, capacity,
                                     m.kv_lora_rank), jnp.bfloat16),
                "krope": jnp.zeros((n_layers, batch, capacity,
                                    m.qk_rope_head_dim), jnp.bfloat16)}
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((n_layers, batch, capacity, hkv, dh),
                           jnp.bfloat16),
            "v": jnp.zeros((n_layers, batch, capacity, hkv, dh),
                           jnp.bfloat16)}


def _cache_of(state, i: Optional[slice] = None):
    if "latent" in state:
        return (state["latent"], state["krope"])
    return (state["k"], state["v"])


def _forward_transformer(params, cfg, x, positions, mode, state, ck):
    b, s, d = x.shape
    nd = cfg.moe.n_dense_layers if cfg.moe else 0
    n_moe = cfg.n_layers - nd if cfg.moe else 0
    aux_total = jnp.zeros((), jnp.float32)

    def run_stack(x, stack, moe_layer, kv_stack, cache_len, want_kv):
        """Scan one homogeneous stack. Returns (x, aux, new_kv_stack)."""
        if mode == "decode":
            def body(carry, xs):
                xc = carry
                lp = xs[0]
                kv = tuple(xs[1:])
                xc, aux, new_kv = _attn_mlp_block(
                    lp, xc, cfg, positions=positions, kv=kv,
                    cache_len=cache_len, moe_layer=moe_layer)
                return xc, (aux, *new_kv)
            x, ys = jax.lax.scan(body, x, (stack, *kv_stack))
            return x, ys[0].sum(), tuple(ys[1:])
        if mode == "prefill":
            def body(carry, lp):
                xc = carry
                xc, aux, kv = _attn_mlp_block(
                    lp, xc, cfg, positions=positions, kv=None,
                    cache_len=None, moe_layer=moe_layer, return_kv=True)
                return xc, (aux, *kv)
            x, ys = jax.lax.scan(body, x, stack)
            return x, ys[0].sum(), tuple(ys[1:])

        def body(carry, lp):
            xc, at = carry
            xc, aux, _ = _attn_mlp_block(
                lp, xc, cfg, positions=positions, kv=None, cache_len=None,
                moe_layer=moe_layer)
            return (xc, at + aux), None

        (x, at), _ = jax.lax.scan(ck(body), (x, jnp.zeros((), jnp.float32)),
                                  stack)
        return x, at, None

    cache_len = state["len"] if mode == "decode" else None
    new_state = None
    if mode in ("prefill", "decode"):
        new_state = {}

    want_kv = mode == "prefill"
    if nd:
        dense_kv = (_split_state(state, "dense") if mode == "decode"
                    else (None,))
        x, aux, new_kv = run_stack(x, params["dense_layers"], False,
                                   dense_kv, cache_len, want_kv)
        aux_total += aux
        if new_state is not None and new_kv is not None:
            _merge_state(new_state, "dense", new_kv, cfg)
    stack = params["layers"]
    main_kv = (_split_state(state, "main") if mode == "decode" else (None,))
    x, aux, new_kv = run_stack(x, stack, cfg.moe is not None, main_kv,
                               cache_len, want_kv)
    aux_total += aux
    if new_state is not None and new_kv is not None:
        _merge_state(new_state, "main", new_kv, cfg)
    return x, aux_total, new_state


def _decode_transformer_unrolled(params, cfg, x, positions, state):
    """Decode with a PYTHON loop over layers and PER-LAYER cache leaves
    (state["main"]["k"] is a LIST of [B, C, Hkv, Dh] arrays).

    §Perf (decode iteration 2): the scanned decode stacks every layer's
    cache into one [L, ...] tensor and accumulates updates through
    dynamic-update-slice on the scan outputs — buffer assignment copies
    the full stacked cache per layer (~40x the useful traffic at glm4
    decode_32k). Unrolled layers keep each cache an independent
    donated buffer: traffic = one in-place token write + one read per
    layer. Decode HLO is tiny, so 40x code duplication is cheap.
    """
    cache_len = state["len"]
    nd = cfg.moe.n_dense_layers if cfg.moe else 0
    aux_total = jnp.zeros((), jnp.float32)
    new_state: dict = {}

    def layer_params(stack, i):
        return jax.tree.map(lambda a: a[i], stack)

    def run_part(x, stack, part, n_layers, moe_layer):
        st = state[part]
        keys = ("latent", "krope") if cfg.mla else ("k", "v")
        new_kv = {k: [] for k in keys}
        for i in range(n_layers):
            kv = tuple(st[k][i] for k in keys)
            lp = layer_params(stack, i)
            x, aux, kv_out = _attn_mlp_block(
                lp, x, cfg, positions=positions, kv=kv,
                cache_len=cache_len, moe_layer=moe_layer)
            for k, t in zip(keys, kv_out):
                new_kv[k].append(t)
        new_state[part] = new_kv
        return x, aux

    if nd:
        x, aux = run_part(x, params["dense_layers"], "dense", nd, False)
        aux_total += aux
    x, aux = run_part(x, params["layers"], "main", cfg.n_layers - nd,
                      cfg.moe is not None)
    aux_total += aux
    return x, aux_total, new_state


def _split_state(state, part):
    if "latent" in state[part]:
        return (state[part]["latent"], state[part]["krope"])
    return (state[part]["k"], state[part]["v"])


def _merge_state(new_state, part, kv, cfg):
    if cfg.mla:
        new_state[part] = {"latent": kv[0], "krope": kv[1]}
    else:
        new_state[part] = {"k": kv[0], "v": kv[1]}


# -- rwkv ---------------------------------------------------------------------


def _forward_rwkv(params, cfg, x, mode, state, ck):
    b = x.shape[0]

    if mode == "train":
        def body(carry, lp):
            xc = carry
            st = RW.init_rwkv_state(cfg, b)
            xc, _ = RW.rwkv_block(lp, xc, cfg, st)
            return xc, None
        x, _ = jax.lax.scan(ck(body), x, params["layers"])
        return x, jnp.zeros((), jnp.float32), None

    if mode == "prefill":
        def body(carry, lp):
            xc = carry
            st = RW.init_rwkv_state(cfg, b)
            xc, new_st = RW.rwkv_block(lp, xc, cfg, st)
            return xc, new_st
        x, sts = jax.lax.scan(body, x, params["layers"])
        return x, jnp.zeros((), jnp.float32), {"rwkv": sts}

    def body(carry, xs):
        xc = carry
        lp, st = xs
        xc, new_st = RW.rwkv_block(lp, xc, cfg, st, single_step=True)
        return xc, new_st
    x, sts = jax.lax.scan(body, x, (params["layers"], state["rwkv"]))
    return x, jnp.zeros((), jnp.float32), {"rwkv": sts}


# -- zamba2 hybrid -------------------------------------------------------------


def _hybrid_layout(cfg: ArchConfig):
    period = cfg.attn_layer_period or 6
    n_groups = cfg.n_layers // period
    tail = cfg.n_layers - n_groups * period
    return period, n_groups, tail


def _forward_hybrid(params, cfg, x, positions, mode, state, ck):
    b = x.shape[0]
    period, n_groups, tail = _hybrid_layout(cfg)
    sh = params["shared_attn_block"]
    cache_len = state["len"] if mode == "decode" else None
    single = mode == "decode"

    def group_of(tree, n=n_groups, p=period):
        return jax.tree.map(
            lambda a: a[:n * p].reshape(n, p, *a.shape[1:]), tree)

    def tail_of(tree, n=n_groups, p=period):
        return jax.tree.map(lambda a: a[n * p:], tree)

    mg, mt = group_of(params["mamba"]), tail_of(params["mamba"])

    def mamba_scan(x, stack, states):
        """Scan mamba layers; states None (zeros) or stacked pytree."""
        if states is None:
            def body(xc, lp):
                st = M2.init_mamba2_state(cfg, b)
                xc, _ = M2.mamba2_block(lp, xc, cfg, st,
                                        single_step=single)
                return xc, None
            x, _ = jax.lax.scan(body, x, stack)
            return x, None
        def body(xc, xs):
            lp, st = xs
            xc, new_st = M2.mamba2_block(lp, xc, cfg, st,
                                         single_step=single)
            return xc, new_st
        return jax.lax.scan(body, x, (stack, states))

    def shared_block(x, kv):
        h, new_kv = L.attention(sh["shared_attn"],
                                _norm(sh["ln1"], x, cfg), cfg,
                                positions=positions, kv_cache=kv,
                                cache_len=cache_len)
        x = x + h
        x = x + L.mlp(sh["shared_mlp"], _norm(sh["ln2"], x, cfg),
                      cfg.mlp_style)
        return logical_constraint(x, "batch", "seq", None), new_kv

    if mode == "train":
        def gbody(xc, gp):
            xc, _ = mamba_scan(xc, gp, None)
            xc, _ = shared_block(xc, None)
            return xc, None
        x, _ = jax.lax.scan(ck(gbody), x, mg)
        if tail:
            x, _ = mamba_scan(x, mt, None)
        return x, jnp.zeros((), jnp.float32), None

    if mode == "prefill":
        # prefill: emit per-layer mamba states; shared-attn K/V via
        # return-kv attention (capacity == prompt length)
        def gbody(xc, gp):
            def mbody(xc2, lp):
                st = M2.init_mamba2_state(cfg, b)
                xc2, new_st = M2.mamba2_block(lp, xc2, cfg, st)
                return xc2, new_st
            xc, msts = jax.lax.scan(mbody, xc, gp)
            h, kv = L.attention(sh["shared_attn"],
                                _norm(sh["ln1"], xc, cfg), cfg,
                                positions=positions, return_kv=True)
            xc = xc + h
            xc = xc + L.mlp(sh["shared_mlp"], _norm(sh["ln2"], xc, cfg),
                            cfg.mlp_style)
            return xc, (msts, kv)
        x, (g_states, kvs) = jax.lax.scan(gbody, x, mg)
        t_states = None
        if tail:
            def mbody(xc2, lp):
                st = M2.init_mamba2_state(cfg, b)
                xc2, new_st = M2.mamba2_block(lp, xc2, cfg, st)
                return xc2, new_st
            x, t_states = jax.lax.scan(mbody, x, mt)
        mamba_states = _cat_group_tail(g_states, t_states)
        return x, jnp.zeros((), jnp.float32), {
            "mamba": mamba_states, "k": kvs[0], "v": kvs[1]}

    # decode
    mstates = state["mamba"]
    g_st, t_st = group_of(mstates), tail_of(mstates)

    def gbody(xc, xs):
        gp, gst, k_g, v_g = xs
        xc, new_st = mamba_scan(xc, gp, gst)
        xc, new_kv = shared_block(xc, (k_g, v_g))
        return xc, (new_st, *new_kv)
    x, ys = jax.lax.scan(gbody, x, (mg, g_st, state["k"], state["v"]))
    new_g_states, new_k, new_v = ys
    new_t = None
    if tail:
        x, new_t = mamba_scan(x, mt, t_st)
    return x, jnp.zeros((), jnp.float32), {
        "mamba": _cat_group_tail(new_g_states, new_t),
        "k": new_k, "v": new_v}


def _cat_group_tail(g_states, t_states):
    """[NG, P, ...] grouped states (+ optional [T, ...] tail) -> [L, ...]."""
    flat = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), g_states)
    if t_states is None:
        return flat
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                        flat, t_states)


# ---------------------------------------------------------------------------
# Decode-state allocation (for serve_step input specs)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ArchConfig, batch: int, capacity: int,
                      unrolled: bool = False) -> dict:
    """Zero-initialized decode state with KV/recurrent capacity.

    ``unrolled``: per-layer cache LISTS for the unrolled decode path
    (transformer families only — see _decode_transformer_unrolled).
    """
    if cfg.family == "ssm":
        hd = cfg.ssm.head_dim
        h = cfg.d_model // hd
        lz = cfg.n_layers
        return {"rwkv": {
            "tm_x": jnp.zeros((lz, batch, cfg.d_model), jnp.bfloat16),
            "cm_x": jnp.zeros((lz, batch, cfg.d_model), jnp.bfloat16),
            "wkv": jnp.zeros((lz, batch, h, hd, hd), jnp.float32)},
            "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        h = d_inner // s.head_dim
        period, n_groups, tail = _hybrid_layout(cfg)
        hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
        return {"mamba": {
            "ssm": jnp.zeros((cfg.n_layers, batch, h, s.head_dim,
                              s.d_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, s.d_conv - 1,
                               d_inner + 2 * s.d_state), jnp.bfloat16)},
            "k": jnp.zeros((n_groups, batch, capacity, hkv, dh),
                           jnp.bfloat16),
            "v": jnp.zeros((n_groups, batch, capacity, hkv, dh),
                           jnp.bfloat16),
            "len": jnp.zeros((), jnp.int32)}
    nd = cfg.moe.n_dense_layers if cfg.moe else 0
    st: dict = {"len": jnp.zeros((), jnp.int32)}
    if nd:
        st["dense"] = _kv_zeros(cfg, nd, batch, capacity)
    st["main"] = _kv_zeros(cfg, cfg.n_layers - nd, batch, capacity)
    if unrolled:
        for part in ("dense", "main"):
            if part in st:
                st[part] = {k: [v[i] for i in range(v.shape[0])]
                            for k, v in st[part].items()}
    return st


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy) and public entry points
# ---------------------------------------------------------------------------


def chunked_xent_loss(params: Params, cfg: ArchConfig, hidden: jax.Array,
                      labels: jax.Array, chunk: int = 512) -> jax.Array:
    """Next-token CE over the sequence in chunks of ``chunk`` tokens.

    hidden [B, S, D]; labels [B, S] (or [B, S, K]). The per-chunk body is
    checkpointed: only the hidden chunk is saved for backward, the [B, C, V]
    logits are rematerialized — peak logits memory is B*C*V, not B*S*V.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk, *labels.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        h, lab = inp
        logits = unembed_hidden(params, cfg, h)       # f32 [B, C, (K,) V]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / labels.size


def loss_fn(params: Params, cfg: ArchConfig, batch: dict, *,
            remat: bool = True, loss_chunk: int = 512) -> tuple:
    """batch: {"tokens", "labels", optional "positions"} -> (loss, metrics)."""
    out = forward(params, cfg, batch["tokens"],
                  positions=batch.get("positions"), mode="train",
                  remat=remat)
    ce = chunked_xent_loss(params, cfg, out.hidden, batch["labels"],
                           chunk=loss_chunk)
    loss = ce + out.aux
    return loss, {"ce": ce, "aux": out.aux}


def full_logits(params: Params, cfg: ArchConfig, tokens: jax.Array, *,
                positions: Optional[jax.Array] = None,
                remat: bool = False) -> tuple:
    """Small-scale helper (smoke tests): full [B, S, V] logits."""
    out = forward(params, cfg, tokens, positions=positions, mode="train",
                  remat=remat)
    return unembed_hidden(params, cfg, out.hidden), out.aux


def decode_step(params: Params, cfg: ArchConfig, tokens: jax.Array,
                state: dict, *, positions=None,
                unroll: bool = False) -> tuple:
    """One decode step. tokens [B, 1] (or [B, 1, K]) -> (logits, state)."""
    out = forward(params, cfg, tokens, positions=positions, mode="decode",
                  state=state, unroll_decode=unroll)
    return unembed_hidden(params, cfg, out.hidden), out.state


def prefill(params: Params, cfg: ArchConfig, tokens: jax.Array, *,
            positions=None) -> tuple:
    """Prompt pass: returns (last-position logits, decode state)."""
    out = forward(params, cfg, tokens, positions=positions, mode="prefill")
    logits = unembed_hidden(params, cfg, out.hidden[:, -1:])
    st = out.state
    if st is not None:
        st["len"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits, st
