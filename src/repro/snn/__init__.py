from repro.snn.lif import (LIFParams, LIFIntParams, lif_step, lif_step_int,
                           alpha_to_shift, spike_fn)
from repro.snn.models import (SNNConfig, MNIST_CONFIG, SHD_CONFIG,
                              init_params, masked_weights, forward)
from repro.snn.quantize import QuantConfig, QuantizedSNN, quantize

__all__ = ["LIFParams", "LIFIntParams", "lif_step", "lif_step_int",
           "alpha_to_shift", "spike_fn", "SNNConfig", "MNIST_CONFIG",
           "SHD_CONFIG", "init_params", "masked_weights", "forward",
           "QuantConfig", "QuantizedSNN", "quantize"]
