"""Discrete-time LIF neuron dynamics (paper Eqs. (2)-(5)) with surrogate gradients.

The float path is used for BPTT training (snnTorch-equivalent); the integer
path (`lif_step_int`) is the bit-exact oracle the SupraSNN engine must match
(deterministic-commit property).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class LIFParams(NamedTuple):
    """Neuron-model constants (paper Table 2)."""
    alpha: float = 0.25        # leak factor; (1 - alpha) V + I
    v_threshold: float = 1.0
    v_reset: float = 0.0


# ---------------------------------------------------------------------------
# Surrogate-gradient spike functions (paper Table 2: ReLU for MNIST, Sigmoid
# for SHD).  Forward is the hard Heaviside of Eq. (4); backward replaces the
# Dirac delta with a smooth/piecewise surrogate.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def spike_fn(v_minus_th: jax.Array, surrogate: str = "relu") -> jax.Array:
    return (v_minus_th >= 0.0).astype(v_minus_th.dtype)


def _spike_fwd(v_minus_th, surrogate):
    return spike_fn(v_minus_th, surrogate), v_minus_th


def _spike_bwd(surrogate, v_minus_th, g):
    if surrogate == "relu":
        # Triangle ("ReLU of 1-|x|") surrogate.
        surr = jnp.maximum(0.0, 1.0 - jnp.abs(v_minus_th))
    elif surrogate == "sigmoid":
        k = 4.0
        s = jax.nn.sigmoid(k * v_minus_th)
        surr = k * s * (1.0 - s)
    elif surrogate == "fast_sigmoid":
        k = 10.0
        surr = 1.0 / (1.0 + k * jnp.abs(v_minus_th)) ** 2
    else:  # pragma: no cover - guarded by config validation
        raise ValueError(f"unknown surrogate {surrogate!r}")
    return (g * surr,)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


def lif_step(v: jax.Array, current: jax.Array, p: LIFParams,
             surrogate: str = "relu") -> tuple[jax.Array, jax.Array]:
    """One LIF timestep. Returns (v_next, spikes).

    Eq. (2): V_upd = (1 - alpha) V + I
    Eq. (4): S = [V_upd >= V_th]
    Eq. (5): V_next = V_reset if S else V_upd
    """
    v_upd = (1.0 - p.alpha) * v + current
    s = spike_fn(v_upd - p.v_threshold, surrogate)
    v_next = jnp.where(s > 0, p.v_reset, v_upd)
    return v_next, s


# ---------------------------------------------------------------------------
# Integer (quantized-hardware) oracle. SupraSNN implements the leak with a
# programmable right shift: (1 - alpha) V  ==  V - (V >> shift).
# All arithmetic is int32; this is the reference the cycle engine and the
# mapped executor must reproduce BIT-EXACTLY.
# ---------------------------------------------------------------------------

class LIFIntParams(NamedTuple):
    leak_shift: int            # alpha approximated as 2**-leak_shift
    v_threshold: int
    v_reset: int


def leak_int(v: np.ndarray | jax.Array, shift: int):
    """V - (V >> shift), arithmetic shift (matches RTL two's-complement)."""
    if isinstance(v, np.ndarray):
        return v - (v >> shift)
    return v - jax.lax.shift_right_arithmetic(v, jnp.int32(shift))


def lif_step_int(v, current, p: LIFIntParams):
    """Integer LIF step. Works for both numpy and jnp int32 arrays."""
    xp = np if isinstance(v, np.ndarray) else jnp
    v_upd = leak_int(v, p.leak_shift) + current
    s = (v_upd >= p.v_threshold)
    v_next = xp.where(s, xp.asarray(p.v_reset, dtype=v_upd.dtype), v_upd)
    return v_next, s.astype(xp.int32)


def alpha_to_shift(alpha: float) -> int:
    """Nearest power-of-two approximation of the leak factor (paper §5)."""
    return int(round(-np.log2(alpha)))
