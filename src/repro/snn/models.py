"""SNN model definitions: SFNN (feedforward) and SRNN (recurrent), with
unstructured-sparsity masks (paper §2, Fig. 2; Table 2 architectures).

Parameters are plain pytrees; forward passes run the whole spike train with
``lax.scan`` over time (BPTT unrolls through it).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.snn.lif import LIFParams, lif_step


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    layer_sizes: tuple[int, ...] = (784, 116, 10)   # MNIST config, Table 2
    recurrent: bool = False                          # SRNN: hidden layers recur
    sparsity: float = 0.5189                         # fraction of PRUNED synapses
    lif: LIFParams = LIFParams()
    surrogate: str = "relu"
    timesteps: int = 10
    # SupraSNN hardware semantics: spikes generated at t-1 are distributed at
    # t (paper §4.2), i.e. one-timestep delay on every internal synapse.
    # External input spikes at t reach first-layer currents at t.
    delayed: bool = True

    @property
    def n_layers(self) -> int:
        return len(self.layer_sizes) - 1


def init_params(cfg: SNNConfig, key: jax.Array) -> dict[str, Any]:
    """Init weights + fixed binary sparsity masks (pruned BEFORE training)."""
    params: dict[str, Any] = {}
    keys = jax.random.split(key, 2 * cfg.n_layers + 1)
    for i in range(cfg.n_layers):
        fan_in, fan_out = cfg.layer_sizes[i], cfg.layer_sizes[i + 1]
        w = jax.random.normal(keys[2 * i], (fan_in, fan_out)) / np.sqrt(fan_in)
        mask = (jax.random.uniform(keys[2 * i + 1], (fan_in, fan_out))
                >= cfg.sparsity).astype(jnp.float32)
        params[f"w{i}"] = w * 3.0  # scale up: sparse fan-in needs larger drive
        params[f"mask{i}"] = mask
        if cfg.recurrent and i < cfg.n_layers - 1:
            kr = jax.random.fold_in(keys[-1], i)
            wr = jax.random.normal(kr, (fan_out, fan_out)) / np.sqrt(fan_out)
            mr = (jax.random.uniform(jax.random.fold_in(kr, 1),
                                     (fan_out, fan_out)) >= cfg.sparsity)
            # no self-loops
            mr = mr & ~jnp.eye(fan_out, dtype=bool)
            params[f"wr{i}"] = wr
            params[f"maskr{i}"] = mr.astype(jnp.float32)
    return params


def masked_weights(params: dict[str, Any], cfg: SNNConfig) -> dict[str, jax.Array]:
    """Effective (pruned) weights; zero-weight synapses simply don't exist."""
    out = {}
    for i in range(cfg.n_layers):
        out[f"w{i}"] = params[f"w{i}"] * params[f"mask{i}"]
        if cfg.recurrent and i < cfg.n_layers - 1:
            out[f"wr{i}"] = params[f"wr{i}"] * params[f"maskr{i}"]
    return out


def forward(params: dict[str, Any], spikes_in: jax.Array, cfg: SNNConfig
            ) -> tuple[jax.Array, jax.Array]:
    """Run the network over a spike train.

    spikes_in: [T, B, n_in] binary.
    Returns (spike_counts [B, n_out], out_spikes [T, B, n_out]).
    Classification = argmax of accumulated output spikes (paper §7.1).
    """
    w = masked_weights(params, cfg)
    B = spikes_in.shape[1]

    v0 = [jnp.zeros((B, n)) for n in cfg.layer_sizes[1:]]
    s0 = [jnp.zeros((B, n)) for n in cfg.layer_sizes[1:]]  # prev-step spikes

    def step(carry, s_in):
        vs, prev = carry
        new_vs, new_spikes = [], []
        layer_in = s_in
        for i in range(cfg.n_layers):
            # delayed (hardware) semantics: internal synapses carry spikes
            # from the PREVIOUS timestep; external inputs arrive same-step.
            src = layer_in if i == 0 else (prev[i - 1] if cfg.delayed
                                           else layer_in)
            cur = src @ w[f"w{i}"]
            if cfg.recurrent and i < cfg.n_layers - 1:
                cur = cur + prev[i] @ w[f"wr{i}"]
            v_next, s = lif_step(vs[i], cur, cfg.lif, cfg.surrogate)
            new_vs.append(v_next)
            new_spikes.append(s)
            layer_in = s
        return (new_vs, new_spikes), new_spikes[-1]

    (_, _), out_spikes = jax.lax.scan(step, (v0, s0), spikes_in)
    return out_spikes.sum(axis=0), out_spikes


MNIST_CONFIG = SNNConfig(layer_sizes=(784, 116, 10), recurrent=False,
                         sparsity=0.5189, lif=LIFParams(alpha=0.25),
                         surrogate="relu", timesteps=10)

SHD_CONFIG = SNNConfig(layer_sizes=(700, 300, 20), recurrent=True,
                       sparsity=0.8704, lif=LIFParams(alpha=0.03125),
                       surrogate="sigmoid", timesteps=100)
