"""BPTT training for SNNs (paper §7.1): surrogate-gradient backprop through
the ``lax.scan`` over timesteps, Adam optimizer, rate encoding for images.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.optimizer.adam import AdamConfig, adam_init, adam_update
from repro.snn.models import SNNConfig, forward, init_params


def rate_encode(images: jax.Array, timesteps: int, key: jax.Array) -> jax.Array:
    """Rate coding: pixel intensity -> Bernoulli spike probability per step.

    images: [B, n_pixels] in [0, 1].  Returns [T, B, n_pixels] binary.
    """
    p = jnp.broadcast_to(images, (timesteps,) + images.shape)
    return jax.random.bernoulli(key, p).astype(jnp.float32)


def spike_count_loss(counts: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross-entropy over accumulated output-spike counts."""
    logp = jax.nn.log_softmax(counts)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@dataclasses.dataclass
class TrainResult:
    params: dict
    accuracy: float
    loss_history: list
    wall_seconds: float


def make_train_step(cfg: SNNConfig, opt: AdamConfig, encode: bool):
    """Returns jit'd (params, opt_state, batch_x, batch_y, key) -> ..."""

    def loss_fn(params, spikes, labels):
        counts, _ = forward(params, spikes, cfg)
        return spike_count_loss(counts, labels), counts

    @jax.jit
    def step(params, opt_state, x, y, key):
        spikes = rate_encode(x, cfg.timesteps, key) if encode else x
        (loss, counts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, spikes, y)
        # masks are not trained; their grads are zero but keep them frozen:
        grads = {k: (jnp.zeros_like(v) if k.startswith("mask") else v)
                 for k, v in grads.items()}
        params, opt_state = adam_update(grads, opt_state, params, opt)
        acc = jnp.mean((jnp.argmax(counts, -1) == y).astype(jnp.float32))
        return params, opt_state, loss, acc

    return step


def evaluate(params, cfg: SNNConfig, xs, ys, key, encode: bool,
             batch: int = 256) -> float:
    """Full-set accuracy."""
    @jax.jit
    def fwd(params, spikes):
        counts, _ = forward(params, spikes, cfg)
        return jnp.argmax(counts, -1)

    correct = 0
    for i in range(0, len(xs), batch):
        x, y = xs[i:i + batch], ys[i:i + batch]
        k = jax.random.fold_in(key, i)
        spikes = rate_encode(jnp.asarray(x), cfg.timesteps, k) if encode \
            else jnp.asarray(x)
        pred = fwd(params, spikes)
        correct += int((np.asarray(pred) == np.asarray(y)).sum())
    return correct / len(xs)


def train(cfg: SNNConfig, data: Iterator, steps: int, lr: float,
          key: jax.Array, encode: bool = True,
          log_every: int = 50, verbose: bool = False) -> TrainResult:
    """data yields (x [B, n_in] float or [T, B, n_in] spikes, y [B] int)."""
    opt = AdamConfig(lr=lr)
    kp, kt = jax.random.split(key)
    params = init_params(cfg, kp)
    opt_state = adam_init(params, opt)
    step_fn = make_train_step(cfg, opt, encode)

    t0 = time.time()
    losses, last_acc = [], 0.0
    for i in range(steps):
        x, y = next(data)
        params, opt_state, loss, acc = step_fn(
            params, opt_state, jnp.asarray(x), jnp.asarray(y),
            jax.random.fold_in(kt, i))
        losses.append(float(loss))
        last_acc = float(acc)
        if verbose and (i % log_every == 0):
            print(f"  step {i:4d}  loss {float(loss):.4f}  acc {last_acc:.3f}")
    return TrainResult(params, last_acc, losses, time.time() - t0)
