"""Post-training quantization to the SupraSNN fixed-point hardware formats
(paper Table 2: 4-bit weights / 5-bit potential for MNIST; §7.3/7.4 sweeps).

Weights -> signed ints of width W_W (symmetric, per-network scale).
Threshold/reset -> same fixed-point scale as the accumulated currents.
Leak alpha -> nearest power-of-two shift (paper §5).

Zero-quantized synapses are dropped from the operation tables entirely —
that is the "post-quantization sparsity" row of Table 2.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.snn.lif import LIFIntParams, alpha_to_shift
from repro.snn.models import SNNConfig, masked_weights


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    weight_bits: int = 4
    potential_bits: int = 5    # informational: membrane register width


@dataclasses.dataclass
class QuantizedSNN:
    """Integer network ready for mapping onto the engine."""
    layer_sizes: tuple
    weights: list              # list of int32 [fan_in, fan_out]
    rec_weights: list          # per hidden layer or None
    scale: float               # float weight = int * scale
    lif: LIFIntParams
    recurrent: bool

    @property
    def n_nonzero_synapses(self) -> int:
        n = sum(int((w != 0).sum()) for w in self.weights)
        n += sum(int((w != 0).sum()) for w in self.rec_weights if w is not None)
        return n

    @property
    def n_total_synapses(self) -> int:
        n = sum(w.size for w in self.weights)
        n += sum(w.size for w in self.rec_weights if w is not None)
        return n

    @property
    def sparsity(self) -> float:
        return 1.0 - self.n_nonzero_synapses / self.n_total_synapses

    @property
    def n_unique_weights(self) -> int:
        vals = np.concatenate(
            [w[w != 0].ravel() for w in self.weights]
            + [w[w != 0].ravel() for w in self.rec_weights if w is not None])
        return len(np.unique(vals)) if vals.size else 0


def quantize(params: dict, cfg: SNNConfig, q: QuantConfig) -> QuantizedSNN:
    w = masked_weights(params, cfg)
    ws = [np.asarray(w[f"w{i}"]) for i in range(cfg.n_layers)]
    wrs = [np.asarray(w[f"wr{i}"]) if (cfg.recurrent and i < cfg.n_layers - 1)
           else None for i in range(cfg.n_layers)]

    absmax = max(float(np.abs(x).max()) for x in ws + [r for r in wrs
                                                       if r is not None])
    qmax = 2 ** (q.weight_bits - 1) - 1
    scale = absmax / qmax if absmax > 0 else 1.0

    def qz(x):
        return np.clip(np.round(x / scale), -qmax - 1, qmax).astype(np.int32)

    wq = [qz(x) for x in ws]
    wrq = [qz(x) if x is not None else None for x in wrs]

    # threshold / reset in the same fixed-point domain as currents
    vth = int(round(cfg.lif.v_threshold / scale))
    vreset = int(round(cfg.lif.v_reset / scale))
    lif = LIFIntParams(leak_shift=alpha_to_shift(cfg.lif.alpha),
                       v_threshold=max(vth, 1), v_reset=vreset)
    return QuantizedSNN(cfg.layer_sizes, wq, wrq, scale, lif, cfg.recurrent)
