"""train_step / prefill_step / serve_step builders.

The returned functions are pure (jit/pjit-able); the logical->physical
sharding binding (``mesh_rules``) is entered INSIDE the function body, so
it is active while jit traces — every ``logical_constraint`` in the model
resolves against the strategy chosen by the launcher.

train_step structure:

    for each microbatch (lax.scan when n_micro > 1):
        loss, grads += value_and_grad(loss_fn)          # remat'd forward
    grads /= n_micro
    [optional cross-pod int8 compression hook]
    params, opt = adam_update(...)

Microbatching is the compute/comm-overlap lever: XLA's latency-hiding
scheduler overlaps the per-microbatch reduce-scatter with the next
microbatch's backward pass, and the activation working set shrinks by
n_micro (napkin math per arch in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import MeshRules, mesh_rules
from repro.models import model as M
from repro.optimizer.adam import AdamConfig, adam_init, adam_update


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    weight_decay: float = 0.0
    n_micro: int = 1                  # gradient-accumulation microbatches
    accum_dtype: Any = jnp.float32    # grad accumulator dtype
    quantized_opt_state: bool = False # int8 Adam m/v (deepseek-v3 scale)
    remat: bool = True
    loss_chunk: int = 512             # chunked-xent sequence chunk


def _adam_cfg(hp: TrainHParams) -> AdamConfig:
    return AdamConfig(lr=hp.lr, weight_decay=hp.weight_decay,
                      quantized_state=hp.quantized_opt_state)


def init_opt_state(params, hp: TrainHParams):
    return adam_init(params, _adam_cfg(hp))


def make_train_step(cfg: ArchConfig, rules: Optional[MeshRules],
                    hp: TrainHParams):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch``: {"tokens", "labels", optional "positions"} with a leading
    global-batch dim divisible by hp.n_micro.
    """
    opt_cfg = _adam_cfg(hp)

    def loss(params, mb):
        l, metrics = M.loss_fn(params, cfg, mb, remat=hp.remat,
                               loss_chunk=hp.loss_chunk)
        return l, metrics

    def train_step(params, opt_state, batch):
        with mesh_rules(rules):
            if hp.n_micro == 1:
                (l, metrics), grads = jax.value_and_grad(
                    loss, has_aux=True)(params, batch)
            else:
                def split(x):
                    # positions [3, B, S] carry batch on dim 1
                    if x.ndim >= 2 and x.shape[0] == 3 and \
                            x.shape[1] % hp.n_micro == 0 and \
                            x.shape[0] != x.shape[1]:
                        return x.reshape(3, hp.n_micro, -1, *x.shape[2:]) \
                                .swapaxes(0, 1)
                    return x.reshape(hp.n_micro, -1, *x.shape[1:])
                micro = jax.tree.map(split, batch)

                def body(carry, mb):
                    acc, ltot = carry
                    (l, metrics), g = jax.value_and_grad(
                        loss, has_aux=True)(params, mb)
                    acc = jax.tree.map(
                        lambda a, b: a + b.astype(hp.accum_dtype), acc, g)
                    return (acc, ltot + l), metrics

                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, hp.accum_dtype), params)
                (grads, ltot), metrics = jax.lax.scan(
                    body, (acc0, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / hp.n_micro, grads)
                l = ltot / hp.n_micro
                metrics = jax.tree.map(lambda m: m.mean(), metrics)

            params, opt_state = adam_update(grads, opt_state, params,
                                            opt_cfg)
        return params, opt_state, {"loss": l, **metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig, rules: Optional[MeshRules]):
    """prefill_step(params, batch) -> (last-token logits, decode state)."""
    def prefill_step(params, batch):
        with mesh_rules(rules):
            logits, state = M.prefill(params, cfg, batch["tokens"],
                                      positions=batch.get("positions"))
        return logits, state
    return prefill_step


def make_serve_step(cfg: ArchConfig, rules: Optional[MeshRules],
                    unroll: bool = False):
    """serve_step(params, tokens, state) -> (next_token ids, new state).

    One decode step for the whole request batch: greedy next token. The
    state argument should be DONATED by the caller's jit so KV caches
    update in place. ``unroll``: unrolled-layer decode with per-layer
    cache leaves (§Perf decode iteration 2).
    """
    def serve_step(params, tokens, state):
        with mesh_rules(rules):
            positions = None
            if cfg.mrope_sections:
                b, s = tokens.shape[:2]
                positions = jnp.broadcast_to(
                    state["len"] + jnp.arange(s), (3, b, s))
            logits, new_state = M.decode_step(params, cfg, tokens, state,
                                              positions=positions,
                                              unroll=unroll)
            next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return next_tok.astype(jnp.int32), new_state
    return serve_step
