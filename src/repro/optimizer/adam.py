"""Adam/AdamW in pure JAX, with optional int8-quantized moments.

The int8 variant ("Adam-8bit") stores m and v block-quantized to int8 with
a per-block fp32 absmax scale — ~2 bytes/param of optimizer state instead
of 8. This is what lets deepseek-v3-671b training fit the production mesh
(DESIGN.md §5).

SHARDING-CRITICAL LAYOUT: blocks are formed by splitting the LAST axis
([..., F] -> [..., F/B, B]) — a pure dimension-split reshape that GSPMD
propagates shardings through, so the quantized state inherits the
parameter's (expert, fsdp, ...) partitioning. A global flatten to
[n_blocks, B] (the textbook layout) breaks propagation and replicates
hundreds of GB of state per chip — observed, not hypothetical (see
EXPERIMENTS.md §Perf, deepseek iteration 0).

Leaves whose last axis is not divisible by the block size (norm scales,
biases — a negligible fraction of state) stay in f32; a zero-size scale
sentinel marks them, keeping m/m_scale as parallel same-structure trees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    quantized_state: bool = False   # int8 m/v
    block: int = 256                # quantization block size (last axis)


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    m_scale: Any = None   # only for quantized_state
    v_scale: Any = None


def _quantizable(p, block: int) -> bool:
    return p.ndim >= 1 and p.shape[-1] % block == 0 and p.size >= block


def _q_init(p, block: int):
    if not _quantizable(p, block):
        return jnp.zeros(p.shape, jnp.float32)
    return jnp.zeros((*p.shape[:-1], p.shape[-1] // block, block), jnp.int8)


def _q_scale_init(p, block: int):
    if not _quantizable(p, block):
        return jnp.zeros((0,), jnp.float32)          # sentinel: unquantized
    return jnp.zeros((*p.shape[:-1], p.shape[-1] // block, 1), jnp.float32)


def _quantize(x, block):
    """[..., F] f32 -> ([..., F/B, B] int8, [..., F/B, 1] f32 scales)."""
    xb = x.reshape(*x.shape[:-1], x.shape[-1] // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _deq(q, scale, shape):
    return (q.astype(jnp.float32) * scale).reshape(shape)


def adam_init(params, cfg: AdamConfig) -> AdamState:
    if cfg.quantized_state:
        b = cfg.block
        return AdamState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(lambda p: _q_init(p, b), params),
            jax.tree.map(lambda p: _q_init(p, b), params),
            jax.tree.map(lambda p: _q_scale_init(p, b), params),
            jax.tree.map(lambda p: _q_scale_init(p, b), params))
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.copy, zeros))


def adam_update(grads, state: AdamState, params, cfg: AdamConfig):
    """Returns (new_params, new_state)."""
    t = state.step + 1
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** tf
    bc2 = 1.0 - cfg.b2 ** tf

    if cfg.quantized_state:
        def upd(p, g, mq, msc, vq, vsc):
            g = g.astype(jnp.float32)
            quantized = msc.size > 0
            if quantized:
                m = cfg.b1 * _deq(mq, msc, p.shape) + (1 - cfg.b1) * g
                v = cfg.b2 * _deq(vq, vsc, p.shape) + (1 - cfg.b2) * g * g
            else:
                m = cfg.b1 * mq + (1 - cfg.b1) * g
                v = cfg.b2 * vq + (1 - cfg.b2) * g * g
            update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            if cfg.weight_decay:
                update = update + cfg.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - cfg.lr * update).astype(p.dtype)
            if quantized:
                mq2, msc2 = _quantize(m, cfg.block)
                vq2, vsc2 = _quantize(v, cfg.block)
            else:
                mq2, msc2, vq2, vsc2 = m, msc, v, vsc
            return p_new, mq2, msc2, vq2, vsc2

        out = jax.tree.map(upd, params, grads, state.m, state.m_scale,
                           state.v, state.v_scale)
        leaves, treedef = jax.tree.flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = treedef.unflatten([l[0] for l in leaves])
        new_m = treedef.unflatten([l[1] for l in leaves])
        new_ms = treedef.unflatten([l[2] for l in leaves])
        new_v = treedef.unflatten([l[3] for l in leaves])
        new_vs = treedef.unflatten([l[4] for l in leaves])
        return new_p, AdamState(t, new_m, new_v, new_ms, new_vs)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - cfg.lr * update).astype(p.dtype)
        return p_new, m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    leaves, treedef = jax.tree.flatten(
        out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_m = treedef.unflatten([l[1] for l in leaves])
    new_v = treedef.unflatten([l[2] for l in leaves])
    return new_p, AdamState(t, new_m, new_v)
