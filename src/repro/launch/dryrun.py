import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run (assignment deliverable e).

Lower + compile every (architecture x input-shape x mesh) cell against the
production mesh built from 512 placeholder host devices, print
``memory_analysis()`` / ``cost_analysis()``, parse the collective schedule
out of the compiled HLO, and derive the three roofline terms
(EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all   # whole grid

NOTE the XLA_FLAGS export above is the FIRST executable line — jax locks
the device count on first init, and only the dry-run wants 512 fake
devices (smoke tests and benches must see 1).
"""
import argparse
import functools
import json
import re
import subprocess
import sys
import time

# TPU v5e hardware constants (assignment §Roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (effective, 1 link)

def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             profile=None, micro=None, seq_shard=None,
             unroll_decode: bool = False,
             verbose: bool = True) -> dict:
    import jax
    from repro.configs import SHAPES, applicable, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import batch_specs, decode_specs, model_specs
    from repro.launch.strategy import make_mesh_rules, pick_strategy
    from repro.train.steps import (make_prefill_step, make_serve_step,
                                   make_train_step)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    assert applicable(cfg, shape_name), \
        f"{arch} x {shape_name} skipped (full attention, DESIGN.md)"
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    strat = pick_strategy(cfg, shape, multi_pod=multi,
                          override_profile=profile, override_micro=micro)
    if seq_shard:
        strat.logical_rules["seq"] = "model"
    rules = make_mesh_rules(mesh, strat)

    shards_of = functools.partial(jax.tree.map, lambda s: s.sharding)
    t0 = time.time()
    if shape.kind == "train":
        pspecs, ospecs = model_specs(cfg, rules, strat.hparams)
        batch = batch_specs(cfg, shape, rules)
        step = make_train_step(cfg, rules, strat.hparams)
        # out_shardings pin the donated (params, opt) layout — without them
        # the optimizer's block-quantize reshapes let GSPMD replicate the
        # int8 state (EXPERIMENTS.md §Perf, deepseek iteration 0)
        lowered = jax.jit(
            step, donate_argnums=(0, 1),
            out_shardings=(shards_of(pspecs), shards_of(ospecs), None)
        ).lower(pspecs, ospecs, batch)
    elif shape.kind == "prefill":
        pspecs, _ = model_specs(cfg, rules)
        batch = batch_specs(cfg, shape, rules)
        step = make_prefill_step(cfg, rules)
        lowered = jax.jit(step).lower(pspecs, batch)
    else:  # decode
        pspecs, _ = model_specs(cfg, rules)
        tokens, state = decode_specs(cfg, shape, rules,
                                     unrolled=unroll_decode)
        step = make_serve_step(cfg, rules, unroll=unroll_decode)
        lowered = jax.jit(
            step, donate_argnums=(2,),
            out_shardings=(None, shards_of(state))
        ).lower(pspecs, tokens, state)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # NOTE: cost_analysis counts while bodies ONCE (scan trip counts are
    # ignored) — hlo_analysis walks the call graph with trip multipliers.
    from repro.launch.hlo_analysis import analyze
    t0 = time.time()
    hlo = compiled.as_text()
    if os.environ.get("DRYRUN_KEEP_HLO"):
        import gzip
        hdir = os.environ.get("DRYRUN_HLO_DIR", "results/hlo")
        os.makedirs(hdir, exist_ok=True)
        with gzip.open(os.path.join(
                hdir, f"{arch}_{shape_name}_{mesh_kind}.hlo.gz"), "wt") as f:
            f.write(hlo)
    acc = analyze(hlo)
    t_analyze = time.time() - t0
    del hlo
    coll = acc["coll"]

    chips = mesh.devices.size
    flops_dev = float(acc["flops"])
    bytes_dev = float(acc["bytes"])
    coll_dev = float(coll["total_bytes"])

    b, s = shape.global_batch, shape.seq_len
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        model_flops = 6 * n_active * b * s
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * b * s
    else:
        model_flops = 2 * n_active * b
    model_flops_dev = model_flops / chips

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_coll)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": int(chips), "strategy": strat.name,
        "n_micro": strat.hparams.n_micro,
        "params": int(cfg.n_params()), "active_params": int(n_active),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "alias_bytes": mem.alias_size_in_bytes,
            # arguments alias outputs for donated params/state; peak HBM =
            # live arguments + temps
            "hbm_estimate_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes,
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "xla_flops_no_trip": float(cost.get("flops", 0.0)),
                 "xla_bytes_no_trip": float(
                     cost.get("bytes accessed", 0.0))},
        "bytes_by_op": dict(sorted(acc["bytes_by_op"].items(),
                                   key=lambda kv: -kv[1])[:20]),
        "collectives": coll,
        "roofline": {
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": model_flops,
            "model_flops_per_device": model_flops_dev,
            "useful_flop_ratio": (model_flops_dev / flops_dev
                                  if flops_dev else 0.0),
            "roofline_fraction": ((model_flops_dev / PEAK_FLOPS) / bound
                                  if bound else 0.0),
        },
    }
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_kind} "
              f"[{strat.name}, {chips} chips] ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/dev={flops_dev:.3e} "
              f"bytes/dev={bytes_dev:.3e}")
        print(f"  hbm estimate: "
              f"{result['memory']['hbm_estimate_bytes']/2**30:.2f} GiB/chip")
        print(f"  collectives: " + ", ".join(
            f"{k}:{v['bytes']/2**20:.1f}MiB/{v['count']}"
            for k, v in coll.items() if isinstance(v, dict) and v["count"]))
        r = result["roofline"]
        print(f"  roofline: compute {r['t_compute_s']*1e3:.2f}ms | memory "
              f"{r['t_memory_s']*1e3:.2f}ms | collective "
              f"{r['t_collective_s']*1e3:.2f}ms -> {r['dominant']}-bound, "
              f"useful-flop ratio {r['useful_flop_ratio']:.2f}, "
              f"roofline fraction {r['roofline_fraction']:.2f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--profile", default=None,
                    help="override strategy profile (fsdp | tp_ep)")
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--seq-shard", action="store_true",
                    help="bind logical 'seq' axis to 'model' (SP variant)")
    ap.add_argument("--unroll-decode", action="store_true",
                    help="unrolled-layer decode, per-layer cache leaves")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="run the full (arch x shape x mesh) grid as "
                         "subprocesses")
    ap.add_argument("--meshes", default="single,multi")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        from repro.configs import all_cells
        cells = all_cells()
        failures = []
        for mesh_kind in args.meshes.split(","):
            for arch, shape in cells:
                tag = f"{arch}_{shape}_{mesh_kind}"
                out_file = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_file):
                    print(f"[skip] {tag} (cached)")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", mesh_kind, "--out", args.out]
                print(f"[run ] {tag}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append(tag)
                    print(f"[FAIL] {tag}\n{r.stdout[-2000:]}"
                          f"\n{r.stderr[-4000:]}", flush=True)
                else:
                    print(r.stdout.rstrip(), flush=True)
        print(f"\n{len(cells) * 2 - len(failures)} ok, "
              f"{len(failures)} failed: {failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch/--shape or --all"
    result = run_cell(args.arch, args.shape, args.mesh,
                      profile=args.profile, micro=args.micro,
                      seq_shard=args.seq_shard,
                      unroll_decode=args.unroll_decode)
    tag = f"{args.arch}_{args.shape}_{args.mesh}"
    suffix = ""
    if args.profile or args.micro or args.seq_shard or args.unroll_decode:
        suffix = f"__{args.profile or ''}m{args.micro or ''}" + \
            ("sp" if args.seq_shard else "") + \
            ("ur" if args.unroll_decode else "")
    with open(os.path.join(args.out, tag + suffix + ".json"), "w") as f:
        json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
