"""Per-(arch x shape) sharding strategy — the LM-scale analogue of the
paper's partitioning framework.

SupraSNN's partitioner maps synapses to SPUs maximizing balance subject to
the Unified-Memory constraint Eq. (9). Here the "synapses" are parameter
tiles, the "SPUs" are chips, and the constraint is HBM. Like the paper we
pick the most-balanced feasible mapping per workload (napkin math in
EXPERIMENTS.md §Dry-run), not one global scheme:

  fsdp   batch+params sharded over EVERY chip (256/512-way ZeRO-3),
         no tensor parallelism. Minimal activation + param memory; per-
         layer all-gather of weights (prefetchable). The right regime for
         <=13B dense models at 1M-token batches.
  tp_ep  2D: batch over 'data', tensor+expert over 'model'. The regime
         for MoE (expert dim wants its own axis: dispatch/combine == the
         paper's MC/ME trees) and for inference (KV cache sharded over
         heads; weights stationary).

Shape kind selects train vs inference strategy; family selects fsdp vs
tp_ep for training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import MeshRules
from repro.train.steps import TrainHParams


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    logical_rules: dict
    hparams: TrainHParams


def _rules(profile: str, multi_pod: bool) -> dict:
    if profile == "fsdp":
        if multi_pod:
            # global batch (256) < devices (512): shard batch over one
            # pod's chips and the SEQUENCE over the pod axis (cross-pod
            # sequence parallelism — the KV all-gather rides the slow
            # inter-pod links once per layer and overlaps with compute)
            return {"batch": ("data", "model"),
                    "fsdp": ("pod", "data", "model"), "tensor": None,
                    "expert": None, "seq": "pod", "kv_heads": None}
        all_axes = ("data", "model")
        return {"batch": all_axes, "fsdp": all_axes, "tensor": None,
                "expert": None, "seq": None, "kv_heads": None}
    if profile == "tp_ep":
        batch = ("pod", "data") if multi_pod else "data"
        fsdp = ("pod", "data") if multi_pod else "data"
        return {"batch": batch, "fsdp": fsdp, "tensor": "model",
                "expert": "model", "seq": None, "kv_heads": "model"}
    if profile == "tp_ep_full":
        # §Perf (deepseek iteration): experts sharded over EVERY chip
        # (model x data = whole-expert ownership) — expert weights are
        # never fsdp-gathered; tokens move instead (all-to-all dispatch,
        # the MC-tree pattern). Kills the n_micro-times weight re-gather.
        batch = ("pod", "data") if multi_pod else "data"
        return {"batch": batch, "fsdp": ("pod", "data") if multi_pod
                else "data", "tensor": "model",
                "expert": ("model", "data"), "seq": None,
                "kv_heads": "model"}
    if profile == "tp_serve":
        # §Perf (decode iteration): INFERENCE wants stationary weights —
        # no ZeRO sharding to gather per token; params live tensor-sharded
        # (model axis), replicated over data. HBM cost: params/16 per chip.
        batch = ("pod", "data") if multi_pod else "data"
        return {"batch": batch, "fsdp": None, "tensor": "model",
                "expert": "model", "seq": None, "kv_heads": "model"}
    raise ValueError(profile)


def pick_strategy(cfg: ArchConfig, shape: ShapeSpec, *,
                  multi_pod: bool = False,
                  override_profile: Optional[str] = None,
                  override_micro: Optional[int] = None) -> Strategy:
    """Default = napkin-math-feasible, balance-max choice per cell."""
    is_moe = cfg.moe is not None
    if shape.kind == "train":
        profile = override_profile or ("tp_ep" if is_moe else "fsdp")
        # microbatches: sized so remat'd layer-boundary activations fit
        # (tokens_local/n_micro * d_model * n_layers * 2B <~ 4 GB)
        if override_micro is not None:
            n_micro = override_micro
        elif cfg.name.startswith("deepseek"):
            n_micro = 8
        elif is_moe:
            n_micro = 4
        else:
            n_micro = 1
        hp = TrainHParams(
            n_micro=n_micro,
            accum_dtype=(jnp.bfloat16 if cfg.name.startswith("deepseek")
                         else jnp.float32),
            quantized_opt_state=cfg.name.startswith("deepseek"),
            loss_chunk=512)
    else:
        profile = override_profile or "tp_ep"
        hp = TrainHParams()
    return Strategy(profile, _rules(profile, multi_pod), hp)


def make_mesh_rules(mesh, strategy: Strategy) -> MeshRules:
    return MeshRules(mesh, strategy.logical_rules)
