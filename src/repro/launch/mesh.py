"""Production mesh definitions (assignment: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import (LOGICAL_RULES_1POD,
                                        LOGICAL_RULES_2POD, MeshRules)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh) -> MeshRules:
    rules = LOGICAL_RULES_2POD if "pod" in mesh.axis_names \
        else LOGICAL_RULES_1POD
    return MeshRules(mesh, rules)


def make_debug_mesh(n_devices: int | None = None, *, model: int = 2):
    """Small mesh over however many (possibly forced-host) devices exist —
    used by tests; same axis names as the single-pod production mesh."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(n_devices: int | None = None):
    """All (possibly forced-host) devices on the ``data`` axis.

    The serving subsystem (:mod:`repro.serve.sharded`) is pure data
    parallelism — the request batch axis shards over ``data`` and the
    mapped program is replicated — so the model axis stays at 1. Axis
    names match the debug/production meshes, and CPU CI gets >= 8
    shards via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    return make_debug_mesh(n_devices, model=1)
