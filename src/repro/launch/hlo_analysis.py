"""Post-SPMD HLO cost analysis with WHILE-LOOP TRIP MULTIPLIERS.

XLA's built-in ``compiled.cost_analysis()`` counts each while body ONCE —
a scan over 61 layers reports 1/61st of the real FLOPs, and per-layer
all-gathers vanish from the collective totals. Since the whole framework
executes layers via ``lax.scan`` (that is what keeps 512-chip compiles
fast), the dry-run roofline would be off by ~n_layers x n_microbatches.

This module walks the compiled module's call graph instead:

    cost(comp) = own_cost(comp) + sum_call mult(call) * cost(callee)

with mult = the while op's ``known_trip_count`` backend config (present on
every scan-lowered loop; falls back to the max s32 constant in the loop
condition), 1 for fusion/call edges.

Per computation we count:
  * dot FLOPs      2 * prod(result_dims) * prod(lhs contracting dims) —
                   operand shapes resolved through a per-computation
                   symbol table (HLO prints operands by name only);
  * HBM bytes      operand + result bytes of every top-level op in
                   CONTROL computations (entry/while bodies); fused
                   computations are internal to one kernel, so only the
                   fusion op's own I/O counts;
  * collectives    result bytes per op kind (per-chip ring-traffic proxy).

All numbers are PER DEVICE — the module is the post-partitioning SPMD
program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count=?\{"?n"?[:=]"?(\d+)"?\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "get-dimension-size", "iota", "copy-start", "copy-done",
}


def _dims(txt: str) -> list[int]:
    return [int(d) for d in txt.split(",") if d]


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Comp:
    name: str
    dot_flops: float = 0.0
    bytes: float = 0.0
    bytes_by_op: dict = dataclasses.field(default_factory=dict)
    coll: dict = dataclasses.field(
        default_factory=lambda: {op: {"count": 0.0, "bytes": 0.0}
                                 for op in COLLECTIVE_OPS})
    calls: list = dataclasses.field(default_factory=list)  # (name, kind, trip)
    max_const: int = 1
    # fused-computation parameter analysis: idx -> window bytes consumed
    # (None = consumed whole); names of parameter instructions -> idx
    param_idx: dict = dataclasses.field(default_factory=dict)
    param_eff: dict = dataclasses.field(default_factory=dict)


def parse_module(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry: Optional[str] = None
    symbols: dict[str, str] = {}

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = _HDR_RE.match(line)
            if m and line.endswith("{"):
                cur = _Comp(m.group(2))
                comps[cur.name] = cur
                symbols = {}
                if m.group(1):
                    entry = cur.name
            continue
        if cur is None or line.strip().startswith("}"):
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_txt, op, rest = m.groups()
        symbols[name] = shape_txt

        if op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", line)
            if pm:
                cur.param_idx[name] = int(pm.group(1))
        else:
            # track how this computation's parameters are consumed
            for a in _OPERAND_RE.findall(rest.split("),", 1)[0]):
                if a in cur.param_idx:
                    idx = cur.param_idx[a]
                    if op in ("dynamic-slice", "slice", "gather"):
                        prev = cur.param_eff.get(idx, 0)
                        if prev is not None:
                            cur.param_eff[idx] = prev + \
                                _shape_bytes(shape_txt)
                    else:
                        cur.param_eff[idx] = None

        cm = re.search(r"constant\((\d+)\)", line)
        if cm:
            cur.max_const = max(cur.max_const, int(cm.group(1)))

        if op == "dot":
            # flops = 2 * prod(result) * prod(lhs contracting dims)
            out_elems = 1
            fs = _SHAPE_RE.search(shape_txt)
            if fs:
                for d in _dims(fs.group(2)):
                    out_elems *= d
            contract = 1
            km = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            args = _OPERAND_RE.findall(rest.split(")", 1)[0])
            if km and args and args[0] in symbols:
                lsh = _SHAPE_RE.search(symbols[args[0]])
                if lsh:
                    ldims = _dims(lsh.group(2))
                    for idx in _dims(km.group(1)):
                        if idx < len(ldims):
                            contract *= ldims[idx]
            cur.dot_flops += 2.0 * out_elems * contract

        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVE_OPS and not op.endswith("-done"):
            cur.coll[base]["count"] += 1
            cur.coll[base]["bytes"] += _shape_bytes(shape_txt)

        if op == "while":
            bm = re.search(r"body=%?([\w.\-]+)", line)
            tm = _TRIP_RE.search(line)
            cmn = re.search(r"condition=%?([\w.\-]+)", line)
            trip = int(tm.group(1)) if tm else None
            if bm:
                cur.calls.append((bm.group(1), "while",
                                  trip if trip is not None
                                  else ("cond", cmn.group(1) if cmn
                                        else None)))
        elif op == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", line)
            if fm:
                cur.calls.append((fm.group(1), "fusion", 1))
        elif op == "conditional":
            for grp in re.findall(
                    r"(?:branch_computations|true_computation|"
                    r"false_computation)=\{?([^}]+)\}?", line):
                for nm in re.findall(r"%([\w.\-]+)", grp):
                    cur.calls.append((nm, "branch", 1))
        elif op == "call":
            fm = re.search(r"to_apply=%?([\w.\-]+)", line)
            if fm:
                cur.calls.append((fm.group(1), "call", 1))

        if op not in _NO_TRAFFIC and not op.endswith("-done"):
            # HBM traffic model per op:
            #   dynamic-slice / gather / slice  -> reads only the WINDOW it
            #       extracts (counting the full operand wildly overstates
            #       scan xs slicing: a [61, ...] stacked cache is NOT read
            #       61x per step);
            #   dynamic-update-slice -> read-modify-write of the update
            #       window (XLA aliases the big operand in place; explicit
            #       copies appear as separate `copy` ops and ARE counted);
            #   everything else -> operands + result.
            out_b = _shape_bytes(shape_txt)
            if op in ("dynamic-slice", "gather", "slice"):
                tb = 2 * out_b
            elif op == "dynamic-update-slice":
                ops_ = _OPERAND_RE.findall(rest.split("),", 1)[0])
                upd = _shape_bytes(symbols.get(ops_[1], "")) \
                    if len(ops_) > 1 else out_b
                tb = 2 * upd
            else:
                tb = out_b
                for a in _OPERAND_RE.findall(rest.split("),", 1)[0]):
                    if a in symbols:
                        tb += _eff_operand_bytes(a, op, line, rest,
                                                 symbols, comps)
            cur.bytes += tb
            # profile signal: attribute fusion bytes to the fused root op
            key = op
            if op == "fusion":
                key = f"fusion:{_fusion_kind(line)}"
            cur.bytes_by_op[key] = cur.bytes_by_op.get(key, 0.0) + tb
    return comps, entry


def _eff_operand_bytes(name: str, op: str, line: str, rest: str,
                       symbols: dict, comps: dict) -> int:
    """Effective read size of one operand. For fusion calls, a parameter
    whose only in-fusion consumers are slice-type ops is charged at the
    consumed-window size, not the full tensor."""
    full = _shape_bytes(symbols[name])
    if op != "fusion":
        return full
    fm = re.search(r"calls=%?([\w.\-]+)", line)
    if not fm or fm.group(1) not in comps:
        return full
    callee = comps[fm.group(1)]
    ops_ = _OPERAND_RE.findall(rest.split("),", 1)[0])
    try:
        idx = ops_.index(name)
    except ValueError:
        return full
    eff = callee.param_eff.get(idx)
    return min(full, eff) if eff is not None else full


def _fusion_kind(line: str) -> str:
    km = re.search(r"kind=k(\w+)", line)
    return km.group(1) if km else "?"


def analyze(text: str) -> dict:
    """Full-module per-device totals with trip multipliers."""
    comps, entry = parse_module(text)
    memo: dict[tuple, dict] = {}

    def zero():
        return {"flops": 0.0, "bytes": 0.0, "bytes_by_op": {},
                "coll": {op: {"count": 0.0, "bytes": 0.0}
                         for op in COLLECTIVE_OPS}}

    def resolve_trip(t) -> int:
        if isinstance(t, int):
            return t
        if isinstance(t, tuple) and t[0] == "cond" and t[1] in comps:
            return max(1, comps[t[1]].max_const)
        return 1

    def walk(name: str, fused: bool, stack=()) -> dict:
        key = (name, fused)
        if key in memo:
            return memo[key]
        if name in stack or name not in comps:
            return zero()
        c = comps[name]
        tot = zero()
        tot["flops"] = c.dot_flops
        tot["bytes"] = 0.0 if fused else c.bytes
        if not fused:
            tot["coll"] = {op: dict(v) for op, v in c.coll.items()}
            tot["bytes_by_op"] = dict(c.bytes_by_op)
        for callee, kind, trip in c.calls:
            mult = resolve_trip(trip) if kind == "while" else 1
            sub = walk(callee, fused or kind == "fusion", stack + (name,))
            tot["flops"] += mult * sub["flops"]
            tot["bytes"] += mult * sub["bytes"]
            for op in COLLECTIVE_OPS:
                tot["coll"][op]["count"] += mult * sub["coll"][op]["count"]
                tot["coll"][op]["bytes"] += mult * sub["coll"][op]["bytes"]
            for op, b in sub["bytes_by_op"].items():
                tot["bytes_by_op"][op] = tot["bytes_by_op"].get(op, 0.0) \
                    + mult * b
        memo[key] = tot
        return tot

    out = walk(entry, False) if entry else zero()
    out["coll"]["total_bytes"] = sum(
        v["bytes"] for k, v in out["coll"].items() if isinstance(v, dict))
    # round counts back to ints for reporting
    for op in COLLECTIVE_OPS:
        out["coll"][op]["count"] = int(out["coll"][op]["count"])
        out["coll"][op]["bytes"] = float(out["coll"][op]["bytes"])
    return out
