"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation (assignment step 2).

For a training cell that is {tokens, labels(, positions)}; for prefill
{tokens(, positions)}; for decode it is (tokens [B, 1], decode-state) with
KV capacity = shape.seq_len. Param/optimizer trees come from
``jax.eval_shape`` over the real initializers, so the dry-run lowers the
EXACT program the launcher would run.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import (MeshRules, input_shardings,
                                        param_shardings)
from repro.models import model as M
from repro.train.steps import TrainHParams, init_opt_state


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec,
                rules: Optional[MeshRules]) -> dict:
    """Host-side input specs for train/prefill cells."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
    batch: dict[str, Any] = {"tokens": _sds(tok_shape, jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = _sds(tok_shape, jnp.int32)
    if cfg.mrope_sections:
        batch["positions"] = _sds((3, b, s), jnp.int32)
    if rules is not None:
        sh = input_shardings(batch, rules, batch_axes={"positions": 1})
        batch = jax.tree.map(
            lambda spec, shd: _sds(spec.shape, spec.dtype, shd), batch, sh)
    return batch


def decode_specs(cfg: ArchConfig, shape: ShapeSpec,
                 rules: Optional[MeshRules],
                 unrolled: bool = False) -> tuple:
    """(tokens, state) specs for a serve_step cell: one new token against
    a cache of capacity seq_len (filled to seq_len - 1)."""
    b, cap = shape.global_batch, shape.seq_len
    tok_shape = (b, 1, cfg.n_codebooks) if cfg.n_codebooks else (b, 1)
    state = jax.eval_shape(
        functools.partial(M.init_decode_state, cfg, b, cap,
                          unrolled=unrolled))
    tokens = _sds(tok_shape, jnp.int32)
    if rules is not None:
        tokens = _sds(tok_shape, jnp.int32,
                      NamedSharding(rules.mesh,
                                    P(rules.rules.get("batch"))
                                    if b % _size(rules, "batch") == 0
                                    else P()))
        state = jax.tree.map(
            lambda l: _sds(l.shape, l.dtype, _state_sharding(l, rules, b)),
            state)
    return tokens, state


def _size(rules: MeshRules, logical: str) -> int:
    ax = rules.rules.get(logical)
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= rules.mesh.shape[a]
        return n
    return rules.mesh.shape[ax]


def _state_sharding(leaf, rules: MeshRules, b: int) -> NamedSharding:
    """Decode-state placement heuristic.

    Batch lives at dim 1 for stacked [L, B, ...] caches, dim 0 for
    unrolled per-layer [B, ...] caches -> shard it over 'batch' when
    divisible. The dim two past batch (kv-heads of GQA caches, latent
    rank of MLA caches, head/channel dims of recurrent states) -> 'tensor'
    when divisible; when it does NOT divide (GQA with few KV heads), shard
    the CAPACITY dim (batch+1) over 'tensor' instead — flash-decode style:
    every model shard scans 1/16th of the context and the softmax merges
    partials with tiny all-reduces. Without this GSPMD all-gathers the
    whole cache per layer (observed: 150 GiB/chip, stablelm decode_32k).
    """
    spec: list = [None] * len(leaf.shape)
    bdim = 0 if (leaf.shape and leaf.shape[0] == b) else 1
    if len(leaf.shape) > bdim:
        ax = rules.rules.get("batch")
        if ax is not None and leaf.shape[bdim] % _size(rules, "batch") == 0:
            spec[bdim] = ax
    ax = rules.rules.get("tensor")
    if ax is not None and len(leaf.shape) >= bdim + 3:
        if leaf.shape[bdim + 2] % _size(rules, "tensor") == 0:
            spec[bdim + 2] = ax
        elif len(leaf.shape) >= bdim + 4 and \
                leaf.shape[bdim + 1] % _size(rules, "tensor") == 0:
            spec[bdim + 1] = ax
    return NamedSharding(rules.mesh, P(*spec))


def model_specs(cfg: ArchConfig, rules: Optional[MeshRules],
                hp: Optional[TrainHParams] = None) -> tuple:
    """(param specs, opt-state specs) via eval_shape — zero allocation."""
    pshapes = jax.eval_shape(
        lambda: M.init_model(cfg, jax.random.PRNGKey(0)))
    if rules is not None:
        psh = param_shardings(pshapes, rules)
        pspecs = jax.tree.map(
            lambda l, s: _sds(l.shape, l.dtype, s), pshapes, psh)
    else:
        pspecs = pshapes
    if hp is None:
        return pspecs, None
    oshapes = jax.eval_shape(functools.partial(init_opt_state, hp=hp),
                             pshapes)
    if rules is not None:
        osh = _opt_shardings(oshapes, pshapes, rules)
        ospecs = jax.tree.map(
            lambda l, s: _sds(l.shape, l.dtype, s), oshapes, osh)
    else:
        ospecs = oshapes
    return pspecs, ospecs


def _opt_shardings(opt_shapes, param_shapes, rules: MeshRules):
    """Adam m/v mirror the param shardings. int8-quantized moments are
    [..., F/B, B] (last-axis block split, optimizer/adam.py), so their
    pspec = the param's leading-dim spec + (None, None); f32 fallbacks and
    same-shape moments reuse the param spec; [0]-sentinel scales and the
    step counter are replicated."""
    psh = param_shardings(param_shapes, rules)

    def axsz(ax):
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= rules.mesh.shape[a]
            return n
        return rules.mesh.shape[ax]

    def follow(tree):
        if tree is None:
            return None
        def one(leaf, p_leaf, p_sh):
            if leaf.shape == p_leaf.shape:             # f32 moment
                return p_sh
            if len(leaf.shape) == len(p_leaf.shape) + 1:
                r = len(p_leaf.shape)
                spec = list(p_sh.spec) + [None] * (r - len(p_sh.spec))
                dropped = spec[r - 1]                  # axis on the block dim
                spec = spec[:r - 1] + [None, None]
                if dropped is not None:
                    # re-home the dropped axis: merge into the first
                    # leading dim that stays divisible (keeps the moment as
                    # sharded as the parameter — see adam.py layout note)
                    for i in range(len(spec)):
                        cur = spec[i]
                        cand = ((tuple(cur) if isinstance(cur, tuple)
                                 else (cur,)) if cur else ()) + \
                            (tuple(dropped) if isinstance(dropped, tuple)
                             else (dropped,))
                        if leaf.shape[i] % (axsz(cur) * axsz(dropped)) == 0:
                            spec[i] = cand if len(cand) > 1 else cand[0]
                            break
                return NamedSharding(rules.mesh, P(*spec))
            return NamedSharding(rules.mesh, P())      # sentinel / scalar
        return jax.tree.map(one, tree, param_shapes, psh)

    rep = NamedSharding(rules.mesh, P())
    return type(opt_shapes)(
        step=rep,
        m=follow(opt_shapes.m), v=follow(opt_shapes.v),
        m_scale=follow(opt_shapes.m_scale),
        v_scale=follow(opt_shapes.v_scale))
