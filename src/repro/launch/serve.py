"""Batched serving launcher: prefill a prompt batch, then decode tokens
with an in-place (donated) KV/recurrent-state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.launch.mesh import make_debug_mesh, make_rules
from repro.models import model as M
from repro.train.steps import make_prefill_step, make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    rules = make_rules(make_debug_mesh()) if len(jax.devices()) > 1 else None
    params = M.init_model(cfg, jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
             if cfg.n_codebooks else (args.batch, args.prompt_len))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)

    # prefill fills a capacity == prompt_len cache; decoding continues into
    # a fresh capacity prompt_len + gen cache (copy once, decode in place)
    prefill = jax.jit(make_prefill_step(cfg, rules))
    serve = jax.jit(make_serve_step(cfg, rules), donate_argnums=(2,))

    pos = None
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(args.prompt_len),
                               (3, args.batch, args.prompt_len))
    t0 = time.time()
    logits, state = prefill(params, {"tokens": prompts, "positions": pos}
                            if pos is not None else {"tokens": prompts})
    state = _grow_cache(cfg, state, args.batch,
                        args.prompt_len + args.gen)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    if cfg.n_codebooks:
        next_tok = jnp.broadcast_to(next_tok[..., None, None] %
                                    cfg.vocab_size,
                                    (args.batch, 1, cfg.n_codebooks))
    out = []
    t0 = time.time()
    for _ in range(args.gen):
        tok_in = (next_tok if cfg.n_codebooks
                  else next_tok.reshape(args.batch, 1))
        next_tok, state = serve(params, tok_in, state)
        out.append(np.asarray(next_tok))
        if cfg.n_codebooks:
            next_tok = jnp.broadcast_to(
                next_tok[..., None, None] % cfg.vocab_size,
                (args.batch, 1, cfg.n_codebooks))
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0

    toks = np.stack(out, axis=1)
    print(f"[prefill] {args.batch}x{args.prompt_len} in {t_prefill:.3f}s")
    print(f"[decode ] {args.gen} steps x batch {args.batch} in "
          f"{t_decode:.3f}s  ({args.gen * args.batch / t_decode:.1f} tok/s)")
    print(f"[sample ] first sequence: {toks[0].ravel()[:16].tolist()}")
    return toks


def _grow_cache(cfg, state, batch: int, capacity: int):
    """Copy a prefill-sized cache into a larger decode cache."""
    fresh = M.init_decode_state(cfg, batch, capacity)

    def graft(f, s):
        if f.ndim >= 3 and s.ndim == f.ndim and f.shape != s.shape:
            # KV caches differ on the capacity axis (axis 2)
            pad = [(0, f.shape[i] - s.shape[i]) for i in range(f.ndim)]
            return jnp.pad(s.astype(f.dtype), pad)
        return s.astype(f.dtype)

    out = jax.tree.map(graft, fresh, state)
    out["len"] = state["len"]
    return out


if __name__ == "__main__":
    main()
