# Launch layer: production mesh builders, dry-run driver, train/serve CLIs.
