"""Fault-tolerant training launcher.

Runs REAL steps (not a dry-run) on whatever devices exist — the reduced
configs train on one CPU; the same driver drives the production mesh on
hardware. Wires together the full fault-tolerance stack:

  * CheckpointManager  async sharded checkpoints, atomic commit, keep-K
  * StepJournal        skip-and-replay journal for exactly-once resume
  * StragglerMonitor   median+hysteresis step-time watchdog; on a
                       persistent straggler the policy is snapshot ->
                       replan_mesh over surviving devices -> reshard
  * elastic restore    checkpoints are mesh-agnostic; --resume replays
                       onto the CURRENT device set whatever it is

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/run1
    PYTHONPATH=src python -m repro.launch.train ... --resume
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.distributed.checkpoint import CheckpointManager, latest_step
from repro.distributed.straggler import StepJournal, StragglerMonitor
from repro.launch.mesh import make_debug_mesh, make_rules
from repro.models import model as M
from repro.train.steps import TrainHParams, init_opt_state, make_train_step


def synthetic_batch(cfg, batch: int, seq: int, step: int, offset: int = 0):
    """Deterministic synthetic LM data (seeded by the GLOBAL data offset so
    skip-and-replay reproduces the exact stream)."""
    rng = np.random.default_rng(1234 + offset + step)
    shape = (batch, seq, cfg.n_codebooks) if cfg.n_codebooks \
        else (batch, seq)
    tokens = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
    b = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}
    if cfg.mrope_sections:
        b["positions"] = jnp.broadcast_to(jnp.arange(seq), (3, batch, seq))
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    n_dev = len(jax.devices())
    rules = make_rules(make_debug_mesh()) if n_dev > 1 else None

    hp = TrainHParams(lr=args.lr, n_micro=args.micro,
                      loss_chunk=min(512, args.seq))
    params = M.init_model(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params, hp)
    step_fn = jax.jit(make_train_step(cfg, rules, hp),
                      donate_argnums=(0, 1))

    start, offset = 0, 0
    ckpt = journal = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        journal = StepJournal(os.path.join(args.ckpt_dir, "journal.jsonl"))
        if args.resume:
            rp = journal.replay_point()
            last = latest_step(args.ckpt_dir)
            if rp is not None and last is not None:
                (params, opt_state), extra = ckpt.restore((params, opt_state),
                                                          step=last)
                # checkpoints hold host numpy; re-place on device(s)
                params, opt_state = jax.tree.map(jnp.asarray,
                                                 (params, opt_state))
                start = last + 1
                offset = rp["data_offset"]
                print(f"[resume] from checkpoint step {last}, "
                      f"data offset {offset}")

    mon = StragglerMonitor()
    losses = []
    for step in range(start, args.steps):
        mon.start_step()
        batch = synthetic_batch(cfg, args.batch, args.seq, step, offset)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        straggler = mon.end_step(step)
        if straggler:
            print(f"[straggler] persistent slow step at {step}; on a real "
                  f"cluster: snapshot -> replan_mesh -> reshard (see "
                  f"repro.distributed.elastic)")
        if ckpt and (step % args.ckpt_every == 0 or step == args.steps - 1):
            ckpt.save(step, (params, opt_state),
                      extra={"loss": loss, "step": step})
            journal.record(step, data_offset=offset, seed=args.seed,
                           checkpoint_step=step)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}")
    if ckpt:
        ckpt.wait()
    print(f"[done] {args.steps - start} steps, "
          f"final loss {losses[-1]:.4f}, {mon.summary()}")
    return losses


if __name__ == "__main__":
    main()
