"""Sharded, atomic, reshardable checkpointing.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       tree structure, shapes, dtypes, shard map, hashes
        shard_00000.npz     flat leaves owned by host-group 0
        shard_00001.npz     ...
        COMMITTED           written LAST (atomic rename) — a step directory
                            without it is garbage from a mid-save crash

Key properties for 1000+-node runs:

* each host saves only the leaves (or leaf slices) it owns — O(params/N)
  I/O per host, no single-writer bottleneck;
* the manifest carries logical shapes + the shard split, so a checkpoint
  saved on one mesh RESTORES ONTO ANY OTHER mesh (resharding happens on
  load by assembling and re-slicing — see ``elastic.reshard_tree``);
* SHA-256 per shard detects bitrot/truncation;
* ``CheckpointManager`` runs saves on a background thread (training does
  not stall on I/O) and keeps the newest K checkpoints.

In this single-process container "host-group" = one shard; the format and
code paths are identical.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# npz cannot represent ml_dtypes (bf16/fp8) — store them viewed as raw
# uints and restore through the manifest's logical dtype
_EXOTIC_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8, "float16": None}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    view = _EXOTIC_VIEW.get(str(arr.dtype))
    return arr.view(view) if view is not None else arr


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) != dtype_name and dtype_name in _EXOTIC_VIEW:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree) -> list[str]:
    paths = []
    def rec(path, node):
        if node is None:
            return                      # jax.tree.flatten drops None too
        if isinstance(node, dict):
            for k in sorted(node):
                rec(path + [str(k)], node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(path + [str(i)], v)
        else:
            paths.append("/".join(path))
    rec([], tree)
    return paths


def save_checkpoint(directory: str, step: int, tree, *,
                    n_shards: int = 1, extra: Optional[dict] = None) -> str:
    """Write one checkpoint. Returns the committed step directory."""
    leaves, treedef = _flatten(tree)
    paths = _tree_paths(tree)
    assert len(paths) == len(leaves)
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        manifest = {"step": step, "n_shards": n_shards,
                    "extra": extra or {},
                    "leaves": [], "shard_hash": {}}
        assign = [i % n_shards for i in range(len(leaves))]
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(leaf)
            manifest["leaves"].append(
                {"path": p, "shape": list(arr.shape), "dtype": str(arr.dtype),
                 "shard": assign[i]})
        for s in range(n_shards):
            payload = {f"leaf_{i}": _to_savable(np.asarray(leaves[i]))
                       for i in range(len(leaves)) if assign[i] == s}
            fn = os.path.join(tmp, f"shard_{s:05d}.npz")
            np.savez(fn, **payload)
            with open(fn, "rb") as f:
                manifest["shard_hash"][str(s)] = \
                    hashlib.sha256(f.read()).hexdigest()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.rename(tmp, step_dir)        # atomic commit
        return step_dir
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> Optional[int]:
    """Newest COMMITTED step in the directory (crash-partial dirs skipped)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(directory, name, "COMMITTED")):
            s = int(name.split("_")[1])
            best = s if best is None else max(best, s)
    return best


def load_checkpoint(directory: str, step: Optional[int], like_tree, *,
                    verify: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of ``like_tree``. Returns (tree, extra).

    The stored leaves are matched BY PATH, so the target tree may have a
    different leaf ordering; shape mismatches raise (resharding to a new
    mesh happens at the jax.device_put level — shapes are logical/global).
    """
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no committed checkpoint under {directory}"
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)

    shards = {}
    for s in range(manifest["n_shards"]):
        fn = os.path.join(step_dir, f"shard_{s:05d}.npz")
        if verify:
            with open(fn, "rb") as fh:
                h = hashlib.sha256(fh.read()).hexdigest()
            assert h == manifest["shard_hash"][str(s)], \
                f"shard {s} hash mismatch (corrupt checkpoint)"
        shards[s] = np.load(fn)

    by_path = {}
    for i, meta in enumerate(manifest["leaves"]):
        by_path[meta["path"]] = _from_saved(
            shards[meta["shard"]][f"leaf_{i}"], meta["dtype"])

    leaves, treedef = _flatten(like_tree)
    paths = _tree_paths(like_tree)
    out = []
    for p, ref in zip(paths, leaves):
        assert p in by_path, f"checkpoint missing leaf {p}"
        arr = by_path[p]
        assert tuple(arr.shape) == tuple(np.shape(ref)), \
            f"{p}: ckpt {arr.shape} != target {np.shape(ref)}"
        out.append(arr)
    return treedef.unflatten(out), manifest["extra"]


class CheckpointManager:
    """Async save + retention. ``save`` snapshots to host then returns;
    the write happens on a daemon thread (training never blocks on disk)."""

    def __init__(self, directory: str, *, keep: int = 3, n_shards: int = 1):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, *, extra: Optional[dict] = None,
             blocking: bool = False):
        self.wait()
        if self._error:
            raise self._error
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                n_shards=self.n_shards, extra=extra)
                self._gc()
            except BaseException as e:   # surfaced on next save/wait
                self._error = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def restore(self, like_tree, step: Optional[int] = None):
        return load_checkpoint(self.directory, step, like_tree)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and
            os.path.exists(os.path.join(self.directory, n, "COMMITTED")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
