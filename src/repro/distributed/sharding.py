"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Model code never mentions mesh axes. It tags activations with LOGICAL axis
names via ``logical_constraint(x, "batch", "seq", "heads", ...)`` and the
parameter tree is mapped to PartitionSpecs by path-pattern RULES. A
``mesh_rules`` context binds logical names -> physical mesh axes; outside
any context every constraint is a no-op, so single-device CPU tests run
the exact same model code.

Physical meshes (launch/mesh.py):
  single-pod  (16, 16)      axes ('data', 'model')
  multi-pod   (2, 16, 16)   axes ('pod', 'data', 'model')

Logical -> physical (the SupraSNN mapping, DESIGN.md §4):
  batch   -> ('pod', 'data')   activations/batch dim (DP)
  fsdp    -> 'data'            parameter/optimizer-state sharding (ZeRO-3)
  tensor  -> 'model'           TP: heads / mlp / vocab (partial-sum merges
                               == the paper's ME tree)
  expert  -> 'model'           EP: MoE expert dim (dispatch == MC tree)
  seq     -> None              (sequence parallelism is a §Perf iteration:
                               bind to 'model' in SP variants)
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Logical-axis binding
# ---------------------------------------------------------------------------


class MeshRules:
    """Binds logical axis names to physical mesh axes for one mesh."""

    def __init__(self, mesh: Mesh, rules: dict[str, Any]):
        self.mesh = mesh
        self.rules = dict(rules)

    def to_pspec(self, logical: tuple) -> P:
        phys = []
        used: set[str] = set()
        for ax in logical:
            m = self.rules.get(ax) if ax is not None else None
            # one physical axis may appear at most once in a PartitionSpec
            if m is None:
                phys.append(None)
            elif isinstance(m, tuple):
                keep = tuple(a for a in m if a not in used)
                used.update(keep)
                phys.append(keep if keep else None)
            else:
                if m in used:
                    phys.append(None)
                else:
                    used.add(m)
                    phys.append(m)
        return P(*phys)

    def sharding(self, logical: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.to_pspec(logical))


LOGICAL_RULES_1POD = {
    "batch": "data",
    "fsdp": "data",
    "tensor": "model",
    "expert": "model",
    "seq": None,
    "kv_heads": "model",     # only applied when divisible (see param rules)
}

LOGICAL_RULES_2POD = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "tensor": "model",
    "expert": "model",
    "seq": None,
    "kv_heads": "model",
}


_STATE = threading.local()


def _current() -> Optional[MeshRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def mesh_rules(rules: Optional[MeshRules]):
    """Activate logical->physical binding for model code in this block."""
    prev = _current()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def logical_constraint(x: jax.Array, *axes) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names; no-op when no
    mesh_rules context is active (single-device tests/smoke runs)."""
    r = _current()
    if r is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    # never constrain an axis the shard count does not divide
    spec = []
    for dim, ax in zip(x.shape, r.to_pspec(tuple(axes))):
        size = _axis_size(r.mesh, ax)
        spec.append(ax if (ax is not None and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*spec)))


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


# ---------------------------------------------------------------------------
# Parameter-tree sharding rules (path-pattern based)
# ---------------------------------------------------------------------------

# Each entry: (path regex, logical axes per dim). First match wins. Paths
# are '/'-joined pytree keys, e.g. "layers/attn/wq". Rank must match.
PARAM_RULES: list[tuple[str, tuple]] = [
    # --- embeddings / heads -------------------------------------------------
    (r"embed_codebooks$", ("tensor", None, "fsdp")),     # [K, V, D] musicgen
    (r"lm_heads$", (None, "fsdp", "tensor")),            # [K, D, V] musicgen
    (r"embed$", ("tensor", "fsdp")),                     # [V, D] vocab-parallel
    (r"lm_head$", ("fsdp", "tensor")),                   # [D, V]
    # --- attention (stacked [L, ...] — leading layer axis unsharded) -------
    (r"attn/w[qkv]$", (None, "fsdp", "tensor")),
    (r"attn/wo$", (None, "tensor", "fsdp")),
    (r"attn/b[qkv]$", (None, "tensor")),
    (r"shared_attn/w[qkv]$", ("fsdp", "tensor")),        # zamba2: unstacked
    (r"shared_attn/wo$", ("tensor", "fsdp")),
    (r"shared_attn/b[qkv]$", ("tensor",)),
    # --- MLA ---------------------------------------------------------------
    (r"attn/wq_a$", (None, "fsdp", "tensor")),
    (r"attn/wq_b$", (None, "fsdp", "tensor")),
    (r"attn/wkv_a$", (None, "fsdp", "tensor")),
    (r"attn/wkv_b$", (None, "fsdp", "tensor")),
    # --- dense MLP ----------------------------------------------------------
    (r"mlp/w_(gate|up)$", (None, "fsdp", "tensor")),
    (r"mlp/w_down$", (None, "tensor", "fsdp")),
    (r"shared_mlp/w_(gate|up)$", ("fsdp", "tensor")),    # zamba2 shared block
    (r"shared_mlp/w_down$", ("tensor", "fsdp")),
    # --- MoE ----------------------------------------------------------------
    (r"moe/router$", (None, "fsdp", None)),
    (r"moe/w_(gate|up)$", (None, "expert", "fsdp", None)),   # [L, E, D, F]
    (r"moe/w_down$", (None, "expert", None, "fsdp")),        # [L, E, F, D]
    (r"moe/shared/w_(gate|up)$", (None, "fsdp", "tensor")),
    (r"moe/shared/w_down$", (None, "tensor", "fsdp")),
    # --- RWKV-6 --------------------------------------------------------------
    (r"time_mix/w[rkvg]$", (None, "fsdp", "tensor")),
    (r"time_mix/wo$", (None, "tensor", "fsdp")),
    (r"time_mix/u$", (None, "tensor", None)),            # [L, H, N]
    (r"time_mix/lora_w1$", (None, "fsdp", None)),
    (r"time_mix/lora_w2$", (None, None, None, "fsdp")),
    (r"time_mix/w1$", (None, "fsdp", None)),
    (r"time_mix/w2$", (None, None, "fsdp")),
    (r"channel_mix/wk$", (None, "fsdp", "tensor")),
    (r"channel_mix/wv$", (None, "tensor", "fsdp")),
    (r"channel_mix/wr$", (None, "fsdp", "tensor")),
    # --- Mamba2 ---------------------------------------------------------------
    (r"in_proj$", (None, "fsdp", "tensor")),
    (r"out_proj$", (None, "tensor", "fsdp")),
    (r"conv_w$", (None, None, "tensor")),
    (r"conv_b$", (None, "tensor")),
    (r"(a_log|dt_bias|d_skip)$", (None, "tensor")),
    (r"shared_attn_group/.*", None),                     # handled by attn rules
]

# 1-D / small tensors (norm scales, biases, mu vectors) -> replicated.


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspec(path: str, shape: tuple, rules: MeshRules) -> P:
    """PartitionSpec for one parameter by path pattern + divisibility."""
    for pat, logical in PARAM_RULES:
        if logical is None:
            continue
        if re.search(pat, path):
            if len(logical) == len(shape):
                spec = []
                for dim, ax in zip(shape, rules.to_pspec(logical)):
                    size = _axis_size(rules.mesh, ax)
                    spec.append(ax if dim % size == 0 else None)
                return P(*spec)
            # rank mismatch (e.g. unstacked variant): try trailing alignment
            if len(logical) == len(shape) + 1 and logical[0] is None:
                spec = []
                for dim, ax in zip(shape,
                                   rules.to_pspec(tuple(logical[1:]))):
                    size = _axis_size(rules.mesh, ax)
                    spec.append(ax if dim % size == 0 else None)
                return P(*spec)
    # default: FSDP-shard the largest divisible dim of big tensors
    if shape and max(shape) >= 1024:
        best, best_dim = None, 0
        for i, dim in enumerate(shape):
            size = _axis_size(rules.mesh, rules.rules.get("fsdp"))
            if dim % size == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is not None:
            spec = [None] * len(shape)
            spec[best] = rules.rules.get("fsdp")
            return P(*spec)
    return P()


def param_shardings(params_shape_tree, rules: MeshRules):
    """NamedSharding tree matching a params (shape-)pytree."""
    def one(path, leaf):
        return NamedSharding(
            rules.mesh, param_pspec(_path_str(path), leaf.shape, rules))
    return jax.tree_util.tree_map_with_path(one, params_shape_tree)


def input_shardings(batch_shape_tree, rules: MeshRules,
                    batch_axes: Optional[dict] = None):
    """Shard every input leaf on its batch dim (default dim 0).

    batch_axes: optional {path_suffix: dim} override (e.g. positions [3,B,S]
    carries batch on dim 1).
    """
    batch_axes = batch_axes or {}

    def one(path, leaf):
        ps = _path_str(path)
        dim = 0
        for suffix, d in batch_axes.items():
            if ps.endswith(suffix):
                dim = d
        spec = [None] * len(leaf.shape)
        ax = rules.rules.get("batch")
        if leaf.shape and leaf.shape[dim] % _axis_size(rules.mesh, ax) == 0:
            spec[dim] = ax
        return NamedSharding(rules.mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_shape_tree)
