from repro.distributed.sharding import (LOGICAL_RULES_1POD,
                                        LOGICAL_RULES_2POD, MeshRules,
                                        logical_constraint, mesh_rules,
                                        param_pspec, param_shardings,
                                        input_shardings)
from repro.distributed.compression import (compress_int8, decompress_int8,
                                           CompressedGrads,
                                           compressed_allreduce_spec)
from repro.distributed.checkpoint import (save_checkpoint, load_checkpoint,
                                          latest_step, CheckpointManager)
from repro.distributed.elastic import replan_mesh, reshard_tree
from repro.distributed.straggler import StragglerMonitor, StepJournal

__all__ = [
    "LOGICAL_RULES_1POD", "LOGICAL_RULES_2POD", "MeshRules",
    "logical_constraint", "mesh_rules", "param_pspec", "param_shardings",
    "input_shardings", "compress_int8", "decompress_int8", "CompressedGrads",
    "compressed_allreduce_spec", "save_checkpoint", "load_checkpoint",
    "latest_step", "CheckpointManager", "replan_mesh", "reshard_tree",
    "StragglerMonitor", "StepJournal",
]
