"""Elastic re-meshing: recompute shardings for a changed device count and
re-place a (checkpointed) state tree onto the new mesh.

On a real cluster the flow after losing a pod / gaining capacity is:

    1. the coordinator picks the largest (pods, data, model) grid that
       fits the surviving devices           -> ``replan_mesh``
    2. every host loads the (mesh-agnostic) checkpoint                 ..
    3. leaves are device_put with the NEW shardings (JAX slices each
       global array to the device-local shards)  -> ``reshard_tree``

Checkpoints store LOGICAL (global) arrays (checkpoint.py), so resharding
is purely a placement decision — no data transformation is ever needed.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import (MeshRules, LOGICAL_RULES_1POD,
                                        LOGICAL_RULES_2POD, param_shardings)


def replan_mesh(n_devices: int, *, model_parallel: int = 16,
                devices=None) -> Mesh:
    """Largest (pod, data, model) grid for ``n_devices``.

    Keeps TP fixed (model weights are sharded to fit HBM — shrinking TP
    can OOM), gives the rest to data, and splits off a pod axis when the
    data extent is >= 32 (two racks' worth).
    """
    devices = devices if devices is not None else jax.devices()[:n_devices]
    assert len(devices) >= model_parallel, \
        f"need >= {model_parallel} devices, got {len(devices)}"
    usable = (len(devices) // model_parallel) * model_parallel
    data = usable // model_parallel
    if data >= 32 and data % 2 == 0:
        shape, axes = (2, data // 2, model_parallel), ("pod", "data", "model")
    else:
        shape, axes = (data, model_parallel), ("data", "model")
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=devices[:n])


def rules_for(mesh: Mesh) -> MeshRules:
    rules = LOGICAL_RULES_2POD if "pod" in mesh.axis_names \
        else LOGICAL_RULES_1POD
    return MeshRules(mesh, rules)


def reshard_tree(tree, mesh: Mesh, *, shardings=None):
    """Place a host-resident tree onto ``mesh`` with the standard rules."""
    r = rules_for(mesh)
    if shardings is None:
        shape_tree = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        shardings = param_shardings(shape_tree, r)
    return jax.device_put(tree, shardings)
