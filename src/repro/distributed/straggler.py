"""Straggler mitigation + step journal for fault-tolerant training loops.

Two pieces, both host-side (the device program stays SPMD/deterministic):

* ``StragglerMonitor`` — tracks per-step wall time; a step slower than
  ``threshold`` x the trailing median flags a straggler event. The
  launcher's policy (train.py) on repeated events is: snapshot -> shrink
  the mesh around the slow host (``elastic.replan_mesh``) -> resume.
  Detection must be cheap and false-positive-robust, hence median +
  hysteresis rather than mean.

* ``StepJournal`` — append-only JSONL of (step, data_offset, rng_seed,
  checkpoint). After a crash, replay = seek the data stream to the
  journaled offset and restore the newest checkpoint <= that step:
  skip-and-replay gives exactly-once step semantics without coordinating
  a distributed snapshot on every step.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Optional


class StragglerMonitor:
    def __init__(self, *, window: int = 32, threshold: float = 2.0,
                 hysteresis: int = 3):
        self.window = window
        self.threshold = threshold
        self.hysteresis = hysteresis
        self.times: list[float] = []
        self.flags = 0
        self.events: list[dict] = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> bool:
        """Record a step; True => persistent straggler (act now)."""
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        baseline = statistics.median(self.times[-self.window:]) \
            if len(self.times) >= 8 else None
        self.times.append(dt)
        if baseline is not None and dt > self.threshold * baseline:
            self.flags += 1
            self.events.append({"step": step, "seconds": dt,
                                "median": baseline})
            if self.flags >= self.hysteresis:
                self.flags = 0
                return True
        else:
            self.flags = max(0, self.flags - 1)
        return False

    def summary(self) -> dict:
        if not self.times:
            return {}
        return {"steps": len(self.times),
                "median_s": statistics.median(self.times),
                "p95_s": sorted(self.times)[int(0.95 * len(self.times))],
                "straggler_events": len(self.events)}


class StepJournal:
    """Append-only recovery journal (one JSON line per step)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def record(self, step: int, *, data_offset: int, seed: int,
               checkpoint_step: Optional[int] = None, **extra):
        entry = {"step": step, "data_offset": data_offset, "seed": seed,
                 "checkpoint_step": checkpoint_step, "t": time.time(),
                 **extra}
        with open(self.path, "a") as f:
            f.write(json.dumps(entry) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def replay_point(self) -> Optional[dict]:
        """Last journaled entry — where to resume after a crash."""
        if not os.path.exists(self.path):
            return None
        last = None
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if line:
                    try:
                        last = json.loads(line)
                    except json.JSONDecodeError:
                        break       # torn tail write from the crash
        return last
