"""Gradient compression for the cross-pod all-reduce.

int8 block quantization with ERROR FEEDBACK: the quantization residual of
step t is added back into the gradient at step t+1, so the compression
error does not accumulate (EF-SGD / 1-bit-Adam family). Used on the 'pod'
axis only — the in-pod reduction stays full precision (reduce-scatter +
all-gather, ZeRO style), the 8x-smaller cross-pod traffic rides the slow
inter-pod links (DESIGN.md §5).

The quantizer is pure JAX and shape-polymorphic; the all-reduce itself is
expressed by doing psum over the 'pod' axis on the int8 payload's
dequantized value inside shard_map (see train/steps.py) — XLA sees an
8x-smaller collective operand.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressedGrads(NamedTuple):
    q: Any          # int8 payload tree
    scale: Any      # f32 per-block scales tree


def _blocks(x: jax.Array, block: int) -> jax.Array:
    n = x.size
    pad = (-n) % block
    return jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, block)


def compress_int8(tree, *, block: int = 1024) -> CompressedGrads:
    """Blockwise symmetric int8 quantization of every leaf."""
    def one(x):
        xb = _blocks(x.astype(jnp.float32), block)
        scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-20)
        q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
        return q, scale
    qs = jax.tree.map(one, tree)
    leaves, treedef = jax.tree.flatten(qs, is_leaf=lambda t: isinstance(t, tuple))
    return CompressedGrads(
        treedef.unflatten([l[0] for l in leaves]),
        treedef.unflatten([l[1] for l in leaves]))


def decompress_int8(c: CompressedGrads, like) -> Any:
    """Dequantize back to the shapes/dtypes of ``like``."""
    def one(q, scale, ref):
        flat = (q.astype(jnp.float32) * scale).reshape(-1)[:ref.size]
        return flat.reshape(ref.shape).astype(jnp.float32)
    return jax.tree.map(one, c.q, c.scale, like)


def compress_error_feedback(grads, error, *, block: int = 1024):
    """Quantize (grads + carried error); return (compressed, new_error).

    new_error = input - dequantized(quantized(input)) stays on-device and
    is added to the NEXT step's gradient — unbiased in the long run.
    """
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error)
    comp = compress_int8(corrected, block=block)
    deq = decompress_int8(comp, corrected)
    new_error = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return comp, deq, new_error


def init_error(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compressed_allreduce_spec(n_params: int, pods: int = 2,
                              link_gbps: float = 50.0) -> dict:
    """Napkin model of the cross-pod traffic saved (for EXPERIMENTS.md)."""
    full = n_params * 4          # f32 all-reduce payload per step
    comp = n_params * 1 + n_params / 1024 * 4
    return {"full_bytes": full, "compressed_bytes": comp,
            "ratio": full / comp,
            "seconds_full": full / (link_gbps * 1e9),
            "seconds_compressed": comp / (link_gbps * 1e9)}
