"""Ahead-of-time compilation + the persistent XLA cache (cold start).

Cold-start compilation dominates first-request serving latency: the
first batch through a freshly-loaded :class:`~repro.core.program
.Program` pays the full XLA trace+compile of the timestep scan — tens
of times the steady-state service time. Two layers kill it:

* **AOT bucket precompile** — ``Program.precompile(buckets, T)`` (and
  the ``precompile=`` hooks on ``Program.load`` / registry insert)
  walks every padded batch shape the serving policy can dispatch
  (:class:`~repro.serve.batcher.BatchPolicy.buckets`) and compiles the
  engine's jitted scan for it NOW, via ``jit(...).lower(shapes)
  .compile()``; ``run()`` dispatches straight to the stored executable,
  so the first real request never traces;
* **persistent compilation cache** — :func:`enable_persistent_cache`
  points jax's on-disk cache at a stable directory, so a *restarted*
  process skips XLA entirely for shapes any previous process compiled.
  The cache is keyed by the serialized HLO, and the lowered program's
  constants (op tables / dense weight plane) are baked into that HLO —
  distinct Programs therefore key distinct entries with no extra salt.
  :func:`content_hash` exposes the salt CI uses to version its cached
  directory (actions/cache key = jax version + program hash).

Both layers are warm-path-only optimizations: they never change what
executes, only when it compiles.
"""
from __future__ import annotations

import hashlib
import os
from pathlib import Path

import numpy as np

ENV_CACHE_DIR = "SUPRASNN_JAX_CACHE_DIR"
DEFAULT_CACHE_DIR = "~/.cache/suprasnn/jax"

_cache_dir: str | None = None


def enable_persistent_cache(cache_dir: str | None = None) -> str | None:
    """Enable jax's on-disk compilation cache; returns its directory.

    Resolution order: explicit argument > ``SUPRASNN_JAX_CACHE_DIR`` >
    ``~/.cache/suprasnn/jax``. Idempotent — later calls with no
    argument keep the first directory. Returns ``None`` (disabled) if
    this jax build lacks the cache config knobs; thresholds are opened
    (min size/compile time -> 0) so even the small SNN scans persist.
    """
    global _cache_dir
    if cache_dir is None:
        if _cache_dir is not None:
            return _cache_dir
        cache_dir = os.environ.get(ENV_CACHE_DIR) or DEFAULT_CACHE_DIR
    cache_dir = str(Path(cache_dir).expanduser())
    import jax
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    except (AttributeError, ValueError):    # jax without these knobs
        return None
    _cache_dir = cache_dir
    return cache_dir


def normalize_buckets(buckets) -> tuple[int, ...]:
    """Coerce a ``BatchPolicy`` or iterable of batch sizes to sorted
    unique positive ints — the shapes AOT precompile walks."""
    buckets = getattr(buckets, "buckets", buckets)
    if isinstance(buckets, (int, np.integer)):
        buckets = (buckets,)
    out = tuple(sorted({int(b) for b in buckets}))
    if not out or out[0] < 1:
        raise ValueError(f"precompile buckets must be positive batch "
                         f"sizes, got {buckets}")
    return out


def content_hash(program) -> str:
    """SHA-256 of everything that determines the compiled computation.

    Covers the lowered op stream (the constants baked into the HLO),
    the routing matrix, the LIF parameters, and the problem dims —
    NOT the search/report metadata, so re-compiling the same mapping
    hashes identically. Used as the CI cache-key salt.
    """
    lw = program.lowered
    h = hashlib.sha256()
    for name in ("op_spu", "op_slot", "op_pre", "op_post_local",
                 "op_weight", "op_pre_end", "op_post_end", "routing"):
        a = np.ascontiguousarray(getattr(lw, name))
        h.update(f"{name}:{a.dtype}:{a.shape}".encode())
        h.update(a.tobytes())
    lif = program.graph.lif
    h.update(f"lif:{lif.leak_shift}:{lif.v_threshold}:{lif.v_reset}"
             f":dims:{lw.n_inputs}:{lw.n_neurons}:{lw.n_internal}"
             f":{lw.n_spus}:{lw.depth}".encode())
    return h.hexdigest()
