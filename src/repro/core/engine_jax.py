"""Compiled, batched executor of mapped OpTables programs.

``engine.run_mapped`` is the *reference* executor: a Python triple loop
over timesteps x OT slots x SPUs that mirrors the hardware datapath
structure op by op. That fidelity costs ~0.5 s per MNIST image — fine for
verification, useless for serving. This module lowers a scheduled program
ONCE into dense arrays (:func:`repro.core.schedule.lower_tables`) and
executes it with ``jax.lax.scan`` over timesteps, with a leading batch
dimension pushing many samples through one mapped program. The body of
the scan is one of three **kernel tiers**, selected by
:class:`~repro.core.execution.ExecutionSpec`:

* ``"fused"`` (platform default) — the whole timestep in ONE Pallas
  launch: multicast routing + per-SPU accumulation as a packed dense
  int contraction, Neuron-Unit update as the in-register epilogue,
  packet counts for free (:mod:`repro.kernels.fused_step`);
* ``"lif"`` — the split pipeline: vectorized segment-sum over all
  (SPU, slot) ops + the small Pallas Neuron-Unit kernel
  (:func:`repro.kernels.lif_update.lif_update_int`);
* ``"reference"`` — segment-sum + pure-jnp ``lif_step_int``.

Why this is still the SAME program, bit for bit (deterministic-commit
property, paper §4.2):

* every non-NOP op contributes ``weight * spike_bit(pre)`` to its post
  neuron exactly once per timestep — Spike Memory bits are set at
  distribution and cleared by Pre-End only after the last reference, so
  within a timestep an op is active iff its pre fired (external spike at
  t, or internal spike at t-1);
* the ME-tree merge and the per-SPU partial sums are plain int32
  additions, which are associative and exact — any summation order
  (segment_sum here, slot-major commit in the reference) yields the
  identical int32 current;
* the Neuron Unit applies the same int32 shift-leak LIF step to every
  post neuron once per timestep.

Outputs therefore match ``run_oracle``/``run_mapped`` bit-exactly, and
the emitted per-timestep MC packet counts equal ``run_mapped``'s stats,
so ``CycleModel`` latency/energy reports are unchanged.

Engines are owned by the :class:`repro.core.program.Program` artifact
(``program.run(ext)`` / ``program.engine(spec)``), which builds them
lazily from its already-lowered program, keyed on the **resolved**
spec, and reuses them across calls; construct :class:`JaxMappedEngine`
directly only when driving a bare ``OpTables`` outside the artifact
API. :meth:`JaxMappedEngine.precompile` AOT-compiles the scan for the
serving buckets so the first real request never traces (see
:mod:`repro.core.aot`).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import packet_stats
from repro.core.execution import (_NU_KERNEL_TIER, ExecutionSpec, as_spec,
                                  spec_from_legacy_kwargs)
from repro.core.graph import SNNGraph
from repro.core.scheduling import LoweredProgram, OpTables, lower_tables
from repro.kernels.fused_step import fused_step, pack_dense
from repro.kernels.lif_update import lif_update_int
from repro.snn.lif import LIFIntParams, lif_step_int


def normalize_ext_spikes(ext_spikes, n_inputs: int
                         ) -> tuple[np.ndarray, bool]:
    """Validate a spike train (batch) into ``[B, T, n_inputs]`` form.

    Returns ``(ext, squeeze)`` where ``squeeze`` records that a 2-D
    ``[T, n_inputs]`` input was promoted and the outputs should drop
    the batch dim again. Shared by the single-device engine and the
    sharded runner so validation cannot drift between them.
    """
    ext = np.asarray(ext_spikes)
    squeeze = ext.ndim == 2
    if squeeze:
        ext = ext[None]
    if ext.ndim != 3 or ext.shape[2] != n_inputs:
        raise ValueError(f"ext_spikes shape {np.shape(ext_spikes)} != "
                         f"[B, T, {n_inputs}] or [T, {n_inputs}]")
    return ext, squeeze


def finalize_outputs(spikes, v, pkts, squeeze: bool
                     ) -> tuple[np.ndarray, np.ndarray, dict]:
    """Device arrays -> the uniform ``(spikes, v_final, stats)`` tuple."""
    spikes = np.asarray(spikes, np.int32)
    v = np.asarray(v, np.int32)
    pkts = np.asarray(pkts, np.int64)
    if squeeze:
        spikes, v, pkts = spikes[0], v[0], pkts[0]
    return spikes, v, packet_stats(pkts)


class JaxMappedEngine:
    """A mapped program compiled for batched execution.

    Construction lowers the tables and jit-compiles the scan; ``run``
    then serves any batch of spike trains through the same program.
    Reuse one engine across calls — compilation is cached per engine,
    per (batch, timesteps) shape.
    """

    def __init__(self, g: SNNGraph, tables: OpTables | LoweredProgram,
                 spec: ExecutionSpec | None = None, *,
                 nu_kernel: bool | None = None,
                 interpret: bool | None = None):
        """``spec`` selects the kernel tier / interpret mode / donation
        (:class:`~repro.core.execution.ExecutionSpec`); ``None`` is the
        platform default (fused tier, interpret off-TPU).
        ``nu_kernel=``/``interpret=`` are the deprecated pre-spec
        kwargs and delegate with a ``DeprecationWarning``."""
        if nu_kernel is not None or interpret is not None:
            if spec is not None:
                raise TypeError("pass spec= OR the deprecated nu_kernel=/"
                                "interpret= kwargs, not both")
            spec = spec_from_legacy_kwargs(
                nu_kernel=nu_kernel, interpret=interpret,
                where="JaxMappedEngine", stacklevel=3)
        spec = as_spec(spec).resolve()
        if spec.engine != "jax" or spec.mesh is not None:
            raise ValueError(
                f"JaxMappedEngine is the single-device jax engine; got "
                f"{spec} (meshes go through repro.serve.sharded)")
        self.spec = spec
        self.lowered = (tables if isinstance(tables, LoweredProgram)
                        else lower_tables(g, tables))
        self.lif: LIFIntParams = g.lif
        self._fn = self._build()
        # donate the membrane-state buffer (v0 -> v_final storage);
        # s0 has no same-shaped output and would just warn
        self._run = jax.jit(self._fn,
                            donate_argnums=(1,) if spec.donate else ())
        self._aot: dict[tuple[int, int], object] = {}

    @property
    def step_fn(self):
        """The uncompiled ``(ext [B,T,in], v0, s0) -> (spikes, v, pkts)``
        program — :mod:`repro.serve.sharded` wraps it in ``shard_map``
        over a device mesh before jitting, so the sharded executor runs
        the byte-identical computation per shard."""
        return self._fn

    # -- compiled program ---------------------------------------------------

    def _build(self):
        lw, lif = self.lowered, self.lif
        tier, interp = self.spec.kernel, self.spec.interpret
        if tier == "fused":
            # whole timestep in one Pallas launch over the packed
            # dense plane — bit-exact vs the split pipeline (int32
            # addition is associative; deterministic-commit, §4.2)
            w = jnp.asarray(pack_dense(lw).weight)

            def step(carry, ext_t):
                v, s_prev = carry
                s_all = jnp.concatenate([ext_t, s_prev], axis=1)
                v_next, s, pkt = fused_step(s_all, v, w, lif,
                                            interpret=interp)
                return (v_next, s), (s, pkt)

            return self._scan(step)

        op_pre = jnp.asarray(lw.op_pre)
        op_w = jnp.asarray(lw.op_weight, jnp.int32)
        accum = functools.partial(jax.ops.segment_sum,
                                  segment_ids=jnp.asarray(lw.op_post_local),
                                  num_segments=lw.n_internal)
        if tier == "lif":
            nu = functools.partial(lif_update_int, p=lif, interpret=interp)
        else:
            nu = functools.partial(lif_step_int, p=lif)

        def step(carry, ext_t):
            v, s_prev = carry
            # distribution phase: one MC packet per fired neuron
            s_all = jnp.concatenate([ext_t, s_prev], axis=1)
            pkt = jnp.sum(s_all != 0, axis=1)
            # synaptic phase: every op gated by its pre's spike bit,
            # merged per post neuron (exact int32 sum == ME tree)
            act = jnp.take(s_all, op_pre, axis=1)
            current = jax.vmap(accum)(act * op_w)
            # Neuron Unit: fused leak/integrate/fire/reset
            v_next, s = nu(v, current)
            s = s.astype(jnp.int32)
            return (v_next, s), (s, pkt)

        return self._scan(step)

    @staticmethod
    def _scan(step):

        def run(ext, v0, s0):
            # ext [B, T, n_inputs] -> scan is time-major
            (v, _), (spikes, pkts) = jax.lax.scan(
                step, (v0, s0), jnp.swapaxes(ext, 0, 1))
            return jnp.swapaxes(spikes, 0, 1), v, jnp.swapaxes(pkts, 0, 1)

        return run

    # -- AOT ----------------------------------------------------------------

    def precompile(self, batch_sizes, timesteps: int) -> list[tuple[int, int]]:
        """AOT-compile the scan for each ``(batch, timesteps)`` shape.

        Lowers + compiles via ``jit(...).lower(shapes).compile()`` and
        stores the executables; :meth:`run` dispatches to a stored
        executable when the incoming shape matches, so a precompiled
        shape's first real request skips XLA tracing entirely. Returns
        the shapes compiled by THIS call (already-warm shapes skip).
        Idempotent; serving passes ``BatchPolicy.buckets`` here.
        """
        lw = self.lowered
        compiled = []
        for b in batch_sizes:
            key = (int(b), int(timesteps))
            if key in self._aot:
                continue
            ext = jax.ShapeDtypeStruct((key[0], key[1], lw.n_inputs),
                                       jnp.int32)
            st = jax.ShapeDtypeStruct((key[0], lw.n_internal), jnp.int32)
            exe = self._run.lower(ext, st, st).compile()
            # execute once on zeros: warms the one-time dispatch costs
            # that live outside the executable (the jnp.zeros fills for
            # these state shapes, host<->device transfer setup), so the
            # first real request runs at steady-state latency
            z = lambda s: jnp.zeros(s.shape, s.dtype)
            jax.block_until_ready(exe(z(ext), z(st), z(st)))
            self._aot[key] = exe
            compiled.append(key)
        return compiled

    # -- public API ---------------------------------------------------------

    def run(self, ext_spikes: np.ndarray
            ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Execute the program on ``ext_spikes``.

        ext_spikes: [T, n_inputs] or batched [B, T, n_inputs], binary.
        Returns (spikes, v_final, stats) shaped like ``run_mapped`` for
        2-D input ([T, n_int] / [n_int] / packet_counts [T]); with a
        batch dimension the leading B is kept ([B, T, n_int] / [B, n_int]
        / [B, T]).
        """
        ext, squeeze = normalize_ext_spikes(ext_spikes,
                                            self.lowered.n_inputs)
        shape = (ext.shape[0], self.lowered.n_internal)
        fn = self._aot.get((ext.shape[0], ext.shape[1]), self._run)
        # two distinct state buffers: under donation v0 and s0 must not
        # alias one another
        spikes, v, pkts = fn(jnp.asarray(ext, jnp.int32),
                             jnp.zeros(shape, jnp.int32),
                             jnp.zeros(shape, jnp.int32))
        return finalize_outputs(spikes, v, pkts, squeeze)


# -- deprecated convenience entry point -------------------------------------

def run_mapped_batched(g: SNNGraph, tables: OpTables, ext_spikes: np.ndarray,
                       *, nu_kernel: bool = True,
                       interpret: bool | None = None
                       ) -> tuple[np.ndarray, np.ndarray, dict]:
    """Deprecated: use ``Program.run`` (:mod:`repro.core.program`).

    Batched counterpart of ``engine.run_mapped``. Builds a fresh
    :class:`JaxMappedEngine` on every call — the former module-level
    ``id()``-keyed cache is gone (recycled ids could alias dead
    programs, and ``interpret=None`` vs its resolved value duplicated
    engines). Compiled engines are now owned by the ``Program``
    artifact, which keys them on resolved build options and reuses
    them across calls; construct one via ``repro.core.compile`` to
    avoid per-call recompilation.
    """
    warnings.warn(
        "run_mapped_batched is deprecated and recompiles per call; use "
        "repro.core.compile(...).run(ext)",
        DeprecationWarning, stacklevel=2)
    eng = JaxMappedEngine(
        g, tables,
        ExecutionSpec(kernel=_NU_KERNEL_TIER[bool(nu_kernel)],
                      interpret=interpret))
    return eng.run(ext_spikes)
