"""Deprecated compile wrappers (pre-Program API).

The end-to-end pipeline now lives in :mod:`repro.core.passes` (the
explicit passes) and :mod:`repro.core.program` (the :class:`Program`
artifact). ``compile_snn`` / ``compile_quantized`` remain as thin
delegating wrappers so pre-artifact callers keep working; new code
should call :func:`repro.core.program.compile` and use the artifact::

    program = compile(g, hw)                  # was: compile_snn(g, hw)
    tables, report, part = (program.tables,   # the old 3-tuple
                            program.report, program.part)

``CompileReport`` and ``initialization_packets`` moved to
:mod:`repro.core.passes`; they are re-exported here unchanged.
"""
from __future__ import annotations

import warnings

from repro.core import program as _program
from repro.core.graph import SNNGraph, from_quantized
from repro.core.memory_model import HardwareConfig
from repro.core.partition import PartitionResult
from repro.core.passes import (CompileReport,  # noqa: F401 (re-export)
                               initialization_packets)
from repro.core.scheduling import OpTables
from repro.snn.quantize import QuantizedSNN


def compile_snn(g: SNNGraph, hw: HardwareConfig, method: str = "framework",
                seed: int = 0, validate: bool = True,
                max_iters: int = 20000, restarts: int = 1
                ) -> tuple[OpTables, CompileReport, PartitionResult]:
    """Deprecated: use :func:`repro.core.program.compile`.

    Same pipeline, same defaults; returns the artifact's parts as the
    historical ``(tables, report, part)`` 3-tuple.
    """
    warnings.warn(
        "compile_snn is deprecated; use repro.core.compile(g, hw, ...) and "
        "the returned Program artifact", DeprecationWarning, stacklevel=2)
    p = _program.compile(g, hw, method=method, seed=seed, validate=validate,
                         max_iters=max_iters, restarts=restarts)
    return p.tables, p.report, p.part


def compile_quantized(qsnn: QuantizedSNN, hw: HardwareConfig, **kw):
    """Deprecated: ``repro.core.compile`` accepts a QuantizedSNN directly."""
    warnings.warn(
        "compile_quantized is deprecated; repro.core.compile accepts a "
        "QuantizedSNN directly", DeprecationWarning, stacklevel=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return compile_snn(from_quantized(qsnn), hw, **kw)
