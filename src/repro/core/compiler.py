"""End-to-end SupraSNN compiler: quantized SNN -> partition -> schedule ->
operation tables + reports + initialization packet stream.

This is the "software framework" box of paper Fig. 8.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import baselines as _baselines
from repro.core.cost import ResourceReport, resources
from repro.core.graph import SNNGraph, from_quantized
from repro.core.memory_model import HardwareConfig
from repro.core.partition import PartitionResult, partition
from repro.core.schedule import NOP, OpTables, schedule, validate_schedule
from repro.snn.quantize import QuantizedSNN


@dataclasses.dataclass
class CompileReport:
    method: str
    feasible: bool
    iterations: int
    perturbations: int
    ot_depth: int
    scores: np.ndarray
    spu_synapse_counts: np.ndarray
    spu_post_counts: np.ndarray          # post-neurons stored per SPU
    spu_weight_counts: np.ndarray        # unique weights per SPU
    resources: ResourceReport
    n_init_packets: int
    compile_seconds: float


def _spu_stats(g: SNNGraph, assign: np.ndarray, m: int):
    syn = np.bincount(assign, minlength=m)
    posts = np.zeros(m, np.int64)
    weights = np.zeros(m, np.int64)
    for i in range(m):
        sel = assign == i
        posts[i] = len(np.unique(g.post[sel]))
        weights[i] = len(np.unique(g.weight[sel]))
    return syn, posts, weights


def initialization_packets(g: SNNGraph, tables: OpTables,
                           hw: HardwareConfig) -> list[tuple[int, int]]:
    """MC-tree initialization stream (paper §4.3, Table 1).

    ctrl=10 selects a unit; ctrl=11 carries its data words. Returns the
    abstract (ctrl, payload) list — its length drives init latency.
    """
    pkts: list[tuple[int, int]] = []
    m = tables.n_spus
    # routing bitstrings (unit id 0 = Routing Unit)
    pkts.append((0b10, 0))
    for q in range(g.n_neurons):
        bits = 0
        for i in range(m):
            if (tables.assign[g.pre == q] == i).any():
                bits |= 1 << i
        pkts.append((0b11, bits))
    # per-SPU operation tables + unified memories (unit ids 1..M)
    for i in range(m):
        pkts.append((0b10, 1 + i))
        for t in range(tables.depth):
            pkts.append((0b11, int(tables.pre[i, t])))
        used_w = np.unique(tables.weight[i][tables.pre[i] != NOP])
        for w in used_w:
            pkts.append((0b11, int(w)))
    # neuron unit (unit id M+1): global index + flags per internal neuron
    pkts.append((0b10, 1 + m))
    for q in range(g.n_inputs, g.n_neurons):
        pkts.append((0b11, q))
    return pkts


def compile_snn(g: SNNGraph, hw: HardwareConfig, method: str = "framework",
                seed: int = 0, validate: bool = True,
                max_iters: int = 20000, restarts: int = 1
                ) -> tuple[OpTables, CompileReport, PartitionResult]:
    t0 = time.time()
    if method == "framework":
        part = None
        for k in range(max(restarts, 1)):
            cand = partition(g, hw, seed=seed + k, max_iters=max_iters)
            if part is None or cand.scores.min() > part.scores.min():
                part = cand
            if part.feasible:
                break
    elif method in _baselines.BASELINES:
        part = _baselines.BASELINES[method](g, hw)
    else:
        raise ValueError(f"unknown method {method!r}; "
                         f"use 'framework' or {list(_baselines.BASELINES)}")

    tables = schedule(g, part.assign, hw)
    if validate:
        validate_schedule(g, tables)

    syn, posts, weights = _spu_stats(g, part.assign, hw.n_spus)
    pkts = initialization_packets(g, tables, hw)
    report = CompileReport(
        method=method, feasible=part.feasible, iterations=part.iterations,
        perturbations=part.perturbations, ot_depth=tables.depth,
        scores=part.scores, spu_synapse_counts=syn, spu_post_counts=posts,
        spu_weight_counts=weights, resources=resources(hw, tables.depth),
        n_init_packets=len(pkts), compile_seconds=time.time() - t0)
    return tables, report, part


def compile_quantized(qsnn: QuantizedSNN, hw: HardwareConfig, **kw):
    return compile_snn(from_quantized(qsnn), hw, **kw)
