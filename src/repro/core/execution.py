"""ExecutionSpec: ONE frozen value that names how a Program executes.

The old run surface was kwarg sprawl — ``Program.run(ext, engine=,
nu_kernel=, interpret=, sharded=, mesh=)`` — five orthogonal-looking
knobs that were not orthogonal at all (``nu_kernel`` only meant
something on the jax engine, ``mesh`` only under ``sharded=True``,
``interpret=None`` resolved to a platform default in three different
places). :class:`ExecutionSpec` replaces all of them:

* ``engine``    — ``"jax"`` (compiled batched), ``"python"`` (per-op
  reference executor), ``"oracle"`` (dense integer LIF);
* ``kernel``    — the jax engine's kernel tier: ``"fused"`` (the
  route/accumulate/Neuron-Unit Pallas megakernel,
  :mod:`repro.kernels.fused_step`), ``"lif"`` (segment-sum synaptic
  phase + the small Pallas LIF kernel), ``"reference"`` (segment-sum +
  pure-jnp LIF). ``None`` resolves to the platform default;
* ``interpret`` — Pallas interpret mode; ``None`` resolves to the
  platform default (True off-TPU);
* ``mesh``      — ``None`` runs single-device; a jax ``Mesh`` (or the
  string ``"auto"`` = every device on the ``data`` axis) data-shards
  the batch through the owned :class:`~repro.serve.sharded
  .ShardedRunner`;
* ``donate``    — donate the membrane/spike state buffers to the
  compiled call (XLA reuses their storage for the outputs).

:meth:`resolve` folds the platform defaults in ONCE and validates the
combination; the **resolved** spec is hashable and is the engine/runner
cache key in ``Program.engine()`` / ``Program.sharded_runner()`` — so
an explicit value and the default it resolves to always share one
compiled engine. All three kernel tiers are bit-exact (deterministic-
commit property): the spec selects a speed/feature point, never a
numerical behavior.
"""
from __future__ import annotations

import dataclasses
import warnings

ENGINES = ("jax", "python", "oracle")
KERNELS = ("fused", "lif", "reference")

AUTO_MESH = "auto"


def default_kernel() -> str:
    """Platform-default kernel tier for the jax engine.

    ``"fused"`` everywhere: the megakernel targets the TPU dataflow
    (one launch per timestep), and in interpret mode on CPU it
    resolves to ONE full-array tile — a single XLA dot + epilogue —
    which matches the split pipeline at toy scale and beats it ~4x on
    the paper-scale SHD instance (see
    ``benchmarks/kernel_benchmarks.py`` tier rows).
    """
    return "fused"


@dataclasses.dataclass(frozen=True)
class ExecutionSpec:
    """How to execute a compiled :class:`~repro.core.program.Program`."""
    engine: str = "jax"
    kernel: str | None = None          # jax only; None -> platform default
    interpret: bool | None = None      # jax only; None -> platform default
    mesh: object | None = None         # jax only; None | Mesh | "auto"
    donate: bool = False               # jax only

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; use one of "
                             f"{ENGINES}")
        if self.kernel is not None and self.kernel not in KERNELS:
            raise ValueError(f"unknown kernel {self.kernel!r}; use one of "
                             f"{KERNELS} (or None for the platform default)")
        if self.engine != "jax":
            if (self.kernel is not None or self.interpret is not None
                    or self.donate):
                raise ValueError(
                    f"kernel/interpret/donate select jax-engine build "
                    f"options; they do not apply to engine={self.engine!r}")
            if self.mesh is not None:
                raise ValueError(f"mesh= shards the jax engine; got "
                                 f"engine={self.engine!r}")

    # -- derived views -------------------------------------------------------

    @property
    def sharded(self) -> bool:
        """True iff this spec routes through a multi-device mesh."""
        return self.mesh is not None

    @property
    def resolved(self) -> bool:
        """True iff no field still names a platform default."""
        if self.engine != "jax":
            return True
        return (self.kernel is not None and self.interpret is not None
                and not isinstance(self.mesh, str))

    def single_device(self) -> "ExecutionSpec":
        """This spec without the mesh — the per-device engine key the
        sharded runner (and its small-batch fallback) builds from."""
        if self.mesh is None:
            return self
        return dataclasses.replace(self, mesh=None)

    # -- resolution ----------------------------------------------------------

    def resolve(self) -> "ExecutionSpec":
        """Fold platform defaults in; validation happened at init.

        Idempotent, and the ONLY place defaults are decided: the
        resolved spec is what engines/runners are keyed on, so
        ``ExecutionSpec()`` and ``ExecutionSpec(kernel="fused",
        interpret=<platform>)`` share one compiled engine.
        """
        if self.engine != "jax":
            return self
        from repro.kernels.ops import _default_interpret
        kernel = self.kernel if self.kernel is not None else default_kernel()
        interpret = (_default_interpret() if self.interpret is None
                     else bool(self.interpret))
        mesh = self.mesh
        if isinstance(mesh, str):
            if mesh != AUTO_MESH:
                raise ValueError(f"mesh={mesh!r}: the only string form is "
                                 f"{AUTO_MESH!r} (every device on 'data')")
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh()
        return dataclasses.replace(self, kernel=kernel, interpret=interpret,
                                   mesh=mesh)


def as_spec(spec: "ExecutionSpec | str | None",
            default_engine: str = "jax") -> ExecutionSpec:
    """Coerce the ``spec`` argument of the run surface.

    ``None`` -> the artifact's default engine; a string is shorthand
    for ``ExecutionSpec(engine=<string>)`` so the common
    ``program.run(ext, "python")`` stays one token.
    """
    if spec is None:
        return ExecutionSpec(engine=default_engine)
    if isinstance(spec, str):
        return ExecutionSpec(engine=spec)
    if not isinstance(spec, ExecutionSpec):
        raise TypeError(f"spec must be an ExecutionSpec, engine-name "
                        f"string, or None; got {type(spec).__name__}")
    return spec


# ---------------------------------------------------------------------------
# Legacy-kwarg shim: the deprecated Program.run(engine=, nu_kernel=,
# interpret=, sharded=, mesh=) surface delegates here.
# ---------------------------------------------------------------------------

_NU_KERNEL_TIER = {True: "lif", False: "reference"}


def spec_from_legacy_kwargs(*, engine=None, nu_kernel=None, interpret=None,
                            sharded=None, mesh=None, default_engine="jax",
                            where="Program.run", stacklevel=3
                            ) -> ExecutionSpec:
    """Map the pre-ExecutionSpec kwargs onto a spec, warning once.

    Preserves the old semantics exactly: ``nu_kernel=True`` was the
    segment-sum + Pallas-LIF pipeline (now the ``"lif"`` tier),
    ``nu_kernel=False`` the pure-jnp step (now ``"reference"``);
    ``sharded=True`` with no mesh meant the default serving mesh, and
    ``sharded=True`` with a non-jax engine was an error with this exact
    message.
    """
    passed = {k: v for k, v in [("engine", engine), ("nu_kernel", nu_kernel),
                                ("interpret", interpret),
                                ("sharded", sharded), ("mesh", mesh)]
              if v is not None}
    warnings.warn(
        f"{where}({', '.join(f'{k}=' for k in passed)}) is deprecated; "
        f"pass ExecutionSpec(engine=, kernel=, interpret=, mesh=, donate=) "
        f"instead (see README 'Migration to ExecutionSpec')",
        DeprecationWarning, stacklevel=stacklevel)
    sharded = bool(sharded)
    if sharded:
        engine = engine or "jax"
        if engine != "jax":
            raise ValueError(f"sharded=True runs the jax engine; got "
                             f"engine={engine!r}")
        mesh = mesh if mesh is not None else AUTO_MESH
    elif mesh is not None:
        mesh = None                     # old API: mesh ignored unless sharded
    engine = engine or default_engine
    if engine != "jax":
        return ExecutionSpec(engine=engine)
    return ExecutionSpec(
        engine="jax",
        kernel=None if nu_kernel is None else _NU_KERNEL_TIER[bool(nu_kernel)],
        interpret=interpret, mesh=mesh)
