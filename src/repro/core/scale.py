"""Synthetic large-graph generator for compiler-scale benchmarking (§11).

``random_graph`` samples uniform (pre, post) pairs — fine for property
tests, but real SNN workloads are LAYERED (feedforward chains with
optional recurrence) and have SKEWED fan-out (a few hub neurons drive
many posts — exactly what stresses hyperedge-aware mapping). This
module builds such graphs at the ROADMAP's 10⁵–10⁶-synapse scale,
fully vectorized, plus a matching multi-chip
:class:`~repro.core.memory_model.HardwareConfig`.

Determinism: a (shape, seed) pair always yields the same graph — the
benchmark pins one and tracks compile seconds / peak RSS against it.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.memory_model import HardwareConfig
from repro.snn.lif import LIFIntParams

TOPOLOGIES = ("layered", "recurrent", "mixed")


def _skewed_sources(rng: np.random.Generator, n_pre: int, count: int,
                    skew: float) -> np.ndarray:
    """Draw ``count`` pre indices with Zipf-like fan-out skew.

    ``skew=0`` is uniform; larger values concentrate fan-out on hub
    neurons (pre i drawn with probability ∝ (i+1)^-skew after a seeded
    shuffle, so the hubs are spread across the layer, not its head).
    """
    if skew <= 0:
        return rng.integers(0, n_pre, count, dtype=np.int64)
    p = (np.arange(1, n_pre + 1, dtype=np.float64)) ** (-skew)
    p /= p.sum()
    perm = rng.permutation(n_pre)
    return perm[rng.choice(n_pre, size=count, p=p)]


def _unique_pairs(rng: np.random.Generator, n_pre: int, n_post: int,
                  count: int, skew: float, pre_base: int, post_base: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Sample ``count`` distinct (pre, post) pairs inside one block,
    skewed over pres; oversample + dedup + top-up until exact."""
    count = min(count, n_pre * n_post)
    keys = np.empty(0, np.int64)
    want = count
    while want > 0:
        pre = _skewed_sources(rng, n_pre, int(want * 1.3) + 8, skew)
        post = rng.integers(0, n_post, len(pre), dtype=np.int64)
        keys = np.unique(np.r_[keys, pre * n_post + post])[:count]
        want = count - len(keys)
    keys = keys[rng.permutation(len(keys))]
    return pre_base + keys // n_post, post_base + keys % n_post


def synthetic_graph(n_synapses: int, *, topology: str = "layered",
                    n_layers: int = 4, neurons_per_synapse: float = 0.02,
                    skew: float = 1.0, recurrent_frac: float = 0.25,
                    seed: int = 0, weight_lo: int = -31, weight_hi: int = 31,
                    lif: LIFIntParams | None = None) -> SNNGraph:
    """Build a layered / recurrent synthetic SNN with ``n_synapses``
    connections (exact) and controllable fan-out skew.

    * ``layered`` — an ``n_layers``-deep feedforward chain; layer sizes
      split ``n_synapses * neurons_per_synapse`` neurons evenly.
    * ``recurrent`` — one input layer plus a single recurrent pool.
    * ``mixed`` — the layered chain with ``recurrent_frac`` of each
      hidden layer's synapse budget rewired within the layer (SRNN
      style, like the paper's SHD network).
    """
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}")
    rng = np.random.default_rng(seed)
    n_neurons = max(int(n_synapses * neurons_per_synapse), 8 * n_layers)
    if topology == "recurrent":
        n_layers = 2
    layer = np.full(n_layers, n_neurons // n_layers, np.int64)
    layer[:n_neurons % n_layers] += 1
    offs = np.r_[0, np.cumsum(layer)]
    n_inputs = int(layer[0])

    # synapse budget per feedforward hop, proportional to the fan-in side
    hop_w = layer[1:].astype(np.float64)
    budget = np.floor(n_synapses * hop_w / hop_w.sum()).astype(np.int64)
    budget[0] += n_synapses - budget.sum()

    pres, posts = [], []
    for h in range(n_layers - 1):
        ff = int(budget[h])
        rec = 0
        if topology == "recurrent" or \
                (topology == "mixed" and h + 1 < n_layers - 1):
            rec = int(ff * recurrent_frac)
            ff -= rec
        p, q = _unique_pairs(rng, int(layer[h]), int(layer[h + 1]), ff,
                             skew, int(offs[h]), int(offs[h + 1]))
        pres.append(p)
        posts.append(q)
        if rec:
            p, q = _unique_pairs(rng, int(layer[h + 1]), int(layer[h + 1]),
                                 rec, skew, int(offs[h + 1]),
                                 int(offs[h + 1]))
            pres.append(p)
            posts.append(q)
    pre = np.concatenate(pres).astype(np.int32)
    post = np.concatenate(posts).astype(np.int32)

    w = np.zeros(len(pre), np.int32)
    while (w == 0).any():
        m = w == 0
        w[m] = rng.integers(weight_lo, weight_hi + 1, m.sum())
    g = SNNGraph(n_inputs, int(offs[-1]), pre, post, w,
                 lif or LIFIntParams(leak_shift=2, v_threshold=15,
                                     v_reset=0),
                 output_slice=(int(offs[-2]), int(offs[-1])))
    g.validate()
    return g


def scale_hw(g: SNNGraph, *, n_chips: int = 1, spus_per_chip: int = 16,
             concentration: int = 3, weight_bits: int = 6,
             headroom: float = 1.3, mesh_x: int = 0,
             mesh_y: int = 0) -> HardwareConfig:
    """A feasibility-plausible HardwareConfig for a synthetic graph: the
    Eq. (9) depth is the balanced per-SPU usage estimate × headroom.

    ``mesh_x``/``mesh_y`` pin the 2D inter-chip mesh (DESIGN.md §12);
    the (0, 0) default keeps the near-square auto factorization.
    """
    m = n_chips * spus_per_chip
    nw = len(np.unique(g.weight))
    per_spu = (-(-g.n_internal // m) + -(-(nw + 1) // concentration))
    return HardwareConfig(
        n_spus=m, unified_mem_depth=int(np.ceil(per_spu * headroom)),
        concentration=concentration, weight_bits=weight_bits,
        potential_bits=18, max_neurons=g.n_neurons,
        max_post_neurons=g.n_internal, n_chips=n_chips,
        mesh_x=mesh_x, mesh_y=mesh_y)
