"""SupraSNN memory model: Unified-Memory constraint Eq. (9), SPU score
Eq. (10), and the total-memory expression Eq. (11).

Multi-chip (DESIGN.md §11): a :class:`HardwareConfig` may describe
``n_chips`` virtual XC7Z-class devices. ``n_spus`` stays the TOTAL
partition count (the flattened virtual tree every mapper/scheduler/
executor already works on); the chips merely group consecutive SPU ids
— chip of SPU ``i`` is ``i // spus_per_chip``. The memory expressions
become per-chip structures replicated ``n_chips`` times and the cycle
model charges ``inter_chip_hop_cycles`` per forwarded spike packet;
with ``n_chips=1`` every number is bit-identical to the single-chip
model (tests/test_multilevel.py pins the conservation).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """Per-design hardware parameters (paper Table 2 'Hardware' block)."""
    n_spus: int = 16                 # M (power of two; tree fabric)
    unified_mem_depth: int = 128     # L   (memory lines per SPU)
    concentration: int = 3           # K   (weights packed per line)
    weight_bits: int = 4             # W_W
    potential_bits: int = 5
    max_neurons: int = 910           # N   (addressing capacity)
    max_post_neurons: int = 126      # N_p (Neuron State SRAM depth)
    clock_mhz: float = 100.0
    # multi-chip dimension (DESIGN.md §11): n_spus is the TOTAL SPU count
    # across n_chips devices; chips group consecutive SPU ids
    n_chips: int = 1
    inter_chip_hop_cycles: int = 8   # per inter-chip mesh hop of a packet
    # 2D-mesh NoC dims (DESIGN.md §12): the chips sit on a mesh_x × mesh_y
    # grid with XY (dimension-ordered) routing; chip c is at column
    # ``c % mesh_x``, row ``c // mesh_x``. ``0`` = auto near-square grid
    # (16 chips -> 4x4, 8 -> 4x2, 2 -> 2x1). The 1D-chain model of §11 is
    # the ``mesh_y=1`` degenerate case.
    mesh_x: int = 0
    mesh_y: int = 0

    def __post_init__(self):
        assert self.n_spus >= 2 and (self.n_spus & (self.n_spus - 1)) == 0, \
            "MC/ME trees require a power-of-two SPU count"
        assert self.n_chips >= 1 and \
            (self.n_chips & (self.n_chips - 1)) == 0, \
            "n_chips must be a power of two (chip fabric mirrors the tree)"
        assert self.n_spus % self.n_chips == 0 and \
            self.n_spus // self.n_chips >= 2, \
            "each chip needs its own power-of-two MC/ME subtree (>= 2 SPUs)"
        assert (self.mesh_x == 0) == (self.mesh_y == 0), \
            "give both mesh dims or neither (0, 0 = auto near-square)"
        if self.mesh_x:
            assert self.mesh_x * self.mesh_y == self.n_chips, \
                f"mesh {self.mesh_x}x{self.mesh_y} != n_chips={self.n_chips}"

    @property
    def tree_depth(self) -> int:
        return int(math.log2(self.n_spus))

    @property
    def spus_per_chip(self) -> int:
        return self.n_spus // self.n_chips

    @property
    def mesh_dims(self) -> tuple[int, int]:
        """(mesh_x, mesh_y) with the auto near-square default resolved."""
        if self.mesh_x:
            return self.mesh_x, self.mesh_y
        b = int(math.log2(self.n_chips))
        x = 1 << ((b + 1) // 2)
        return x, self.n_chips // x

    def chip_of(self, spu):
        """Chip id of an SPU id (scalar or array)."""
        return spu // self.spus_per_chip

    def chip_coords(self, chip):
        """(col, row) mesh coordinates of a chip id (scalar or array)."""
        x, _ = self.mesh_dims
        return chip % x, chip // x

    def chip_hops(self, a, b):
        """XY-routing hop count between chips ``a`` and ``b`` (Manhattan
        distance on the mesh; scalar or array)."""
        ax, ay = self.chip_coords(a)
        bx, by = self.chip_coords(b)
        return np.abs(ax - bx) + np.abs(ay - by)


def spu_usage(n_unique_weights: int, n_posts: int, k: int) -> int:
    """Memory lines used by one SPU: ceil((|Q|+1)/K) + |P| (LHS of Eq. 9)."""
    return math.ceil((n_unique_weights + 1) / k) + n_posts


def spu_score(n_unique_weights: int, n_posts: int, hw: HardwareConfig) -> int:
    """Eq. (10): L - (ceil((|Q|+1)/K) + |P|). Negative => violation."""
    return hw.unified_mem_depth - spu_usage(n_unique_weights, n_posts,
                                            hw.concentration)


def scores_from_assignment(weights: np.ndarray, posts: np.ndarray,
                           assign: np.ndarray, hw: HardwareConfig
                           ) -> np.ndarray:
    """Vectorized per-SPU scores for a synapse->SPU assignment.

    weights/posts: [E] synapse attributes; assign: [E] SPU ids.
    """
    m = hw.n_spus
    uq = np.zeros(m, np.int64)
    up = np.zeros(m, np.int64)
    # unique (spu, weight) and (spu, post) pairs; factorizing the values
    # first keeps the keys dense and makes empty SPUs (and an empty graph)
    # well-defined — no min/max of the full value array
    for arr, out in ((weights, uq), (posts, up)):
        vals, inv = np.unique(arr, return_inverse=True)
        if not len(vals):
            continue
        pairs = np.unique(assign.astype(np.int64) * len(vals) + inv)
        np.add.at(out, pairs // len(vals), 1)
    return (hw.unified_mem_depth
            - (-(-(uq + 1) // hw.concentration) + up))


def usage_from_assignment(weights: np.ndarray, posts: np.ndarray,
                          assign: np.ndarray, hw: HardwareConfig
                          ) -> np.ndarray:
    """Vectorized per-SPU memory-line usage (LHS of Eq. 9) for a
    synapse->SPU assignment; ``scores_from_assignment`` is
    ``unified_mem_depth - usage`` elementwise."""
    return hw.unified_mem_depth - scores_from_assignment(weights, posts,
                                                         assign, hw)


def total_memory_bits(hw: HardwareConfig, op_table_depth: int) -> int:
    """Eq. (11): routing + M*(OT + UM + Spike Memory) + Neuron State SRAM.

    Every SPU holds an N-bit Spike Memory bitmap (one bit per
    addressable neuron, set by the MC tree and cleared on Pre-End);
    :func:`bram_count` has always packed it as a physical structure
    (``m * ceil(n / 18Kb)`` halves), so it belongs in the bit total too
    — the two models must agree about what memory exists
    (tests/test_scheduling.py pins both against the Table 2 point).

    With ``n_chips > 1`` the expression is the per-chip structure set
    (routing over the chip's own SPUs, one Neuron Unit per chip — every
    chip must address every neuron, so routing/spike bitmaps span the
    full N) replicated ``n_chips`` times; at ``n_chips=1`` it reduces
    bit-identically to the single-chip Eq. (11).
    """
    n, np_ = hw.max_neurons, hw.max_post_neurons
    m_chip = hw.spus_per_chip                # SPUs per device
    s_um, k, ww = hw.unified_mem_depth, hw.concentration, hw.weight_bits
    lg = lambda x: math.ceil(math.log2(max(x, 2)))
    ot_entry = 2 * lg(s_um) + lg(k) + lg(n) + 2
    routing = n * m_chip
    ot = op_table_depth * ot_entry
    um = k * ww * s_um
    spike = n                                # per-SPU Spike Memory bitmap
    nu = np_ * (lg(n) + k * ww - lg(np_) + 1)
    per_chip = routing + m_chip * (ot + um + spike) + nu
    return hw.n_chips * per_chip


def total_memory_kb(hw: HardwareConfig, op_table_depth: int) -> float:
    return total_memory_bits(hw, op_table_depth) / 8 / 1024


def bram_count(hw: HardwareConfig, op_table_depth: int,
               bram_kbits: int = 18) -> float:
    """Simple 7-series packing model: each physical memory structure rounds
    up to half-BRAM (18 Kb) granularity, reported in units of 36 Kb BRAMs.

    With ``n_chips > 1`` the packing is done per chip (each device owns
    its routing table, OT/UM/spike structures for its own SPUs, and a
    Neuron Unit) and summed; bit-identical to the single-chip packing
    at ``n_chips=1``.
    """
    n, np_ = hw.max_neurons, hw.max_post_neurons
    m_chip = hw.spus_per_chip
    s_um, k, ww = hw.unified_mem_depth, hw.concentration, hw.weight_bits
    lg = lambda x: math.ceil(math.log2(max(x, 2)))
    ot_entry = 2 * lg(s_um) + lg(k) + lg(n) + 2
    halves = 0
    halves += math.ceil(n * m_chip / (bram_kbits * 1024))            # routing
    halves += m_chip * math.ceil(op_table_depth * ot_entry
                                 / (bram_kbits * 1024))
    halves += m_chip * math.ceil(k * ww * s_um / (bram_kbits * 1024))  # UM
    halves += m_chip * math.ceil(n / (bram_kbits * 1024))          # spike mem
    halves += math.ceil(np_ * (lg(n) + k * ww - lg(np_) + 1)
                        / (bram_kbits * 1024))                     # NU state
    return hw.n_chips * halves / 2.0
