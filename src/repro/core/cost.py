"""FPGA resource model (LUT/FF/BRAM) with constants fitted to the two
implementation points of paper Table 2 (XC7Z020/MNIST and XC7Z030/SHD).

LUT/FF scale with SPU count x datapath width (Fig. 12a: logic is set by
architectural parameters, not by network density); BRAM comes from the
memory model (Eq. 11) with half-BRAM packing granularity.
"""
from __future__ import annotations

import dataclasses

from repro.core.memory_model import HardwareConfig, bram_count, total_memory_kb


@dataclasses.dataclass(frozen=True)
class ResourceModel:
    lut_fixed: float = 800.0     # trees + injector + handler + NU control
    lut_per_spu: float = 72.56
    lut_per_spu_bit: float = 7.855
    ff_fixed: float = 800.0
    ff_per_spu: float = 68.47
    ff_per_spu_bit: float = 8.03

    def luts(self, hw: HardwareConfig) -> int:
        bits = hw.weight_bits + hw.potential_bits
        return int(self.lut_fixed
                   + hw.n_spus * (self.lut_per_spu + bits * self.lut_per_spu_bit))

    def ffs(self, hw: HardwareConfig) -> int:
        bits = hw.weight_bits + hw.potential_bits
        return int(self.ff_fixed
                   + hw.n_spus * (self.ff_per_spu + bits * self.ff_per_spu_bit))


@dataclasses.dataclass
class ResourceReport:
    luts: int
    ffs: int
    brams: float
    memory_kb: float


def resources(hw: HardwareConfig, ot_depth: int,
              model: ResourceModel | None = None) -> ResourceReport:
    model = model or ResourceModel()
    return ResourceReport(
        luts=model.luts(hw), ffs=model.ffs(hw),
        brams=bram_count(hw, ot_depth),
        memory_kb=total_memory_kb(hw, ot_depth))
