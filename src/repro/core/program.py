"""The compiled SupraSNN deployment artifact.

:func:`compile` runs the explicit pass pipeline of
:mod:`repro.core.passes` (partition -> schedule -> validate -> lower)
and returns a :class:`Program`: ONE object owning the graph, the
scheduled :class:`~repro.core.schedule.OpTables`, the dense
:class:`~repro.core.schedule.LoweredProgram`, the
:class:`~repro.core.passes.CompileReport`, and the
:class:`~repro.core.partition.PartitionResult`. Everything the rest of
the repo needs hangs off that artifact:

* ``program.run(ext, spec)`` — uniform ``[T, n_inputs]`` /
  ``[B, T, n_inputs]`` input shapes and a uniform
  ``(spikes, v_final, stats)`` return across all executors; ``spec``
  is an :class:`~repro.core.execution.ExecutionSpec` (or an
  engine-name string ``"jax"|"python"|"oracle"``) naming engine,
  kernel tier, interpret mode, mesh, and donation in ONE value. The
  pre-spec kwargs (``engine=, nu_kernel=, interpret=, sharded=,
  mesh=``) survive as deprecated delegating shims;
* ``program.profile(stats)`` — CycleModel latency + energy and the
  FPGA resource report in one :class:`ProfileReport`;
* ``program.init_packets()`` — the MC-tree configuration stream;
* ``program.save(path)`` / ``Program.load(path)`` — a version-stamped
  npz artifact (JSON header + dense arrays) that round-trips
  bit-exactly, so serving processes NEVER re-run the stochastic
  partitioner.

JAX engines are owned, lazily-built members of the artifact, keyed on
the **resolved** :class:`~repro.core.execution.ExecutionSpec` — there
is no module-level engine cache (the old ``id()``-keyed one could
alias recycled ids and duplicated engines for ``interpret=None`` vs
its resolved value). ``program.precompile(buckets, T)`` AOT-compiles
the serving shapes and enables the persistent XLA cache
(:mod:`repro.core.aot`), so loaded artifacts serve their first
request without paying XLA.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.core.cost import ResourceReport
from repro.core.engine import (CycleModel, CycleReport, PowerModel,
                               oracle_packet_counts, packet_stats,
                               run_mapped, run_oracle)
from repro.core.engine_jax import JaxMappedEngine
from repro.core.execution import (AUTO_MESH, ENGINES, ExecutionSpec, as_spec,
                                  spec_from_legacy_kwargs)
from repro.core.graph import SNNGraph, from_quantized
from repro.core.memory_model import HardwareConfig
from repro.core.mapping.search import SearchConfig, SearchTrace
from repro.core.partition import PartitionResult
from repro.core.passes import (CompileReport, build_report,
                               initialization_packets, lower_pass,
                               partition_pass, schedule_pass, search_pass,
                               validate_pass)
from repro.core.profiling import current_profiler, phase, profiled
from repro.core.scheduling import LoweredProgram, OpTables
from repro.snn.quantize import QuantizedSNN

PROGRAM_FORMAT = "suprasnn-program"
PROGRAM_FORMAT_VERSION = 1
# HardwareConfig fields added after format v1 shipped; serialized only at
# non-default values (so old artifacts and new single-chip ones share the
# same header schema, and v1 readers never see them)
_POST_V1_HW_FIELDS = frozenset({"n_chips", "inter_chip_hop_cycles",
                                "mesh_x", "mesh_y"})


@dataclasses.dataclass
class ProfileReport:
    """One-call profile of a run: timing/energy + hardware resources.

    ``per_sample`` holds one :class:`CycleReport` per batch sample;
    ``cycle`` aggregates them (mean over the batch; equal to
    ``per_sample[0]`` for unbatched runs). The scalar properties
    delegate to the aggregate.
    """
    cycle: CycleReport
    resources: ResourceReport
    per_sample: list[CycleReport]

    @property
    def latency_us(self) -> float:
        return self.cycle.latency_us

    @property
    def power_w(self) -> float:
        return self.cycle.power_w

    @property
    def energy_mj(self) -> float:
        return self.cycle.energy_mj

    @property
    def energy_per_synapse_nj(self) -> float:
        return self.cycle.energy_per_synapse_nj


def _aggregate_cycles(reports: list[CycleReport]) -> CycleReport:
    if len(reports) == 1:
        return reports[0]

    def mean(f):
        return float(np.mean([getattr(r, f) for r in reports]))

    return CycleReport(
        cycles_total=int(round(mean("cycles_total"))),
        cycles_distribution=int(round(mean("cycles_distribution"))),
        cycles_synaptic=int(round(mean("cycles_synaptic"))),
        cycles_overhead=int(round(mean("cycles_overhead"))),
        latency_us=mean("latency_us"), power_w=reports[0].power_w,
        energy_mj=mean("energy_mj"),
        energy_per_synapse_nj=mean("energy_per_synapse_nj"))


@dataclasses.dataclass
class Program:
    """A compiled, runnable, persistable SupraSNN deployment artifact."""
    graph: SNNGraph
    hw: HardwareConfig
    tables: OpTables
    lowered: LoweredProgram
    report: CompileReport
    part: PartitionResult
    default_engine: str = "jax"
    _engines: dict = dataclasses.field(default_factory=dict, repr=False,
                                       compare=False)

    # -- summary properties -------------------------------------------------

    @property
    def feasible(self) -> bool:
        return self.report.feasible

    @property
    def ot_depth(self) -> int:
        return self.tables.depth

    @property
    def n_inputs(self) -> int:
        return self.graph.n_inputs

    @property
    def n_synapses(self) -> int:
        return self.graph.n_synapses

    # -- engines ------------------------------------------------------------

    def engine(self, spec: ExecutionSpec | None = None, *,
               nu_kernel: bool | None = None,
               interpret: bool | None = None) -> JaxMappedEngine:
        """The owned compiled single-device executor for ``spec``.

        The spec is resolved (platform defaults folded in) BEFORE
        keying, so an explicit value and the default it resolves to
        share one engine. Engines build lazily from the
        already-lowered program and live as long as the artifact.
        ``nu_kernel=``/``interpret=`` are the deprecated pre-spec
        kwargs.
        """
        if nu_kernel is not None or interpret is not None:
            if spec is not None:
                raise TypeError("pass spec= OR the deprecated nu_kernel=/"
                                "interpret= kwargs, not both")
            spec = spec_from_legacy_kwargs(
                nu_kernel=nu_kernel, interpret=interpret,
                where="Program.engine", stacklevel=3)
        spec = as_spec(spec).resolve().single_device()
        if spec.engine != "jax":
            raise ValueError(f"Program.engine builds the jax engine; got "
                             f"engine={spec.engine!r}")
        eng = self._engines.get(spec)
        if eng is None:
            eng = JaxMappedEngine(self.graph, self.lowered, spec)
            self._engines[spec] = eng
        return eng

    def sharded_runner(self, spec=None, *, nu_kernel: bool | None = None,
                       interpret: bool | None = None):
        """The owned multi-device runner for ``spec``.

        ``spec`` may be an :class:`ExecutionSpec` (``mesh=None`` means
        the default serving mesh here), a bare jax ``Mesh``, or
        ``None`` (default mesh). Wraps the owned engine in
        ``shard_map`` — see :mod:`repro.serve.sharded`. Runners are
        cached like engines: same resolved spec -> same object.
        ``nu_kernel=``/``interpret=`` are the deprecated pre-spec
        kwargs.
        """
        from repro.serve.sharded import ShardedRunner
        mesh = None
        if spec is not None and not isinstance(spec, ExecutionSpec):
            mesh, spec = spec, None         # bare-Mesh convenience form
        if nu_kernel is not None or interpret is not None:
            if spec is not None:
                raise TypeError("pass spec= OR the deprecated nu_kernel=/"
                                "interpret= kwargs, not both")
            spec = spec_from_legacy_kwargs(
                sharded=True, mesh=mesh, nu_kernel=nu_kernel,
                interpret=interpret, where="Program.sharded_runner",
                stacklevel=3)
        elif spec is None:
            spec = ExecutionSpec(mesh=mesh if mesh is not None else AUTO_MESH)
        if spec.mesh is None:
            spec = dataclasses.replace(spec, mesh=AUTO_MESH)
        spec = spec.resolve()
        runner = self._engines.get(spec)
        if runner is None:
            runner = ShardedRunner(self, spec=spec)
            self._engines[spec] = runner
        return runner

    # -- AOT ----------------------------------------------------------------

    def precompile(self, batch_sizes, timesteps: int,
                   spec: ExecutionSpec | None = None) -> list:
        """AOT-compile the jax engine for every serving shape NOW.

        ``batch_sizes`` is a :class:`~repro.serve.batcher.BatchPolicy`
        or an iterable of batch sizes (the padded buckets serving can
        dispatch); ``timesteps`` fixes the T axis. Also enables the
        persistent XLA cache (:mod:`repro.core.aot`), so restarted
        processes reuse these compilations from disk. Returns the
        shapes compiled by this call; idempotent per engine.
        """
        from repro.core.aot import enable_persistent_cache, normalize_buckets
        enable_persistent_cache()
        spec = as_spec(spec).resolve()
        if spec.engine != "jax":
            raise ValueError(f"precompile targets the jax engine; got "
                             f"engine={spec.engine!r}")
        target = (self.sharded_runner(spec) if spec.sharded
                  else self.engine(spec))
        return target.precompile(normalize_buckets(batch_sizes), timesteps)

    def content_hash(self) -> str:
        """SHA-256 over the lowered program + LIF params — the stable
        identity of the compiled computation (:mod:`repro.core.aot`)."""
        from repro.core.aot import content_hash
        return content_hash(self)

    # -- execution ----------------------------------------------------------

    def run(self, ext_spikes: np.ndarray,
            spec: "ExecutionSpec | str | None" = None, *,
            engine: str | None = None, nu_kernel: bool | None = None,
            interpret: bool | None = None, sharded: bool | None = None,
            mesh=None) -> tuple[np.ndarray, np.ndarray, dict]:
        """Execute the program on a spike train (batch).

        ext_spikes: binary ``[T, n_inputs]`` or ``[B, T, n_inputs]``.
        spec: an :class:`~repro.core.execution.ExecutionSpec`, an
        engine-name string (``"jax"`` compiled batched, ``"python"``
        per-op reference executor, ``"oracle"`` dense integer LIF), or
        ``None`` for ``self.default_engine``. All engines and kernel
        tiers return ``(spikes, v_final, stats)`` with matching shapes
        — ``[T, n_internal]`` / ``[n_internal]`` / packet_counts
        ``[T]``, batched with a leading ``B`` — and identical bits.

        ``ExecutionSpec(mesh=...)`` data-parallelizes the batch axis
        over a jax mesh through the owned
        :class:`~repro.serve.sharded.ShardedRunner` — jax engine only,
        outputs bit-exact vs the single-device run (ragged batches
        pad-and-mask; tiny batches fall back to one device).

        ``engine=/nu_kernel=/interpret=/sharded=/mesh=`` are the
        deprecated pre-spec kwargs and delegate with a
        ``DeprecationWarning`` (see README, 'Migration to
        ExecutionSpec').
        """
        if (engine is not None or nu_kernel is not None
                or interpret is not None or sharded is not None
                or mesh is not None):
            if spec is not None:
                raise TypeError("pass spec OR the deprecated engine=/"
                                "nu_kernel=/interpret=/sharded=/mesh= "
                                "kwargs, not both")
            spec = spec_from_legacy_kwargs(
                engine=engine, nu_kernel=nu_kernel, interpret=interpret,
                sharded=sharded, mesh=mesh,
                default_engine=self.default_engine)
        spec = as_spec(spec, self.default_engine)
        if spec.engine == "jax":
            if spec.mesh is not None:
                return self.sharded_runner(spec).run(ext_spikes)
            return self.engine(spec).run(ext_spikes)

        ext = np.asarray(ext_spikes)
        squeeze = ext.ndim == 2
        if squeeze:
            ext = ext[None]
        if ext.ndim != 3 or ext.shape[2] != self.graph.n_inputs:
            raise ValueError(f"ext_spikes shape {np.shape(ext_spikes)} != "
                             f"[B, T, {self.graph.n_inputs}] or "
                             f"[T, {self.graph.n_inputs}]")

        spikes, vs, pkts = [], [], []
        for b in range(ext.shape[0]):
            e = ext[b].astype(np.int32)
            if spec.engine == "python":
                s, v, st = run_mapped(self.graph, self.tables, e,
                                      routing=self.lowered.routing)
                p = st["packet_counts"]
            else:
                s, v = run_oracle(self.graph, e)
                p = oracle_packet_counts(e, s)
            spikes.append(s)
            vs.append(v)
            pkts.append(p)
        s_all = np.stack(spikes)
        v_all = np.stack(vs)
        p_all = np.stack(pkts)
        if squeeze:
            s_all, v_all, p_all = s_all[0], v_all[0], p_all[0]
        return s_all, v_all, packet_stats(p_all)

    # -- profiling ----------------------------------------------------------

    def profile(self, stats: dict | np.ndarray, *,
                n_synapses: int | None = None,
                power: PowerModel | None = None,
                inter_chip_counts: np.ndarray | None = None
                ) -> ProfileReport:
        """CycleModel timing/energy + resource report in one call.

        ``stats`` is the dict returned by :meth:`run` (or a raw
        packet-counts array, ``[T]`` or ``[B, T]``). ``n_synapses``
        overrides the energy-per-synapse denominator (e.g. the
        pre-pruning synapse count of a quantized model); defaults to
        the mapped graph's nonzero synapses. On a multi-chip target
        pass ``inter_chip_counts`` (same shape as the packet counts;
        see :meth:`inter_chip_counts`) to charge the forwarded packets
        their hop cost — omitted, the profile is the single-chip model.
        """
        pkts = stats["packet_counts"] if isinstance(stats, dict) else stats
        pkts = np.atleast_2d(np.asarray(pkts))
        if inter_chip_counts is None:
            ics = [None] * pkts.shape[0]
        else:
            ic = np.atleast_2d(np.asarray(inter_chip_counts))
            if ic.shape != pkts.shape:
                raise ValueError(f"inter_chip_counts shape {ic.shape} != "
                                 f"packet_counts shape {pkts.shape}")
            ics = list(ic)
        n_syn = self.graph.n_synapses if n_synapses is None else n_synapses
        cm = CycleModel(self.hw, power)
        per = [cm.run(row, self.tables.depth, n_syn, inter_chip_counts=i)
               for row, i in zip(pkts, ics)]
        return ProfileReport(cycle=_aggregate_cycles(per),
                             resources=self.report.resources,
                             per_sample=per)

    # -- multi-chip accounting (DESIGN.md §11) --------------------------------

    def chip_span(self) -> np.ndarray:
        """[n_neurons] distinct chips each neuron's fan-out spans under
        this program's mapping (all-ones/zeros on a single-chip hw)."""
        from repro.core.mapping.hypergraph import chip_span
        return chip_span(self.graph, self.tables.assign, self.hw)

    def mesh_hops(self) -> np.ndarray:
        """[n_neurons] 2D-mesh hop cost of each neuron's multicast under
        this program's mapping (DESIGN.md §12; all zeros on a
        single-chip hw)."""
        from repro.core.mapping.hypergraph import mesh_hops
        return mesh_hops(self.graph, self.tables.assign, self.hw)

    def inter_chip_counts(self, ext_spikes: np.ndarray,
                          spikes: np.ndarray) -> np.ndarray:
        """Per-timestep inter-chip MESH HOPS of a run — the companion of
        the ``packet_counts`` stat, for :meth:`profile`'s
        ``inter_chip_counts=``. Each firing neuron charges the XY-mesh
        bounding-box hop count of its multicast (:meth:`mesh_hops`), so
        the cycle model's ``inter_chip_hop_cycles`` term scales with
        actual mesh distance (DESIGN.md §12; on a two-chip chain this
        is exactly the §11 ``span - 1`` forward count). ``ext_spikes``
        and ``spikes`` are the run's input and output spike trains
        (``[T, n]`` or ``[B, T, n]``). All zeros when ``n_chips == 1``.
        """
        from repro.core.mapping.hypergraph import inter_chip_hop_counts
        return inter_chip_hop_counts(ext_spikes, spikes, self.mesh_hops())

    # -- static verification (DESIGN.md §13) ----------------------------------

    def verify(self, checkers: "list[str] | None" = None):
        """Statically verify the artifact WITHOUT executing any engine.

        Runs the registered analysis checkers of
        :mod:`repro.analysis` — schedule hazards, integer range
        analysis, Eq. 9/11 memory audit — and returns their
        :class:`~repro.analysis.diagnostics.VerifyReport`
        (``report.ok`` iff no ERROR diagnostic). The CLI form is
        ``python -m repro.analysis.verify artifact.npz``.
        """
        from repro.analysis import verify as _verify
        return _verify(self, checkers=checkers)

    # -- initialization stream ----------------------------------------------

    def init_packets(self) -> list[tuple[int, int]]:
        """The MC-tree (ctrl, payload) configuration stream (§4.3)."""
        return initialization_packets(self.graph, self.tables, self.hw,
                                      routing=self.lowered.routing)

    # -- persistence --------------------------------------------------------

    def save(self, path: str | Path) -> Path:
        """Persist the artifact as npz (JSON header + dense arrays).

        Returns the actual file path (``.npz`` appended if missing).
        ``Program.load(path)`` round-trips bit-exactly — the lowered
        program is re-derived deterministically; the partitioner is
        NOT re-run.
        """
        path = Path(path)
        if path.suffix != ".npz":
            path = path.with_name(path.name + ".npz")
        g, hw, rep, part = self.graph, self.hw, self.report, self.part
        res = rep.resources
        header = {
            "format": PROGRAM_FORMAT,
            "version": PROGRAM_FORMAT_VERSION,
            "default_engine": self.default_engine,
            "graph": {
                "n_inputs": int(g.n_inputs),
                "n_neurons": int(g.n_neurons),
                "output_slice": [int(g.output_slice[0]),
                                 int(g.output_slice[1])],
                "lif": {"leak_shift": int(g.lif.leak_shift),
                        "v_threshold": int(g.lif.v_threshold),
                        "v_reset": int(g.lif.v_reset)},
            },
            # post-v1 HardwareConfig fields are elided at their defaults so
            # single-chip artifacts keep the exact v1 header bytes
            # (tests/test_serving.py golden roundtrip); Program.load fills
            # absent keys from the dataclass defaults
            "hw": {f.name: getattr(hw, f.name)
                   for f in dataclasses.fields(hw)
                   if f.name not in _POST_V1_HW_FIELDS
                   or getattr(hw, f.name) != f.default},
            "report": {
                "method": rep.method,
                "feasible": bool(rep.feasible),
                "iterations": int(rep.iterations),
                "perturbations": int(rep.perturbations),
                "ot_depth": int(rep.ot_depth),
                "n_init_packets": int(rep.n_init_packets),
                "compile_seconds": float(rep.compile_seconds),
                "resources": {"luts": int(res.luts), "ffs": int(res.ffs),
                              "brams": float(res.brams),
                              "memory_kb": float(res.memory_kb)},
                "search": rep.search.to_json() if rep.search else None,
                "candidates_tried": int(rep.candidates_tried),
                "schedule_method": rep.schedule_method,
                "schedule_depths": ({k: int(v) for k, v
                                     in rep.schedule_depths.items()}
                                    if rep.schedule_depths else None),
                # phase profile keys are elided when absent so pre-§12
                # artifacts keep their exact v1 header (golden roundtrip)
                **({"phase_seconds": {k: float(v) for k, v
                                      in rep.phase_seconds.items()}}
                   if rep.phase_seconds else {}),
                **({"phase_alloc_mb": {k: float(v) for k, v
                                       in rep.phase_alloc_mb.items()}}
                   if rep.phase_alloc_mb else {}),
            },
            "part": {
                "feasible": bool(part.feasible),
                "iterations": int(part.iterations),
                "perturbations": int(part.perturbations),
            },
        }
        np.savez_compressed(
            path,
            header=np.asarray(json.dumps(header)),
            g_pre=g.pre, g_post=g.post, g_weight=g.weight,
            t_pre=self.tables.pre, t_post=self.tables.post,
            t_weight=self.tables.weight, t_pre_end=self.tables.pre_end,
            t_post_end=self.tables.post_end, t_assign=self.tables.assign,
            part_assign=part.assign, part_scores=part.scores,
            part_history=np.asarray(part.score_history, np.float64),
            rep_scores=rep.scores,
            rep_spu_synapse_counts=rep.spu_synapse_counts,
            rep_spu_post_counts=rep.spu_post_counts,
            rep_spu_weight_counts=rep.spu_weight_counts)
        return path

    @classmethod
    def load(cls, path: str | Path, *, precompile=None,
             timesteps: int | None = None,
             spec: ExecutionSpec | None = None) -> "Program":
        """Load a saved artifact; rejects unknown formats/versions.

        ``precompile=`` (a :class:`~repro.serve.batcher.BatchPolicy`
        or iterable of batch buckets, with ``timesteps=`` fixing the T
        axis) AOT-compiles the jax engine for every serving shape at
        load time — see :meth:`precompile` — so the artifact is warm
        before its first request.
        """
        with np.load(path) as z:
            if "header" not in z.files:
                raise ValueError(f"{path}: not a {PROGRAM_FORMAT} artifact")
            header = json.loads(str(z["header"][()]))
            if header.get("format") != PROGRAM_FORMAT:
                raise ValueError(
                    f"{path}: format {header.get('format')!r} != "
                    f"{PROGRAM_FORMAT!r}")
            if header.get("version") != PROGRAM_FORMAT_VERSION:
                raise ValueError(
                    f"{path}: format version {header.get('version')} "
                    f"unsupported (have {PROGRAM_FORMAT_VERSION})")
            arrays = {k: z[k] for k in z.files if k != "header"}

        from repro.snn.lif import LIFIntParams
        gh = header["graph"]
        g = SNNGraph(
            n_inputs=gh["n_inputs"], n_neurons=gh["n_neurons"],
            pre=arrays["g_pre"], post=arrays["g_post"],
            weight=arrays["g_weight"],
            lif=LIFIntParams(**gh["lif"]),
            output_slice=tuple(gh["output_slice"]))
        hw = HardwareConfig(**header["hw"])
        tables = OpTables.from_dense(
            arrays["t_pre"], arrays["t_post"], arrays["t_weight"],
            arrays["t_pre_end"], arrays["t_post_end"], arrays["t_assign"])
        ph = header["part"]
        part = PartitionResult(
            assign=arrays["part_assign"], scores=arrays["part_scores"],
            feasible=ph["feasible"], iterations=ph["iterations"],
            perturbations=ph["perturbations"],
            score_history=arrays["part_history"].tolist())
        rh = header["report"]
        report = CompileReport(
            method=rh["method"], feasible=rh["feasible"],
            iterations=rh["iterations"], perturbations=rh["perturbations"],
            ot_depth=rh["ot_depth"], scores=arrays["rep_scores"],
            spu_synapse_counts=arrays["rep_spu_synapse_counts"],
            spu_post_counts=arrays["rep_spu_post_counts"],
            spu_weight_counts=arrays["rep_spu_weight_counts"],
            resources=ResourceReport(**rh["resources"]),
            n_init_packets=rh["n_init_packets"],
            compile_seconds=rh["compile_seconds"],
            search=(SearchTrace.from_json(rh["search"])
                    if rh.get("search") else None),
            candidates_tried=rh.get("candidates_tried", 1),
            schedule_method=rh.get("schedule_method", "slack"),
            schedule_depths=rh.get("schedule_depths"),
            phase_seconds=rh.get("phase_seconds"),
            phase_alloc_mb=rh.get("phase_alloc_mb"))
        # re-lower (pure, deterministic) — never re-partition
        lowered = lower_pass(g, tables)
        prog = cls(g, hw, tables, lowered, report, part,
                   default_engine=header.get("default_engine", "jax"))
        if precompile is not None:
            if timesteps is None:
                raise ValueError("Program.load(precompile=...) needs "
                                 "timesteps= to fix the T axis of the AOT "
                                 "shapes")
            prog.precompile(precompile, timesteps, spec)
        return prog


# ---------------------------------------------------------------------------
# The compile entry point.
# ---------------------------------------------------------------------------

def compile(g_or_qsnn: SNNGraph | QuantizedSNN, hw: HardwareConfig, *,
            method: str = "framework", engine: str = "jax", seed: int = 0,
            validate: bool = True, max_iters: int = 20000,
            restarts: int = 1, workers: int = 1,
            schedule_method: str = "slack",
            search: SearchConfig | None = None,
            n_chips: int | None = None,
            profile_phases: bool = True) -> Program:
    """Compile an SNN (graph or quantized model) into a :class:`Program`.

    Runs the explicit pipeline partition -> schedule -> [validate] ->
    lower (see :mod:`repro.core.passes`) and wraps every product in the
    artifact. ``engine`` picks the default executor of
    :meth:`Program.run`; ``method``/``seed``/``max_iters``/``restarts``/
    ``workers`` parameterize the partitioning pass, and
    ``schedule_method`` names the registered
    :class:`~repro.core.scheduling.ScheduleStrategy` ordering the post
    transmissions (``'slack'`` is the original scheduler).

    ``n_chips=N`` scales the target out to N virtual devices
    (DESIGN.md §11): ``hw`` describes ONE chip and is replicated —
    ``n_spus`` becomes ``hw.n_spus * N`` over the flattened virtual
    tree every pass already understands, and the memory/cycle models
    pick up the per-chip structures and inter-chip hop costs. The
    mapped program's chip traffic is exposed by
    :meth:`Program.chip_span` / :meth:`Program.inter_chip_counts`.

    Passing ``search=SearchConfig(...)`` replaces the single partition
    pass with the joint portfolio search (framework restarts raced
    against every baseline, each feasible mapping scheduled under every
    registered schedule strategy; best (mapping, strategy) pair by OT
    depth and memory wins). The per-candidate trace lands on
    ``program.report.search``, the winning strategy on
    ``program.report.schedule_method``, and both survive
    ``save``/``load``.

    ``profile_phases=True`` (the default) records a per-phase wall-time
    breakdown of the pipeline onto ``report.phase_seconds`` (DESIGN.md
    §12); wrap the call in ``profiled(PhaseProfiler(alloc=True))`` to
    also capture per-phase allocation on ``report.phase_alloc_mb``.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; use one of {ENGINES}")
    t0 = time.time()
    if n_chips is not None and n_chips != 1:
        if hw.n_chips != 1:
            raise ValueError(
                f"compile(n_chips={n_chips}) replicates a SINGLE-chip "
                f"HardwareConfig; hw already has n_chips={hw.n_chips}")
        hw = dataclasses.replace(hw, n_spus=hw.n_spus * n_chips,
                                 n_chips=n_chips)
    g = (from_quantized(g_or_qsnn) if isinstance(g_or_qsnn, QuantizedSNN)
         else g_or_qsnn)
    trace = None
    tables = None
    schedule_depths = None
    # phase profiler (DESIGN.md §12): reuse a caller-installed profiler
    # (``with profiled(PhaseProfiler(alloc=True)):``) so nested compiles
    # accumulate into it; otherwise install a wall-clock-only one unless
    # profiling is disabled.
    prof = current_profiler()
    ctx = (contextlib.nullcontext(prof)
           if (prof is not None or not profile_phases) else profiled())
    with ctx as prof:
        if search is not None:
            if (method, seed, max_iters, restarts, workers,
                    schedule_method) != \
                    ("framework", 0, 20000, 1, 1, "slack"):
                raise ValueError(
                    "search= runs the joint portfolio and takes its "
                    "parameters from the SearchConfig; pass "
                    "seed/max_iters/restarts/workers there instead of as "
                    "compile() arguments (the portfolio explores every "
                    "registered schedule strategy, so schedule_method= "
                    "does not apply)")
            with phase("partition"):
                part, trace, tables = search_pass(g, hw, search)
            method = "portfolio"
            if tables is not None:
                sel = trace.selected
                schedule_method = sel.schedule_method or "slack"
                schedule_depths = sel.schedule_depths
            else:
                schedule_method = "slack"  # infeasible winner: default
        else:
            with phase("partition"):
                part = partition_pass(g, hw, method=method, seed=seed,
                                      max_iters=max_iters,
                                      restarts=restarts, workers=workers)
        if tables is None:
            with phase("schedule"):
                tables = schedule_pass(g, part, hw, method=schedule_method)
        if validate:
            with phase("validate"):
                validate_pass(g, tables)
        with phase("lower"):
            lowered = lower_pass(g, tables)
        with phase("report"):
            report = build_report(g, hw, tables, part, method=method,
                                  compile_seconds=time.time() - t0,
                                  routing=lowered.routing, search=trace,
                                  schedule_method=schedule_method,
                                  schedule_depths=schedule_depths)
    if prof is not None:
        report.phase_seconds = {k: float(v) for k, v in prof.seconds.items()}
        if prof.alloc:
            report.phase_alloc_mb = {k: float(v)
                                     for k, v in prof.alloc_mb.items()}
    return Program(g, hw, tables, lowered, report, part,
                   default_engine=engine)
