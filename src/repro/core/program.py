"""The compiled SupraSNN deployment artifact.

:func:`compile` runs the explicit pass pipeline of
:mod:`repro.core.passes` (partition -> schedule -> validate -> lower)
and returns a :class:`Program`: ONE object owning the graph, the
scheduled :class:`~repro.core.schedule.OpTables`, the dense
:class:`~repro.core.schedule.LoweredProgram`, the
:class:`~repro.core.passes.CompileReport`, and the
:class:`~repro.core.partition.PartitionResult`. Everything the rest of
the repo needs hangs off that artifact:

* ``program.run(ext, engine="jax"|"python"|"oracle")`` — uniform
  ``[T, n_inputs]`` / ``[B, T, n_inputs]`` input shapes and a uniform
  ``(spikes, v_final, stats)`` return across all three executors;
* ``program.profile(stats)`` — CycleModel latency + energy and the
  FPGA resource report in one :class:`ProfileReport`;
* ``program.init_packets()`` — the MC-tree configuration stream;
* ``program.save(path)`` / ``Program.load(path)`` — a version-stamped
  npz artifact (JSON header + dense arrays) that round-trips
  bit-exactly, so serving processes NEVER re-run the stochastic
  partitioner.

JAX engines are owned, lazily-built members of the artifact, keyed on
their *resolved* build options — there is no module-level engine cache
(the old ``id()``-keyed one could alias recycled ids and duplicated
engines for ``interpret=None`` vs its resolved value).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.core.cost import ResourceReport
from repro.core.engine import (CycleModel, CycleReport, PowerModel,
                               oracle_packet_counts, packet_stats,
                               run_mapped, run_oracle)
from repro.core.engine_jax import JaxMappedEngine
from repro.core.graph import SNNGraph, from_quantized
from repro.core.memory_model import HardwareConfig
from repro.core.mapping.search import SearchConfig, SearchTrace
from repro.core.partition import PartitionResult
from repro.core.passes import (CompileReport, build_report,
                               initialization_packets, lower_pass,
                               partition_pass, schedule_pass, search_pass,
                               validate_pass)
from repro.core.scheduling import LoweredProgram, OpTables
from repro.kernels.ops import _default_interpret
from repro.snn.quantize import QuantizedSNN

PROGRAM_FORMAT = "suprasnn-program"
PROGRAM_FORMAT_VERSION = 1

ENGINES = ("jax", "python", "oracle")


@dataclasses.dataclass
class ProfileReport:
    """One-call profile of a run: timing/energy + hardware resources.

    ``per_sample`` holds one :class:`CycleReport` per batch sample;
    ``cycle`` aggregates them (mean over the batch; equal to
    ``per_sample[0]`` for unbatched runs). The scalar properties
    delegate to the aggregate.
    """
    cycle: CycleReport
    resources: ResourceReport
    per_sample: list[CycleReport]

    @property
    def latency_us(self) -> float:
        return self.cycle.latency_us

    @property
    def power_w(self) -> float:
        return self.cycle.power_w

    @property
    def energy_mj(self) -> float:
        return self.cycle.energy_mj

    @property
    def energy_per_synapse_nj(self) -> float:
        return self.cycle.energy_per_synapse_nj


def _aggregate_cycles(reports: list[CycleReport]) -> CycleReport:
    if len(reports) == 1:
        return reports[0]

    def mean(f):
        return float(np.mean([getattr(r, f) for r in reports]))

    return CycleReport(
        cycles_total=int(round(mean("cycles_total"))),
        cycles_distribution=int(round(mean("cycles_distribution"))),
        cycles_synaptic=int(round(mean("cycles_synaptic"))),
        cycles_overhead=int(round(mean("cycles_overhead"))),
        latency_us=mean("latency_us"), power_w=reports[0].power_w,
        energy_mj=mean("energy_mj"),
        energy_per_synapse_nj=mean("energy_per_synapse_nj"))


@dataclasses.dataclass
class Program:
    """A compiled, runnable, persistable SupraSNN deployment artifact."""
    graph: SNNGraph
    hw: HardwareConfig
    tables: OpTables
    lowered: LoweredProgram
    report: CompileReport
    part: PartitionResult
    default_engine: str = "jax"
    _engines: dict = dataclasses.field(default_factory=dict, repr=False,
                                       compare=False)

    # -- summary properties -------------------------------------------------

    @property
    def feasible(self) -> bool:
        return self.report.feasible

    @property
    def ot_depth(self) -> int:
        return self.tables.depth

    @property
    def n_inputs(self) -> int:
        return self.graph.n_inputs

    @property
    def n_synapses(self) -> int:
        return self.graph.n_synapses

    # -- engines ------------------------------------------------------------

    def engine(self, *, nu_kernel: bool = True,
               interpret: bool | None = None) -> JaxMappedEngine:
        """The owned compiled executor for these build options.

        ``interpret=None`` resolves to the platform default BEFORE
        keying, so explicit and default values share one engine.
        Engines build lazily from the already-lowered program and live
        as long as the artifact.
        """
        key = (bool(nu_kernel),
               _default_interpret() if interpret is None else bool(interpret))
        eng = self._engines.get(key)
        if eng is None:
            eng = JaxMappedEngine(self.graph, self.lowered,
                                  nu_kernel=key[0], interpret=key[1])
            self._engines[key] = eng
        return eng

    def sharded_runner(self, mesh=None, *, nu_kernel: bool = True,
                       interpret: bool | None = None):
        """The owned multi-device runner for these build options.

        Wraps the owned engine in ``shard_map`` over ``mesh`` (default:
        every device on the ``data`` axis) — see
        :mod:`repro.serve.sharded`. Runners are cached like engines:
        same (mesh, resolved build options) -> same object.
        """
        from repro.serve.sharded import ShardedRunner
        key = ("sharded", mesh, bool(nu_kernel),
               _default_interpret() if interpret is None else bool(interpret))
        runner = self._engines.get(key)
        if runner is None:
            runner = ShardedRunner(self, mesh, nu_kernel=nu_kernel,
                                   interpret=interpret)
            self._engines[key] = runner
        return runner

    # -- execution ----------------------------------------------------------

    def run(self, ext_spikes: np.ndarray, *, engine: str | None = None,
            nu_kernel: bool = True, interpret: bool | None = None,
            sharded: bool = False, mesh=None
            ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Execute the program on a spike train (batch).

        ext_spikes: binary ``[T, n_inputs]`` or ``[B, T, n_inputs]``.
        engine: ``"jax"`` (compiled batched), ``"python"`` (per-op
        reference executor), or ``"oracle"`` (dense integer LIF);
        defaults to ``self.default_engine``. All three return
        ``(spikes, v_final, stats)`` with matching shapes —
        ``[T, n_internal]`` / ``[n_internal]`` / packet_counts ``[T]``,
        batched with a leading ``B`` — and identical bits.

        ``sharded=True`` data-parallelizes the batch axis over a jax
        mesh (``mesh``, default every device on ``data``) through the
        owned :class:`~repro.serve.sharded.ShardedRunner` — jax engine
        only, outputs bit-exact vs the single-device run (ragged
        batches pad-and-mask).
        """
        engine = engine or ("jax" if sharded else self.default_engine)
        if sharded:
            if engine != "jax":
                raise ValueError(f"sharded=True runs the jax engine; got "
                                 f"engine={engine!r}")
            return self.sharded_runner(mesh, nu_kernel=nu_kernel,
                                       interpret=interpret).run(ext_spikes)
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; use one of "
                             f"{ENGINES}")
        ext = np.asarray(ext_spikes)
        squeeze = ext.ndim == 2
        if squeeze:
            ext = ext[None]
        if ext.ndim != 3 or ext.shape[2] != self.graph.n_inputs:
            raise ValueError(f"ext_spikes shape {np.shape(ext_spikes)} != "
                             f"[B, T, {self.graph.n_inputs}] or "
                             f"[T, {self.graph.n_inputs}]")

        if engine == "jax":
            return self.engine(nu_kernel=nu_kernel, interpret=interpret) \
                .run(ext_spikes)

        spikes, vs, pkts = [], [], []
        for b in range(ext.shape[0]):
            e = ext[b].astype(np.int32)
            if engine == "python":
                s, v, st = run_mapped(self.graph, self.tables, e,
                                      routing=self.lowered.routing)
                p = st["packet_counts"]
            else:
                s, v = run_oracle(self.graph, e)
                p = oracle_packet_counts(e, s)
            spikes.append(s)
            vs.append(v)
            pkts.append(p)
        s_all = np.stack(spikes)
        v_all = np.stack(vs)
        p_all = np.stack(pkts)
        if squeeze:
            s_all, v_all, p_all = s_all[0], v_all[0], p_all[0]
        return s_all, v_all, packet_stats(p_all)

    # -- profiling ----------------------------------------------------------

    def profile(self, stats: dict | np.ndarray, *,
                n_synapses: int | None = None,
                power: PowerModel | None = None) -> ProfileReport:
        """CycleModel timing/energy + resource report in one call.

        ``stats`` is the dict returned by :meth:`run` (or a raw
        packet-counts array, ``[T]`` or ``[B, T]``). ``n_synapses``
        overrides the energy-per-synapse denominator (e.g. the
        pre-pruning synapse count of a quantized model); defaults to
        the mapped graph's nonzero synapses.
        """
        pkts = stats["packet_counts"] if isinstance(stats, dict) else stats
        pkts = np.atleast_2d(np.asarray(pkts))
        n_syn = self.graph.n_synapses if n_synapses is None else n_synapses
        cm = CycleModel(self.hw, power)
        per = [cm.run(row, self.tables.depth, n_syn) for row in pkts]
        return ProfileReport(cycle=_aggregate_cycles(per),
                             resources=self.report.resources,
                             per_sample=per)

    # -- initialization stream ----------------------------------------------

    def init_packets(self) -> list[tuple[int, int]]:
        """The MC-tree (ctrl, payload) configuration stream (§4.3)."""
        return initialization_packets(self.graph, self.tables, self.hw,
                                      routing=self.lowered.routing)

    # -- persistence --------------------------------------------------------

    def save(self, path: str | Path) -> Path:
        """Persist the artifact as npz (JSON header + dense arrays).

        Returns the actual file path (``.npz`` appended if missing).
        ``Program.load(path)`` round-trips bit-exactly — the lowered
        program is re-derived deterministically; the partitioner is
        NOT re-run.
        """
        path = Path(path)
        if path.suffix != ".npz":
            path = path.with_name(path.name + ".npz")
        g, hw, rep, part = self.graph, self.hw, self.report, self.part
        res = rep.resources
        header = {
            "format": PROGRAM_FORMAT,
            "version": PROGRAM_FORMAT_VERSION,
            "default_engine": self.default_engine,
            "graph": {
                "n_inputs": int(g.n_inputs),
                "n_neurons": int(g.n_neurons),
                "output_slice": [int(g.output_slice[0]),
                                 int(g.output_slice[1])],
                "lif": {"leak_shift": int(g.lif.leak_shift),
                        "v_threshold": int(g.lif.v_threshold),
                        "v_reset": int(g.lif.v_reset)},
            },
            "hw": {f.name: getattr(hw, f.name)
                   for f in dataclasses.fields(hw)},
            "report": {
                "method": rep.method,
                "feasible": bool(rep.feasible),
                "iterations": int(rep.iterations),
                "perturbations": int(rep.perturbations),
                "ot_depth": int(rep.ot_depth),
                "n_init_packets": int(rep.n_init_packets),
                "compile_seconds": float(rep.compile_seconds),
                "resources": {"luts": int(res.luts), "ffs": int(res.ffs),
                              "brams": float(res.brams),
                              "memory_kb": float(res.memory_kb)},
                "search": rep.search.to_json() if rep.search else None,
                "candidates_tried": int(rep.candidates_tried),
                "schedule_method": rep.schedule_method,
                "schedule_depths": ({k: int(v) for k, v
                                     in rep.schedule_depths.items()}
                                    if rep.schedule_depths else None),
            },
            "part": {
                "feasible": bool(part.feasible),
                "iterations": int(part.iterations),
                "perturbations": int(part.perturbations),
            },
        }
        np.savez_compressed(
            path,
            header=np.asarray(json.dumps(header)),
            g_pre=g.pre, g_post=g.post, g_weight=g.weight,
            t_pre=self.tables.pre, t_post=self.tables.post,
            t_weight=self.tables.weight, t_pre_end=self.tables.pre_end,
            t_post_end=self.tables.post_end, t_assign=self.tables.assign,
            part_assign=part.assign, part_scores=part.scores,
            part_history=np.asarray(part.score_history, np.float64),
            rep_scores=rep.scores,
            rep_spu_synapse_counts=rep.spu_synapse_counts,
            rep_spu_post_counts=rep.spu_post_counts,
            rep_spu_weight_counts=rep.spu_weight_counts)
        return path

    @classmethod
    def load(cls, path: str | Path) -> "Program":
        """Load a saved artifact; rejects unknown formats/versions."""
        with np.load(path) as z:
            if "header" not in z.files:
                raise ValueError(f"{path}: not a {PROGRAM_FORMAT} artifact")
            header = json.loads(str(z["header"][()]))
            if header.get("format") != PROGRAM_FORMAT:
                raise ValueError(
                    f"{path}: format {header.get('format')!r} != "
                    f"{PROGRAM_FORMAT!r}")
            if header.get("version") != PROGRAM_FORMAT_VERSION:
                raise ValueError(
                    f"{path}: format version {header.get('version')} "
                    f"unsupported (have {PROGRAM_FORMAT_VERSION})")
            arrays = {k: z[k] for k in z.files if k != "header"}

        from repro.snn.lif import LIFIntParams
        gh = header["graph"]
        g = SNNGraph(
            n_inputs=gh["n_inputs"], n_neurons=gh["n_neurons"],
            pre=arrays["g_pre"], post=arrays["g_post"],
            weight=arrays["g_weight"],
            lif=LIFIntParams(**gh["lif"]),
            output_slice=tuple(gh["output_slice"]))
        hw = HardwareConfig(**header["hw"])
        tables = OpTables.from_dense(
            arrays["t_pre"], arrays["t_post"], arrays["t_weight"],
            arrays["t_pre_end"], arrays["t_post_end"], arrays["t_assign"])
        ph = header["part"]
        part = PartitionResult(
            assign=arrays["part_assign"], scores=arrays["part_scores"],
            feasible=ph["feasible"], iterations=ph["iterations"],
            perturbations=ph["perturbations"],
            score_history=arrays["part_history"].tolist())
        rh = header["report"]
        report = CompileReport(
            method=rh["method"], feasible=rh["feasible"],
            iterations=rh["iterations"], perturbations=rh["perturbations"],
            ot_depth=rh["ot_depth"], scores=arrays["rep_scores"],
            spu_synapse_counts=arrays["rep_spu_synapse_counts"],
            spu_post_counts=arrays["rep_spu_post_counts"],
            spu_weight_counts=arrays["rep_spu_weight_counts"],
            resources=ResourceReport(**rh["resources"]),
            n_init_packets=rh["n_init_packets"],
            compile_seconds=rh["compile_seconds"],
            search=(SearchTrace.from_json(rh["search"])
                    if rh.get("search") else None),
            candidates_tried=rh.get("candidates_tried", 1),
            schedule_method=rh.get("schedule_method", "slack"),
            schedule_depths=rh.get("schedule_depths"))
        # re-lower (pure, deterministic) — never re-partition
        lowered = lower_pass(g, tables)
        return cls(g, hw, tables, lowered, report, part,
                   default_engine=header.get("default_engine", "jax"))


# ---------------------------------------------------------------------------
# The compile entry point.
# ---------------------------------------------------------------------------

def compile(g_or_qsnn: SNNGraph | QuantizedSNN, hw: HardwareConfig, *,
            method: str = "framework", engine: str = "jax", seed: int = 0,
            validate: bool = True, max_iters: int = 20000,
            restarts: int = 1, schedule_method: str = "slack",
            search: SearchConfig | None = None) -> Program:
    """Compile an SNN (graph or quantized model) into a :class:`Program`.

    Runs the explicit pipeline partition -> schedule -> [validate] ->
    lower (see :mod:`repro.core.passes`) and wraps every product in the
    artifact. ``engine`` picks the default executor of
    :meth:`Program.run`; ``method``/``seed``/``max_iters``/``restarts``
    parameterize the partitioning pass, and ``schedule_method`` names
    the registered
    :class:`~repro.core.scheduling.ScheduleStrategy` ordering the post
    transmissions (``'slack'`` is the original scheduler).

    Passing ``search=SearchConfig(...)`` replaces the single partition
    pass with the joint portfolio search (framework restarts raced
    against every baseline, each feasible mapping scheduled under every
    registered schedule strategy; best (mapping, strategy) pair by OT
    depth and memory wins). The per-candidate trace lands on
    ``program.report.search``, the winning strategy on
    ``program.report.schedule_method``, and both survive
    ``save``/``load``.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; use one of {ENGINES}")
    t0 = time.time()
    g = (from_quantized(g_or_qsnn) if isinstance(g_or_qsnn, QuantizedSNN)
         else g_or_qsnn)
    trace = None
    tables = None
    schedule_depths = None
    if search is not None:
        if (method, seed, max_iters, restarts, schedule_method) != \
                ("framework", 0, 20000, 1, "slack"):
            raise ValueError(
                "search= runs the joint portfolio and takes its parameters "
                "from the SearchConfig; pass seed/max_iters/restarts there "
                "instead of as compile() arguments (the portfolio explores "
                "every registered schedule strategy, so schedule_method= "
                "does not apply)")
        part, trace, tables = search_pass(g, hw, search)
        method = "portfolio"
        if tables is not None:
            sel = trace.selected
            schedule_method = sel.schedule_method or "slack"
            schedule_depths = sel.schedule_depths
        else:
            schedule_method = "slack"   # infeasible winner: default pipeline
    else:
        part = partition_pass(g, hw, method=method, seed=seed,
                              max_iters=max_iters, restarts=restarts)
    if tables is None:
        tables = schedule_pass(g, part, hw, method=schedule_method)
    if validate:
        validate_pass(g, tables)
    lowered = lower_pass(g, tables)
    report = build_report(g, hw, tables, part, method=method,
                          compile_seconds=time.time() - t0,
                          routing=lowered.routing, search=trace,
                          schedule_method=schedule_method,
                          schedule_depths=schedule_depths)
    return Program(g, hw, tables, lowered, report, part,
                   default_engine=engine)
