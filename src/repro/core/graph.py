"""SNN-as-graph representation (paper Eq. (6)): G = (V, E, W).

Neurons are globally indexed. Indices [0, n_inputs) are input neurons
(off-chip spike sources, no on-chip state); [n_inputs, n_neurons) are
internal neurons whose state lives in the Neuron Unit. Internal neurons
also carry a *local* index (global - n_inputs), which is what SPU
operation tables and the Neuron Unit use (paper §4.4.3).

Synapses are stored as flat arrays (pre, post, weight) over the NONZERO
connections only — the operation-based execution model simply omits
zero-weight synapses (paper §4.4.2 advantage 1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.snn.lif import LIFIntParams
from repro.snn.quantize import QuantizedSNN


@dataclasses.dataclass
class SNNGraph:
    n_inputs: int
    n_neurons: int             # inputs + internal
    pre: np.ndarray            # [E] int32 global pre index
    post: np.ndarray           # [E] int32 global post index (always internal)
    weight: np.ndarray         # [E] int32 quantized weight (nonzero)
    lif: LIFIntParams
    output_slice: tuple[int, int] = (0, 0)   # global [start, stop) of outputs

    def __post_init__(self):
        assert self.pre.shape == self.post.shape == self.weight.shape
        assert (self.weight != 0).all(), "zero-weight synapses must be dropped"
        assert (self.post >= self.n_inputs).all(), \
            "post-synaptic neurons must be internal"

    @property
    def n_internal(self) -> int:
        return self.n_neurons - self.n_inputs

    @property
    def n_synapses(self) -> int:
        return int(self.pre.shape[0])

    def local(self, global_idx: np.ndarray) -> np.ndarray:
        return global_idx - self.n_inputs

    def validate(self):
        assert (self.pre >= 0).all() and (self.pre < self.n_neurons).all()
        assert (self.post >= self.n_inputs).all() and \
               (self.post < self.n_neurons).all()
        # no duplicate synapses
        key = self.pre.astype(np.int64) * self.n_neurons + self.post
        assert len(np.unique(key)) == len(key), "duplicate synapses"


def from_quantized(qsnn: QuantizedSNN) -> SNNGraph:
    """Flatten a layered quantized SNN into the global graph."""
    sizes = qsnn.layer_sizes
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    pres, posts, ws = [], [], []
    for i, w in enumerate(qsnn.weights):
        r, c = np.nonzero(w)
        pres.append(r + offsets[i])
        posts.append(c + offsets[i + 1])
        ws.append(w[r, c])
    for i, wr in enumerate(qsnn.rec_weights):
        if wr is None:
            continue
        r, c = np.nonzero(wr)
        pres.append(r + offsets[i + 1])
        posts.append(c + offsets[i + 1])
        ws.append(wr[r, c])
    g = SNNGraph(
        n_inputs=sizes[0], n_neurons=int(offsets[-1]),
        pre=np.concatenate(pres).astype(np.int32),
        post=np.concatenate(posts).astype(np.int32),
        weight=np.concatenate(ws).astype(np.int32),
        lif=qsnn.lif,
        output_slice=(int(offsets[-2]), int(offsets[-1])))
    g.validate()
    return g


def random_graph(n_inputs: int, n_internal: int, n_synapses: int,
                 seed: int = 0, weight_lo: int = -7, weight_hi: int = 7,
                 lif: LIFIntParams | None = None) -> SNNGraph:
    """Random irregular graph (for property tests — paper Fig. 2b style)."""
    rng = np.random.default_rng(seed)
    n = n_inputs + n_internal
    # sample unique (pre, post) pairs; post must be internal
    max_e = n * n_internal
    n_synapses = min(n_synapses, max_e)
    flat = rng.choice(max_e, size=n_synapses, replace=False)
    pre = (flat // n_internal).astype(np.int32)
    post = (flat % n_internal + n_inputs).astype(np.int32)
    w = np.zeros(n_synapses, np.int32)
    while (w == 0).any():  # nonzero weights only
        m = w == 0
        w[m] = rng.integers(weight_lo, weight_hi + 1, m.sum())
    g = SNNGraph(n_inputs, n, pre, post, w,
                 lif or LIFIntParams(leak_shift=2, v_threshold=15, v_reset=0),
                 output_slice=(n - min(4, n_internal), n))
    g.validate()
    return g
