"""The original pure-Python partition loop, preserved as the reference.

This is the seed repo's ``partition.py`` rebalancing loop (paper §6.2
with the recorded deviations of DESIGN.md §8), kept verbatim except for
ONE canonicalization: per-SPU membership is iterated in ascending
synapse-index order instead of CPython-set hash order. Set order was
implementation-defined (and impossible to reproduce from array code);
index order is a well-defined draw from the same distribution. With
that order pinned, the vectorized core in :mod:`.search` consumes the
identical RNG stream and must reproduce this loop's assignment
BIT-EXACTLY for any (graph, hw, seed) — tests/test_mapping.py enforces
it, and ``benchmarks/partitioner_throughput.py`` races the two.

Do not optimize this module; its value is being the slow, obviously-
faithful spine the fast path is proven against.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import PartitionResult
from repro.core.memory_model import HardwareConfig


def _walk(p: np.ndarray, r: np.ndarray, depth: int) -> np.ndarray:
    """Route every synapse through the tree. p, r: [M-1, E]."""
    e = p.shape[1]
    idx = np.arange(e)
    prefix = np.zeros(e, np.int64)
    for d in range(depth):
        sw = (1 << d) - 1 + prefix
        go_right = r[sw, idx] >= p[sw, idx]
        prefix = (prefix << 1) | go_right
    return prefix.astype(np.int32)


def _leaf_path(leaf: int, depth: int) -> list[tuple[int, int]]:
    """[(switch_heap_index, side)] from root to leaf; side 0=left, 1=right."""
    path = []
    prefix = 0
    for d in range(depth):
        side = (leaf >> (depth - 1 - d)) & 1
        path.append(((1 << d) - 1 + prefix, side))
        prefix = (prefix << 1) | side
    return path


class _Books:
    """Incremental per-SPU occupancy + global post/weight location maps."""

    def __init__(self, g: SNNGraph, assign: np.ndarray, hw: HardwareConfig):
        m = hw.n_spus
        self.hw = hw
        self.g = g
        self.cnt_post = [dict() for _ in range(m)]
        self.cnt_w = [dict() for _ in range(m)]
        self.syn_of = [set() for _ in range(m)]
        self.post_locs: dict[int, set[int]] = {}
        self.w_locs: dict[int, set[int]] = {}
        for s, spu in enumerate(assign):
            self._add(int(spu), s)

    def _add(self, spu: int, syn: int):
        p, w = int(self.g.post[syn]), int(self.g.weight[syn])
        self.cnt_post[spu][p] = self.cnt_post[spu].get(p, 0) + 1
        if self.cnt_post[spu][p] == 1:
            self.post_locs.setdefault(p, set()).add(spu)
        self.cnt_w[spu][w] = self.cnt_w[spu].get(w, 0) + 1
        if self.cnt_w[spu][w] == 1:
            self.w_locs.setdefault(w, set()).add(spu)
        self.syn_of[spu].add(syn)

    def _del(self, spu: int, syn: int):
        p, w = int(self.g.post[syn]), int(self.g.weight[syn])
        self.cnt_post[spu][p] -= 1
        if not self.cnt_post[spu][p]:
            del self.cnt_post[spu][p]
            self.post_locs[p].discard(spu)
        self.cnt_w[spu][w] -= 1
        if not self.cnt_w[spu][w]:
            del self.cnt_w[spu][w]
            self.w_locs[w].discard(spu)
        self.syn_of[spu].remove(syn)

    def move(self, syn: int, src: int, dst: int):
        self._del(src, syn)
        self._add(dst, syn)

    def scores(self) -> np.ndarray:
        k, l = self.hw.concentration, self.hw.unified_mem_depth
        return np.array([
            l - (math.ceil((len(cw) + 1) / k) + len(cp))
            for cw, cp in zip(self.cnt_w, self.cnt_post)], np.int64)

    def total_usage(self) -> int:
        k = self.hw.concentration
        return sum(math.ceil((len(cw) + 1) / k) + len(cp)
                   for cw, cp in zip(self.cnt_w, self.cnt_post))


def partition_legacy(g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                     max_iters: int = 50000, eta: float = 0.25,
                     move_mode: str = "decisive",
                     stagnation_window: int = 300, cooldown: int = 64,
                     scan_cap: int = 384,
                     ) -> PartitionResult:
    m, depth, e = hw.n_spus, hw.tree_depth, g.n_synapses
    rng = np.random.default_rng(seed)
    p = np.full((m - 1, e), 0.5, np.float64)
    r = rng.random((m - 1, e))

    posts, weights = g.post, g.weight
    assign = _walk(p, r, depth)
    books = _Books(g, assign, hw)
    scores = books.scores()

    history: list[float] = []
    moved_at = np.full(e, -(1 << 30), np.int64)
    perturbations = 0
    best_min = int(scores.min())
    best_total = books.total_usage()
    best_state = (assign.copy(), scores.copy())
    last_improve = 0

    def note_progress(it):
        """Track (worst score, global line usage) improvements."""
        nonlocal best_min, best_total, best_state, last_improve
        mn, tot = int(scores.min()), books.total_usage()
        if mn > best_min:
            best_min = mn
            best_state = (assign.copy(), scores.copy())
            last_improve = it
        if tot < best_total:
            best_total = tot
            last_improve = it

    def perturb(it):
        nonlocal assign, books, scores, perturbations, last_improve
        # reflective boundaries: stay uniform, preserve locality
        rr = r + rng.uniform(-0.1, 0.1, r.shape)
        rr = np.where(rr < 0.0, -rr, rr)
        rr = np.where(rr > 1.0, 2.0 - rr, rr)
        r[:] = rr
        perturbations += 1
        last_improve = it
        assign = _walk(p, r, depth)
        books = _Books(g, assign, hw)
        scores = books.scores()
        note_progress(it)

    for it in range(max_iters):
        if scores.min() >= 0:
            return PartitionResult(assign, scores, True, it, perturbations,
                                   history)
        history.append(float(scores.mean()))

        # --- stagnation: no worst-score progress in the window -> shake ---
        if it - last_improve >= stagnation_window:
            perturb(it)
            continue

        # --- pick overloaded SPU and a synapse to evict ---
        ov = int(scores.argmin())
        better = scores > scores[ov]
        better[ov] = False
        better_set = set(np.flatnonzero(better).tolist())
        cnt_post, cnt_w = books.cnt_post[ov], books.cnt_w[ov]
        best_rank, cands = (9,), []
        members = sorted(books.syn_of[ov])     # canonical index order
        if len(members) > scan_cap:
            # rank a random sample — at 30k+ synapses the full scan is the
            # per-iteration cost; eviction quality is rank-based, and a
            # 384-sample preserves the rank distribution (DESIGN.md §8)
            members = [members[i] for i in
                       rng.choice(len(members), scan_cap, replace=False)]
        for s in members:
            if it - moved_at[s] < cooldown:
                continue
            sp_, sw_ = int(posts[s]), int(weights[s])
            pu = cnt_post[sp_] == 1
            pa = not better_set.isdisjoint(books.post_locs.get(sp_, ()))
            wu = cnt_w[sw_] == 1
            wa = not better_set.isdisjoint(books.w_locs.get(sw_, ()))
            rank = (not pu, not pa, not wu, not wa)
            if rank < best_rank:
                best_rank, cands = rank, [s]
            elif rank == best_rank:
                cands.append(s)
        if not cands:        # everything in ov is cooling down; shake
            perturb(it)
            continue
        syn = int(cands[rng.integers(len(cands))])
        sp, sw_val = int(posts[syn]), int(weights[syn])

        # --- destination by 4-level priority among higher-scored SPUs ---
        has_post = np.zeros(m, bool)
        has_post[list(books.post_locs.get(sp, ()))] = True
        has_w = np.zeros(m, bool)
        has_w[list(books.w_locs.get(sw_val, ()))] = True
        # equal-scored SPUs are acceptable only for *consolidating* moves
        # (post/weight already present there -> net line-usage decrease);
        # this matters under tight constraints where every SPU is equally
        # overloaded and no strictly-better destination exists.
        equal = scores == scores[ov]
        equal[ov] = False
        dst = None
        for mask in (better & has_post & has_w, better & has_post,
                     better & has_w, equal & has_post & has_w,
                     equal & has_post, better, equal & has_w):
            if mask.any():
                idxs = np.flatnonzero(mask)
                dst = int(idxs[np.argmax(scores[idxs])])
                break
        if dst is None:  # nowhere productive to move; shake and retry
            perturb(it)
            continue

        # --- adjust probabilities along both paths below the LCA ---
        # (routing goes LEFT when R < P, so P is P(left))
        path_ov = _leaf_path(ov, depth)
        path_dst = _leaf_path(dst, depth)
        lca = 0
        while lca < depth and path_ov[lca] == path_dst[lca]:
            lca += 1
        for sw, side in path_ov[lca:]:
            # make the branch toward `ov` less likely
            p[sw, syn] += -eta if side == 0 else eta
        if move_mode == "decisive":
            # land exactly in dst: put P just past R on its path
            for sw, side in path_dst[lca:]:
                if side == 0:   # need LEFT: R < P
                    p[sw, syn] = min(1.0, r[sw, syn] + eta)
                else:           # need RIGHT: R >= P
                    p[sw, syn] = max(0.0, r[sw, syn] - eta)
        else:
            for sw, side in path_dst[lca:]:
                p[sw, syn] += eta if side == 0 else -eta
        np.clip(p[:, syn], 0.0, 1.0, out=p[:, syn])

        # --- re-route the synapse (only its own entries changed) ---
        if move_mode == "decisive":
            new_spu = dst
        else:
            prefix = 0
            for d in range(depth):
                sw = (1 << d) - 1 + prefix
                prefix = (prefix << 1) | int(r[sw, syn] >= p[sw, syn])
            new_spu = int(prefix)
        if new_spu != assign[syn]:
            books.move(syn, int(assign[syn]), new_spu)
            assign[syn] = new_spu
            moved_at[syn] = it
            # POST-GROUP BURST: once the post exists in dst, every further
            # synapse of (ov, post) ranks dst first under the paper's
            # priority order — fast-forward those consecutive single moves
            # (large instances never consolidate otherwise; DESIGN.md §8)
            if move_mode == "decisive" and new_spu == dst:
                rest = [s2 for s2 in sorted(books.syn_of[ov])
                        if int(posts[s2]) == sp]
                for s2 in rest:
                    for sw, side in path_ov[lca:]:
                        p[sw, s2] += -eta if side == 0 else eta
                    for sw, side in path_dst[lca:]:
                        if side == 0:
                            p[sw, s2] = min(1.0, r[sw, s2] + eta)
                        else:
                            p[sw, s2] = max(0.0, r[sw, s2] - eta)
                    np.clip(p[:, s2], 0.0, 1.0, out=p[:, s2])
                    books.move(int(s2), ov, dst)
                    assign[s2] = dst
                    moved_at[s2] = it
            scores = books.scores()
            note_progress(it)

    assign, scores = best_state
    return PartitionResult(assign, scores, bool(scores.min() >= 0),
                           max_iters, perturbations, history)
