# SupraSNN mapping search subsystem (paper §6.2) — see DESIGN.md §6.
#   books       flat numpy occupancy bookkeeping (Eq. 9/10), batched
#   tree        partitioning-tree walk / path / LCA geometry, batched
#   search      vectorized restart population + portfolio driver
#   strategies  the MappingStrategy registry behind compile(method=...)
#   legacy      the original pure-Python loop, kept as the parity reference
from repro.core.mapping.books import Books, PartitionResult
from repro.core.mapping.legacy import partition_legacy
from repro.core.mapping.search import (CandidateTrace, SearchConfig,
                                       SearchTrace, framework_partition,
                                       portfolio_search)
from repro.core.mapping.strategies import (BaselineStrategy,
                                           FrameworkStrategy,
                                           MappingStrategy, STRATEGIES,
                                           get_strategy, register_strategy)
from repro.core.mapping.tree import lca_depths, leaf_paths, walk

__all__ = [
    "Books", "PartitionResult", "partition_legacy",
    "CandidateTrace", "SearchConfig", "SearchTrace",
    "framework_partition", "portfolio_search",
    "BaselineStrategy", "FrameworkStrategy", "MappingStrategy",
    "STRATEGIES", "get_strategy", "register_strategy",
    "lca_depths", "leaf_paths", "walk",
]
