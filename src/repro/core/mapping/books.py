"""Flat occupancy bookkeeping for the partition search (paper Eq. 9/10).

Replaces the former per-SPU ``dict``/``set`` bookkeeping (``_Books`` in
the monolithic ``partition.py``) with dense numpy count arrays carrying
a leading restart dimension:

    cnt_post  [R, M, n_neurons]   synapses of post q on SPU i
    cnt_w     [R, M, n_wvals]     synapses with weight-id w on SPU i
    n_posts   [R, M]              unique posts stored per SPU
    n_weights [R, M]              unique weight values per SPU

Rebuilds after a perturbation are one ``np.bincount`` over the synapse
array; moves are O(group) slice updates; Eq. (10) scores are an O(M)
vectorized expression of ``n_posts``/``n_weights`` — no Python dict
churn anywhere on the search's hot path. Weight values are remapped to
dense ids once at construction (quantized weights span a few hundred
distinct values, so the count planes stay small).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.memory_model import HardwareConfig


@dataclasses.dataclass
class PartitionResult:
    assign: np.ndarray          # [E] synapse -> SPU
    scores: np.ndarray          # [M] final Eq. (10) scores
    feasible: bool
    iterations: int
    perturbations: int
    score_history: list         # mean score per iteration


class Books:
    """Batched per-SPU occupancy arrays over a restart population."""

    def __init__(self, g: SNNGraph, hw: HardwareConfig, assign: np.ndarray):
        """assign: ``[R, E]`` synapse -> SPU per restart."""
        assert assign.ndim == 2
        self.hw = hw
        self.post = g.post.astype(np.int64)
        self.w_vals, w_id = np.unique(g.weight, return_inverse=True)
        self.w_id = w_id.astype(np.int64)
        self.n_wvals = int(len(self.w_vals))
        self.n_neurons = int(g.n_neurons)
        r, m = assign.shape[0], hw.n_spus
        self.cnt_post = np.zeros((r, m, self.n_neurons), np.int32)
        self.cnt_w = np.zeros((r, m, self.n_wvals), np.int32)
        self.n_posts = np.zeros((r, m), np.int64)
        self.n_weights = np.zeros((r, m), np.int64)
        # presence counters: on how many SPUs does post q / weight w live?
        # (lets the search test "present on any better-scored SPU" as a
        # complement over the few worst SPUs instead of a plane reduction)
        self.np_post = np.zeros((r, self.n_neurons), np.int32)
        self.np_w = np.zeros((r, self.n_wvals), np.int32)
        for rr in range(r):
            self.rebuild(rr, assign[rr])

    # -- construction / perturbation ----------------------------------------

    def rebuild(self, rr: int, assign_r: np.ndarray) -> None:
        """Re-derive restart ``rr``'s occupancy from scratch (one bincount
        per plane — the O(E) ground-truth rebuild after a perturbation)."""
        m = self.hw.n_spus
        a = assign_r.astype(np.int64)
        self.cnt_post[rr] = np.bincount(
            a * self.n_neurons + self.post,
            minlength=m * self.n_neurons).reshape(m, self.n_neurons)
        self.cnt_w[rr] = np.bincount(
            a * self.n_wvals + self.w_id,
            minlength=m * self.n_wvals).reshape(m, self.n_wvals)
        self.n_posts[rr] = (self.cnt_post[rr] > 0).sum(1)
        self.n_weights[rr] = (self.cnt_w[rr] > 0).sum(1)
        self.np_post[rr] = (self.cnt_post[rr] > 0).sum(0)
        self.np_w[rr] = (self.cnt_w[rr] > 0).sum(0)

    # -- moves ---------------------------------------------------------------

    def move_group(self, rr: int, syns: np.ndarray, src: int, dst: int
                   ) -> None:
        """Move synapses ``syns`` (all sharing ONE post-neuron) src -> dst.

        Post counts are a scalar delta; weight counts are one bincount
        delta with unique-count maintenance — O(group + n_wvals), no
        per-synapse Python loop.
        """
        k = len(syns)
        if not k:
            return
        p = int(self.post[syns[0]])
        cp = self.cnt_post[rr]
        if cp[src, p] == k:
            self.n_posts[rr, src] -= 1
            self.np_post[rr, p] -= 1
        if cp[dst, p] == 0:
            self.n_posts[rr, dst] += 1
            self.np_post[rr, p] += 1
        cp[src, p] -= k
        cp[dst, p] += k

        wc = np.bincount(self.w_id[syns], minlength=self.n_wvals)
        moved = wc > 0
        cw_src, cw_dst = self.cnt_w[rr, src], self.cnt_w[rr, dst]
        gone = (cw_src == wc) & moved
        self.n_weights[rr, src] -= int(gone.sum())
        self.np_w[rr] -= gone
        cw_src -= wc
        new = (cw_dst == 0) & moved
        self.n_weights[rr, dst] += int(new.sum())
        self.np_w[rr] += new
        cw_dst += wc

    def move_one(self, rr: int, syn: int, src: int, dst: int) -> None:
        """Scalar fast path of :meth:`move_group` for single-synapse moves
        (the search's most common operation — no bincount, ~10 scalar
        updates)."""
        p, w = int(self.post[syn]), int(self.w_id[syn])
        cp, cw = self.cnt_post[rr], self.cnt_w[rr]
        c = cp[src, p]
        if c == 1:
            self.n_posts[rr, src] -= 1
            self.np_post[rr, p] -= 1
        if cp[dst, p] == 0:
            self.n_posts[rr, dst] += 1
            self.np_post[rr, p] += 1
        cp[src, p] = c - 1
        cp[dst, p] += 1
        c = cw[src, w]
        if c == 1:
            self.n_weights[rr, src] -= 1
            self.np_w[rr, w] -= 1
        if cw[dst, w] == 0:
            self.n_weights[rr, dst] += 1
            self.np_w[rr, w] += 1
        cw[src, w] = c - 1
        cw[dst, w] += 1

    # -- Eq. (10) ------------------------------------------------------------

    def scores_r(self, rr: int) -> np.ndarray:
        """[M] Eq. (10) scores: L - (ceil((|Q|+1)/K) + |P|)."""
        k, l = self.hw.concentration, self.hw.unified_mem_depth
        return l - (-(-(self.n_weights[rr] + 1) // k) + self.n_posts[rr])

    def scores(self) -> np.ndarray:
        """[R, M] scores for the whole population."""
        k, l = self.hw.concentration, self.hw.unified_mem_depth
        return l - (-(-(self.n_weights + 1) // k) + self.n_posts)

    def total_usage_r(self, rr: int) -> int:
        """Total memory lines used across SPUs (portfolio tie-breaker)."""
        k = self.hw.concentration
        return int((-(-(self.n_weights[rr] + 1) // k)
                    + self.n_posts[rr]).sum())
