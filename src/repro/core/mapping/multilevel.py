"""Multilevel (coarsen–partition–refine) mapping for large graphs (§11).

The framework search of :mod:`repro.core.mapping.search` walks single
synapses and converges beautifully at paper scale (~33k synapses) but
not at the ROADMAP's 10⁵–10⁶-synapse target. This module wraps it
KaHyPar-style:

1. **Coarsen** — cluster post-neurons by greedy hyperedge-overlap
   matching: two posts that co-occur in many fan-out hyperedges (share
   many pre-neurons) are merged, so the multicast reuse the Multi-Cast
   Tree exploits is preserved INSIDE clusters and the coarse problem
   keeps the fine problem's traffic structure. Rounds of maximal
   matching shrink the synapse count geometrically until it reaches
   ``coarse_target`` (paper scale, where the framework search is known
   to work).
2. **Partition** — run the existing vectorized ``framework_partition``
   on the coarse graph, against a derived coarse memory depth
   (balanced-usage estimate × headroom; the real Eq. (9) is enforced at
   the fine level).
3. **Uncoarsen + refine** — project the coarse assignment through the
   cluster map onto the fine synapses and run the FM-style boundary
   refinement of :func:`repro.core.mapping.hypergraph.refine_mapping`
   against the real :class:`HardwareConfig` — Eq. (10) overflow first,
   then the multicast/inter-chip affinity term. Refinement only
   accepts strict improvements, so the projected mapping never gets
   worse.

Registered as the ``multilevel`` strategy; on graphs at or below
``coarse_target`` synapses it simply delegates to the direct
``hypergraph`` greedy (coarsening would be a no-op detour).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import PartitionResult
from repro.core.mapping.hypergraph import hypergraph_partition, refine_mapping
from repro.core.mapping.search import framework_partition
from repro.core.memory_model import HardwareConfig, scores_from_assignment

#: coarse problem size the framework search handles comfortably
COARSE_TARGET = 30_000


@dataclasses.dataclass(frozen=True)
class CoarseGraph:
    """A coarsened graph plus the maps back to the fine one."""
    graph: SNNGraph          # coarse posts are clusters of fine posts
    cluster: np.ndarray      # [n_internal] fine local post -> cluster id
    syn_map: np.ndarray      # [E_fine] fine synapse -> coarse synapse
    n_clusters: int
    levels: int


def _coarse_keys(g: SNNGraph, cluster: np.ndarray, n_cl: int) -> np.ndarray:
    """Sorted unique (pre, cluster) keys of the current clustering."""
    ck = cluster[g.post.astype(np.int64) - g.n_inputs]
    return np.unique(g.pre.astype(np.int64) * n_cl + ck)


def _match_round(keys: np.ndarray, n_cl: int, sizes: np.ndarray,
                 edge_cap: int, size_cap: int) -> np.ndarray | None:
    """One maximal-matching round over hyperedge co-occurrence pairs.

    ``keys`` are the sorted unique (pre, cluster) pairs; consecutive
    clusters inside one pre's fan-out co-occur in that hyperedge, and
    the pair count over all (small) hyperedges is the overlap weight.
    Returns the merge map (cluster -> representative) or None when no
    pair can merge.
    """
    upre, ucl = keys // n_cl, keys % n_cl
    fanout = np.bincount(upre.astype(np.int64).astype(np.intp),
                         minlength=int(upre[-1]) + 1 if len(upre) else 1)
    same = upre[1:] == upre[:-1]
    small = fanout[upre[1:]] <= edge_cap
    a, b = ucl[:-1][same & small], ucl[1:][same & small]
    if not len(a):
        return None
    pk, counts = np.unique(a * n_cl + b, return_counts=True)
    order = np.lexsort((pk, -counts))
    merge = np.arange(n_cl, dtype=np.int64)
    matched = np.zeros(n_cl, bool)
    merges = 0
    for idx in order:
        x, y = int(pk[idx] // n_cl), int(pk[idx] % n_cl)
        if matched[x] or matched[y] or sizes[x] + sizes[y] > size_cap:
            continue
        merge[y] = x
        matched[x] = matched[y] = True
        merges += 1
        if 2 * merges >= n_cl:          # matching is maximal; stop scanning
            break
    return merge if merges else None


def coarsen_graph(g: SNNGraph, hw: HardwareConfig, *,
                  coarse_target: int = COARSE_TARGET, edge_cap: int = 64,
                  size_cap: int | None = None, max_levels: int = 20
                  ) -> CoarseGraph:
    """Cluster posts by hyperedge overlap until the coarse synapse count
    reaches ``coarse_target`` (or matching stalls).

    ``size_cap`` bounds fine posts per cluster — a cluster lands whole
    on one SPU, where each fine post later costs one UM line, so the
    default keeps clusters well under the Eq. (9) depth.
    """
    if size_cap is None:
        size_cap = max(4, hw.unified_mem_depth // 4)
    m = hw.n_spus
    cluster = np.arange(g.n_internal, dtype=np.int64)
    sizes = np.ones(g.n_internal, np.int64)
    n_cl = g.n_internal
    levels = 0
    for _ in range(max_levels):
        keys = _coarse_keys(g, cluster, n_cl)
        if len(keys) <= coarse_target or n_cl <= 4 * m:
            break
        merge = _match_round(keys, n_cl, sizes, edge_cap, size_cap)
        if merge is None:
            break
        _, new_id = np.unique(merge, return_inverse=True)
        cluster = new_id[merge[cluster]]
        n_cl = int(cluster.max()) + 1
        sizes = np.bincount(cluster, minlength=n_cl).astype(np.int64)
        levels += 1

    # the coarse SNNGraph: every fine neuron may be a pre (coarse inputs
    # span them all); coarse posts are the clusters. Synapses dedup to
    # unique (pre, cluster); the representative weight is the fine weight
    # at the FIRST fine synapse of each coarse synapse (np.unique order —
    # deterministic), a stand-in that keeps the |Q| structure plausible.
    ck = cluster[g.post.astype(np.int64) - g.n_inputs]
    key = g.pre.astype(np.int64) * n_cl + ck
    ukey, first, syn_map = np.unique(key, return_index=True,
                                     return_inverse=True)
    gc = SNNGraph(
        n_inputs=g.n_neurons, n_neurons=g.n_neurons + n_cl,
        pre=(ukey // n_cl).astype(np.int32),
        post=(g.n_neurons + ukey % n_cl).astype(np.int32),
        weight=g.weight[first].astype(np.int32), lif=g.lif)
    return CoarseGraph(gc, cluster, syn_map.astype(np.int64), n_cl, levels)


def _coarse_depth(gc: SNNGraph, hw: HardwareConfig,
                  headroom: float = 1.15) -> int:
    """Memory depth for the coarse search: the balanced-usage estimate
    (posts spread evenly, every SPU holding the full weight alphabet)
    plus headroom. Real Eq. (9) feasibility is judged at the fine level."""
    nw = len(np.unique(gc.weight))
    per_spu = (-(-gc.n_internal // hw.n_spus)
               + -(-(nw + 1) // hw.concentration))
    return int(np.ceil(per_spu * headroom))


def multilevel_partition(g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                         max_iters: int = 20000, restarts: int = 1,
                         coarse_target: int = COARSE_TARGET,
                         edge_cap: int = 64, size_cap: int | None = None,
                         refine_passes: int = 4) -> PartitionResult:
    """Coarsen–partition–refine (see module docstring).

    Graphs at or below ``coarse_target`` synapses go straight to the
    direct greedy :func:`hypergraph_partition`. The coarse framework
    search gets a capped iteration budget: it only roughs out the
    placement (and exits early if it reaches coarse feasibility) — the
    fine-level refinement is what enforces the real Eq. (9)/(10)
    objective, and letting the coarse search run its full budget on a
    problem it rarely closes just burns compile seconds.
    """
    if g.n_synapses <= coarse_target:
        return hypergraph_partition(g, hw, seed=seed,
                                    refine_passes=refine_passes)

    cg = coarsen_graph(g, hw, coarse_target=coarse_target,
                       edge_cap=edge_cap, size_cap=size_cap)
    hwc = dataclasses.replace(hw, unified_mem_depth=_coarse_depth(cg.graph,
                                                                  hw))
    coarse, _, _ = framework_partition(cg.graph, hwc, seed=seed,
                                       restarts=restarts,
                                       max_iters=min(max_iters, 5000))
    assign = coarse.assign[cg.syn_map].astype(np.int32)
    assign, stats = refine_mapping(g, hw, assign, passes=refine_passes)
    scores = scores_from_assignment(g.weight, g.post, assign, hw)
    return PartitionResult(assign, scores, bool(scores.min() >= 0),
                           coarse.iterations + stats.moves,
                           coarse.perturbations, [])
