"""Multilevel (coarsen–partition–refine) mapping for large graphs (§11/§12).

The framework search of :mod:`repro.core.mapping.search` walks single
synapses and converges beautifully at paper scale (~33k synapses) but
not at the ROADMAP's 10⁵–10⁶-synapse target. This module wraps it
KaHyPar-style:

1. **Coarsen** — cluster post-neurons by greedy hyperedge-overlap
   matching: two posts that co-occur in many fan-out hyperedges (share
   many pre-neurons) are merged, so the multicast reuse the Multi-Cast
   Tree exploits is preserved INSIDE clusters and the coarse problem
   keeps the fine problem's traffic structure. Rounds of maximal
   matching shrink the synapse count geometrically until it reaches
   ``coarse_target``. Each round is pure array work (first-occurrence
   matching over the priority-ordered pair list — no per-edge Python
   loop), and the (pre, cluster) key set is carried ACROSS rounds, so
   only the first round ever touches the fine synapse list.
2. **Coarse seeds** — race a small candidate set of coarse
   partitionings: the direct greedy :func:`hypergraph_partition` on
   the coarse graph (candidate 0 — cheap and usually the winner:
   profile-guided measurement at the 10⁵ pinned shape showed the
   capped framework search costing ~2 s to produce a WORSE projection
   than the 0.02 s greedy) plus ``restarts - 1`` capped framework
   searches on distinct seeds. ``workers > 1`` fans the framework
   seeds out over processes; the reduction — lexicographic best
   (projected overflow, projected hop-weighted traffic, candidate
   index) — is computed in the parent and is worker-count-invariant.
3. **Project + place** — project the winning coarse assignment through
   the cluster map onto the fine synapses, then run the chip-placement
   stage (:func:`place_chips`): group SPUs onto chips by shared-pre
   affinity and place the chips on the 2D mesh so hop-weighted
   multicast traffic is small — making WHICH CHIP a group lands on an
   optimized dimension rather than an accident of SPU numbering
   (DESIGN.md §12).
4. **Refine** — FM boundary refinement of
   :func:`repro.core.mapping.hypergraph.refine_mapping` against the
   real :class:`HardwareConfig` — Eq. (10) overflow first, then the
   multicast + mesh-hop traffic term — followed by the within-chip
   :func:`balance_loads` OT-depth pass. Refinement only accepts strict
   improvements, so the projected mapping never gets worse.

Each stage records itself on the active compile-phase profiler
(``coarsen`` / ``coarse_search`` / ``project`` / ``place`` /
``refine`` — see :mod:`repro.core.profiling`).

Registered as the ``multilevel`` strategy; on graphs at or below
``coarse_target`` synapses it simply delegates to the direct
``hypergraph`` greedy (coarsening would be a no-op detour).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import multiprocessing as mp

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import PartitionResult
from repro.core.mapping.hypergraph import (balance_loads,
                                           hypergraph_partition,
                                           mapping_traffic, mesh_hops,
                                           refine_mapping)
from repro.core.mapping.search import framework_partition
from repro.core.memory_model import HardwareConfig, scores_from_assignment
from repro.core.profiling import phase

#: coarse problem size the framework search handles comfortably
COARSE_TARGET = 30_000


@dataclasses.dataclass(frozen=True)
class CoarseGraph:
    """A coarsened graph plus the maps back to the fine one."""
    graph: SNNGraph          # coarse posts are clusters of fine posts
    cluster: np.ndarray      # [n_internal] fine local post -> cluster id
    syn_map: np.ndarray      # [E_fine] fine synapse -> coarse synapse
    n_clusters: int
    levels: int


def _match_round(keys: np.ndarray, n_cl: int, sizes: np.ndarray,
                 edge_cap: int, size_cap: int) -> np.ndarray | None:
    """One maximal-matching round over hyperedge co-occurrence pairs.

    ``keys`` are the sorted unique (pre, cluster) pairs; consecutive
    clusters inside one pre's fan-out co-occur in that hyperedge, and
    the pair count over all (small) hyperedges is the overlap weight.
    A pair is matched iff it is the FIRST pair, in descending-overlap
    priority order, touching EITHER of its endpoints — the vectorized
    first-choice matching (two ``np.minimum.at`` first-occurrence
    scans, no per-pair Python loop); like any matching it never merges
    a cluster twice per round. Returns the merge map (cluster ->
    representative) or None when no pair can merge.
    """
    upre, ucl = keys // n_cl, keys % n_cl
    fanout = np.bincount(upre.astype(np.int64).astype(np.intp),
                         minlength=int(upre[-1]) + 1 if len(upre) else 1)
    same = upre[1:] == upre[:-1]
    small = fanout[upre[1:]] <= edge_cap
    a, b = ucl[:-1][same & small], ucl[1:][same & small]
    if not len(a):
        return None
    pk, counts = np.unique(a * n_cl + b, return_counts=True)
    order = np.lexsort((pk, -counts))
    x, y = pk[order] // n_cl, pk[order] % n_cl
    fits = sizes[x] + sizes[y] <= size_cap
    x, y = x[fits], y[fits]
    if not len(x):
        return None
    rank = np.arange(len(x), dtype=np.int64)
    first = np.full(n_cl, len(x), np.int64)
    np.minimum.at(first, x, rank)
    np.minimum.at(first, y, rank)
    take = (first[x] == rank) & (first[y] == rank)
    if not take.any():
        return None
    merge = np.arange(n_cl, dtype=np.int64)
    merge[y[take]] = x[take]
    return merge


def coarsen_graph(g: SNNGraph, hw: HardwareConfig, *,
                  coarse_target: int = COARSE_TARGET, edge_cap: int = 64,
                  size_cap: int | None = None, max_levels: int = 20
                  ) -> CoarseGraph:
    """Cluster posts by hyperedge overlap until the coarse synapse count
    reaches ``coarse_target`` (or matching stalls).

    ``size_cap`` bounds fine posts per cluster — a cluster lands whole
    on one SPU, where each fine post later costs one UM line, so the
    default keeps clusters well under the Eq. (9) depth. The unique
    (pre, cluster) key set — the coarse hyperedge view — is built once
    from the fine synapse list and then merged level-to-level, so each
    round costs O(coarse keys), not O(fine synapses).
    """
    if size_cap is None:
        size_cap = max(4, hw.unified_mem_depth // 4)
    m = hw.n_spus
    cluster = np.arange(g.n_internal, dtype=np.int64)
    sizes = np.ones(g.n_internal, np.int64)
    n_cl = g.n_internal
    levels = 0
    ck = cluster[g.post.astype(np.int64) - g.n_inputs]
    keys = np.unique(g.pre.astype(np.int64) * n_cl + ck)
    for _ in range(max_levels):
        if len(keys) <= coarse_target or n_cl <= 4 * m:
            break
        merge = _match_round(keys, n_cl, sizes, edge_cap, size_cap)
        if merge is None:
            break
        _, new_id = np.unique(merge, return_inverse=True)
        cluster = new_id[merge[cluster]]
        n_new = int(new_id.max()) + 1
        upre, ucl = keys // n_cl, keys % n_cl
        keys = np.unique(upre * n_new + new_id[merge[ucl]])
        n_cl = n_new
        sizes = np.bincount(cluster, minlength=n_cl).astype(np.int64)
        levels += 1

    # the coarse SNNGraph: every fine neuron may be a pre (coarse inputs
    # span them all); coarse posts are the clusters. Synapses dedup to
    # unique (pre, cluster); the representative weight is the fine weight
    # at the FIRST fine synapse of each coarse synapse (np.unique order —
    # deterministic), a stand-in that keeps the |Q| structure plausible.
    ck = cluster[g.post.astype(np.int64) - g.n_inputs]
    key = g.pre.astype(np.int64) * n_cl + ck
    ukey, first, syn_map = np.unique(key, return_index=True,
                                     return_inverse=True)
    gc = SNNGraph(
        n_inputs=g.n_neurons, n_neurons=g.n_neurons + n_cl,
        pre=(ukey // n_cl).astype(np.int32),
        post=(g.n_neurons + ukey % n_cl).astype(np.int32),
        weight=g.weight[first].astype(np.int32), lif=g.lif)
    return CoarseGraph(gc, cluster, syn_map.astype(np.int64), n_cl, levels)


def _coarse_depth(gc: SNNGraph, hw: HardwareConfig,
                  headroom: float = 1.15) -> int:
    """Memory depth for the coarse search: the balanced-usage estimate
    (posts spread evenly, every SPU holding the full weight alphabet)
    plus headroom. Real Eq. (9) feasibility is judged at the fine level."""
    nw = len(np.unique(gc.weight))
    per_spu = (-(-gc.n_internal // hw.n_spus)
               + -(-(nw + 1) // hw.concentration))
    return int(np.ceil(per_spu * headroom))


# ---------------------------------------------------------------------------
# Chip placement (DESIGN.md §12): which chip does a group land on?
# ---------------------------------------------------------------------------

def place_chips(g: SNNGraph, hw: HardwareConfig, assign: np.ndarray, *,
                max_sweeps: int = 8) -> np.ndarray:
    """Relabel SPUs so chip membership and mesh position improve.

    The mapper's SPU ids are logical; which PHYSICAL chip an SPU's
    subtree sits on — and where that chip sits on the 2D mesh — is free
    to choose, because a relabeling is a pure permutation: Eq. (9)/(10)
    scores, λ and the OT depth are untouched, only the mesh-hop traffic
    changes. This stage runs a deterministic QAP-style local search
    over SPU↔SPU swaps, starting from the CURRENT labeling (identity)
    and minimizing the pairwise proxy

        Σ_{i<j} A[i, j] · meshdist(chip(i), chip(j))

    with ``A[i, j]`` = pres held by both i and j (every shared pre
    whose SPUs land on distant chips stretches that multicast's mesh
    bounding box). The result is accepted only when the TRUE
    :func:`~repro.core.mapping.hypergraph.mesh_hops` total strictly
    drops, so the stage can never lose to the §11 consecutive-id
    grouping it starts from. Identity at ``n_chips=1``.
    """
    m, spc, c = hw.n_spus, hw.spus_per_chip, hw.n_chips
    if c == 1:
        return assign
    pres = np.zeros((m, g.n_neurons), np.float32)
    pres[assign.astype(np.int64), g.pre.astype(np.int64)] = 1.0
    aff = (pres @ pres.T).astype(np.int64)               # [M, M] shared pres
    np.fill_diagonal(aff, 0)
    slots = np.arange(c)
    dist = hw.chip_hops(slots[:, None], slots[None, :]).astype(np.int64)

    perm = np.arange(m, dtype=np.int64)                  # old spu -> new
    chip = perm // spc                                   # [M] chip of spu
    for _ in range(max_sweeps):
        improved = False
        for i in range(m):
            for j in range(i + 1, m):
                a_c, b_c = int(chip[i]), int(chip[j])
                if a_c == b_c:
                    continue
                # QAP swap delta: mutual term is symmetric-invariant,
                # the k∈{i,j} cross terms cancel out of the k-sum
                dd = dist[b_c, chip] - dist[a_c, chip]
                delta = int(((aff[i] - aff[j]) * dd).sum()) \
                    + 2 * int(aff[i, j]) * int(dist[a_c, b_c])
                if delta < 0:
                    perm[i], perm[j] = perm[j], perm[i]
                    chip[i], chip[j] = chip[j], chip[i]
                    improved = True
        if not improved:
            break

    out = perm[assign.astype(np.int64)].astype(np.int32)
    if int(mesh_hops(g, out, hw).sum()) < int(mesh_hops(g, assign,
                                                        hw).sum()):
        return out
    return assign


# ---------------------------------------------------------------------------
# Raced coarse seeds.
# ---------------------------------------------------------------------------

def _framework_seed(gc: SNNGraph, hwc: HardwareConfig, seed: int,
                    max_iters: int) -> tuple[np.ndarray, int, int]:
    """One capped framework search on the coarse graph (process-safe)."""
    res, _, _ = framework_partition(gc, hwc, seed=seed,
                                    max_iters=max_iters)
    return res.assign, res.iterations, res.perturbations


def _projected_quality(g: SNNGraph, hw: HardwareConfig,
                       fine_assign: np.ndarray) -> tuple[int, int]:
    """(overflow lines, hop-weighted traffic) of a projected mapping —
    the deterministic coarse-seed reduction key."""
    scores = scores_from_assignment(g.weight, g.post, fine_assign, hw)
    overflow = int(np.maximum(-scores, 0).sum())
    t = mapping_traffic(g, fine_assign, hw)
    hop = hw.inter_chip_hop_cycles if hw.n_chips > 1 else 0
    return overflow, t["dests_total"] + hop * t["mesh_hops_total"]


def multilevel_partition(g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                         max_iters: int = 20000, restarts: int = 1,
                         workers: int = 1,
                         coarse_target: int = COARSE_TARGET,
                         edge_cap: int = 64, size_cap: int | None = None,
                         refine_passes: int = 4,
                         chip_placement: bool = True) -> PartitionResult:
    """Coarsen – race coarse seeds – project – place – refine.

    Graphs at or below ``coarse_target`` synapses go straight to the
    direct greedy :func:`hypergraph_partition`. Above it, the coarse
    candidates are the greedy overlap partitioner plus ``restarts - 1``
    capped framework searches (distinct seeds); ``workers > 1`` runs
    the framework seeds in parallel processes, and the best-of
    reduction — lexicographic (projected overflow, projected
    hop-weighted traffic, candidate index) — is evaluated in the parent
    so the result is identical for ANY worker count.
    ``chip_placement=False`` skips the mesh placement stage (the §11
    consecutive-id chain overlay; kept for the counterfactual bench
    row).
    """
    if g.n_synapses <= coarse_target:
        return hypergraph_partition(g, hw, seed=seed,
                                    refine_passes=refine_passes)

    with phase("coarsen"):
        cg = coarsen_graph(g, hw, coarse_target=coarse_target,
                           edge_cap=edge_cap, size_cap=size_cap)
    hwc = dataclasses.replace(hw, unified_mem_depth=_coarse_depth(cg.graph,
                                                                  hw))

    with phase("coarse_search"):
        iters = min(max_iters, 5000)
        greedy = hypergraph_partition(cg.graph, hwc, seed=seed)
        seeds = [(greedy.assign, greedy.iterations, 0)]
        n_fw = max(restarts - 1, 0)
        if n_fw and workers > 1:
            ctx = mp.get_context("spawn")
            with cf.ProcessPoolExecutor(
                    max_workers=min(workers, n_fw),
                    mp_context=ctx) as pool:
                futs = [pool.submit(_framework_seed, cg.graph, hwc,
                                    seed + k, iters)
                        for k in range(n_fw)]
                seeds += [f.result() for f in futs]
        else:
            seeds += [_framework_seed(cg.graph, hwc, seed + k, iters)
                      for k in range(n_fw)]

    with phase("project"):
        projected = [a[cg.syn_map].astype(np.int32) for a, _, _ in seeds]
        best = min(range(len(projected)),
                   key=lambda i: (*_projected_quality(g, hw, projected[i]),
                                  i))
    assign = projected[best]
    c_iters, c_perturb = seeds[best][1], seeds[best][2]

    with phase("refine"):
        assign, stats = refine_mapping(g, hw, assign, passes=refine_passes)
        assign, bstats = balance_loads(g, hw, assign)

    if chip_placement and hw.n_chips > 1:
        # final re-placement: the refiner/balancer moved groups, so
        # re-solve the (pure relabeling) chip grouping + mesh placement
        # for the FINAL per-SPU contents; place_chips accepts only on
        # strictly fewer true mesh hops, so this can never lose to the
        # consecutive-id grouping it starts from
        with phase("place"):
            assign = place_chips(g, hw, assign)
    scores = scores_from_assignment(g.weight, g.post, assign, hw)
    return PartitionResult(assign, scores, bool(scores.min() >= 0),
                           c_iters + stats.moves + bstats["moves"],
                           c_perturb, [])
