"""Hyperedge model of the SNN fan-out + overlap-driven mapping (§11).

SupraSNN's Multi-Cast Tree delivers one spike packet to EVERY SPU that
holds a synapse of the firing neuron — a neuron's fan-out is therefore
a *hyperedge* (one source, many sinks), and the spike traffic of a
mapping is the classic hypergraph connectivity metric: the number of
destination SPUs each hyperedge spans (λ). Standard graph partitioning
cannot see this multicast reuse; hyperedge-overlap partitioning
(Ronzani & Silvano 2026) reports 20–30% less inter-core traffic by
maximizing co-destination overlap. This module provides:

* :class:`HyperView` — CSR adjacency of the fan-out hyperedges over an
  :class:`~repro.core.graph.SNNGraph` (post -> fan-in synapses,
  pre -> fan-out posts);
* :func:`hypergraph_partition` — a deterministic greedy partitioner
  that places whole fan-in groups by descending size, choosing the SPU
  maximizing the second-order affinity term (shared fan-in pres ->
  reused multicast deliveries, then shared weight values -> reused UM
  lines) among the Eq. (9)-feasible SPUs;
* :func:`refine_mapping` — FM-style boundary refinement moving whole
  (SPU, post) fan-in groups under the extended objective
  ``J = (overflow, traffic)``: Eq. (10) overflow lines first, then
  multicast deliveries + inter-chip forwards (DESIGN.md §11). Moves
  are only accepted on strict lexicographic improvement, so the
  refined mapping NEVER scores worse than its input — the multilevel
  mapper's uncoarsening contract;
* traffic accounting — :func:`multicast_dests`, :func:`chip_span`,
  :func:`mapping_traffic`, :func:`inter_chip_packet_counts` — the
  static mapping metrics behind the ``mapping.*`` benchmark rows and
  the multi-chip cycle-model term.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import Books, PartitionResult
from repro.core.memory_model import HardwareConfig, scores_from_assignment


# ---------------------------------------------------------------------------
# The hyperedge view.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HyperView:
    """CSR adjacency of a graph's fan-out hyperedge structure.

    ``posts`` are the graph's distinct post-neurons; post ``posts[j]``
    owns fan-in synapses ``fanin_syn[fanin_ptr[j]:fanin_ptr[j + 1]]``
    (sorted by synapse id). ``fanout_ptr``/``fanout_post`` give each
    PRE neuron's hyperedge: the posts it reaches (indexed by global
    pre id, empty rows for neurons with no fan-out).
    """
    posts: np.ndarray           # [P] distinct post ids, ascending
    fanin_ptr: np.ndarray       # [P+1] CSR offsets into fanin_syn
    fanin_syn: np.ndarray       # [E] synapse ids grouped by post
    fanout_ptr: np.ndarray      # [n_neurons+1] CSR offsets per pre
    fanout_post: np.ndarray     # [E] post ids grouped by pre

    @property
    def n_posts(self) -> int:
        return int(len(self.posts))

    def fanin(self, j: int) -> np.ndarray:
        """Synapse ids of post ``posts[j]``."""
        return self.fanin_syn[self.fanin_ptr[j]:self.fanin_ptr[j + 1]]


def hyper_view(g: SNNGraph) -> HyperView:
    """Build the CSR hyperedge view (two argsorts, no Python loops)."""
    e = g.n_synapses
    order = np.argsort(g.post.astype(np.int64) * e + np.arange(e))
    posts = np.unique(g.post).astype(np.int64)
    fanin_ptr = np.searchsorted(g.post[order], np.r_[posts, g.n_neurons])
    fanin_ptr = np.r_[fanin_ptr[:-1], e].astype(np.int64)
    by_pre = np.argsort(g.pre.astype(np.int64) * np.int64(g.n_neurons)
                        + g.post)
    fanout_ptr = np.searchsorted(
        g.pre[by_pre], np.arange(g.n_neurons + 1)).astype(np.int64)
    return HyperView(posts, fanin_ptr, order.astype(np.int64),
                     fanout_ptr, g.post[by_pre].astype(np.int64))


# ---------------------------------------------------------------------------
# Traffic accounting (the hyperedge connectivity metric + chips).
# ---------------------------------------------------------------------------

def multicast_dests(g: SNNGraph, assign: np.ndarray, n_spus: int
                    ) -> np.ndarray:
    """[n_neurons] destination-SPU count of each neuron's hyperedge.

    Entry q is the number of SPUs holding at least one synapse with
    pre q — the MC-tree deliveries one spike of q costs (λ of the
    hyperedge). Zero for neurons without fan-out.
    """
    pairs = np.unique(g.pre.astype(np.int64) * n_spus
                      + assign.astype(np.int64))
    return np.bincount(pairs // n_spus, minlength=g.n_neurons)


def chip_span(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig
              ) -> np.ndarray:
    """[n_neurons] distinct chips each neuron's fan-out spans."""
    chips = hw.chip_of(assign.astype(np.int64))
    pairs = np.unique(g.pre.astype(np.int64) * hw.n_chips + chips)
    return np.bincount(pairs // hw.n_chips, minlength=g.n_neurons)


def mapping_traffic(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig
                    ) -> dict:
    """Static spike-traffic metrics of a mapping (per source spike).

    ``dests_total`` is the summed hyperedge connectivity λ (fabric
    deliveries if every source fired once); ``inter_chip_total`` the
    summed (chips spanned - 1) forwards. ``dests_total`` is invariant
    under the chip grouping and ``inter_chip_total == 0`` at
    ``n_chips=1`` — the conservation the multi-chip model must keep.
    """
    dests = multicast_dests(g, assign, hw.n_spus)
    span = chip_span(g, assign, hw)
    sources = dests > 0
    return {
        "dests_total": int(dests.sum()),
        "dests_mean": float(dests[sources].mean()) if sources.any() else 0.0,
        "inter_chip_total": int(np.maximum(span - 1, 0).sum()),
        "n_sources": int(sources.sum()),
    }


def inter_chip_packet_counts(ext_spikes: np.ndarray, spikes: np.ndarray,
                             span: np.ndarray) -> np.ndarray:
    """Per-timestep inter-chip forwarded packets of a run.

    Mirrors :func:`repro.core.engine.oracle_packet_counts`: the
    distribution phase of timestep t carries the external inputs of t
    plus the internal spikes of t-1; each firing neuron q adds
    ``max(span[q] - 1, 0)`` forwards. ``span`` is the
    :func:`chip_span` vector (length ``n_neurons``; the internal block
    is its tail). Accepts ``[T, n]`` or batched ``[B, T, n]`` spike
    arrays, returning ``[T]`` / ``[B, T]`` counts.
    """
    ext = np.asarray(ext_spikes)
    s = np.asarray(spikes)
    if ext.ndim not in (2, 3) or s.ndim != ext.ndim:
        raise ValueError(f"expected matching [T, n] or [B, T, n] arrays; "
                         f"got {ext.shape} and {s.shape}")
    hops = np.maximum(np.asarray(span, np.int64) - 1, 0)
    n_in = ext.shape[-1]
    ext_hops = hops[:n_in]
    int_hops = hops[len(hops) - s.shape[-1]:]
    counts = (ext != 0).astype(np.int64) @ ext_hops
    counts[..., 1:] += (s[..., :-1, :] != 0).astype(np.int64) @ int_hops
    return counts


# ---------------------------------------------------------------------------
# Greedy hyperedge-overlap partitioning.
# ---------------------------------------------------------------------------

def hypergraph_partition(g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                         refine: bool = True, refine_passes: int = 2
                         ) -> PartitionResult:
    """Deterministic greedy overlap partitioner (Ronzani & Silvano style).

    Whole fan-in groups are placed in descending size order (heaviest
    posts first — they fix the layout the small ones then overlap
    onto). For each post the destination is chosen among the SPUs that
    stay Eq. (9)-feasible by the lexicographic affinity key

        (max shared fan-in pres, min new UM weight lines,
         max remaining Eq. (10) score, min SPU id)

    — multicast reuse first (every shared pre is one MC delivery the
    SPU already receives), weight reuse second, load balance third.
    If no SPU stays feasible the least-overflowing one is taken and
    the result may be infeasible (exactly like the baselines). A
    final :func:`refine_mapping` pass (on by default) cleans up the
    greedy tail. ``seed`` is accepted for the
    :class:`~repro.core.mapping.strategies.MappingStrategy` protocol;
    the algorithm is deterministic and ignores it.
    """
    hv = hyper_view(g)
    m, k, cap = hw.n_spus, hw.concentration, hw.unified_mem_depth
    w_vals, w_id = np.unique(g.weight, return_inverse=True)
    nw = len(w_vals)

    pre_present = np.zeros((m, g.n_neurons), bool)
    w_present = np.zeros((m, nw), bool)
    n_posts = np.zeros(m, np.int64)
    n_weights = np.zeros(m, np.int64)
    assign = np.zeros(g.n_synapses, np.int32)

    sizes = np.diff(hv.fanin_ptr)
    order = np.lexsort((hv.posts, -sizes))      # big fan-ins first
    spu_idx = np.arange(m)
    for j in order:
        syns = hv.fanin(j)
        pres = g.pre[syns].astype(np.int64)     # unique: one syn per (pre, q)
        uw = np.unique(w_id[syns])
        overlap = pre_present[:, pres].sum(1)                    # [M]
        new_w = (~w_present[:, uw]).sum(1)                       # [M]
        lines_now = -(-(n_weights + 1) // k) + n_posts
        lines_after = -(-(n_weights + new_w + 1) // k) + n_posts + 1
        feasible = lines_after <= cap
        if feasible.any():
            # lexicographic affinity key over the feasible SPUs
            f = spu_idx[feasible]
            pick = f[np.lexsort((f, lines_after[f],
                                 lines_after[f] - lines_now[f],
                                 -overlap[f]))[0]]
        else:
            pick = int(np.lexsort((spu_idx, lines_after))[0])
        assign[syns] = pick
        pre_present[pick, pres] = True
        w_present[pick, uw] = True
        n_posts[pick] += 1
        n_weights[pick] = w_present[pick].sum()

    iterations = hv.n_posts
    if refine:
        assign, stats = refine_mapping(g, hw, assign, passes=refine_passes)
        iterations += stats.moves
    scores = scores_from_assignment(g.weight, g.post, assign, hw)
    return PartitionResult(assign.astype(np.int32), scores,
                           bool(scores.min() >= 0), iterations, 0, [])


# ---------------------------------------------------------------------------
# FM-style boundary refinement under the extended objective.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RefineStats:
    """What one :func:`refine_mapping` call did (and proves)."""
    passes: int
    moves: int
    overflow_before: int
    overflow_after: int
    traffic_before: int
    traffic_after: int


def _overflow(scores: np.ndarray) -> int:
    """Total Eq. (10) violation lines (0 iff the mapping is feasible)."""
    return int(np.maximum(-scores, 0).sum())


def refine_mapping(g: SNNGraph, hw: HardwareConfig, assign: np.ndarray, *,
                   passes: int = 3
                   ) -> tuple[np.ndarray, RefineStats]:
    """FM-style whole-group boundary refinement of a mapping.

    Moves (SPU, post) fan-in groups between SPUs, accepting a move only
    on STRICT lexicographic improvement of

        J = (overflow, traffic)
        overflow = Σ_i max(0, -score_i)              -- Eq. (10) repair
        traffic  = Σ_q λ(q) + hop · Σ_q (chips(q)-1) -- multicast reuse

    where λ(q) is the destination-SPU count of neuron q's hyperedge and
    ``hop = hw.inter_chip_hop_cycles`` prices inter-chip forwards
    (DESIGN.md §11's second-order affinity term next to Eq. (10)).
    Because acceptance is strict, the returned mapping NEVER scores
    worse than the input on (overflow, traffic) — the property
    tests/test_multilevel.py pins. Groups are visited worst-SPU-first;
    the pass loop stops early when a full sweep accepts nothing.
    """
    m, k, cap = hw.n_spus, hw.concentration, hw.unified_mem_depth
    c_chips = hw.n_chips
    hop = hw.inter_chip_hop_cycles if c_chips > 1 else 0
    assign = assign.astype(np.int32).copy()
    books = Books(g, hw, assign[None])
    w_id = books.w_id
    pre = g.pre.astype(np.int64)
    post = g.post.astype(np.int64)

    cnt_pre = np.zeros((m, g.n_neurons), np.int32)
    np.add.at(cnt_pre, (assign, pre), 1)
    cnt_chip = cnt_pre.reshape(c_chips, hw.spus_per_chip,
                               g.n_neurons).sum(1)
    dests = int((cnt_pre > 0).sum())
    inter = int(np.maximum((cnt_chip > 0).sum(0)
                           - (cnt_pre.sum(0) > 0), 0).sum())

    scores = books.scores_r(0)
    overflow = _overflow(scores)
    traffic = dests + hop * inter
    stats = RefineStats(0, 0, overflow, overflow, traffic, traffic)
    spus = np.arange(m)

    def lines_of(nw_, np_):
        return -(-(nw_ + 1) // k) + np_

    for _ in range(passes):
        stats.passes += 1
        accepted = False
        # (spu, post) groups, worst-scored SPUs first, then post id
        key = assign.astype(np.int64) * g.n_neurons + post
        uniq, inv = np.unique(key, return_inverse=True)
        g_spu = (uniq // g.n_neurons).astype(np.int64)
        g_post = uniq % g.n_neurons
        visit = np.lexsort((g_post, scores[g_spu]))
        syn_order = np.argsort(inv, kind="stable")
        starts = np.r_[0, np.cumsum(np.bincount(inv))]
        for gi in visit:
            i = int(g_spu[gi])
            q = int(g_post[gi])
            syns = syn_order[starts[gi]:starts[gi + 1]]
            # groups move whole, so a changed first-synapse owner means
            # the group left i; a changed count means another (i', q)
            # group merged INTO i — either way this snapshot is stale and
            # its deltas would be wrong, so revisit next pass instead
            if int(assign[syns[0]]) != i \
                    or int(books.cnt_post[0, i, q]) != len(syns):
                continue
            pres = pre[syns]
            uw, uw_cnt = np.unique(w_id[syns], return_counts=True)

            # Δtraffic: pres leaving i entirely vs pres new on each dest
            leave = int((cnt_pre[i, pres] == 1).sum())
            add_d = (cnt_pre[:, pres] == 0).sum(1)               # [M]
            d_dests = add_d - leave
            if hop:
                ci = i // hw.spus_per_chip
                leave_c = int((cnt_chip[ci, pres] == 1).sum())
                add_c = (cnt_chip[:, pres] == 0).sum(1)          # [C]
                cd = spus // hw.spus_per_chip
                d_inter = np.where(cd == ci, 0, add_c[cd] - leave_c)
            else:
                d_inter = np.zeros(m, np.int64)

            # Δoverflow: i loses post q + its unique weights; d gains
            gone_w = int((books.cnt_w[0, i, uw] == uw_cnt).sum())
            new_w = (books.cnt_w[0, :, uw] == 0).sum(0)          # [M]
            has_q = books.cnt_post[0, :, q] > 0                  # [M]
            nw0, np0 = books.n_weights[0], books.n_posts[0]
            sc_i_new = cap - lines_of(nw0[i] - gone_w, np0[i] - 1)
            sc_d_new = cap - lines_of(nw0 + new_w, np0 + ~has_q)
            d_over = (np.maximum(-sc_i_new, 0) - np.maximum(-scores[i], 0)
                      + np.maximum(-sc_d_new, 0)
                      - np.maximum(-scores, 0))
            d_traf = d_dests + hop * d_inter

            d_over[i] = d_traf[i] = 0           # staying is never a move
            better = (d_over < 0) | ((d_over == 0) & (d_traf < 0))
            better[i] = False
            if not better.any():
                continue
            cand = spus[better]
            d = int(cand[np.lexsort((cand, d_traf[cand],
                                     d_over[cand]))[0]])

            books.move_group(0, syns, i, d)
            assign[syns] = d
            cnt_pre[i, pres] -= 1
            cnt_pre[d, pres] += 1
            if c_chips > 1:
                cnt_chip[i // hw.spus_per_chip, pres] -= 1
                cnt_chip[d // hw.spus_per_chip, pres] += 1
            dests += int(d_dests[d])
            inter += int(d_inter[d])
            scores = books.scores_r(0)
            overflow += int(d_over[d])
            stats.moves += 1
            accepted = True
        if not accepted:
            break

    stats.overflow_after = _overflow(books.scores_r(0))
    stats.traffic_after = dests + hop * inter
    return assign, stats
