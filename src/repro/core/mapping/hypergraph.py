"""Hyperedge model of the SNN fan-out + overlap-driven mapping (§11/§12).

SupraSNN's Multi-Cast Tree delivers one spike packet to EVERY SPU that
holds a synapse of the firing neuron — a neuron's fan-out is therefore
a *hyperedge* (one source, many sinks), and the spike traffic of a
mapping is the classic hypergraph connectivity metric: the number of
destination SPUs each hyperedge spans (λ). Standard graph partitioning
cannot see this multicast reuse; hyperedge-overlap partitioning
(Ronzani & Silvano 2026) reports 20–30% less inter-core traffic by
maximizing co-destination overlap. This module provides:

* :class:`HyperView` — CSR adjacency of the fan-out hyperedges over an
  :class:`~repro.core.graph.SNNGraph` (post -> fan-in synapses,
  pre -> fan-out posts);
* :func:`hypergraph_partition` — a deterministic greedy partitioner
  that places whole fan-in groups by descending size, choosing the SPU
  maximizing the second-order affinity term (shared fan-in pres ->
  reused multicast deliveries, then shared weight values -> reused UM
  lines) among the Eq. (9)-feasible SPUs;
* :func:`refine_mapping` — FM-style boundary refinement moving whole
  (SPU, post) fan-in groups under the extended objective
  ``J = (overflow, traffic)``: Eq. (10) overflow lines first, then
  multicast deliveries + mesh-hop-weighted inter-chip forwards
  (DESIGN.md §12). Each pass evaluates EVERY group's move deltas in
  one vectorized sweep off the occupancy :class:`Books` (no per-group
  Python recomputation), then applies the winners with a cheap scalar
  recheck against live state — so acceptance stays strictly
  monotone and the refined mapping NEVER scores worse than its input
  (the multilevel mapper's uncoarsening contract);
* :func:`balance_loads` — within-chip OT load balancing: the
  traffic-first refinement concentrates fan-in groups, which blows up
  the OT depth (the busiest SPU's operation count); this pass spreads
  whole groups from each chip's most- to least-loaded SPUs under
  Eq. (9), leaving chip-level (mesh) traffic invariant;
* traffic accounting — :func:`multicast_dests`, :func:`chip_span`,
  :func:`mesh_hops`, :func:`mapping_traffic`,
  :func:`inter_chip_packet_counts`, :func:`inter_chip_hop_counts` —
  the static mapping metrics behind the ``mapping.*`` benchmark rows
  and the multi-chip cycle-model term.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import Books, PartitionResult
from repro.core.memory_model import HardwareConfig, scores_from_assignment


# ---------------------------------------------------------------------------
# The hyperedge view.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HyperView:
    """CSR adjacency of a graph's fan-out hyperedge structure.

    ``posts`` are the graph's distinct post-neurons; post ``posts[j]``
    owns fan-in synapses ``fanin_syn[fanin_ptr[j]:fanin_ptr[j + 1]]``
    (sorted by synapse id). ``fanout_ptr``/``fanout_post`` give each
    PRE neuron's hyperedge: the posts it reaches (indexed by global
    pre id, empty rows for neurons with no fan-out).
    """
    posts: np.ndarray           # [P] distinct post ids, ascending
    fanin_ptr: np.ndarray       # [P+1] CSR offsets into fanin_syn
    fanin_syn: np.ndarray       # [E] synapse ids grouped by post
    fanout_ptr: np.ndarray      # [n_neurons+1] CSR offsets per pre
    fanout_post: np.ndarray     # [E] post ids grouped by pre

    @property
    def n_posts(self) -> int:
        return int(len(self.posts))

    def fanin(self, j: int) -> np.ndarray:
        """Synapse ids of post ``posts[j]``."""
        return self.fanin_syn[self.fanin_ptr[j]:self.fanin_ptr[j + 1]]


def hyper_view(g: SNNGraph) -> HyperView:
    """Build the CSR hyperedge view (two argsorts, no Python loops)."""
    e = g.n_synapses
    order = np.argsort(g.post.astype(np.int64) * e + np.arange(e))
    posts = np.unique(g.post).astype(np.int64)
    fanin_ptr = np.searchsorted(g.post[order], np.r_[posts, g.n_neurons])
    fanin_ptr = np.r_[fanin_ptr[:-1], e].astype(np.int64)
    by_pre = np.argsort(g.pre.astype(np.int64) * np.int64(g.n_neurons)
                        + g.post)
    fanout_ptr = np.searchsorted(
        g.pre[by_pre], np.arange(g.n_neurons + 1)).astype(np.int64)
    return HyperView(posts, fanin_ptr, order.astype(np.int64),
                     fanout_ptr, g.post[by_pre].astype(np.int64))


# ---------------------------------------------------------------------------
# Traffic accounting (the hyperedge connectivity metric + chips).
# ---------------------------------------------------------------------------

def multicast_dests(g: SNNGraph, assign: np.ndarray, n_spus: int
                    ) -> np.ndarray:
    """[n_neurons] destination-SPU count of each neuron's hyperedge.

    Entry q is the number of SPUs holding at least one synapse with
    pre q — the MC-tree deliveries one spike of q costs (λ of the
    hyperedge). Zero for neurons without fan-out.
    """
    pairs = np.unique(g.pre.astype(np.int64) * n_spus
                      + assign.astype(np.int64))
    return np.bincount(pairs // n_spus, minlength=g.n_neurons)


def chip_span(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig
              ) -> np.ndarray:
    """[n_neurons] distinct chips each neuron's fan-out spans."""
    chips = hw.chip_of(assign.astype(np.int64))
    pairs = np.unique(g.pre.astype(np.int64) * hw.n_chips + chips)
    return np.bincount(pairs // hw.n_chips, minlength=g.n_neurons)


def mesh_hops(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig
              ) -> np.ndarray:
    """[n_neurons] 2D-mesh hop cost of each neuron's multicast.

    With the chips on an XY-routed ``mesh_x × mesh_y`` grid
    (DESIGN.md §12), a multicast to destination chip set D costs at
    least the half-perimeter of D's bounding box — the hop count of a
    dimension-ordered distribution tree, and the standard wirelength
    proxy the placer/refiner optimize. Zero for neurons whose fan-out
    stays on one chip; on a ``mesh_y == 1`` chain of two chips this is
    exactly the §11 ``span - 1`` forward count.
    """
    mx, my = hw.mesh_dims
    chips = hw.chip_of(assign.astype(np.int64))
    pairs = np.unique(g.pre.astype(np.int64) * hw.n_chips + chips)
    p, c = pairs // hw.n_chips, pairs % hw.n_chips
    cx, cy = c % mx, c // mx
    n = g.n_neurons
    minx = np.full(n, mx, np.int64)
    maxx = np.full(n, -1, np.int64)
    miny = np.full(n, my, np.int64)
    maxy = np.full(n, -1, np.int64)
    np.minimum.at(minx, p, cx)
    np.maximum.at(maxx, p, cx)
    np.minimum.at(miny, p, cy)
    np.maximum.at(maxy, p, cy)
    return np.where(maxx >= 0, (maxx - minx) + (maxy - miny), 0)


def mapping_traffic(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig
                    ) -> dict:
    """Static spike-traffic metrics of a mapping (per source spike).

    ``dests_total`` is the summed hyperedge connectivity λ (fabric
    deliveries if every source fired once); ``inter_chip_total`` the
    summed (chips spanned - 1) forwards; ``mesh_hops_total`` the summed
    2D-mesh bounding-box hops (== ``inter_chip_total`` on a two-chip
    chain). ``dests_total`` is invariant under the chip grouping and
    the chip terms are 0 at ``n_chips=1`` — the conservation the
    multi-chip model must keep.
    """
    dests = multicast_dests(g, assign, hw.n_spus)
    span = chip_span(g, assign, hw)
    sources = dests > 0
    return {
        "dests_total": int(dests.sum()),
        "dests_mean": float(dests[sources].mean()) if sources.any() else 0.0,
        "inter_chip_total": int(np.maximum(span - 1, 0).sum()),
        "mesh_hops_total": int(mesh_hops(g, assign, hw).sum()),
        "n_sources": int(sources.sum()),
    }


def _weighted_spike_counts(ext_spikes: np.ndarray, spikes: np.ndarray,
                           weights: np.ndarray) -> np.ndarray:
    """Per-timestep Σ weights[q] over the firing neurons of each step.

    Mirrors :func:`repro.core.engine.oracle_packet_counts`: the
    distribution phase of timestep t carries the external inputs of t
    plus the internal spikes of t-1. ``weights`` is indexed by global
    neuron id (length ``n_neurons``; the internal block is its tail).
    Accepts ``[T, n]`` or batched ``[B, T, n]`` spike arrays, returning
    ``[T]`` / ``[B, T]`` counts.
    """
    ext = np.asarray(ext_spikes)
    s = np.asarray(spikes)
    if ext.ndim not in (2, 3) or s.ndim != ext.ndim:
        raise ValueError(f"expected matching [T, n] or [B, T, n] arrays; "
                         f"got {ext.shape} and {s.shape}")
    w = np.asarray(weights, np.int64)
    n_in = ext.shape[-1]
    ext_w = w[:n_in]
    int_w = w[len(w) - s.shape[-1]:]
    counts = (ext != 0).astype(np.int64) @ ext_w
    counts[..., 1:] += (s[..., :-1, :] != 0).astype(np.int64) @ int_w
    return counts


def inter_chip_packet_counts(ext_spikes: np.ndarray, spikes: np.ndarray,
                             span: np.ndarray) -> np.ndarray:
    """Per-timestep inter-chip forwarded packets of a run: each firing
    neuron q adds ``max(span[q] - 1, 0)`` forwards (``span`` is the
    :func:`chip_span` vector) — the §11 topology-blind forward count."""
    hops = np.maximum(np.asarray(span, np.int64) - 1, 0)
    return _weighted_spike_counts(ext_spikes, spikes, hops)


def inter_chip_hop_counts(ext_spikes: np.ndarray, spikes: np.ndarray,
                          hops: np.ndarray) -> np.ndarray:
    """Per-timestep inter-chip MESH HOPS of a run: each firing neuron q
    adds ``hops[q]`` (the :func:`mesh_hops` vector), so the cycle
    model's ``inter_chip_hop_cycles`` charge scales with the actual
    XY-mesh distance the multicast travels (DESIGN.md §12)."""
    return _weighted_spike_counts(ext_spikes, spikes,
                                  np.asarray(hops, np.int64))


# ---------------------------------------------------------------------------
# Greedy hyperedge-overlap partitioning.
# ---------------------------------------------------------------------------

def hypergraph_partition(g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                         refine: bool = True, refine_passes: int = 2,
                         balance: bool = True) -> PartitionResult:
    """Deterministic greedy overlap partitioner (Ronzani & Silvano style).

    Whole fan-in groups are placed in descending size order (heaviest
    posts first — they fix the layout the small ones then overlap
    onto). For each post the destination is chosen among the SPUs that
    stay Eq. (9)-feasible by the lexicographic affinity key

        (max shared fan-in pres, min new UM weight lines,
         max remaining Eq. (10) score, min SPU id)

    — multicast reuse first (every shared pre is one MC delivery the
    SPU already receives), weight reuse second, load balance third.
    If no SPU stays feasible the least-overflowing one is taken and
    the result may be infeasible (exactly like the baselines). A
    final :func:`refine_mapping` pass (on by default) cleans up the
    greedy tail, and :func:`balance_loads` (``balance=True``) spreads
    the op load within each chip so the OT depth tracks the mean SPU
    load, not the overlap-greedy maximum. ``seed`` is accepted for the
    :class:`~repro.core.mapping.strategies.MappingStrategy` protocol;
    the algorithm is deterministic and ignores it.
    """
    hv = hyper_view(g)
    m, k, cap = hw.n_spus, hw.concentration, hw.unified_mem_depth
    w_vals, w_id = np.unique(g.weight, return_inverse=True)
    nw = len(w_vals)

    pre_present = np.zeros((m, g.n_neurons), bool)
    w_present = np.zeros((m, nw), bool)
    n_posts = np.zeros(m, np.int64)
    n_weights = np.zeros(m, np.int64)
    assign = np.zeros(g.n_synapses, np.int32)

    sizes = np.diff(hv.fanin_ptr)
    order = np.lexsort((hv.posts, -sizes))      # big fan-ins first
    spu_idx = np.arange(m)
    for j in order:
        syns = hv.fanin(j)
        pres = g.pre[syns].astype(np.int64)     # unique: one syn per (pre, q)
        uw = np.unique(w_id[syns])
        overlap = pre_present[:, pres].sum(1)                    # [M]
        new_w = (~w_present[:, uw]).sum(1)                       # [M]
        lines_now = -(-(n_weights + 1) // k) + n_posts
        lines_after = -(-(n_weights + new_w + 1) // k) + n_posts + 1
        feasible = lines_after <= cap
        if feasible.any():
            # lexicographic affinity key over the feasible SPUs
            f = spu_idx[feasible]
            pick = f[np.lexsort((f, lines_after[f],
                                 lines_after[f] - lines_now[f],
                                 -overlap[f]))[0]]
        else:
            pick = int(np.lexsort((spu_idx, lines_after))[0])
        assign[syns] = pick
        pre_present[pick, pres] = True
        w_present[pick, uw] = True
        n_posts[pick] += 1
        n_weights[pick] = w_present[pick].sum()

    iterations = hv.n_posts
    if refine:
        assign, stats = refine_mapping(g, hw, assign, passes=refine_passes)
        iterations += stats.moves
    if balance:
        assign, bstats = balance_loads(g, hw, assign)
        iterations += bstats["moves"]
    scores = scores_from_assignment(g.weight, g.post, assign, hw)
    return PartitionResult(assign.astype(np.int32), scores,
                           bool(scores.min() >= 0), iterations, 0, [])


# ---------------------------------------------------------------------------
# FM-style boundary refinement under the extended objective.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RefineStats:
    """What one :func:`refine_mapping` call did (and proves)."""
    passes: int
    moves: int
    overflow_before: int
    overflow_after: int
    traffic_before: int
    traffic_after: int


def _overflow(scores: np.ndarray) -> int:
    """Total Eq. (10) violation lines (0 iff the mapping is feasible)."""
    return int(np.maximum(-scores, 0).sum())


def _extent_lut(bits: int) -> np.ndarray:
    """LUT over occupancy bitmasks of one mesh axis: mask -> extent
    (msb - lsb), the axis' contribution to the bounding-box hops."""
    if bits > 16:
        raise ValueError(f"mesh axis of {bits} chips is beyond the LUT "
                         f"model (max 16 per axis)")
    masks = np.arange(1, 1 << bits, dtype=np.int64)
    msb = np.floor(np.log2(masks)).astype(np.int64)
    lsb = np.floor(np.log2(masks & -masks)).astype(np.int64)
    return np.r_[0, msb - lsb]


class _MeshState:
    """Incremental per-axis chip-occupancy state of a mapping.

    For each pre neuron, ``colmask``/``rowmask`` hold the bitmask of
    occupied mesh columns/rows and ``cnt_col``/``cnt_row`` the synapse
    counts behind each bit, so a group move updates masks in O(group)
    and the bounding-box hop total stays exact (== Σ
    :func:`mesh_hops`). Only built when ``n_chips > 1``.
    """

    def __init__(self, hw: HardwareConfig, cnt_pre: np.ndarray):
        self.mx, self.my = hw.mesh_dims
        self.spc = hw.spus_per_chip
        n = cnt_pre.shape[1]
        cnt_chip = cnt_pre.reshape(hw.n_chips, self.spc, n).sum(1)
        self.cnt_col = np.ascontiguousarray(
            cnt_chip.reshape(self.my, self.mx, n).sum(0))        # [mx, n]
        self.cnt_row = np.ascontiguousarray(
            cnt_chip.reshape(self.my, self.mx, n).sum(1))        # [my, n]
        self.colmask = ((self.cnt_col > 0).astype(np.int64)
                        * (np.int64(1) << np.arange(self.mx))[:, None]
                        ).sum(0)                                 # [n]
        self.rowmask = ((self.cnt_row > 0).astype(np.int64)
                        * (np.int64(1) << np.arange(self.my))[:, None]
                        ).sum(0)
        self.ext_x = _extent_lut(self.mx)
        self.ext_y = _extent_lut(self.my)
        self.total = int((self.ext_x[self.colmask]
                          + self.ext_y[self.rowmask]).sum())

    def chip_xy(self, spu):
        c = spu // self.spc
        return c % self.mx, c // self.mx

    def move_masks(self, pres, sx, sy, dx, dy):
        """New (colmask, rowmask) per pre if one synapse of each pre in
        ``pres`` moves from mesh cell (sx, sy) to (dx, dy). ``sx``/``sy``
        are per-pre arrays or scalars; ``dx``/``dy`` scalars."""
        cm, rm = self.colmask[pres], self.rowmask[pres]
        gone_c = self.cnt_col[sx, pres] == 1
        gone_r = self.cnt_row[sy, pres] == 1
        new_cm = np.where(gone_c, cm & ~(np.int64(1) << sx), cm) \
            | (np.int64(1) << dx)
        new_rm = np.where(gone_r, rm & ~(np.int64(1) << sy), rm) \
            | (np.int64(1) << dy)
        return cm, rm, new_cm, new_rm

    def hops_delta(self, pres, src_spu, dst_spu) -> int:
        """Exact Σ bounding-box hop delta of moving one synapse of each
        pre in ``pres`` (unique) from ``src_spu`` to ``dst_spu``."""
        sx, sy = self.chip_xy(src_spu)
        dx, dy = self.chip_xy(dst_spu)
        if sx == dx and sy == dy:
            return 0
        cm, rm, new_cm, new_rm = self.move_masks(pres, sx, sy, dx, dy)
        return int((self.ext_x[new_cm] - self.ext_x[cm]
                    + self.ext_y[new_rm] - self.ext_y[rm]).sum())

    def apply(self, pres, src_spu, dst_spu, delta: int) -> None:
        """Commit a group move (``pres`` unique within the group)."""
        sx, sy = self.chip_xy(src_spu)
        dx, dy = self.chip_xy(dst_spu)
        if sx == dx and sy == dy:
            return
        self.cnt_col[sx, pres] -= 1
        vac = self.cnt_col[sx, pres] == 0
        self.colmask[pres[vac]] &= ~(np.int64(1) << sx)
        self.cnt_col[dx, pres] += 1
        new = self.cnt_col[dx, pres] == 1
        self.colmask[pres[new]] |= np.int64(1) << dx
        self.cnt_row[sy, pres] -= 1
        vac = self.cnt_row[sy, pres] == 0
        self.rowmask[pres[vac]] &= ~(np.int64(1) << sy)
        self.cnt_row[dy, pres] += 1
        new = self.cnt_row[dy, pres] == 1
        self.rowmask[pres[new]] |= np.int64(1) << dy
        self.total += delta


def refine_mapping(g: SNNGraph, hw: HardwareConfig, assign: np.ndarray, *,
                   passes: int = 3, repair_rounds: int = 32
                   ) -> tuple[np.ndarray, RefineStats]:
    """FM-style whole-group boundary refinement of a mapping.

    Moves (SPU, post) fan-in groups between SPUs, accepting a move only
    on STRICT lexicographic improvement of

        J = (overflow, traffic)
        overflow = Σ_i max(0, -score_i)            -- Eq. (10) repair
        traffic  = Σ_q λ(q) + hop · Σ_q mesh(q)    -- multicast reuse

    where λ(q) is the destination-SPU count of neuron q's hyperedge,
    ``mesh(q)`` its 2D-mesh bounding-box hops (:func:`mesh_hops`), and
    ``hop = hw.inter_chip_hop_cycles`` prices each mesh hop
    (DESIGN.md §12; on a two-chip chain the mesh term IS the §11
    ``span - 1`` forward count, bit-identically).

    Each pass (1) snapshots the (SPU, post) grouping, (2) evaluates the
    move deltas of EVERY group to EVERY SPU in one chunked vectorized
    sweep off the occupancy :class:`Books` planes, and (3) applies the
    per-group best strictly-improving candidates in worst-SPU-first
    order, rechecking each against the LIVE books with an O(group)
    scalar pass before committing — stale snapshots (the group moved or
    another merged into it) are skipped, exactly like the former
    per-group scan, so acceptance stays strict and the returned mapping
    NEVER scores worse than the input on (overflow, traffic) — the
    property tests/test_multilevel.py pins. The pass loop stops early
    when a full sweep accepts nothing.

    Snapshot deltas go stale as moves land within a pass, so the batch
    sweeps can stall short of feasibility; up to ``repair_rounds``
    LIVE sweeps over the groups still sitting on overflowing SPUs run
    afterwards (each move strictly reduces total overflow, so the
    lexicographic guarantee holds). Few groups remain by then, which
    keeps the live scan cheap — it is the targeted remainder of the
    former always-live pass.
    """
    m, k, cap = hw.n_spus, hw.concentration, hw.unified_mem_depth
    hop = hw.inter_chip_hop_cycles if hw.n_chips > 1 else 0
    assign = assign.astype(np.int32).copy()
    books = Books(g, hw, assign[None])
    w_id = books.w_id
    nw = books.n_wvals
    pre = g.pre.astype(np.int64)
    post = g.post.astype(np.int64)
    n = g.n_neurons

    cnt_pre = np.zeros((m, n), np.int32)
    np.add.at(cnt_pre, (assign, pre), 1)
    dests = int((cnt_pre > 0).sum())
    mesh = _MeshState(hw, cnt_pre) if hop else None

    scores = books.scores_r(0)
    overflow = _overflow(scores)
    traffic = dests + hop * (mesh.total if mesh else 0)
    stats = RefineStats(0, 0, overflow, overflow, traffic, traffic)

    def lines_of(nw_, np_):
        return -(-(nw_ + 1) // k) + np_

    # chunk caps: bound the [nc, M] / [nc, nw] delta planes and the
    # [M, chunk_synapses] boundary plane to a few tens of MB each
    chunk_syns = max(4096, (1 << 25) // m)
    nc_cap = max(256, (1 << 21) // max(nw, m))

    for _ in range(passes):
        stats.passes += 1
        accepted = False
        # ---- snapshot grouping --------------------------------------------
        key = assign.astype(np.int64) * n + post
        uniq, inv = np.unique(key, return_inverse=True)
        n_groups = len(uniq)
        if not n_groups:
            break
        g_spu = (uniq // n).astype(np.int64)
        g_post = (uniq % n).astype(np.int64)
        syn_order = np.argsort(inv, kind="stable")
        counts = np.bincount(inv, minlength=n_groups)
        starts = np.r_[0, np.cumsum(counts)]

        # ---- batched delta evaluation vs the pass-start snapshot ----------
        best_d = np.zeros(n_groups, np.int64)
        has_cand = np.zeros(n_groups, bool)
        nw0, np0 = books.n_weights[0], books.n_posts[0]
        pen0 = np.maximum(-scores, 0)                            # [M]
        new_w_dest = (books.cnt_w[0] == 0).astype(np.int32)      # [M, nw]
        c0 = 0
        while c0 < n_groups:
            c1 = int(np.searchsorted(starts, starts[c0] + chunk_syns,
                                     side="right")) - 1
            c1 = min(max(c1, c0 + 1), c0 + nc_cap, n_groups)
            nc = c1 - c0
            sz = counts[c0:c1]
            syns_ch = syn_order[starts[c0]:starts[c1]]
            loc = (starts[c0:c1] - starts[c0]).astype(np.intp)
            pres = pre[syns_ch]
            i_ch = g_spu[c0:c1]
            q_ch = g_post[c0:c1]
            rep = np.repeat(np.arange(nc, dtype=np.intp), sz)

            # Δoverflow [nc, M]
            cw_g = np.zeros((nc, nw), np.int32)
            np.add.at(cw_g, (rep, w_id[syns_ch]), 1)
            present = cw_g > 0
            gone_w = ((books.cnt_w[0, i_ch] == cw_g) & present).sum(1)
            new_w = present.astype(np.int32) @ new_w_dest.T      # [nc, M]
            no_q = (books.cnt_post[0][:, q_ch] == 0).T           # [nc, M]
            sc_i_new = cap - lines_of(nw0[i_ch] - gone_w, np0[i_ch] - 1)
            sc_d_new = cap - lines_of(nw0[None, :] + new_w,
                                      np0[None, :] + no_q)
            d_over = (np.maximum(-sc_i_new, 0)[:, None] - pen0[i_ch][:, None]
                      + np.maximum(-sc_d_new, 0) - pen0[None, :])

            # Δdests [nc, M]
            leave = np.add.reduceat(
                (cnt_pre[np.repeat(i_ch, sz), pres] == 1).astype(np.int64),
                loc)
            add_d = np.add.reduceat((cnt_pre[:, pres] == 0).astype(np.int64),
                                    loc, axis=1)                 # [M, nc]
            d_traf = add_d.T - leave[:, None]

            # Δmesh hops [nc, M] (chip-resolution, expanded over SPUs)
            if mesh is not None:
                sx, sy = mesh.chip_xy(i_ch)
                sx_s, sy_s = sx[rep], sy[rep]
                base = (mesh.ext_x[mesh.colmask[pres]]
                        + mesh.ext_y[mesh.rowmask[pres]])
                d_chip = np.zeros((nc, hw.n_chips), np.int64)
                for cd in range(hw.n_chips):
                    dx, dy = cd % mesh.mx, cd // mesh.mx
                    _, _, new_cm, new_rm = mesh.move_masks(
                        pres, sx_s, sy_s, dx, dy)
                    dh = mesh.ext_x[new_cm] + mesh.ext_y[new_rm] - base
                    d_chip[:, cd] = np.add.reduceat(dh, loc)
                d_traf = d_traf + hop * d_chip[
                    :, np.arange(m) // hw.spus_per_chip]

            # per-group best strictly-improving (d_over, d_traf, spu)
            rows = np.arange(nc)
            d_over[rows, i_ch] = 0
            d_traf[rows, i_ch] = 0
            better = (d_over < 0) | ((d_over == 0) & (d_traf < 0))
            better[rows, i_ch] = False
            k1 = 2 * int(np.abs(d_traf).max(initial=0)) + 1
            lex = (d_over * k1 + d_traf) * m + np.arange(m)[None, :]
            lex = np.where(better, lex, np.iinfo(np.int64).max)
            best_d[c0:c1] = np.argmin(lex, axis=1)
            has_cand[c0:c1] = better.any(1)
            c0 = c1

        # ---- apply, worst-SPU-first, with a live-state recheck ------------
        visit = np.lexsort((g_post, scores[g_spu]))
        for gi in visit[has_cand[visit]]:
            i, q, d = int(g_spu[gi]), int(g_post[gi]), int(best_d[gi])
            syns = syn_order[starts[gi]:starts[gi + 1]]
            # groups move whole, so a changed first-synapse owner means
            # the group left i; a changed count means another (i', q)
            # group merged INTO i — either way this snapshot is stale and
            # its deltas would be wrong, so revisit next pass instead
            if int(assign[syns[0]]) != i \
                    or int(books.cnt_post[0, i, q]) != len(syns):
                continue
            pres_g = pre[syns]
            wc = np.bincount(w_id[syns], minlength=nw)
            moved_w = wc > 0
            gone = int(((books.cnt_w[0, i] == wc) & moved_w).sum())
            new = int(((books.cnt_w[0, d] == 0) & moved_w).sum())
            sc_i = cap - lines_of(int(books.n_weights[0, i]) - gone,
                                  int(books.n_posts[0, i]) - 1)
            sc_d = cap - lines_of(
                int(books.n_weights[0, d]) + new,
                int(books.n_posts[0, d])
                + (1 if books.cnt_post[0, d, q] == 0 else 0))
            d_over = (max(-sc_i, 0) - max(-int(scores[i]), 0)
                      + max(-sc_d, 0) - max(-int(scores[d]), 0))
            d_dests = (int((cnt_pre[d, pres_g] == 0).sum())
                       - int((cnt_pre[i, pres_g] == 1).sum()))
            d_mesh = mesh.hops_delta(pres_g, i, d) if mesh else 0
            d_traf = d_dests + hop * d_mesh
            if not (d_over < 0 or (d_over == 0 and d_traf < 0)):
                continue

            books.move_group(0, syns, i, d)
            assign[syns] = d
            cnt_pre[i, pres_g] -= 1
            cnt_pre[d, pres_g] += 1
            if mesh is not None:
                mesh.apply(pres_g, i, d, d_mesh)
            dests += d_dests
            overflow += d_over
            scores[i], scores[d] = sc_i, sc_d
            stats.moves += 1
            accepted = True
        if not accepted:
            break

    # ---- live repair of the residual overflow -----------------------------
    # every accept strictly reduces total overflow (traffic only breaks
    # candidate ties), so this is still a lexicographic improvement
    spus = np.arange(m)
    for _ in range(repair_rounds):
        if overflow <= 0:
            break
        key = assign.astype(np.int64) * n + post
        uniq, inv = np.unique(key, return_inverse=True)
        g_spu = (uniq // n).astype(np.int64)
        g_post = (uniq % n).astype(np.int64)
        syn_order = np.argsort(inv, kind="stable")
        starts = np.r_[0, np.cumsum(np.bincount(inv, minlength=len(uniq)))]
        order = np.lexsort((g_post, scores[g_spu]))
        order = order[scores[g_spu[order]] < 0]
        accepted = False
        nw0, np0 = books.n_weights[0], books.n_posts[0]      # live views
        for gi in order:
            i, q = int(g_spu[gi]), int(g_post[gi])
            if scores[i] >= 0:
                continue
            syns = syn_order[starts[gi]:starts[gi + 1]]
            if int(assign[syns[0]]) != i \
                    or int(books.cnt_post[0, i, q]) != len(syns):
                continue
            pres_g = pre[syns]
            wc = np.bincount(w_id[syns], minlength=nw)
            moved_w = wc > 0
            gone_w = int(((books.cnt_w[0, i] == wc) & moved_w).sum())
            new_w = (books.cnt_w[0][:, moved_w] == 0).sum(1)     # [M]
            no_q = books.cnt_post[0, :, q] == 0
            sc_i_new = cap - lines_of(int(nw0[i]) - gone_w, int(np0[i]) - 1)
            sc_d_new = cap - lines_of(nw0 + new_w, np0 + no_q)
            pen = np.maximum(-scores, 0)
            d_over = (max(-sc_i_new, 0) - pen[i]
                      + np.maximum(-sc_d_new, 0) - pen)
            d_dests = ((cnt_pre[:, pres_g] == 0).sum(1)
                       - int((cnt_pre[i, pres_g] == 1).sum()))
            d_over[i] = 0
            better = d_over < 0
            better[i] = False
            if not better.any():
                continue
            cand = spus[better]
            d = int(cand[np.lexsort((cand, d_dests[cand],
                                     d_over[cand]))[0]])
            d_mesh = mesh.hops_delta(pres_g, i, d) if mesh else 0
            books.move_group(0, syns, i, d)
            assign[syns] = d
            cnt_pre[i, pres_g] -= 1
            cnt_pre[d, pres_g] += 1
            if mesh is not None:
                mesh.apply(pres_g, i, d, d_mesh)
            dests += int(d_dests[d])
            overflow += int(d_over[d])
            scores[i], scores[d] = sc_i_new, int(sc_d_new[d])
            stats.moves += 1
            accepted = True
        if not accepted:
            break

    stats.overflow_after = _overflow(books.scores_r(0))
    stats.traffic_after = dests + hop * (mesh.total if mesh else 0)
    return assign, stats


# ---------------------------------------------------------------------------
# Within-chip OT load balancing (DESIGN.md §12 satellite).
# ---------------------------------------------------------------------------

def balance_loads(g: SNNGraph, hw: HardwareConfig, assign: np.ndarray, *,
                  max_moves: int | None = None
                  ) -> tuple[np.ndarray, dict]:
    """Spread per-SPU op load within each chip under Eq. (9).

    The OT depth tracks the busiest SPU's operation count (≈ its
    synapse count plus stored posts), and the traffic-first greedy/
    refinement concentrate fan-in groups — great for multicast reuse,
    terrible for the schedule. This pass repeatedly moves the
    best-fitting whole (SPU, post) fan-in group from each chip's most-
    loaded SPU to its least-loaded one, accepting a move only when the
    total Eq. (9) violation does not increase (on feasible instances
    the receiving SPU stays feasible; on infeasible ones draining the
    overfull SPU may even repair lines) and the load gap strictly
    shrinks. Moves never cross chips, so the
    chip-level traffic (:func:`mesh_hops`, :func:`chip_span`) is
    INVARIANT — only λ within the chip may grow, which is the recorded
    depth-vs-packets tradeoff (`mapping.hypergraph.balanced_*` rows).

    Returns ``(assign, stats)`` with ``stats`` holding move count and
    the max per-SPU load before/after.
    """
    m, k, cap = hw.n_spus, hw.concentration, hw.unified_mem_depth
    spc = hw.spus_per_chip
    assign = assign.astype(np.int32).copy()
    books = Books(g, hw, assign[None])
    w_id, nw = books.w_id, books.n_wvals
    post = g.post.astype(np.int64)
    if max_moves is None:
        max_moves = 8 * m

    load = (np.bincount(assign, minlength=m).astype(np.int64)
            + books.n_posts[0])
    scores = books.scores_r(0)
    stats = {"moves": 0, "max_load_before": int(load.max(initial=0)),
             "max_load_after": 0}

    # one snapshot grouping; moved groups keep their (new) owner for the
    # rest of the call, so membership never goes stale
    key = assign.astype(np.int64) * g.n_neurons + post
    uniq, inv = np.unique(key, return_inverse=True)
    syn_order = np.argsort(inv, kind="stable")
    starts = np.r_[0, np.cumsum(np.bincount(inv))]
    g_spu = (uniq // g.n_neurons).astype(np.int64)
    g_size = np.diff(starts)

    def lines_of(nw_, np_):
        return -(-(nw_ + 1) // k) + np_

    # per SPU: its group indices, largest first (deterministic)
    by_spu = [[] for _ in range(m)]
    for gi in np.lexsort((np.arange(len(uniq)), -g_size)):
        by_spu[g_spu[gi]].append(int(gi))

    for chip in range(hw.n_chips):
        spus = np.arange(chip * spc, (chip + 1) * spc)
        for _ in range(max_moves // max(hw.n_chips, 1) + 1):
            order = np.argsort(load[spus], kind="stable")
            moved = False
            for i in map(int, spus[order[::-1]]):      # most loaded first
                gis = np.array(by_spu[i], dtype=np.int64)
                if not len(gis):
                    continue
                # evaluate EVERY (group of i -> SPU of chip) move at once:
                # the binding constraint is usually weight lines, so the
                # good receiver is the one already holding the group's
                # weight values — not necessarily the least-loaded SPU
                szs = g_size[gis]
                rep = np.repeat(np.arange(len(gis)), szs)
                syns_all = np.concatenate(
                    [syn_order[starts[gi]:starts[gi + 1]] for gi in gis])
                cw = np.zeros((len(gis), nw), np.int32)
                np.add.at(cw, (rep, w_id[syns_all]), 1)
                present = cw > 0
                gone = ((books.cnt_w[0, i] == cw) & present).sum(1)
                new = present.astype(np.int32) @ \
                    (books.cnt_w[0, spus] == 0).astype(np.int32).T
                q_g = post[syns_all[np.r_[0, np.cumsum(szs)[:-1]]]]
                no_q = (books.cnt_post[0][spus][:, q_g] == 0).T
                sc_i_new = cap - lines_of(
                    int(books.n_weights[0, i]) - gone,
                    int(books.n_posts[0, i]) - 1)            # [G]
                sc_j_new = cap - lines_of(
                    books.n_weights[0, spus][None, :] + new,
                    books.n_posts[0, spus][None, :] + no_q)  # [G, spc]
                d_over = (np.maximum(-sc_i_new, 0)[:, None]
                          - max(-int(scores[i]), 0)
                          + np.maximum(-sc_j_new, 0)
                          - np.maximum(-scores[spus], 0)[None, :])
                gap = load[i] - load[spus]                   # [spc]
                ok = ((d_over <= 0) & (2 * szs[:, None] <= gap[None, :])
                      & (spus[None, :] != i))
                if not ok.any():
                    continue
                gg, jj = np.nonzero(ok)
                # biggest group first, then emptiest receiver, then id
                pick = np.lexsort((spus[jj], load[spus[jj]], -szs[gg]))[0]
                gi, j = int(gis[gg[pick]]), int(spus[jj[pick]])
                sz = int(szs[gg[pick]])
                syns = syn_order[starts[gi]:starts[gi + 1]]
                q = int(post[syns[0]])
                sc_i = int(sc_i_new[gg[pick]])
                sc_j = int(sc_j_new[gg[pick], jj[pick]])
                books.move_group(0, syns, i, j)
                assign[syns] = j
                load[i] -= sz + (1 if books.cnt_post[0, i, q] == 0 else 0)
                load[j] += sz + (1 if books.cnt_post[0, j, q] == sz else 0)
                scores[i], scores[j] = sc_i, sc_j
                by_spu[i].remove(gi)
                by_spu[j].append(gi)
                g_spu[gi] = j
                stats["moves"] += 1
                moved = True
                break
            if not moved:
                break

    stats["max_load_after"] = int(load.max(initial=0))
    return assign, stats
