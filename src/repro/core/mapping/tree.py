"""Partitioning-tree geometry (paper §6.2).

The binary Partitioning Tree mirrors the ME tree: M-1 probability
switches in heap order, M SPU leaves. This module owns the pure
geometry — routing synapses through the switches and the root-path /
LCA tables the rebalancing moves need — all as precomputed arrays so
the search loop never re-derives paths.

``walk`` is batched over arbitrary leading dimensions: the portfolio
search advances a whole restart population with one call on
``[R, M-1, E]`` state instead of R serial walks.
"""
from __future__ import annotations

import numpy as np


def walk(p: np.ndarray, r: np.ndarray, depth: int) -> np.ndarray:
    """Route every synapse through the tree.

    p, r: ``[..., M-1, E]`` switch probabilities and fixed draws (a
    synapse goes LEFT at a switch when R < P). Returns the leaf (SPU)
    index per synapse, ``[..., E]`` int32. Leading dimensions batch
    independent populations (restart seeds) through one call.
    """
    prefix = np.zeros(p.shape[:-2] + p.shape[-1:], np.int64)
    for d in range(depth):
        sw = ((1 << d) - 1 + prefix)[..., None, :]
        pv = np.take_along_axis(p, sw, axis=-2)[..., 0, :]
        rv = np.take_along_axis(r, sw, axis=-2)[..., 0, :]
        prefix = (prefix << 1) | (rv >= pv)
    return prefix.astype(np.int32)


def leaf_paths(depth: int) -> tuple[np.ndarray, np.ndarray]:
    """Root-to-leaf paths for all M = 2**depth leaves.

    Returns ``(switch, side)``, both ``[M, depth]``: ``switch[leaf, d]``
    is the heap index of the switch at depth d on the path to ``leaf``,
    ``side[leaf, d]`` is 0 for left, 1 for right.
    """
    m = 1 << depth
    switch = np.zeros((m, depth), np.int64)
    side = np.zeros((m, depth), np.int8)
    for leaf in range(m):
        prefix = 0
        for d in range(depth):
            s = (leaf >> (depth - 1 - d)) & 1
            switch[leaf, d] = (1 << d) - 1 + prefix
            side[leaf, d] = s
            prefix = (prefix << 1) | s
    return switch, side


def lca_depths(depth: int) -> np.ndarray:
    """``[M, M]`` table: first depth at which the root paths of two
    leaves diverge (== the depth *below* their lowest common ancestor).
    ``lca_depths(d)[a, a] == d`` (identical paths never diverge)."""
    m = 1 << depth
    bits = (np.arange(m)[:, None] >> (depth - 1 - np.arange(depth))) & 1
    diff = bits[:, None, :] != bits[None, :, :]
    return np.where(diff.any(-1), diff.argmax(-1), depth)
