"""Vectorized partition search + portfolio driver (paper §6.2).

Two layers:

1. ``framework_partition`` — the probabilistic rebalancing loop as a
   *population*: K restart seeds share ``[R, M-1, E]`` switch state and
   advance in lockstep, one iteration of every live restart per outer
   step. Within a restart, an iteration is pure array work on the flat
   occupancy planes of :class:`~repro.core.mapping.books.Books` —
   candidate ranking, destination priority, and path updates are numpy
   expressions, not dict churn. Each restart consumes its own RNG
   stream exactly as the reference loop does, so restart k is
   BIT-IDENTICAL to ``legacy.partition_legacy(seed=seed+k)``
   (tests/test_mapping.py proves it).

2. ``portfolio_search`` — the portfolio driver behind
   ``compile(search=SearchConfig(...))``: races the framework
   population against every :data:`repro.core.baselines.BASELINES`
   seed, schedules each feasible candidate under every registered
   schedule strategy, and keeps the best JOINT (mapping, strategy)
   pair by (feasible, min OT depth, min memory) — §6.3
   co-optimization over both axes. Supports early exit at the first
   feasible restart and a wall-clock budget; every candidate is
   recorded in a :class:`SearchTrace` that rides on the
   ``CompileReport``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import Books, PartitionResult
from repro.core.mapping.tree import lca_depths, leaf_paths, walk
from repro.core.memory_model import HardwareConfig, total_memory_kb

_NEVER = -(1 << 30)

# destination priority categories, indexed by has_post*2 + has_weight:
# better-scored SPUs rank {both: 0, post: 1, weight: 2, plain: 5},
# equal-scored ones {both: 3, post: 4, weight: 6, plain: 8 = never}
_LUT_BETTER = (5, 2, 1, 0)
_LUT_EQUAL = (8, 6, 4, 3)


# ---------------------------------------------------------------------------
# The lockstep restart population.
# ---------------------------------------------------------------------------

class _Population:
    """K probabilistic searches advancing in lockstep on batched state."""

    def __init__(self, g: SNNGraph, hw: HardwareConfig, seeds: list[int], *,
                 max_iters: int, eta: float, move_mode: str,
                 stagnation_window: int, cooldown: int, scan_cap: int):
        self.g, self.hw = g, hw
        self.max_iters = max_iters
        self.eta, self.move_mode = eta, move_mode
        self.window, self.cooldown = stagnation_window, cooldown
        self.scan_cap = scan_cap

        n = len(seeds)
        m, depth, e = hw.n_spus, hw.tree_depth, g.n_synapses
        self.n, self.m, self.depth = n, m, depth
        self.rngs = [np.random.default_rng(s) for s in seeds]
        self.p = np.full((n, m - 1, e), 0.5, np.float64)
        self.r = np.stack([rng.random((m - 1, e)) for rng in self.rngs]) \
            if n else np.zeros((0, m - 1, e))
        self.post = g.post.astype(np.int64)

        # batched initial walk: the whole population in one call
        self.assign = walk(self.p, self.r, depth)           # [R, E]
        self.books = Books(g, hw, self.assign)
        self.scores = self.books.scores()                   # [R, M]
        # per-SPU membership (sorted synapse ids), maintained incrementally
        self.mem = [[np.flatnonzero(self.assign[rr] == s) for s in range(m)]
                    for rr in range(n)]
        self.SW, self.SIDE = leaf_paths(depth)
        self.LCA = lca_depths(depth)

        self.moved_at = np.full((n, e), _NEVER, np.int64)
        self.history: list[list[float]] = [[] for _ in range(n)]
        self.perturbations = np.zeros(n, np.int64)
        self.last_improve = np.zeros(n, np.int64)
        self.best_min = self.scores.min(1).astype(np.int64) if n \
            else np.zeros(0, np.int64)
        self.best_total = np.array(
            [self.books.total_usage_r(rr) for rr in range(n)], np.int64)
        self.best_state = [(self.assign[rr].copy(), self.scores[rr].copy())
                           for rr in range(n)]
        self.done = np.zeros(n, bool)
        self.results: list[PartitionResult | None] = [None] * n
        # flat [E*(M-1)] views of each restart's switch state: path updates
        # become 1D fancy indexing (much cheaper than 2D advanced indexing)
        self.e = e
        self.p_flat = [self.p[rr].reshape(-1) for rr in range(n)]
        self.r_flat = [self.r[rr].reshape(-1) for rr in range(n)]
        # per-(ov, dst) below-LCA path constants, precomputed once:
        # (switch row offsets, P deltas away from ov, dst switch offsets,
        #  dst sides == left, dst side count)
        self._paths = {}
        for a in range(m):
            for b in range(m):
                if a == b:
                    continue
                lca = int(self.LCA[a, b])
                sw_a, sd_a = self.SW[a, lca:], self.SIDE[a, lca:]
                sw_b, sd_b = self.SW[b, lca:], self.SIDE[b, lca:]
                self._paths[a, b] = (
                    sw_a * e, np.where(sd_a == 0, -self.eta, self.eta),
                    sw_b * e, sd_b == 0)

    # -- progress & perturbation (identical policy to the reference loop) ----

    def _note_progress(self, rr: int, it: int) -> None:
        scores = self.scores[rr]
        mn = int(scores.min())
        # Eq. (10): score_i = L - usage_i, so total line usage is an O(1)
        # rearrangement of the score sum — no occupancy re-scan
        tot = self.m * self.hw.unified_mem_depth - int(scores.sum())
        if mn > self.best_min[rr]:
            self.best_min[rr] = mn
            self.best_state[rr] = (self.assign[rr].copy(),
                                   self.scores[rr].copy())
            self.last_improve[rr] = it
        if tot < self.best_total[rr]:
            self.best_total[rr] = tot
            self.last_improve[rr] = it

    def _perturb(self, rr: int, it: int) -> None:
        # reflective boundaries: stay uniform, preserve locality
        r = self.r[rr]
        rn = r + self.rngs[rr].uniform(-0.1, 0.1, r.shape)
        rn = np.where(rn < 0.0, -rn, rn)
        rn = np.where(rn > 1.0, 2.0 - rn, rn)
        self.r[rr] = rn
        self.perturbations[rr] += 1
        self.last_improve[rr] = it
        self.assign[rr] = walk(self.p[rr], self.r[rr], self.depth)
        self.books.rebuild(rr, self.assign[rr])
        self.mem[rr] = [np.flatnonzero(self.assign[rr] == s)
                        for s in range(self.m)]
        self.scores[rr] = self.books.scores_r(rr)
        self._note_progress(rr, it)

    def _finish(self, rr: int, it: int, *, from_best: bool) -> None:
        if from_best:
            assign, scores = self.best_state[rr]
            feasible = bool(scores.min() >= 0)
        else:
            assign = self.assign[rr].copy()
            scores = self.scores[rr].copy()
            feasible = True
        self.results[rr] = PartitionResult(
            assign, scores, feasible, it, int(self.perturbations[rr]),
            self.history[rr])
        self.done[rr] = True

    # -- one iteration of one restart (all-array inner work) -----------------

    def _step(self, rr: int, it: int) -> bool:
        """Advance restart ``rr`` one iteration; True when it finished."""
        scores = self.scores[rr]
        ov = int(scores.argmin())
        smin = int(scores[ov])
        if smin >= 0:
            self._finish(rr, it, from_best=False)
            return True
        # == scores.mean(): M small integers are exact in float64
        self.history[rr].append(int(scores.sum()) / self.m)

        # stagnation: no worst-score progress in the window -> shake
        if it - self.last_improve[rr] >= self.window:
            self._perturb(rr, it)
            return False

        books, rng, eta = self.books, self.rngs[rr], self.eta
        cp, cw = books.cnt_post[rr], books.cnt_w[rr]

        # -- rank the overloaded SPU's members in one vector pass --
        members_all = self.mem[rr][ov]
        members = members_all
        if len(members) > self.scan_cap:
            members = members[rng.choice(len(members), self.scan_cap,
                                         replace=False)]
        members = members[it - self.moved_at[rr, members] >= self.cooldown]
        if not len(members):     # everything in ov is cooling down; shake
            self._perturb(rr, it)
            return False
        # the reference loop keeps the members of minimum rank
        # (not pu, not pa, not wu, not wa); lexicographic REFINEMENT —
        # keep the members setting each bit in turn, if any do — selects
        # the identical candidate set in the identical order, but each
        # stage runs on an ever-smaller subset.
        nb = np.flatnonzero(scores == smin)     # the not-better set, incl ov

        def present_on_better(ids, plane, npresent):
            # "present on a better-scored SPU", tested over whichever side
            # of the score split is smaller: directly over the better rows,
            # or via the global presence counter minus the minimum-score
            # rows (a member's own post/weight counts once for ov itself)
            if len(nb) == 1:
                return npresent[ids] > 1
            if 2 * len(nb) - 1 >= self.m:
                bidx = np.flatnonzero(scores > smin)
                if not len(bidx):
                    return np.zeros(len(ids), bool)
                return (plane[bidx[:, None], ids] > 0).any(0)
            nbo = nb[nb != ov]
            return (npresent[ids]
                    - (plane[nbo[:, None], ids] > 0).sum(0)) > 1

        mp = self.post[members]
        pu = cp[ov, mp] == 1                    # frees a whole line in ov
        if pu.any():
            members = members[pu]
            mp = mp[pu]
        pa = present_on_better(mp, cp, books.np_post[rr])
        if pa.any():
            members = members[pa]
        mw = books.w_id[members]
        wu = cw[ov, mw] == 1
        if wu.any():
            members = members[wu]
            mw = mw[wu]
        wa = present_on_better(mw, cw, books.np_w[rr])
        if wa.any():
            members = members[wa]
        cands = members
        syn = int(cands[rng.integers(len(cands))])
        sp = int(self.post[syn])
        swid = int(books.w_id[syn])

        # -- destination by the 4-level priority among higher-scored SPUs,
        # falling back to consolidating moves into equal-scored ones; a
        # scalar scan of the M SPUs beats array ops at M=16 --
        cat_best, s_best, dst = 9, 0, -1
        sc = scores.tolist()
        hp = (cp[:, sp] > 0).tolist()
        hw_ = (cw[:, swid] > 0).tolist()
        for i in range(self.m):
            s = sc[i]
            if i == ov or s < smin:
                continue
            if s > smin:                       # better-scored SPU
                c = _LUT_BETTER[hp[i] * 2 + hw_[i]]
            else:                              # equal: consolidating only
                c = _LUT_EQUAL[hp[i] * 2 + hw_[i]]
                if c > 6:                      # plain equal: not a dest
                    continue
            if c < cat_best or (c == cat_best and s > s_best):
                cat_best, s_best, dst = c, s, i
        if dst < 0:  # nowhere productive to move; shake and retry
            self._perturb(rr, it)
            return False

        # -- adjust probabilities along both paths below the LCA; the flat
        # views turn every update into cheap 1D fancy indexing. Only the
        # entries touched here can leave [0, 1] (decisive placements are
        # in range by construction), so clipping them IS the reference
        # loop's whole-column clip --
        off_ov, delta_ov, off_dst, left_dst = self._paths[ov, dst]
        p1, r1 = self.p_flat[rr], self.r_flat[rr]
        io = off_ov + syn
        v = p1[io] + delta_ov
        np.minimum(v, 1.0, out=v)
        np.maximum(v, 0.0, out=v)
        p1[io] = v
        idd = off_dst + syn
        if self.move_mode == "decisive":
            # land exactly in dst: put P just past R on its path
            rv = r1[idd]
            p1[idd] = np.where(left_dst,
                               np.minimum(1.0, rv + eta),
                               np.maximum(0.0, rv - eta))
        else:
            v = p1[idd] + np.where(left_dst, eta, -eta)
            np.minimum(v, 1.0, out=v)
            np.maximum(v, 0.0, out=v)
            p1[idd] = v

        # -- re-route the synapse (only its own entries changed) --
        if self.move_mode == "decisive":
            new_spu = dst
        else:
            prefix = 0
            for d in range(self.depth):
                sw = (1 << d) - 1 + prefix
                prefix = (prefix << 1) | int(r1[sw * self.e + syn]
                                             >= p1[sw * self.e + syn])
            new_spu = int(prefix)
        if new_spu != self.assign[rr, syn]:
            books.move_one(rr, syn, ov, new_spu)
            self.assign[rr, syn] = new_spu
            self.moved_at[rr, syn] = it
            mem = self.mem[rr]
            # POST-GROUP BURST: once the post exists in dst, every further
            # synapse of (ov, post) ranks dst first — fast-forward those
            # consecutive single moves as ONE sliced update (DESIGN.md §8)
            if self.move_mode == "decisive":       # new_spu == dst
                # only syn moved since members_all was taken, so ov's
                # remaining (ov, post) group is a filter of it
                mask_sp = self.post[members_all] == sp
                moving = members_all[mask_sp]      # the whole fan-in group
                mem[ov] = members_all[~mask_sp]
                darr = mem[dst]
                # sorted merge of the group into dst (np.insert, sans its
                # python overhead)
                out = np.empty(len(darr) + len(moving), darr.dtype)
                at = np.searchsorted(darr, moving) + np.arange(len(moving))
                keep = np.ones(len(out), bool)
                keep[at] = False
                out[at] = moving
                out[keep] = darr
                mem[dst] = out
                rest = moving[moving != syn]
                if len(rest):
                    nres = len(rest)
                    idx = (off_ov[:, None] + rest).ravel()
                    v = p1[idx] + np.repeat(delta_ov, nres)
                    np.minimum(v, 1.0, out=v)
                    np.maximum(v, 0.0, out=v)
                    p1[idx] = v
                    idx = (off_dst[:, None] + rest).ravel()
                    rb = r1[idx]
                    p1[idx] = np.where(np.repeat(left_dst, nres),
                                       np.minimum(1.0, rb + eta),
                                       np.maximum(0.0, rb - eta))
                    books.move_group(rr, rest, ov, dst)
                    self.assign[rr, rest] = dst
                    self.moved_at[rr, rest] = it
            else:
                pos = int(np.searchsorted(members_all, syn))
                mem[ov] = np.concatenate([members_all[:pos],
                                          members_all[pos + 1:]])
                darr = mem[new_spu]
                pos = int(np.searchsorted(darr, syn))
                mem[new_spu] = np.concatenate(
                    [darr[:pos], np.array([syn], darr.dtype), darr[pos:]])
            # only ov and the destination changed occupancy: refresh their
            # two Eq. (10) entries in place instead of rebuilding [M]
            k, l = self.hw.concentration, self.hw.unified_mem_depth
            for i in (ov, new_spu):
                scores[i] = l - (-(-(int(books.n_weights[rr, i]) + 1) // k)
                                 + int(books.n_posts[rr, i]))
            self._note_progress(rr, it)
        return False

    # -- the lockstep driver -------------------------------------------------

    def run(self, *, early_exit: bool = True,
            deadline: float | None = None) -> bool:
        """Advance all restarts; returns True if the wall-clock budget
        cut the search short."""
        for it in range(self.max_iters):
            if self.done.all():
                return False
            feasible_now = False
            for rr in range(self.n):
                # deadline INSIDE the restart sweep: one restart's step is
                # the atomic unit, so a slow sweep over a large population
                # cannot overshoot the budget by more than a single step
                if deadline is not None and time.perf_counter() >= deadline:
                    self._abort_active(it)
                    return True
                if not self.done[rr] and self._step(rr, it):
                    feasible_now |= self.results[rr].feasible
            if early_exit and feasible_now:
                self._abort_active(it)
                return False
        # max_iters exhausted: remaining restarts fall back to best state
        for rr in range(self.n):
            if not self.done[rr]:
                self._finish(rr, self.max_iters, from_best=True)
        return False

    def _abort_active(self, it: int) -> None:
        for rr in range(self.n):
            if not self.done[rr]:
                self._finish(rr, it, from_best=True)


def framework_partition(g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                        restarts: int = 1, max_iters: int = 50000,
                        eta: float = 0.25, move_mode: str = "decisive",
                        stagnation_window: int = 300, cooldown: int = 64,
                        scan_cap: int = 384, early_exit: bool = True,
                        deadline: float | None = None,
                        ) -> tuple[PartitionResult, list[PartitionResult],
                                   bool]:
    """Run the vectorized framework search over ``restarts`` seeds.

    Returns ``(winner, all_results, budget_exhausted)``. The winner is
    the lowest-seed feasible restart, else the best worst-SPU score
    (earliest seed on ties). With ``restarts > 1`` the lockstep
    population differs from the old serial loop (DESIGN.md §8): under
    ``early_exit`` the FIRST restart to reach feasibility wins by
    iteration count, where the serial loop ran seeds to completion in
    seed order — so multi-restart results may differ from pre-refactor
    runs. Single-restart behavior is bit-identical to the reference.
    """
    seeds = [seed + k for k in range(max(restarts, 1))]
    pop = _Population(g, hw, seeds, max_iters=max_iters, eta=eta,
                      move_mode=move_mode,
                      stagnation_window=stagnation_window,
                      cooldown=cooldown, scan_cap=scan_cap)
    exhausted = pop.run(early_exit=early_exit, deadline=deadline)
    results = [res for res in pop.results if res is not None]
    # same preference order as the old serial restart loop: the first
    # feasible seed, else the best worst-SPU score (earliest on ties)
    winner = next((res for res in results if res.feasible), None)
    if winner is None:
        winner = max(results, key=lambda res: res.scores.min())
    return winner, results, exhausted


# ---------------------------------------------------------------------------
# The portfolio driver.
# ---------------------------------------------------------------------------

#: above this synapse count the "auto" portfolio also races ``multilevel``
LARGE_GRAPH_SYNAPSES = 50_000


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Knobs of the portfolio mapping search (``compile(search=...)``).

    ``extra_strategies`` names registered
    :class:`~repro.core.mapping.strategies.MappingStrategy` entries
    raced alongside the baselines and framework restarts. The default
    ``"auto"`` races ``hypergraph`` always and adds ``multilevel``
    above :data:`LARGE_GRAPH_SYNAPSES` synapses; pass ``()`` for the
    pre-§11 portfolio.

    ``workers > 1`` fans the mapping candidates across a process pool
    (:mod:`concurrent.futures`). Each framework restart then runs as an
    independent single-seed search (identical to
    ``framework_partition(seed=seed+k, restarts=1)``), and results are
    reduced in fixed candidate order, so the winner never depends on
    worker timing — only the wall-clock ``budget_seconds`` can shrink
    the candidate set (a deterministic PREFIX of it, plus the always-
    awaited first candidate). ``early_exit`` has no cross-restart
    effect in the parallel path.
    """
    restarts: int = 4                    # framework population size; also
                                         # sizes the multilevel coarse race
    seed: int = 0                        # first restart seed
    max_iters: int = 20000               # per-restart iteration budget
    include_baselines: bool = True       # race the round-robin seeds too
    early_exit: bool = True              # stop at the first feasible restart
    budget_seconds: float | None = None  # wall-clock cap on the whole search
    workers: int = 1                     # mapping-candidate process pool
    extra_strategies: tuple | str | None = "auto"    # see class docstring


@dataclasses.dataclass
class CandidateTrace:
    """One candidate mapping tried by the portfolio search."""
    strategy: str                 # "framework" or a baseline name
    seed: int | None              # restart seed (None for baselines)
    feasible: bool
    min_score: int                # worst-SPU Eq. (10) score
    iterations: int
    seconds: float
    ot_depth: int | None = None   # best strategy's depth (feasible only)
    memory_kb: float | None = None        # Eq. (11) at this OT depth
    memory_lines: int | None = None       # total UM lines the mapping uses
    selected: bool = False
    # joint co-optimization (§6.3): the best ScheduleStrategy for this
    # mapping, and the OT depth under every registered strategy
    schedule_method: str | None = None
    schedule_depths: dict | None = None


@dataclasses.dataclass
class SearchTrace:
    """Per-candidate record of one portfolio search."""
    candidates: list[CandidateTrace]
    seconds: float
    budget_exhausted: bool = False

    @property
    def n_feasible(self) -> int:
        return sum(c.feasible for c in self.candidates)

    @property
    def selected(self) -> CandidateTrace:
        return next(c for c in self.candidates if c.selected)

    def to_json(self) -> dict:
        return {"seconds": self.seconds,
                "budget_exhausted": self.budget_exhausted,
                "candidates": [dataclasses.asdict(c)
                               for c in self.candidates]}

    @classmethod
    def from_json(cls, d: dict) -> "SearchTrace":
        return cls(candidates=[CandidateTrace(**c)
                               for c in d.get("candidates", [])],
                   seconds=float(d.get("seconds", 0.0)),
                   budget_exhausted=bool(d.get("budget_exhausted", False)))


def _resolve_extras(cfg: SearchConfig, g: SNNGraph) -> tuple:
    if cfg.extra_strategies == "auto":
        return (("hypergraph", "multilevel")
                if g.n_synapses > LARGE_GRAPH_SYNAPSES else ("hypergraph",))
    return tuple(cfg.extra_strategies or ())


def _eval_spec(g: SNNGraph, hw: HardwareConfig, spec: tuple, seed: int,
               max_iters: int, budget: float | None = None,
               restarts: int = 1, strategy_workers: int = 1
               ) -> tuple[PartitionResult, float]:
    """Evaluate one mapping candidate (a process-pool work item).

    ``spec`` is ``("framework", restart_seed)``, ``("baseline", name)``
    or ``("strategy", name)``. Top-level so it pickles; strategies are
    resolved from the import-time registry. Workers start via *spawn*
    (fork after jax's thread pools exist can deadlock), so only
    strategies registered at import of ``repro.core.mapping`` exist in
    the children — a custom ``extra_strategies`` entry registered at
    runtime needs ``workers=1`` and surfaces here as a ``KeyError``.

    ``restarts``/``strategy_workers`` parameterize ``("strategy", ...)``
    specs only (the multilevel coarse-candidate race); framework specs
    are one restart each by construction. ``strategy_workers`` stays 1
    inside a pool worker — nesting process pools would oversubscribe —
    and strategy results are worker-count-invariant, so the serial and
    parallel portfolio paths still agree.
    """
    kind, val = spec
    t0 = time.perf_counter()
    if kind == "framework":
        deadline = None if budget is None else t0 + budget
        res, _, _ = framework_partition(g, hw, seed=val, restarts=1,
                                        max_iters=max_iters,
                                        deadline=deadline)
    elif kind == "baseline":
        from repro.core.baselines import BASELINES
        res = BASELINES[val](g, hw)
    else:
        from repro.core.mapping.strategies import get_strategy
        res = get_strategy(val).partition(g, hw, seed=seed,
                                          max_iters=max_iters,
                                          restarts=restarts,
                                          workers=strategy_workers)
    return res, time.perf_counter() - t0


def _trace_of(spec: tuple, cfg: SearchConfig, res: PartitionResult,
              seconds: float) -> CandidateTrace:
    kind, val = spec
    return CandidateTrace(
        strategy="framework" if kind == "framework" else val,
        seed=(val if kind == "framework"
              else cfg.seed if kind == "strategy" else None),
        feasible=res.feasible, min_score=int(res.scores.min()),
        iterations=res.iterations, seconds=seconds)


def _parallel_candidates(g, hw, cfg: SearchConfig, specs: list[tuple],
                         deadline: float | None
                         ) -> tuple[list, bool]:
    """Fan the candidate specs over a process pool; reduce in spec order.

    The first candidate is always awaited (compile needs at least one
    mapping); afterwards each result gets whatever budget remains, and
    a timeout abandons the rest — the surviving set is a prefix of the
    fixed spec order, never a function of which worker finished first.
    """
    import concurrent.futures as cf
    import multiprocessing

    entries: list[tuple[CandidateTrace, PartitionResult]] = []
    exhausted = False
    budget = None if deadline is None \
        else max(deadline - time.perf_counter(), 0.05)
    ctx = multiprocessing.get_context("spawn")
    with cf.ProcessPoolExecutor(max_workers=cfg.workers,
                                mp_context=ctx) as ex:
        futs = [ex.submit(_eval_spec, g, hw, s, cfg.seed, cfg.max_iters,
                          budget, cfg.restarts) for s in specs]
        for i, fut in enumerate(futs):
            timeout = None
            if i > 0 and deadline is not None:
                timeout = max(deadline - time.perf_counter(), 0.0)
            try:
                res, secs = fut.result(timeout=timeout)
            except cf.TimeoutError:
                exhausted = True
                for other in futs[i:]:
                    other.cancel()
                ex.shutdown(wait=False, cancel_futures=True)
                break
            entries.append((_trace_of(specs[i], cfg, res, secs), res))
    return entries, exhausted


def portfolio_search(g: SNNGraph, hw: HardwareConfig,
                     config: SearchConfig | None = None):
    """Joint portfolio search over (mapping, schedule strategy) pairs.

    Framework restarts are raced against the round-robin baselines;
    every feasible candidate mapping is then scheduled under EVERY
    registered :class:`~repro.core.scheduling.ScheduleStrategy`, and
    the joint pair minimizing (infeasible, OT depth, memory) wins —
    the paper's §6.3 co-optimization closed over both axes.

    Returns ``(part, trace, tables)`` where ``tables`` is the winner's
    already-scheduled OpTables under its best strategy (None if the
    winner is infeasible — callers schedule it themselves, matching
    single-seed ``compile``). The winning strategy and per-strategy
    depths ride on ``trace.selected.schedule_method`` /
    ``.schedule_depths``.
    """
    from repro.core.baselines import BASELINES          # no import cycle
    from repro.core.scheduling import (SCHEDULE_STRATEGIES, group_info,
                                       schedule)

    cfg = config or SearchConfig()
    t0 = time.perf_counter()
    deadline = None if cfg.budget_seconds is None else t0 + cfg.budget_seconds
    exhausted = False
    extras = _resolve_extras(cfg, g)

    if cfg.workers > 1:
        specs: list[tuple] = []
        if cfg.include_baselines:
            specs += [("baseline", name) for name in BASELINES]
        specs += [("strategy", name) for name in extras]
        specs += [("framework", cfg.seed + k)
                  for k in range(max(cfg.restarts, 1))]
        entries, exhausted = _parallel_candidates(g, hw, cfg, specs,
                                                  deadline)
    else:
        entries = []
        if cfg.include_baselines:
            for name, fn in BASELINES.items():
                if deadline is not None and time.perf_counter() >= deadline:
                    exhausted = True
                    break
                tb = time.perf_counter()
                res = fn(g, hw)
                entries.append((CandidateTrace(
                    strategy=name, seed=None, feasible=res.feasible,
                    min_score=int(res.scores.min()),
                    iterations=res.iterations,
                    seconds=time.perf_counter() - tb), res))

        for name in extras:
            if entries and deadline is not None \
                    and time.perf_counter() >= deadline:
                exhausted = True
                break
            res, secs = _eval_spec(g, hw, ("strategy", name), cfg.seed,
                                   cfg.max_iters, restarts=cfg.restarts,
                                   strategy_workers=cfg.workers)
            entries.append((_trace_of(("strategy", name), cfg, res, secs),
                            res))

        tb = time.perf_counter()
        _, fw_results, fw_exhausted = framework_partition(
            g, hw, seed=cfg.seed, restarts=cfg.restarts,
            max_iters=cfg.max_iters, early_exit=cfg.early_exit,
            deadline=deadline)
        exhausted |= fw_exhausted
        fw_seconds = time.perf_counter() - tb
        for k, res in enumerate(fw_results):
            entries.append((CandidateTrace(
                strategy="framework", seed=cfg.seed + k,
                feasible=res.feasible, min_score=int(res.scores.min()),
                iterations=res.iterations,
                seconds=fw_seconds / max(len(fw_results), 1)), res))

    # schedule the feasible candidates under EVERY registered schedule
    # strategy: min OT depth over strategies decides the race, with
    # total memory-line usage (the assignment's real footprint — memory_kb
    # is a pure function of depth for fixed hw) as the tie-breaker. The
    # budget still applies: once it is spent, at least one feasible
    # candidate is scheduled (compile needs its tables) and the rest keep
    # ot_depth=None. Strategy ties go to the earliest-registered name
    # (the 'slack' default), so results are deterministic.
    scheduled: dict[int, object] = {}
    m, l = hw.n_spus, hw.unified_mem_depth
    for i, (ct, res) in enumerate(entries):
        if not ct.feasible:
            continue
        ct.memory_lines = int(m * l - res.scores.sum())     # Eq. (10) sum
        if scheduled and deadline is not None \
                and time.perf_counter() >= deadline:
            exhausted = True
            continue
        info = group_info(g, res.assign)        # one grouping, S strategies
        depths: dict[str, int] = {}
        best_tables = best_name = None
        for name in SCHEDULE_STRATEGIES:
            tables = schedule(g, res.assign, hw, method=name, info=info)
            depths[name] = int(tables.depth)
            if best_tables is None or tables.depth < best_tables.depth:
                best_tables, best_name = tables, name
        scheduled[i] = best_tables
        ct.ot_depth = int(best_tables.depth)
        ct.schedule_method = best_name
        ct.schedule_depths = depths
        ct.memory_kb = float(total_memory_kb(hw, best_tables.depth))

    feasible = [i for i, (ct, _) in enumerate(entries) if ct.feasible]
    if feasible:
        win = min(feasible,
                  key=lambda i: (entries[i][0].ot_depth is None,
                                 entries[i][0].ot_depth or 0,
                                 entries[i][0].memory_lines))
    else:   # nothing feasible anywhere: closest-to-feasible candidate
        win = max(range(len(entries)),
                  key=lambda i: entries[i][0].min_score)
    ct, best = entries[win]
    ct.selected = True
    tables = scheduled.get(win)     # winner's tables, reused by compile
    trace = SearchTrace(candidates=[c for c, _ in entries],
                        seconds=time.perf_counter() - t0,
                        budget_exhausted=exhausted)
    return best, trace, tables
