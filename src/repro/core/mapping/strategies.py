"""Pluggable mapping-strategy registry.

``partition_pass`` used to special-case ``method`` strings ("framework"
vs keys of ``baselines.BASELINES``). Every way of producing a synapse ->
SPU assignment now implements one protocol and lives in one registry;
the pass just resolves the name. Registering a new strategy (an ILP
mapper, a hardware-vendor heuristic, a learned policy) is one
``register_strategy`` call — no compiler changes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

from repro.core.graph import SNNGraph
from repro.core.mapping.books import PartitionResult
from repro.core.mapping.search import framework_partition
from repro.core.memory_model import HardwareConfig


@runtime_checkable
class MappingStrategy(Protocol):
    """One way of producing a synapse -> SPU assignment."""

    name: str

    def partition(self, g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                  max_iters: int = 20000, restarts: int = 1,
                  workers: int = 1) -> PartitionResult:
        ...


@dataclasses.dataclass(frozen=True)
class FrameworkStrategy:
    """The paper's probabilistic search (§6.2), vectorized population."""

    name: str = "framework"

    def partition(self, g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                  max_iters: int = 20000, restarts: int = 1,
                  workers: int = 1) -> PartitionResult:
        winner, _, _ = framework_partition(g, hw, seed=seed,
                                           max_iters=max_iters,
                                           restarts=restarts)
        return winner


@dataclasses.dataclass(frozen=True)
class HypergraphStrategy:
    """Greedy hyperedge-overlap mapping + FM refinement (DESIGN.md §11);
    deterministic, so seed/iters/restarts are ignored."""

    name: str = "hypergraph"

    def partition(self, g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                  max_iters: int = 20000, restarts: int = 1,
                  workers: int = 1) -> PartitionResult:
        from repro.core.mapping.hypergraph import hypergraph_partition
        return hypergraph_partition(g, hw, seed=seed)


@dataclasses.dataclass(frozen=True)
class MultilevelStrategy:
    """Coarsen–partition–refine for compiler-scale graphs (DESIGN.md §11)."""

    name: str = "multilevel"

    def partition(self, g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                  max_iters: int = 20000, restarts: int = 1,
                  workers: int = 1) -> PartitionResult:
        from repro.core.mapping.multilevel import multilevel_partition
        return multilevel_partition(g, hw, seed=seed, max_iters=max_iters,
                                    restarts=restarts, workers=workers)


@dataclasses.dataclass(frozen=True)
class BaselineStrategy:
    """A deterministic baseline (paper §7.4.1); seed/iters are ignored."""

    name: str
    fn: Callable[[SNNGraph, HardwareConfig], PartitionResult]

    def partition(self, g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
                  max_iters: int = 20000, restarts: int = 1,
                  workers: int = 1) -> PartitionResult:
        return self.fn(g, hw)


STRATEGIES: dict[str, MappingStrategy] = {}


def register_strategy(strategy: MappingStrategy, *,
                      replace: bool = False) -> MappingStrategy:
    """Add a strategy to the registry (its ``name`` is the compile
    ``method=`` key). Re-registering a taken name requires
    ``replace=True``."""
    if not replace and strategy.name in STRATEGIES:
        raise ValueError(f"mapping strategy {strategy.name!r} already "
                         f"registered; pass replace=True to override")
    STRATEGIES[strategy.name] = strategy
    return strategy


def get_strategy(name: str) -> MappingStrategy:
    """Resolve a ``method=`` name; unknown names list what exists."""
    try:
        return STRATEGIES[name]
    except KeyError:
        raise ValueError(f"unknown method {name!r}; "
                         f"use one of {sorted(STRATEGIES)}") from None


def _register_builtins() -> None:
    from repro.core.baselines import BASELINES
    register_strategy(FrameworkStrategy(), replace=True)
    register_strategy(HypergraphStrategy(), replace=True)
    register_strategy(MultilevelStrategy(), replace=True)
    for name, fn in BASELINES.items():
        register_strategy(BaselineStrategy(name, fn), replace=True)


_register_builtins()
