"""Compile-phase profiler (DESIGN.md §12).

The compile pipeline is a handful of named passes, but at compiler
scale (10⁵–10⁶ synapses) the interesting costs live INSIDE one of them
— the multilevel partitioner's coarsen / coarse-search / project /
refine stages. A :class:`PhaseProfiler` accumulates wall seconds (and
optionally allocation deltas) per named phase; the active profiler is
carried in a :class:`contextvars.ContextVar` so deeply nested stages
record phases without threading a profiler argument through every
mapping-strategy signature.

Usage::

    with profiled(PhaseProfiler()) as prof:
        ...                         # any code calling phase("name")
    prof.seconds                    # {"coarsen": 0.07, "refine": 0.61, ...}

``phase("name")`` is a no-op context manager when no profiler is
active, so instrumented code costs nothing in un-profiled runs
(tests/test_profiling.py pins both behaviors). Phases may repeat and
nest; repeated entries accumulate, nested phases are recorded under
their own names (the compile pipeline's top-level pass phases —
``partition``/``schedule``/``validate``/``lower``/``report`` — contain
the partitioner's sub-phases, so summing ONLY the top-level keys gives
the pipeline total).
"""
from __future__ import annotations

import contextlib
import contextvars
import time
import tracemalloc

#: the compile pipeline's top-level pass phases; they tile the whole
#: compile, so their sum approximates ``CompileReport.compile_seconds``
#: (sub-phases like ``coarsen``/``refine`` nest inside ``partition``)
TOP_LEVEL_PHASES = ("partition", "schedule", "validate", "lower", "report")


class PhaseProfiler:
    """Accumulates per-phase wall seconds (and, optionally, allocation).

    ``alloc=True`` additionally records each phase's net allocation
    delta and in-phase peak, in MB, via :mod:`tracemalloc` (started by
    :func:`profiled` if not already tracing) — useful for attributing
    the compiler's RSS, at a 2–4x wall-clock cost.
    """

    def __init__(self, *, alloc: bool = False):
        self.alloc = alloc
        self.seconds: dict[str, float] = {}
        self.alloc_mb: dict[str, float] = {}
        self.peak_mb: dict[str, float] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        if self.alloc:
            tracemalloc.reset_peak()
            base, _ = tracemalloc.get_traced_memory()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.seconds[name] = self.seconds.get(name, 0.0) + dt
            if self.alloc:
                cur, peak = tracemalloc.get_traced_memory()
                mb = 1024.0 * 1024.0
                self.alloc_mb[name] = (self.alloc_mb.get(name, 0.0)
                                       + (cur - base) / mb)
                self.peak_mb[name] = max(self.peak_mb.get(name, 0.0),
                                         peak / mb)


_ACTIVE: contextvars.ContextVar[PhaseProfiler | None] = \
    contextvars.ContextVar("suprasnn_phase_profiler", default=None)


def current_profiler() -> PhaseProfiler | None:
    """The profiler installed by the innermost :func:`profiled`, if any."""
    return _ACTIVE.get()


@contextlib.contextmanager
def profiled(profiler: PhaseProfiler | None = None):
    """Install ``profiler`` (a fresh wall-only one if omitted) as the
    active profiler for the dynamic extent of the block."""
    prof = profiler if profiler is not None else PhaseProfiler()
    started_tracing = False
    if prof.alloc and not tracemalloc.is_tracing():
        tracemalloc.start()
        started_tracing = True
    token = _ACTIVE.set(prof)
    try:
        yield prof
    finally:
        _ACTIVE.reset(token)
        if started_tracing:
            tracemalloc.stop()


class _NullPhase:
    """Shared no-op context manager: ``phase()`` without an active
    profiler must cost nothing (no generator frame, no allocation)."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_PHASE = _NullPhase()


def phase(name: str):
    """Record a named phase on the active profiler (no-op when none)."""
    prof = _ACTIVE.get()
    return _NULL_PHASE if prof is None else prof.phase(name)
