"""Heuristic scheduling (paper §6.3) — compatibility shim.

The implementation moved to the :mod:`repro.core.scheduling` package
(DESIGN.md §7.2): ``scheduling.tables`` owns the OpTables /
LoweredProgram containers and the lowering, ``scheduling.vectorized``
the array-core scheduler, ``scheduling.legacy`` the preserved reference
loop, ``scheduling.strategies`` the registry behind
``compile(schedule_method=...)``, and ``scheduling.validate`` the
legality checks.

:func:`repro.core.scheduling.schedule` keeps the original signature and
is BIT-IDENTICAL to the pre-split scheduler for the default
``method='slack'`` (the parity suite in tests/test_scheduling.py
enforces tables, send_slot/send_order, and infeasibility-message
equality against the preserved loop).
"""
from repro.core.scheduling import (NOP, LoweredProgram,  # noqa: F401
                                   OpTables, lower_tables, schedule,
                                   validate_schedule)

__all__ = ["NOP", "OpTables", "LoweredProgram", "lower_tables",
           "schedule", "validate_schedule"]
