"""Heuristic scheduling (paper §6.3).

Given a synapse->SPU assignment, produce per-SPU *Operation Tables* whose
execution order guarantees ME-tree merge correctness: every SPU holding
synapses of post-neuron p injects p's partial current in the SAME slot.

Algorithm (faithful to the paper, plus an explicit send-slot recurrence
that guarantees backward-fill feasibility):

  1. Sort post-neurons ascending by max-synapses-on-any-single-SPU
     (high-fan-in posts transmit last, maximizing slack).
  2. Walk the sorted order keeping per-SPU cumulative op counts cum_i;
     post p gets send slot  t_p = max(t_prev + 1, max_i cum_i(p) - 1).
     (The paper uses consecutive slots, which suffices when #posts >=
     per-SPU load; the max() generalizes it — with balanced load the depth
     converges to max_i(total ops_i), exactly the paper's Fig. 13 regime.)
  3. Fix one synapse of each (SPU, post) group at t_p with Post-End set.
  4. Backward-fill the remaining synapses into free earlier slots,
     processing posts in REVERSE send order (EDF-style; provably feasible
     given the recurrence in 2).
  5. Set Pre-End on the last op referencing each pre-synaptic neuron.
  6. Remaining slots are NOPs.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.memory_model import HardwareConfig


NOP = -1


@dataclasses.dataclass
class OpTables:
    """The mapped + scheduled program for the whole engine."""
    depth: int                  # S_OT: operation-table depth == #slots
    # all arrays are [M, depth]; NOP slots have pre == NOP
    pre: np.ndarray             # global pre-neuron index
    post: np.ndarray            # global post-neuron index
    weight: np.ndarray          # int weight value
    pre_end: np.ndarray         # bool
    post_end: np.ndarray        # bool
    send_slot: dict             # post global idx -> slot
    send_order: list            # posts in send order
    assign: np.ndarray          # [E] synapse -> SPU (the partition)

    @property
    def n_spus(self) -> int:
        return self.pre.shape[0]

    @classmethod
    def from_dense(cls, pre: np.ndarray, post: np.ndarray, weight: np.ndarray,
                   pre_end: np.ndarray, post_end: np.ndarray,
                   assign: np.ndarray) -> "OpTables":
        """Rebuild OpTables from the dense arrays alone.

        ``send_slot``/``send_order`` are derived, not stored: every
        Post-End op of post p sits in p's send slot (validate_schedule
        invariant b), so the flags fully determine both. Used by
        :meth:`repro.core.program.Program.load` to round-trip an
        artifact without serializing Python containers.
        """
        spus, slots = np.nonzero(post_end)
        send_slot = {int(p): int(t)
                     for p, t in zip(post[spus, slots], slots)}
        send_order = sorted(send_slot, key=send_slot.__getitem__)
        return cls(int(pre.shape[1]), pre, post, weight, pre_end, post_end,
                   send_slot, send_order, assign)


def schedule(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig) -> OpTables:
    m = hw.n_spus
    e = g.n_synapses

    # group synapses by (spu, post)
    order = np.lexsort((g.pre, g.post, assign))
    s_spu, s_post = assign[order], g.post[order]

    posts = np.unique(g.post)
    # count per (spu, post): c[spu][post]
    group_keys = s_spu.astype(np.int64) * g.n_neurons + s_post
    uniq_keys, key_start, key_count = np.unique(
        group_keys, return_index=True, return_counts=True)

    # per-post max count over SPUs (step 1)
    post_of_key = (uniq_keys % g.n_neurons).astype(np.int64)
    cmax: dict[int, int] = {}
    for pk, c in zip(post_of_key.tolist(), key_count.tolist()):
        cmax[pk] = max(cmax.get(pk, 0), int(c))
    send_order = sorted(posts.tolist(), key=lambda q: (cmax[q], q))

    # step 2: send slots via the feasibility recurrence
    groups: dict[tuple[int, int], np.ndarray] = {}
    for k, st, c in zip(uniq_keys.tolist(), key_start.tolist(),
                        key_count.tolist()):
        spu, pq = int(k // g.n_neurons), int(k % g.n_neurons)
        groups[(spu, pq)] = order[st:st + c]

    cum = np.zeros(m, np.int64)
    send_slot: dict[int, int] = {}
    t_prev = -1
    for pq in send_order:
        for spu in range(m):
            grp = groups.get((spu, pq))
            if grp is not None:
                cum[spu] += len(grp)
        t = max(t_prev + 1, int(cum.max()) - 1)
        send_slot[pq] = t
        t_prev = t
    depth = t_prev + 1 if send_order else 0

    pre_t = np.full((m, depth), NOP, np.int64)
    post_t = np.full((m, depth), NOP, np.int64)
    w_t = np.zeros((m, depth), np.int64)
    pe_t = np.zeros((m, depth), bool)
    poe_t = np.zeros((m, depth), bool)

    # step 3: pin final synapse of every (spu, post) group at t_p
    for (spu, pq), grp in groups.items():
        t = send_slot[pq]
        syn = int(grp[-1])
        pre_t[spu, t] = g.pre[syn]
        post_t[spu, t] = pq
        w_t[spu, t] = g.weight[syn]
        poe_t[spu, t] = True

    # free-slot lists per SPU (ascending), minus the pinned send slots
    free = []
    for spu in range(m):
        pinned = {int(send_slot[pq]) for (s, pq) in groups if s == spu}
        free.append([t for t in range(depth) if t not in pinned])

    # step 4: backward fill, reverse send order
    for pq in reversed(send_order):
        t_p = send_slot[pq]
        for spu in range(m):
            grp = groups.get((spu, pq))
            if grp is None or len(grp) == 1:
                continue
            rest = grp[:-1]
            fl = free[spu]
            # indices of free slots strictly before t_p
            hi = bisect.bisect_left(fl, t_p)
            assert hi >= len(rest), (
                f"schedule infeasible: SPU {spu} post {pq} needs "
                f"{len(rest)} slots before {t_p}, has {hi}")
            take = fl[hi - len(rest):hi]
            del fl[hi - len(rest):hi]
            for t, syn in zip(take, rest.tolist()):
                pre_t[spu, t] = g.pre[syn]
                post_t[spu, t] = pq
                w_t[spu, t] = g.weight[syn]

    # step 5: Pre-End on the last op touching each pre, per SPU
    for spu in range(m):
        seen: set[int] = set()
        for t in range(depth - 1, -1, -1):
            pr = int(pre_t[spu, t])
            if pr != NOP and pr not in seen:
                pe_t[spu, t] = True
                seen.add(pr)

    return OpTables(depth, pre_t, post_t, w_t, pe_t, poe_t,
                    send_slot, send_order, assign.astype(np.int32))


@dataclasses.dataclass(frozen=True)
class LoweredProgram:
    """Dense array form of a scheduled program, ready for compiled execution.

    The (SPU, slot) grid of the OpTables is flattened into slot-major op
    streams (all SPUs of slot 0, then slot 1, ...) — the exact order the
    hardware commits ops — plus the MC-tree routing bitmap. This is the
    single lowering shared by the Python reference executor
    (``engine.run_mapped`` uses ``routing``) and the compiled batched
    executor (``engine_jax`` uses the op streams). The Pre-End/Post-End
    flags are not needed by the scan executor (its spike gating subsumes
    them) but are kept so the lowering is the COMPLETE dense program —
    the form a slot-level hardware executor would consume.
    """
    n_inputs: int
    n_neurons: int
    n_internal: int
    n_spus: int
    depth: int                  # S_OT of the source tables
    # flattened non-NOP ops, slot-major; all arrays are [n_ops]
    op_spu: np.ndarray          # int32 SPU executing the op
    op_slot: np.ndarray         # int32 OT slot of the op
    op_pre: np.ndarray          # int32 global pre-neuron index
    op_post_local: np.ndarray   # int32 LOCAL post index (global - n_inputs)
    op_weight: np.ndarray       # int32 weight
    op_pre_end: np.ndarray      # bool Pre-End flag
    op_post_end: np.ndarray     # bool Post-End flag
    # MC-tree routing bitstrings: routing[q, i] == SPU i holds a synapse of q
    routing: np.ndarray         # [n_neurons, n_spus] bool

    @property
    def n_ops(self) -> int:
        return int(self.op_pre.shape[0])


def lower_tables(g: SNNGraph, tables: OpTables) -> LoweredProgram:
    """Lower scheduled OpTables into the dense :class:`LoweredProgram`."""
    m, depth = tables.pre.shape
    spu, slot = np.nonzero(tables.pre != NOP)
    order = np.lexsort((spu, slot))          # slot-major commit order
    spu, slot = spu[order], slot[order]

    routing = np.zeros((g.n_neurons, m), bool)
    routing[g.pre, tables.assign] = True

    return LoweredProgram(
        n_inputs=g.n_inputs,
        n_neurons=g.n_neurons,
        n_internal=g.n_internal,
        n_spus=m,
        depth=depth,
        op_spu=spu.astype(np.int32),
        op_slot=slot.astype(np.int32),
        op_pre=tables.pre[spu, slot].astype(np.int32),
        op_post_local=(tables.post[spu, slot] - g.n_inputs).astype(np.int32),
        op_weight=tables.weight[spu, slot].astype(np.int32),
        op_pre_end=tables.pre_end[spu, slot].copy(),
        op_post_end=tables.post_end[spu, slot].copy(),
        routing=routing,
    )


def validate_schedule(g: SNNGraph, tables: OpTables) -> None:
    """Legality checks (DESIGN.md §7.3): raises AssertionError on violation.

    All four invariants are numpy mask/lexsort expressions over the
    ``[M, depth]`` tables — no Python loop over slots — so validation
    stays a negligible slice of compile time at large OT depths. The
    assertion messages are identical to the original loop-based checks.
    """
    valid = tables.pre != NOP
    spu_i, slot_i = np.nonzero(valid)           # row-major: (spu, t) order
    pre_v = tables.pre[spu_i, slot_i]
    post_v = tables.post[spu_i, slot_i]
    w_v = tables.weight[spu_i, slot_i]

    # (a) every synapse appears exactly once
    n_placed = int(valid.sum())
    assert n_placed == g.n_synapses, \
        f"{n_placed} ops != {g.n_synapses} synapses"
    have = np.lexsort((w_v, post_v, pre_v))
    want = np.lexsort((g.weight, g.post, g.pre))
    assert (np.array_equal(pre_v[have], g.pre[want])
            and np.array_equal(post_v[have], g.post[want])
            and np.array_equal(w_v[have], g.weight[want])), \
        "op multiset != synapse multiset"

    # send slot per post as a dense lookup table
    n = g.n_neurons
    ss = np.full(n, -1, np.int64)
    for pq, t in tables.send_slot.items():
        ss[pq] = t

    # (b) merge alignment: all post_end slots of post p identical across SPUs
    pe_spu, pe_slot = np.nonzero(tables.post_end)
    pe_post = tables.post[pe_spu, pe_slot]
    bad = ss[pe_post] != pe_slot
    if bad.any():
        i = int(np.argmax(bad))                 # first violation, (spu, t)
        raise AssertionError(
            f"post {int(pe_post[i])} sent at {int(pe_slot[i])} "
            f"!= slot {tables.send_slot[int(pe_post[i])]}")
    # exactly one post_end per (spu, post with synapses there)
    pe_key = pe_spu * n + pe_post
    assert len(np.unique(pe_key)) == len(pe_key), \
        "duplicate post_end in one SPU"
    assert np.array_equal(np.unique(pe_key), np.unique(spu_i * n + post_v)), \
        "missing post_end"

    # (c) all ops of (spu, post) at slots <= send slot
    assert (slot_i <= ss[post_v]).all()

    # (d) pre_end exactly on last reference per (spu, pre)
    key = spu_i * n + pre_v
    order = np.lexsort((slot_i, key))
    k_sorted, s_sorted = key[order], slot_i[order]
    is_last = np.r_[k_sorted[1:] != k_sorted[:-1], np.ones(min(len(key), 1),
                                                           bool)]
    fe_spu, fe_slot = np.nonzero(tables.pre_end)
    fkey = fe_spu * n + tables.pre[fe_spu, fe_slot]
    forder = np.lexsort((fe_slot, fkey))
    fk, fs = fkey[forder], fe_slot[forder]
    f_last = np.r_[fk[1:] != fk[:-1], np.ones(min(len(fk), 1), bool)]
    assert (np.array_equal(fk[f_last], k_sorted[is_last])
            and np.array_equal(fs[f_last], s_sorted[is_last])), \
        "pre_end flags wrong"
