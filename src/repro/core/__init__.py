# SupraSNN core: the paper's primary contribution.
#   graph         SNN-as-graph (Eq. 6)
#   memory_model  Eqs. (9)-(11)
#   mapping/      the mapping search subsystem (§6.2): vectorized
#                 partitioner core, lockstep restart population, portfolio
#                 search, strategy registry, legacy parity reference
#   partition     single-seed compatibility shim over mapping/
#   baselines     round-robin baselines (§7.4.1)
#   scheduling/   the scheduling subsystem (§6.3): vectorized array core,
#                 schedule-strategy registry, legacy parity reference,
#                 OpTables/LoweredProgram + lowering, legality checks
#   schedule      compatibility shim over scheduling/
#   engine        functional executor + cycle/energy model (§4, §7)
#   engine_jax    compiled batched executor (lax.scan + Pallas NU)
#   cost          FPGA resource model (Table 2 fit)
#   passes        explicit compile passes (partition/search/schedule/
#                 validate/lower)
#   execution     ExecutionSpec: ONE frozen value naming engine/kernel
#                 tier/interpret/mesh/donation; the engine cache key
#   aot           AOT bucket precompile + persistent XLA cache
#   program       the Program artifact: compile -> run/profile/save/load
#   compiler      deprecated pre-Program wrappers
from repro.core.aot import enable_persistent_cache
from repro.core.execution import (ExecutionSpec, KERNELS, default_kernel)
from repro.core.graph import SNNGraph, from_quantized, random_graph
from repro.core.memory_model import (HardwareConfig, spu_score, spu_usage,
                                     scores_from_assignment,
                                     total_memory_bits, total_memory_kb,
                                     bram_count)
from repro.core.partition import PartitionResult, partition
from repro.core.mapping import (CandidateTrace, MappingStrategy,
                                SearchConfig, SearchTrace, STRATEGIES,
                                framework_partition, get_strategy,
                                portfolio_search, register_strategy)
from repro.core.baselines import (BASELINES, post_neuron_round_robin,
                                  synapse_round_robin, weight_round_robin)
from repro.core.scheduling import (NOP, LoweredProgram, OpTables,
                                   SCHEDULE_STRATEGIES, ScheduleStrategy,
                                   get_schedule_strategy, lower_tables,
                                   register_schedule_strategy, schedule,
                                   validate_schedule)
from repro.core.engine import (CycleModel, CycleReport, PowerModel,
                               MergeAlignmentError, oracle_packet_counts,
                               packet_stats, run_mapped, run_oracle)
from repro.core.engine_jax import JaxMappedEngine, run_mapped_batched
from repro.core.cost import ResourceModel, ResourceReport, resources
from repro.core.passes import (CompileReport, build_report,
                               initialization_packets, lower_pass,
                               partition_pass, schedule_pass, search_pass,
                               validate_pass)
from repro.core.program import (ENGINES, PROGRAM_FORMAT_VERSION, Program,
                                ProfileReport, compile)
from repro.core.compiler import compile_snn, compile_quantized

__all__ = [
    "SNNGraph", "from_quantized", "random_graph", "HardwareConfig",
    "spu_score", "spu_usage", "scores_from_assignment", "total_memory_bits",
    "total_memory_kb", "bram_count", "PartitionResult", "partition",
    "BASELINES", "post_neuron_round_robin", "synapse_round_robin",
    "weight_round_robin", "NOP", "LoweredProgram", "OpTables", "lower_tables",
    "schedule", "validate_schedule",
    # scheduling subsystem
    "SCHEDULE_STRATEGIES", "ScheduleStrategy", "get_schedule_strategy",
    "register_schedule_strategy",
    "CycleModel", "CycleReport", "PowerModel", "MergeAlignmentError",
    "oracle_packet_counts", "packet_stats", "run_mapped", "run_oracle",
    "JaxMappedEngine", "run_mapped_batched", "ResourceModel", "ResourceReport",
    "resources",
    # mapping search subsystem
    "CandidateTrace", "MappingStrategy", "SearchConfig", "SearchTrace",
    "STRATEGIES", "framework_partition", "get_strategy", "portfolio_search",
    "register_strategy",
    # pass pipeline + artifact API
    "CompileReport", "build_report", "initialization_packets", "lower_pass",
    "partition_pass", "schedule_pass", "search_pass", "validate_pass",
    "ENGINES", "PROGRAM_FORMAT_VERSION", "Program", "ProfileReport",
    "compile",
    # execution spec + AOT layer
    "ExecutionSpec", "KERNELS", "default_kernel", "enable_persistent_cache",
    # deprecated wrappers
    "compile_snn", "compile_quantized",
]
