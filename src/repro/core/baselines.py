"""Baseline partitioning strategies (paper §7.4.1).

1. post-neuron round-robin — whole fan-ins assigned to SPUs round-robin:
   no neuron-state duplication, but imbalanced synaptic load.
2. synapse round-robin — individual synapses round-robin: perfectly
   balanced, but post-neuron state duplicated across (almost) all SPUs.
3. weight round-robin — clusters of same-valued weights round-robin:
   maximal weight reuse, poor balance and heavy post duplication.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import PartitionResult
from repro.core.memory_model import HardwareConfig, scores_from_assignment


def _result(g: SNNGraph, hw: HardwareConfig, assign: np.ndarray
            ) -> PartitionResult:
    scores = scores_from_assignment(g.weight, g.post, assign, hw)
    return PartitionResult(assign.astype(np.int32), scores,
                           bool(scores.min() >= 0), 0, 0, [])


def post_neuron_round_robin(g: SNNGraph, hw: HardwareConfig
                            ) -> PartitionResult:
    posts = np.unique(g.post)
    spu_of_post = {int(q): i % hw.n_spus for i, q in enumerate(posts)}
    assign = np.array([spu_of_post[int(q)] for q in g.post], np.int32)
    return _result(g, hw, assign)


def synapse_round_robin(g: SNNGraph, hw: HardwareConfig) -> PartitionResult:
    assign = np.arange(g.n_synapses, dtype=np.int32) % hw.n_spus
    return _result(g, hw, assign)


def weight_round_robin(g: SNNGraph, hw: HardwareConfig) -> PartitionResult:
    vals = np.unique(g.weight)
    spu_of_w = {int(v): i % hw.n_spus for i, v in enumerate(vals)}
    assign = np.array([spu_of_w[int(v)] for v in g.weight], np.int32)
    return _result(g, hw, assign)


BASELINES = {
    "post_neuron_rr": post_neuron_round_robin,
    "synapse_rr": synapse_round_robin,
    "weight_rr": weight_round_robin,
}
