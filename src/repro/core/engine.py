"""SupraSNN execution engine.

Two layers:

1. ``run_mapped`` — a *functional* executor of the mapped program
   (OpTables): simulates Spike Memory set/clear, per-SPU partial-current
   accumulation, ME-tree merging with slot-alignment assertions, and the
   centralized Neuron Unit's integer LIF update. Its outputs must match
   ``run_oracle`` BIT-EXACTLY — the paper's deterministic-commit property.

2. ``CycleModel`` — cycle-accurate timing of the same execution (MC-tree
   distribution phase + 2-cycles/op synaptic phase + ME/NU pipeline drain),
   used for the latency/energy numbers of Tables 2/3 and Figs. 12/13.

``run_mapped`` is the slow, structure-faithful reference; the compiled
batched counterpart lives in :mod:`repro.core.engine_jax` and must stay
bit-exact with it (tests/test_engine_jax.py). Both are normally reached
through the one compiled artifact —
``repro.core.program.Program.run(ext, engine="python"|"jax"|"oracle")``
— which gives all three executors a uniform surface.

Hardware semantics (paper §4.2): spikes generated in timestep t-1 are
distributed at the start of timestep t; external input spikes for timestep
t arrive through the Spike Handler in the same window.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.memory_model import HardwareConfig
from repro.core.scheduling import NOP, OpTables
from repro.snn.lif import lif_step_int


def packet_stats(pkt_counts: np.ndarray) -> dict:
    """Per-run stats dict shared by the Python and JAX executors."""
    return {"packet_counts": pkt_counts,
            "mean_packets_per_step": float(pkt_counts.mean())}


def oracle_packet_counts(ext_spikes: np.ndarray, spikes: np.ndarray
                         ) -> np.ndarray:
    """Per-timestep MC packet counts implied by a dense (oracle) run.

    The distribution phase of timestep t carries one packet per neuron
    that fired: external inputs of t plus internal spikes of t-1
    (``run_mapped`` counts exactly this set). Lets the oracle engine of
    :meth:`repro.core.program.Program.run` report the same stats dict as
    the mapped executors.

    Accepts ``[T, n]`` inputs (returning ``[T]`` counts) or batched
    ``[B, T, n]`` (returning ``[B, T]``): one vectorized count + shift
    along the timestep axis, no per-step loop.
    """
    ext = np.asarray(ext_spikes)
    s = np.asarray(spikes)
    if ext.ndim not in (2, 3) or s.ndim != ext.ndim:
        raise ValueError(f"expected matching [T, n] or [B, T, n] arrays; "
                         f"got {ext.shape} and {s.shape}")
    pkts = np.count_nonzero(ext, axis=-1).astype(np.int64)
    pkts[..., 1:] += np.count_nonzero(s[..., :-1, :], axis=-1)
    return pkts


# ---------------------------------------------------------------------------
# Oracle: dense integer LIF with hardware (delayed) semantics.
# ---------------------------------------------------------------------------

def run_oracle(g: SNNGraph, ext_spikes: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
    """Dense reference simulation.

    ext_spikes: [T, n_inputs] binary.
    Returns (spikes [T, n_internal], v_final [n_internal]) int32.
    """
    t_steps = ext_spikes.shape[0]
    n_int = g.n_internal
    # dense weight matrix [n_neurons, n_internal]
    w = np.zeros((g.n_neurons, n_int), np.int64)
    w[g.pre, g.local(g.post)] = g.weight

    v = np.zeros(n_int, np.int32)
    s_prev = np.zeros(n_int, np.int32)          # internal spikes at t-1
    out = np.zeros((t_steps, n_int), np.int32)
    for t in range(t_steps):
        s_all = np.concatenate([ext_spikes[t].astype(np.int64),
                                s_prev.astype(np.int64)])
        current = (s_all @ w).astype(np.int32)
        v, s = lif_step_int(v, current, g.lif)
        out[t] = s
        s_prev = s
    return out, v


# ---------------------------------------------------------------------------
# Functional executor of the mapped program.
# ---------------------------------------------------------------------------

class MergeAlignmentError(AssertionError):
    pass


def run_mapped(g: SNNGraph, tables: OpTables, ext_spikes: np.ndarray,
               check_alignment: bool = True,
               routing: np.ndarray | None = None
               ) -> tuple[np.ndarray, np.ndarray, dict]:
    """Execute the scheduled program. Returns (spikes, v_final, stats).

    stats carries per-timestep packet counts for the cycle model.
    ``routing`` takes the precomputed MC-tree bitmap (e.g.
    ``program.lowered.routing``) to skip the O(E log E) re-lowering;
    built here when omitted.
    """
    m, depth = tables.pre.shape
    t_steps = ext_spikes.shape[0]
    n_int = g.n_internal

    # routing bitstrings: bit[i] of neuron q == SPU i holds a synapse from q
    if routing is None:
        routing = np.zeros((g.n_neurons, m), bool)
        routing[g.pre, tables.assign] = True

    spike_mem = np.zeros((m, g.n_neurons), bool)   # per-SPU bitmap SRAM
    partial = np.zeros((m, n_int), np.int64)       # per-SPU partial currents
    v = np.zeros(n_int, np.int32)
    s_prev = np.zeros(n_int, np.int32)
    out = np.zeros((t_steps, n_int), np.int32)
    pkt_counts = np.zeros(t_steps, np.int64)

    pre_l = tables.pre            # [M, depth]
    post_l = tables.post
    w_l = tables.weight
    pe_l = tables.pre_end
    poe_l = tables.post_end

    for t in range(t_steps):
        # ---- distribution phase: MC packets into Spike Memory ----
        fired = np.flatnonzero(np.concatenate(
            [ext_spikes[t].astype(bool),
             s_prev.astype(bool)]))
        pkt_counts[t] = len(fired)
        for q in fired:
            spike_mem[routing[q], q] = True

        # ---- synaptic phase: execute slots; merge in ME tree ----
        for slot in range(depth):
            valid = pre_l[:, slot] != NOP
            if not valid.any():
                continue
            spus = np.flatnonzero(valid)
            pres = pre_l[spus, slot]
            posts = post_l[spus, slot]
            act = spike_mem[spus, pres]
            loc = posts - g.n_inputs
            partial[spus, loc] += np.where(act, w_l[spus, slot], 0)
            # pre_end: clear spike bit for next timestep
            pe = pe_l[spus, slot]
            if pe.any():
                spike_mem[spus[pe], pres[pe]] = False
            # post_end: inject ME packets; bufferless merge = same slot
            poe = poe_l[spus, slot]
            if poe.any():
                inj_posts = posts[poe]
                if check_alignment and len(set(inj_posts.tolist())) != 1:
                    raise MergeAlignmentError(
                        f"t={t} slot={slot}: misaligned posts {inj_posts}")
                q = int(inj_posts[0])
                lq = q - g.n_inputs
                current = int(partial[spus[poe], lq].sum())
                partial[spus[poe], lq] = 0
                # ---- Neuron Unit: integer LIF on this neuron ----
                v_q, s_q = lif_step_int(v[lq:lq + 1],
                                        np.array([current], np.int32), g.lif)
                v[lq] = v_q[0]
                if s_q[0]:
                    out[t, lq] = 1
        s_prev = out[t]

    return out, v, packet_stats(pkt_counts)


# ---------------------------------------------------------------------------
# Cycle-accurate timing + energy model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PowerModel:
    """FPGA power model with constants fitted to paper Table 2 (DESIGN.md §8).

    P_total = static + dynamic;  dynamic = per-SPU switching cost scaled by
    datapath width, plus fabric (trees + Neuron Unit) cost.
    """
    static_w: float = 0.106                    # XC7Z020 static (Table 2)
    spu_dyn_w_per_bit: float = 0.000355        # per SPU per datapath bit
    fabric_dyn_w: float = 0.015

    def total_w(self, hw: HardwareConfig) -> float:
        bits = hw.weight_bits + hw.potential_bits
        return (self.static_w + self.fabric_dyn_w
                + hw.n_spus * bits * self.spu_dyn_w_per_bit)


@dataclasses.dataclass
class CycleReport:
    cycles_total: int
    cycles_distribution: int
    cycles_synaptic: int
    cycles_overhead: int
    latency_us: float
    power_w: float
    energy_mj: float
    energy_per_synapse_nj: float


class CycleModel:
    """Per-timestep cycle counting (see module docstring).

    distribution:  n_packets + 1 (end pkt) + tree_depth (MC pipeline)
    synaptic:      2 * OT_depth  (single-port Unified Memory, §4.4.3)
    drain:         tree_depth (ME adders) + 4 (NU pipeline) + 1 (end pkt)
    """
    NU_PIPELINE = 4

    def __init__(self, hw: HardwareConfig, power: PowerModel | None = None):
        self.hw = hw
        self.power = power or PowerModel()

    def timestep_cycles(self, n_packets: int, ot_depth: int,
                        n_inter_chip: int = 0) -> tuple[int, int, int]:
        d = self.hw.tree_depth
        dist = n_packets + 1 + d \
            + n_inter_chip * self.hw.inter_chip_hop_cycles
        syn = 2 * ot_depth
        drain = d + self.NU_PIPELINE + 1
        return dist, syn, drain

    def run(self, packet_counts: np.ndarray, ot_depth: int,
            n_synapses_total: int,
            inter_chip_counts: np.ndarray | None = None) -> CycleReport:
        """Aggregate one sample's per-timestep packet counts.

        ``packet_counts`` must be 1-D ``[T]``; the per-timestep phase
        costs are affine in the packet count, so the whole run reduces
        to one sum instead of a Python loop. Batched ``[B, T]`` arrays
        are rejected — aggregate per sample (what
        :meth:`repro.core.program.Program.profile` does) rather than
        silently iterating rows.

        ``inter_chip_counts`` takes the per-timestep forwarded-packet
        counts of a multi-chip mapping (DESIGN.md §11; see
        :func:`repro.core.mapping.hypergraph.inter_chip_packet_counts`),
        each charged ``hw.inter_chip_hop_cycles`` distribution cycles.
        Omitted (or all-zero, the ``n_chips=1`` case) the report is
        bit-identical to the single-chip model.
        """
        pkts = np.asarray(packet_counts)
        if pkts.ndim != 1:
            raise ValueError(
                f"packet_counts must be 1-D [T]; got shape {pkts.shape} — "
                f"profile batched runs per sample (Program.profile "
                f"aggregates them)")
        inter = 0
        if inter_chip_counts is not None:
            ic = np.asarray(inter_chip_counts)
            if ic.shape != pkts.shape:
                raise ValueError(
                    f"inter_chip_counts shape {ic.shape} != packet_counts "
                    f"shape {pkts.shape}")
            inter = int(ic.sum()) * self.hw.inter_chip_hop_cycles
        t_steps = len(pkts)
        d = self.hw.tree_depth
        dist = int(pkts.sum()) + t_steps * (1 + d) + inter
        syn = t_steps * 2 * ot_depth
        over = t_steps * (d + self.NU_PIPELINE + 1)
        total = dist + syn + over
        lat_us = total / self.hw.clock_mhz
        p = self.power.total_w(self.hw)
        e_mj = p * lat_us * 1e-3
        eps_nj = (e_mj * 1e6 / n_synapses_total) if n_synapses_total else 0.0
        return CycleReport(total, dist, syn, over, lat_us, p, e_mj, eps_nj)
