"""The compile pipeline as explicit, individually-testable passes.

The paper's Fig. 8 software framework is one pipeline::

    partition -> schedule -> validate -> lower

Each stage is a named pass here; :func:`repro.core.program.compile`
assembles them into the :class:`repro.core.program.Program` artifact.
Calling a pass directly is supported (e.g. re-schedule a hand-edited
assignment, or lower baselines for comparison) — every pass is a pure
function of its inputs.

This module also owns :class:`CompileReport` (the pipeline's summary)
and :func:`initialization_packets` (the MC-tree configuration stream a
deployed artifact is initialized from), both formerly in
``repro.core.compiler``, which now only hosts deprecated wrappers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost import ResourceReport, resources
from repro.core.graph import SNNGraph
from repro.core.mapping.books import PartitionResult
from repro.core.mapping.search import (SearchConfig, SearchTrace,
                                       portfolio_search)
from repro.core.mapping.strategies import get_strategy
from repro.core.memory_model import HardwareConfig
from repro.core.scheduling import (NOP, LoweredProgram, OpTables,
                                   lower_tables, schedule)


@dataclasses.dataclass
class CompileReport:
    """Summary of one compile-pipeline run (paper Fig. 8 outputs)."""
    method: str
    feasible: bool
    iterations: int
    perturbations: int
    ot_depth: int
    scores: np.ndarray
    spu_synapse_counts: np.ndarray
    spu_post_counts: np.ndarray          # post-neurons stored per SPU
    spu_weight_counts: np.ndarray        # unique weights per SPU
    resources: ResourceReport
    n_init_packets: int
    compile_seconds: float
    search: SearchTrace | None = None    # portfolio trace (search= compiles)
    candidates_tried: int = 1            # mappings evaluated to pick this one
    schedule_method: str = "slack"       # the ScheduleStrategy that won
    # OT depth under every strategy evaluated for the chosen mapping
    # ({schedule_method: ot_depth} when only one was run)
    schedule_depths: dict | None = None
    # per-phase wall seconds from the compile-phase profiler (DESIGN.md
    # §12): the top-level pass phases plus the partitioner's sub-phases
    # (coarsen/coarse_search/project/place/refine). None when profiling
    # was disabled.
    phase_seconds: dict | None = None
    # per-phase net allocation MB (only when an alloc=True profiler was
    # installed around compile(); None otherwise)
    phase_alloc_mb: dict | None = None


# ---------------------------------------------------------------------------
# Passes.
# ---------------------------------------------------------------------------

def partition_pass(g: SNNGraph, hw: HardwareConfig, *,
                   method: str = "framework", seed: int = 0,
                   max_iters: int = 20000, restarts: int = 1,
                   workers: int = 1) -> PartitionResult:
    """Synapse -> SPU assignment (paper §6.2, or a round-robin baseline).

    ``method`` names a registered
    :class:`~repro.core.mapping.strategies.MappingStrategy`:
    ``'framework'`` is the probabilistic search (vectorized over up to
    ``restarts`` lockstep seeds, keeping the first feasible / best
    worst-SPU score); the :data:`repro.core.baselines.BASELINES` keys
    select those baselines. Unknown names raise ``ValueError`` listing
    the registry. ``workers > 1`` lets strategies with internal
    candidate races (``multilevel`` coarse seeds) fan out over
    processes; results are worker-count-invariant.
    """
    return get_strategy(method).partition(g, hw, seed=seed,
                                          max_iters=max_iters,
                                          restarts=restarts,
                                          workers=workers)


def search_pass(g: SNNGraph, hw: HardwareConfig,
                config: SearchConfig | None = None
                ) -> tuple[PartitionResult, SearchTrace, OpTables | None]:
    """Portfolio mapping search (``compile(search=...)``): the framework
    restart population raced against every baseline; returns the best
    (feasible, min OT depth, min memory) candidate, the per-candidate
    :class:`~repro.core.mapping.search.SearchTrace`, and the winner's
    already-scheduled tables (None if infeasible)."""
    return portfolio_search(g, hw, config)


def schedule_pass(g: SNNGraph, part: PartitionResult | np.ndarray,
                  hw: HardwareConfig, *, method: str = "slack") -> OpTables:
    """Heuristic scheduling (paper §6.3) of an assignment into OpTables.

    ``method`` names a registered
    :class:`~repro.core.scheduling.strategies.ScheduleStrategy` (the
    post transmit-order policy); ``'slack'`` is the original scheduler.
    """
    assign = part.assign if isinstance(part, PartitionResult) else part
    return schedule(g, assign, hw, method=method)


def validate_pass(g: SNNGraph, tables: OpTables) -> None:
    """Schedule legality checks; raises AssertionError on violation.

    Routed through the static-analysis framework (DESIGN.md §13): the
    hazard detector of :mod:`repro.analysis.schedule` computes ALL
    structured diagnostics and the legacy shim raises the
    highest-priority one with the historical message.
    ``Program.verify()`` exposes the full diagnostic list plus the
    range/memory checkers over a finished artifact.
    """
    from repro.analysis.schedule import check_schedule, raise_legacy
    raise_legacy(check_schedule(g, tables))


def lower_pass(g: SNNGraph, tables: OpTables) -> LoweredProgram:
    """Lower OpTables to the dense slot-major program the executors run."""
    return lower_tables(g, tables)


def _spu_stats(g: SNNGraph, assign: np.ndarray, m: int):
    # unique (spu, value) pair counts — one np.unique per attribute
    # instead of an M-pass boolean scan over the synapse list
    syn = np.bincount(assign, minlength=m).astype(np.int64)
    posts = np.zeros(m, np.int64)
    weights = np.zeros(m, np.int64)
    a = assign.astype(np.int64)
    for arr, out in ((g.post, posts), (g.weight, weights)):
        vals, inv = np.unique(arr, return_inverse=True)
        if not len(vals):
            continue
        pairs = np.unique(a * len(vals) + inv)
        np.add.at(out, pairs // len(vals), 1)
    return syn, posts, weights


def build_report(g: SNNGraph, hw: HardwareConfig, tables: OpTables,
                 part: PartitionResult, *, method: str,
                 compile_seconds: float,
                 routing: np.ndarray | None = None,
                 search: SearchTrace | None = None,
                 schedule_method: str = "slack",
                 schedule_depths: dict | None = None) -> CompileReport:
    """Assemble the :class:`CompileReport` for a finished pipeline run."""
    syn, posts, weights = _spu_stats(g, part.assign, hw.n_spus)
    return CompileReport(
        method=method, feasible=part.feasible, iterations=part.iterations,
        perturbations=part.perturbations, ot_depth=tables.depth,
        scores=part.scores, spu_synapse_counts=syn, spu_post_counts=posts,
        spu_weight_counts=weights, resources=resources(hw, tables.depth),
        n_init_packets=n_initialization_packets(g, tables),
        compile_seconds=compile_seconds,
        search=search,
        candidates_tried=len(search.candidates) if search else 1,
        schedule_method=schedule_method,
        schedule_depths=(schedule_depths if schedule_depths is not None
                         else {schedule_method: int(tables.depth)}))


# ---------------------------------------------------------------------------
# Initialization stream of the compiled artifact.
# ---------------------------------------------------------------------------

def n_initialization_packets(g: SNNGraph, tables: OpTables) -> int:
    """Length of :func:`initialization_packets` WITHOUT materializing the
    (ctrl, payload) tuple list — at 10⁶ synapses the stream is millions
    of entries and the report only needs its length. Closed form:
    one select + ``n_neurons`` routing words, per SPU one select +
    ``depth`` OT words + its used-weight words, one select +
    ``n_internal`` Neuron Unit words (tests pin equality with the
    materialized stream).
    """
    mask = tables.pre != NOP                      # [M, depth]
    w = tables.weight.astype(np.int64)
    span = int(w.max(initial=0)) - int(w.min(initial=0)) + 1
    i_idx = np.nonzero(mask)[0]
    keys = np.unique(i_idx * span + (w[mask] - int(w.min(initial=0))))
    used_w = int(len(keys))
    m = tables.n_spus
    return (1 + g.n_neurons
            + m * (1 + int(tables.depth)) + used_w
            + 1 + (g.n_neurons - g.n_inputs))


def initialization_packets(g: SNNGraph, tables: OpTables,
                           hw: HardwareConfig,
                           routing: np.ndarray | None = None
                           ) -> list[tuple[int, int]]:
    """MC-tree initialization stream (paper §4.3, Table 1).

    ctrl=10 selects a unit; ctrl=11 carries its data words. Returns the
    abstract (ctrl, payload) list — its length drives init latency.
    ``routing`` takes the precomputed [n_neurons, n_spus] bitmap (e.g.
    ``lowered.routing``); built vectorized here when omitted.
    """
    pkts: list[tuple[int, int]] = []
    m = tables.n_spus
    if routing is None:
        routing = np.zeros((g.n_neurons, m), bool)
        routing[g.pre, tables.assign] = True
    # routing bitstrings (unit id 0 = Routing Unit): one packed-bits
    # matvec per 32-SPU chunk instead of a per-neuron flatnonzero loop
    pkts.append((0b10, 0))
    chunks = [(int(c), routing[:, c:c + 32].astype(np.int64)
               @ (np.int64(1) << np.arange(min(32, m - c), dtype=np.int64)))
              for c in range(0, m, 32)]
    pkts.extend(
        (0b11, sum(int(word[q]) << shift for shift, word in chunks))
        for q in range(g.n_neurons))
    # per-SPU operation tables + unified memories (unit ids 1..M)
    for i in range(m):
        pkts.append((0b10, 1 + i))
        for t in range(tables.depth):
            pkts.append((0b11, int(tables.pre[i, t])))
        used_w = np.unique(tables.weight[i][tables.pre[i] != NOP])
        for w in used_w:
            pkts.append((0b11, int(w)))
    # neuron unit (unit id M+1): global index + flags per internal neuron
    pkts.append((0b10, 1 + m))
    for q in range(g.n_inputs, g.n_neurons):
        pkts.append((0b11, q))
    return pkts
