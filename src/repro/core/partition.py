"""Probabilistic partitioning (paper §6.2) — compatibility shim.

The implementation moved to the :mod:`repro.core.mapping` package
(DESIGN.md §6): ``mapping.books`` owns the flat occupancy bookkeeping,
``mapping.tree`` the partitioning-tree geometry, ``mapping.search`` the
vectorized restart population and the portfolio driver, and
``mapping.strategies`` the registry behind ``compile(method=...)``.

:func:`partition` keeps the original single-seed entry point. For a
fixed (graph, hw, seed) it is BIT-IDENTICAL to the preserved reference
loop ``mapping.legacy.partition_legacy`` (the parity suite in
tests/test_mapping.py enforces assignment/scores/iterations/history
equality). Note one recorded deviation vs the pre-refactor loop
(DESIGN.md §8 "membership order"): per-SPU membership is iterated in
synapse-index order instead of CPython-set hash order, so same-seed
assignments are a different — equally distributed — draw than the seed
repo produced; mappings persisted in Program artifacts are unaffected.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.mapping.books import PartitionResult  # noqa: F401 (re-export)
from repro.core.mapping.search import framework_partition
from repro.core.memory_model import HardwareConfig


def partition(g: SNNGraph, hw: HardwareConfig, *, seed: int = 0,
              max_iters: int = 50000, eta: float = 0.25,
              move_mode: str = "decisive",
              stagnation_window: int = 300, cooldown: int = 64,
              scan_cap: int = 384,
              ) -> PartitionResult:
    """Single-seed probabilistic partition (paper §6.2).

    Thin wrapper over the vectorized search with one restart; all the
    original knobs pass straight through.
    """
    winner, _, _ = framework_partition(
        g, hw, seed=seed, restarts=1, max_iters=max_iters, eta=eta,
        move_mode=move_mode, stagnation_window=stagnation_window,
        cooldown=cooldown, scan_cap=scan_cap)
    winner.assign = np.ascontiguousarray(winner.assign)
    return winner
