"""Vectorized heuristic scheduling (paper §6.3) — the array core.

Same algorithm as :func:`repro.core.scheduling.legacy.schedule_legacy`
(see its docstring for the six steps), with every Python loop replaced
by lexsort/cumsum/segmented array ops over ALL (SPU, post) groups at
once:

* step 1-2 — the per-post send-slot recurrence
  ``t_p = max(t_prev + 1, max_i cum_i(p) - 1)`` has the closed form
  ``t_i = i + max(0, running_max(a_j - j))`` with
  ``a_j = max_i cum_i(j) - 1``, one ``cumsum`` + ``maximum.accumulate``
  over the [P, M] count matrix;
* step 3 — the final synapse of every group is pinned with one fancy
  scatter (group ends come straight from the lexsort);
* step 4 — the reverse-order backward fill is a *fixed-position* greedy:
  processing groups by descending send slot, each takes the largest
  still-free slots below its deadline, so the consumed positions in the
  (never-mutated) per-SPU free-slot array advance monotonically.  The
  per-group start/end offsets obey ``e_q = max(e_{q-1}, a_q) + r_q``
  (``a_q`` = free slots at or above the deadline, ``r_q`` = group
  demand), whose closed form is again a running max —
  ``e_q = R_q + max_{k<=q}(a_k - R_{k-1})`` — evaluated for every SPU
  simultaneously with the segmented-offset trick;
* step 5 — Pre-End flags are the last op per (SPU, pre), one lexsort.

Bit-exactness vs the legacy loop — identical tables,
``send_slot``/``send_order``, and infeasibility assertion messages — is
enforced by tests/test_scheduling.py and raced by
``benchmarks/scheduler_throughput.py`` (≥10x on the paper-scale SHD
instance).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.memory_model import HardwareConfig
from repro.core.scheduling.tables import NOP, OpTables


@dataclasses.dataclass(frozen=True)
class GroupInfo:
    """The (SPU, post) grouping of an assignment, shared by the slot
    recurrence, the backward fill, and every
    :class:`~repro.core.scheduling.strategies.ScheduleStrategy` (which
    orders posts from the per-post statistics without regrouping)."""
    order: np.ndarray        # [E] synapse ids lexsorted by (spu, post, pre)
    key_start: np.ndarray    # [G] group start offsets into ``order``
    key_count: np.ndarray    # [G] group sizes
    spu_of_key: np.ndarray   # [G] SPU of each group
    post_of_key: np.ndarray  # [G] post of each group
    posts: np.ndarray        # [P] unique posts, ascending
    cmax: np.ndarray         # [P] max synapses of the post on any one SPU
    total: np.ndarray        # [P] total synapses of the post


def group_info(g: SNNGraph, assign: np.ndarray) -> GroupInfo:
    """Group synapses by (SPU, post) and derive per-post statistics.

    One argsort on the combined (spu, post, pre) key — unique per
    synapse, so even the unstable default sort reproduces the legacy
    ``lexsort((pre, post, assign))`` order at a third of the sort
    passes — with group boundaries read off the sorted key instead of
    a second sort inside ``np.unique``.
    """
    n = np.int64(g.n_neurons)
    key = (assign.astype(np.int64) * n + g.post) * n + g.pre
    # keys are unique per synapse (SNNGraph.validate: no duplicate
    # (pre, post) pairs), so the unstable default sort is deterministic
    # and equals the legacy stable lexsort order
    order = np.argsort(key)
    gkey = key[order] // n                      # (spu, post) group key
    first = np.r_[np.ones(min(len(gkey), 1), bool), gkey[1:] != gkey[:-1]]
    key_start = np.flatnonzero(first)
    key_count = np.diff(np.r_[key_start, len(gkey)])
    uniq = gkey[key_start] if len(key_start) else gkey[:0]
    spu_of_key = uniq // n
    post_of_key = uniq % n

    posts = np.unique(g.post).astype(np.int64)
    pidx = np.searchsorted(posts, post_of_key)
    cmax = np.zeros(len(posts), np.int64)
    np.maximum.at(cmax, pidx, key_count)
    total = np.zeros(len(posts), np.int64)
    np.add.at(total, pidx, key_count)
    return GroupInfo(order, key_start, key_count, spu_of_key, post_of_key,
                     posts, cmax, total)


def slack_send_order(info: GroupInfo) -> np.ndarray:
    """The legacy default order: ascending (max-synapses-per-SPU, post)."""
    return info.posts[np.lexsort((info.posts, info.cmax))]


def schedule_vectorized(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig,
                        send_order: np.ndarray | list | None = None,
                        send_slots: dict[int, int] | None = None,
                        info: GroupInfo | None = None) -> OpTables:
    """Array-core scheduler, bit-exact vs :func:`schedule_legacy`.

    ``send_order``/``send_slots`` are the same injection hooks as the
    legacy reference (an externally-chosen post transmit order, or
    externally-chosen post -> slot assignments replacing the
    recurrence). ``info`` takes a precomputed :func:`group_info` so
    multi-strategy callers (the portfolio) group only once.
    """
    m = hw.n_spus
    gi = info if info is not None else group_info(g, assign)
    posts = gi.posts
    n = g.n_neurons

    # -- steps 1-2: send order + send slots ---------------------------------
    if send_slots is not None:
        so = np.asarray(sorted(send_slots, key=send_slots.__getitem__),
                        np.int64)
        if not np.array_equal(np.sort(so), posts):
            raise ValueError("send_slots must assign a slot to every "
                             "post-neuron of the graph")
        t = np.array([send_slots[int(q)] for q in so], np.int64)
    else:
        if send_order is None:
            so = slack_send_order(gi)
        else:
            so = np.asarray(send_order, np.int64)
            if not np.array_equal(np.sort(so), posts):
                raise ValueError("send_order must be a permutation of the "
                                 "graph's post-neurons")
        p_n = len(so)
        rank = np.full(n, -1, np.int64)
        rank[so] = np.arange(p_n)
        cum = np.zeros((p_n, m), np.int64)
        cum[rank[gi.post_of_key], gi.spu_of_key] = gi.key_count
        a = np.cumsum(cum, 0).max(1) - 1 if p_n else np.zeros(0, np.int64)
        idx = np.arange(p_n)
        t = idx + np.maximum(np.maximum.accumulate(a - idx), 0) if p_n \
            else np.zeros(0, np.int64)
    depth = int(t[-1]) + 1 if len(so) else 0
    send_order_l = [int(q) for q in so]
    send_slot = {q: int(tt) for q, tt in zip(send_order_l, t)}

    slot_of_post = np.full(n, -1, np.int64)
    slot_of_post[so] = t
    t_of_key = slot_of_post[gi.post_of_key]

    pre_t = np.full((m, depth), NOP, np.int64)
    post_t = np.full((m, depth), NOP, np.int64)
    w_t = np.zeros((m, depth), np.int64)
    pe_t = np.zeros((m, depth), bool)
    poe_t = np.zeros((m, depth), bool)
    if not len(so):
        return OpTables(depth, pre_t, post_t, w_t, pe_t, poe_t,
                        send_slot, send_order_l, assign.astype(np.int32))

    # -- step 3: pin the final synapse of every group at its send slot ------
    last_syn = gi.order[gi.key_start + gi.key_count - 1]
    pin_pre = g.pre[last_syn].astype(np.int64)
    pre_t[gi.spu_of_key, t_of_key] = pin_pre
    post_t[gi.spu_of_key, t_of_key] = gi.post_of_key
    w_t[gi.spu_of_key, t_of_key] = g.weight[last_syn]
    poe_t[gi.spu_of_key, t_of_key] = True

    # dense last-reference plane for step 5, fed as ops are produced
    last_ref = np.full(m * n, -1, np.int64)     # (spu, pre) -> max slot
    np.maximum.at(last_ref, gi.spu_of_key * n + pin_pre, t_of_key)

    # per-SPU free slots, ascending: everything not pinned (poe_t IS the
    # pinned mask — one Post-End per group, groups pin distinct slots)
    f_spu, f_slot = np.nonzero(~poe_t)          # row-major: spu, then slot
    nf = (~poe_t).sum(1)
    f_start = np.concatenate([[0], np.cumsum(nf)])

    # -- step 4: backward fill, descending send slots, per SPU --------------
    sel = gi.key_count >= 2
    if sel.any():
        # groups in legacy processing order per SPU: descending send slot
        gs = np.flatnonzero(sel)
        ordk = np.lexsort((-t_of_key[gs], gi.spu_of_key[gs]))
        gs = gs[ordk]
        gs_spu = gi.spu_of_key[gs]
        gs_post = gi.post_of_key[gs]
        gs_t = t_of_key[gs]
        gs_r = gi.key_count[gs] - 1             # backward-fill demand
        gs_begin = gi.key_start[gs]

        # a_q: free slots at-or-above the deadline on the group's SPU
        f_key = f_spu.astype(np.int64) * depth + f_slot
        pos = np.searchsorted(f_key, gs_spu * np.int64(depth) + gs_t)
        a_free = f_start[gs_spu + 1] - pos

        # e_q = max(e_{q-1}, a_q) + r_q  per SPU  ==  segment-local
        # R_q + running_max(a_q - R_{q-1}), via the offset trick
        cum_r = np.cumsum(gs_r)
        seg_first = np.r_[True, gs_spu[1:] != gs_spu[:-1]]
        seg_base = np.maximum.accumulate(
            np.where(seg_first, cum_r - gs_r, 0))
        r_loc = cum_r - seg_base
        big = np.int64(depth + g.n_synapses + 2)
        run = np.maximum.accumulate(a_free - (r_loc - gs_r) + gs_spu * big)
        e = r_loc + run - gs_spu * big
        s = e - gs_r

        bad = e > nf[gs_spu]
        if bad.any():
            # the first violation the legacy loop would hit: outermost
            # reverse send order, innermost ascending SPU
            vi = np.flatnonzero(bad)
            first = vi[np.lexsort((gs_spu[vi], -gs_t[vi]))[0]]
            spu_b = int(gs_spu[first])
            raise AssertionError(
                f"schedule infeasible: SPU {spu_b} post "
                f"{int(gs_post[first])} needs {int(gs_r[first])} slots "
                f"before {int(gs_t[first])}, has "
                f"{int(nf[spu_b] - s[first])}")

        # expand per-group [nf-e, nf-s) windows of the per-SPU free array
        # into per-op scatters; window j pairs with rest synapse j
        gidx = np.repeat(np.arange(len(gs_r)), gs_r)
        within = np.arange(int(cum_r[-1])) - np.repeat(cum_r - gs_r, gs_r)
        fpos = (f_start[gs_spu] + nf[gs_spu] - e)[gidx] + within
        fill_slot = f_slot[fpos]
        fill_syn = gi.order[gs_begin[gidx] + within]
        fill_spu = gs_spu[gidx]
        fill_pre = g.pre[fill_syn].astype(np.int64)
        pre_t[fill_spu, fill_slot] = fill_pre
        post_t[fill_spu, fill_slot] = g.post[fill_syn]
        w_t[fill_spu, fill_slot] = g.weight[fill_syn]
        np.maximum.at(last_ref, fill_spu * n + fill_pre, fill_slot)

    # -- step 5: Pre-End on the last op touching each (SPU, pre) ------------
    ref = np.flatnonzero(last_ref >= 0)
    pe_t[ref // n, last_ref[ref]] = True

    return OpTables(depth, pre_t, post_t, w_t, pe_t, poe_t,
                    send_slot, send_order_l, assign.astype(np.int32))
