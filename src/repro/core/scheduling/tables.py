"""Operation-table containers and lowering (paper §6.3 outputs).

:class:`OpTables` is the mapped + scheduled program (the [M, depth]
grid a SupraSNN engine executes); :class:`LoweredProgram` is its dense
slot-major form shared by the Python reference executor and the
compiled batched executor. Both moved here from the old monolithic
``core/schedule.py`` unchanged — the scheduling *algorithms* live in
:mod:`repro.core.scheduling.vectorized` (the array core) and
:mod:`repro.core.scheduling.legacy` (the preserved reference loop).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import SNNGraph


NOP = -1


@dataclasses.dataclass
class OpTables:
    """The mapped + scheduled program for the whole engine."""
    depth: int                  # S_OT: operation-table depth == #slots
    # all arrays are [M, depth]; NOP slots have pre == NOP
    pre: np.ndarray             # global pre-neuron index
    post: np.ndarray            # global post-neuron index
    weight: np.ndarray          # int weight value
    pre_end: np.ndarray         # bool
    post_end: np.ndarray        # bool
    send_slot: dict             # post global idx -> slot
    send_order: list            # posts in send order
    assign: np.ndarray          # [E] synapse -> SPU (the partition)

    @property
    def n_spus(self) -> int:
        return self.pre.shape[0]

    @classmethod
    def from_dense(cls, pre: np.ndarray, post: np.ndarray, weight: np.ndarray,
                   pre_end: np.ndarray, post_end: np.ndarray,
                   assign: np.ndarray) -> "OpTables":
        """Rebuild OpTables from the dense arrays alone.

        ``send_slot``/``send_order`` are derived, not stored: every
        Post-End op of post p sits in p's send slot (validate_schedule
        invariant b), so the flags fully determine both. Used by
        :meth:`repro.core.program.Program.load` to round-trip an
        artifact without serializing Python containers.
        """
        spus, slots = np.nonzero(post_end)
        send_slot = {int(p): int(t)
                     for p, t in zip(post[spus, slots], slots)}
        send_order = sorted(send_slot, key=send_slot.__getitem__)
        return cls(int(pre.shape[1]), pre, post, weight, pre_end, post_end,
                   send_slot, send_order, assign)


@dataclasses.dataclass(frozen=True)
class LoweredProgram:
    """Dense array form of a scheduled program, ready for compiled execution.

    The (SPU, slot) grid of the OpTables is flattened into slot-major op
    streams (all SPUs of slot 0, then slot 1, ...) — the exact order the
    hardware commits ops — plus the MC-tree routing bitmap. This is the
    single lowering shared by the Python reference executor
    (``engine.run_mapped`` uses ``routing``) and the compiled batched
    executor (``engine_jax`` uses the op streams). The Pre-End/Post-End
    flags are not needed by the scan executor (its spike gating subsumes
    them) but are kept so the lowering is the COMPLETE dense program —
    the form a slot-level hardware executor would consume.
    """
    n_inputs: int
    n_neurons: int
    n_internal: int
    n_spus: int
    depth: int                  # S_OT of the source tables
    # flattened non-NOP ops, slot-major; all arrays are [n_ops]
    op_spu: np.ndarray          # int32 SPU executing the op
    op_slot: np.ndarray         # int32 OT slot of the op
    op_pre: np.ndarray          # int32 global pre-neuron index
    op_post_local: np.ndarray   # int32 LOCAL post index (global - n_inputs)
    op_weight: np.ndarray       # int32 weight
    op_pre_end: np.ndarray      # bool Pre-End flag
    op_post_end: np.ndarray     # bool Post-End flag
    # MC-tree routing bitstrings: routing[q, i] == SPU i holds a synapse of q
    routing: np.ndarray         # [n_neurons, n_spus] bool

    @property
    def n_ops(self) -> int:
        return int(self.op_pre.shape[0])


def lower_tables(g: SNNGraph, tables: OpTables) -> LoweredProgram:
    """Lower scheduled OpTables into the dense :class:`LoweredProgram`."""
    m, depth = tables.pre.shape
    spu, slot = np.nonzero(tables.pre != NOP)
    order = np.lexsort((spu, slot))          # slot-major commit order
    spu, slot = spu[order], slot[order]

    routing = np.zeros((g.n_neurons, m), bool)
    routing[g.pre, tables.assign] = True

    return LoweredProgram(
        n_inputs=g.n_inputs,
        n_neurons=g.n_neurons,
        n_internal=g.n_internal,
        n_spus=m,
        depth=depth,
        op_spu=spu.astype(np.int32),
        op_slot=slot.astype(np.int32),
        op_pre=tables.pre[spu, slot].astype(np.int32),
        op_post_local=(tables.post[spu, slot] - g.n_inputs).astype(np.int32),
        op_weight=tables.weight[spu, slot].astype(np.int32),
        op_pre_end=tables.pre_end[spu, slot].copy(),
        op_post_end=tables.post_end[spu, slot].copy(),
        routing=routing,
    )
