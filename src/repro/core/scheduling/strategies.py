"""Pluggable schedule-strategy registry (mirrors ``mapping.strategies``).

A :class:`ScheduleStrategy` chooses the post-neuron *transmit order* of
§6.3; the send-slot recurrence, the pinning, and the backward fill are
order-independent (the recurrence guarantees backward-fill feasibility
for ANY permutation), so a strategy is exactly one policy decision —
which posts send early and which send late. The registry sits behind
``compile(schedule_method=...)`` and the portfolio's joint
(mapping, schedule) selection; ``register_schedule_strategy`` adds
custom orderings (a learned policy, a hardware-vendor heuristic)
without compiler changes.

Built-ins:

* ``slack`` — the repo default: ascending max-synapses-on-any-single-
  SPU, so high-fan-in posts transmit last and backward-fill slack is
  maximized (the order the legacy loop hard-coded).
* ``consecutive`` — the paper's baseline: posts transmit in natural
  index order; whenever #posts >= per-SPU load the recurrence's max()
  never binds and the send slots are literally consecutive.
* ``load_balance`` — ascending TOTAL fan-in (ties by per-SPU max, then
  index): posts whose synapses are spread across many SPUs transmit
  late, keeping every SPU's early slots available for fill.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.scheduling.vectorized import GroupInfo, slack_send_order


@runtime_checkable
class ScheduleStrategy(Protocol):
    """One policy for ordering post-neuron transmissions."""

    name: str

    def send_order(self, info: GroupInfo) -> np.ndarray:
        """Return the posts of ``info`` as a send-order permutation."""
        ...


@dataclasses.dataclass(frozen=True)
class SlackStrategy:
    """Ascending (max synapses per SPU, post) — maximizes fill slack."""

    name: str = "slack"

    def send_order(self, info: GroupInfo) -> np.ndarray:
        return slack_send_order(info)


@dataclasses.dataclass(frozen=True)
class ConsecutiveStrategy:
    """The paper's consecutive-slot baseline: natural post order."""

    name: str = "consecutive"

    def send_order(self, info: GroupInfo) -> np.ndarray:
        return info.posts.copy()


@dataclasses.dataclass(frozen=True)
class LoadBalanceStrategy:
    """Ascending (total fan-in, max per SPU, post) — spread posts late."""

    name: str = "load_balance"

    def send_order(self, info: GroupInfo) -> np.ndarray:
        return info.posts[np.lexsort((info.posts, info.cmax, info.total))]


SCHEDULE_STRATEGIES: dict[str, ScheduleStrategy] = {}


def register_schedule_strategy(strategy: ScheduleStrategy, *,
                               replace: bool = False) -> ScheduleStrategy:
    """Add a strategy to the registry (its ``name`` is the compile
    ``schedule_method=`` key). Re-registering a taken name requires
    ``replace=True``."""
    if not replace and strategy.name in SCHEDULE_STRATEGIES:
        raise ValueError(f"schedule strategy {strategy.name!r} already "
                         f"registered; pass replace=True to override")
    SCHEDULE_STRATEGIES[strategy.name] = strategy
    return strategy


def get_schedule_strategy(name: str) -> ScheduleStrategy:
    """Resolve a ``schedule_method=`` name; unknown names list what
    exists."""
    try:
        return SCHEDULE_STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule_method {name!r}; "
            f"use one of {sorted(SCHEDULE_STRATEGIES)}") from None


def _register_builtins() -> None:
    # "slack" first: the portfolio's joint selection iterates the
    # registry in insertion order with a strict depth comparison, so the
    # default strategy wins per-candidate ties
    register_schedule_strategy(SlackStrategy(), replace=True)
    register_schedule_strategy(ConsecutiveStrategy(), replace=True)
    register_schedule_strategy(LoadBalanceStrategy(), replace=True)


_register_builtins()
