"""SupraSNN scheduling subsystem (paper §6.3) — see DESIGN.md §7.2.

#   tables      OpTables / LoweredProgram containers + lower_tables
#   vectorized  the array-core scheduler (lexsort/cumsum/segment ops)
#   legacy      the original Python loop, kept as the parity reference
#   strategies  the ScheduleStrategy registry behind
#               compile(schedule_method=...)
#   validate    schedule legality checks

:func:`schedule` is the public entry: resolve the strategy name to a
send order, run the vectorized core. ``schedule(g, assign, hw)`` with
no ``method`` is bit-exact with the pre-split ``core/schedule.py``
(and with :func:`~repro.core.scheduling.legacy.schedule_legacy`).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.memory_model import HardwareConfig
from repro.core.scheduling.legacy import schedule_legacy
from repro.core.scheduling.strategies import (SCHEDULE_STRATEGIES,
                                              ConsecutiveStrategy,
                                              LoadBalanceStrategy,
                                              ScheduleStrategy,
                                              SlackStrategy,
                                              get_schedule_strategy,
                                              register_schedule_strategy)
from repro.core.scheduling.tables import (NOP, LoweredProgram, OpTables,
                                          lower_tables)
from repro.core.scheduling.validate import validate_schedule
from repro.core.scheduling.vectorized import (GroupInfo, group_info,
                                              schedule_vectorized)


def schedule(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig, *,
             method: str = "slack",
             info: GroupInfo | None = None) -> OpTables:
    """Heuristic scheduling (paper §6.3) of an assignment into OpTables.

    ``method`` names a registered :class:`ScheduleStrategy` (the post
    transmit-order policy); the default ``'slack'`` reproduces the
    original scheduler bit-exactly. ``info`` takes a precomputed
    :func:`group_info` so multi-strategy callers group only once.
    """
    strategy = get_schedule_strategy(method)
    gi = info if info is not None else group_info(g, assign)
    return schedule_vectorized(g, assign, hw,
                               send_order=strategy.send_order(gi), info=gi)


__all__ = [
    "NOP", "OpTables", "LoweredProgram", "lower_tables",
    "schedule", "schedule_legacy", "schedule_vectorized",
    "GroupInfo", "group_info", "validate_schedule",
    "ScheduleStrategy", "SlackStrategy", "ConsecutiveStrategy",
    "LoadBalanceStrategy", "SCHEDULE_STRATEGIES",
    "get_schedule_strategy", "register_schedule_strategy",
]
