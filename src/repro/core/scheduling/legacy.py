"""The original Python scheduling loop, preserved as the parity reference.

This is the seed repo's ``schedule.py`` heuristic (paper §6.3), kept
verbatim: per-post/per-SPU ``cum`` recurrence, dict-of-groups, per-group
``bisect`` backward fill, reverse Pre-End scan. The vectorized core in
:mod:`repro.core.scheduling.vectorized` must reproduce it BIT-EXACTLY —
same tables, same ``send_slot``/``send_order``, same infeasibility
assertion messages — for any (graph, assignment, hw, send order);
tests/test_scheduling.py enforces it and
``benchmarks/scheduler_throughput.py`` races the two.

Two injection hooks were added for strategy parity testing (they default
to the original behavior and leave the loop itself untouched):

* ``send_order`` — an externally-chosen post transmit order (what a
  :class:`~repro.core.scheduling.strategies.ScheduleStrategy` produces);
  ``None`` computes the original ascending max-synapses-per-SPU order.
* ``send_slots`` — externally-chosen post -> slot assignments, replacing
  the feasibility recurrence entirely (the backward fill can then run
  out of room, exercising the infeasibility assertion).

Do not optimize this module; its value is being the slow, obviously-
faithful spine the fast path is proven against.
"""
from __future__ import annotations

import bisect

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.memory_model import HardwareConfig
from repro.core.scheduling.tables import NOP, OpTables


def schedule_legacy(g: SNNGraph, assign: np.ndarray, hw: HardwareConfig,
                    send_order: list | np.ndarray | None = None,
                    send_slots: dict[int, int] | None = None) -> OpTables:
    """The original loop-based scheduler (see module docstring).

    Algorithm (faithful to the paper, plus an explicit send-slot
    recurrence that guarantees backward-fill feasibility):

      1. Sort post-neurons ascending by max-synapses-on-any-single-SPU
         (high-fan-in posts transmit last, maximizing slack).
      2. Walk the sorted order keeping per-SPU cumulative op counts
         cum_i; post p gets send slot t_p = max(t_prev + 1,
         max_i cum_i(p) - 1). (The paper uses consecutive slots, which
         suffices when #posts >= per-SPU load; the max() generalizes it
         — with balanced load the depth converges to max_i(total
         ops_i), exactly the paper's Fig. 13 regime.)
      3. Fix one synapse of each (SPU, post) group at t_p with Post-End
         set.
      4. Backward-fill the remaining synapses into free earlier slots,
         processing posts in REVERSE send order (EDF-style; provably
         feasible given the recurrence in 2).
      5. Set Pre-End on the last op referencing each pre-synaptic
         neuron.
      6. Remaining slots are NOPs.
    """
    m = hw.n_spus

    # group synapses by (spu, post)
    order = np.lexsort((g.pre, g.post, assign))
    s_spu, s_post = assign[order], g.post[order]

    posts = np.unique(g.post)
    # count per (spu, post): c[spu][post]
    group_keys = s_spu.astype(np.int64) * g.n_neurons + s_post
    uniq_keys, key_start, key_count = np.unique(
        group_keys, return_index=True, return_counts=True)

    # per-post max count over SPUs (step 1)
    post_of_key = (uniq_keys % g.n_neurons).astype(np.int64)
    cmax: dict[int, int] = {}
    for pk, c in zip(post_of_key.tolist(), key_count.tolist()):
        cmax[pk] = max(cmax.get(pk, 0), int(c))
    if send_order is None:
        send_order = sorted(posts.tolist(), key=lambda q: (cmax[q], q))
    else:
        send_order = [int(q) for q in send_order]

    # step 2: send slots via the feasibility recurrence
    groups: dict[tuple[int, int], np.ndarray] = {}
    for k, st, c in zip(uniq_keys.tolist(), key_start.tolist(),
                        key_count.tolist()):
        spu, pq = int(k // g.n_neurons), int(k % g.n_neurons)
        groups[(spu, pq)] = order[st:st + c]

    if send_slots is None:
        cum = np.zeros(m, np.int64)
        send_slot: dict[int, int] = {}
        t_prev = -1
        for pq in send_order:
            for spu in range(m):
                grp = groups.get((spu, pq))
                if grp is not None:
                    cum[spu] += len(grp)
            t = max(t_prev + 1, int(cum.max()) - 1)
            send_slot[pq] = t
            t_prev = t
        depth = t_prev + 1 if send_order else 0
    else:
        send_slot = {int(q): int(t) for q, t in send_slots.items()}
        send_order = sorted(send_slot, key=send_slot.__getitem__)
        depth = max(send_slot.values()) + 1 if send_slot else 0

    pre_t = np.full((m, depth), NOP, np.int64)
    post_t = np.full((m, depth), NOP, np.int64)
    w_t = np.zeros((m, depth), np.int64)
    pe_t = np.zeros((m, depth), bool)
    poe_t = np.zeros((m, depth), bool)

    # step 3: pin final synapse of every (spu, post) group at t_p
    for (spu, pq), grp in groups.items():
        t = send_slot[pq]
        syn = int(grp[-1])
        pre_t[spu, t] = g.pre[syn]
        post_t[spu, t] = pq
        w_t[spu, t] = g.weight[syn]
        poe_t[spu, t] = True

    # free-slot lists per SPU (ascending), minus the pinned send slots
    free = []
    for spu in range(m):
        pinned = {int(send_slot[pq]) for (s, pq) in groups if s == spu}
        free.append([t for t in range(depth) if t not in pinned])

    # step 4: backward fill, reverse send order
    for pq in reversed(send_order):
        t_p = send_slot[pq]
        for spu in range(m):
            grp = groups.get((spu, pq))
            if grp is None or len(grp) == 1:
                continue
            rest = grp[:-1]
            fl = free[spu]
            # indices of free slots strictly before t_p
            hi = bisect.bisect_left(fl, t_p)
            assert hi >= len(rest), (
                f"schedule infeasible: SPU {spu} post {pq} needs "
                f"{len(rest)} slots before {t_p}, has {hi}")
            take = fl[hi - len(rest):hi]
            del fl[hi - len(rest):hi]
            for t, syn in zip(take, rest.tolist()):
                pre_t[spu, t] = g.pre[syn]
                post_t[spu, t] = pq
                w_t[spu, t] = g.weight[syn]

    # step 5: Pre-End on the last op touching each pre, per SPU
    for spu in range(m):
        seen: set[int] = set()
        for t in range(depth - 1, -1, -1):
            pr = int(pre_t[spu, t])
            if pr != NOP and pr not in seen:
                pe_t[spu, t] = True
                seen.add(pr)

    return OpTables(depth, pre_t, post_t, w_t, pe_t, poe_t,
                    send_slot, send_order, assign.astype(np.int32))
