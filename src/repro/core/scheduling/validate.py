"""Schedule legality checks (DESIGN.md §7.3) — compat shim.

The actual analysis lives in :mod:`repro.analysis.schedule` (the
schedule hazard detector of the static artifact verifier, DESIGN.md
§13): it re-derives send-slot occupancy from the raw tables and emits
structured :class:`~repro.analysis.diagnostics.Diagnostic` records
naming the offending (post, SPU, slot) — including hazards the old
bare asserts never covered (send-slot collisions, malformed NOP
slots).

:func:`validate_schedule` keeps the historical raise-on-violation
contract: it runs the detector and raises ``AssertionError`` with the
EXACT legacy message of the highest-priority violation
(``tests/test_scheduling.py`` / ``tests/test_mapping.py`` pin those
messages), so every pre-framework caller keeps working unchanged.
"""
from __future__ import annotations

from repro.core.graph import SNNGraph
from repro.core.scheduling.tables import OpTables


def validate_schedule(g: SNNGraph, tables: OpTables) -> None:
    """Legality checks: raises AssertionError on the first violation
    (legacy check order and message format); use
    :func:`repro.analysis.schedule.check_schedule` for the full
    structured diagnostic list."""
    # lazy: repro.analysis sits above the scheduling layer
    from repro.analysis.schedule import check_schedule, raise_legacy
    raise_legacy(check_schedule(g, tables))
