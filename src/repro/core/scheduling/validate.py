"""Schedule legality checks (DESIGN.md §7.3)."""
from __future__ import annotations

import numpy as np

from repro.core.graph import SNNGraph
from repro.core.scheduling.tables import NOP, OpTables


def validate_schedule(g: SNNGraph, tables: OpTables) -> None:
    """Legality checks (DESIGN.md §7.3): raises AssertionError on violation.

    All four invariants are numpy mask/lexsort expressions over the
    ``[M, depth]`` tables — no Python loop over slots — so validation
    stays a negligible slice of compile time at large OT depths.
    Messages keep the original loop-based wording, with two deliberate
    repairs: invariant (b) reads the expected slot through the dense
    table (a post missing from ``send_slot`` reports slot -1 instead of
    KeyError-ing inside the f-string), and invariant (c) names the
    offending (post, SPU, slot) instead of asserting bare.
    """
    valid = tables.pre != NOP
    spu_i, slot_i = np.nonzero(valid)           # row-major: (spu, t) order
    pre_v = tables.pre[spu_i, slot_i]
    post_v = tables.post[spu_i, slot_i]
    w_v = tables.weight[spu_i, slot_i]

    # (a) every synapse appears exactly once
    n_placed = int(valid.sum())
    assert n_placed == g.n_synapses, \
        f"{n_placed} ops != {g.n_synapses} synapses"
    have = np.lexsort((w_v, post_v, pre_v))
    want = np.lexsort((g.weight, g.post, g.pre))
    assert (np.array_equal(pre_v[have], g.pre[want])
            and np.array_equal(post_v[have], g.post[want])
            and np.array_equal(w_v[have], g.weight[want])), \
        "op multiset != synapse multiset"

    # send slot per post as a dense lookup table
    n = g.n_neurons
    ss = np.full(n, -1, np.int64)
    for pq, t in tables.send_slot.items():
        ss[pq] = t

    # (b) merge alignment: all post_end slots of post p identical across SPUs
    pe_spu, pe_slot = np.nonzero(tables.post_end)
    pe_post = tables.post[pe_spu, pe_slot]
    bad = ss[pe_post] != pe_slot
    if bad.any():
        i = int(np.argmax(bad))                 # first violation, (spu, t)
        # report the expected slot through the dense table: a post with
        # no send_slot entry at all reads as -1 instead of KeyError-ing
        # inside the message formatting
        raise AssertionError(
            f"post {int(pe_post[i])} sent at {int(pe_slot[i])} "
            f"!= slot {int(ss[int(pe_post[i])])}")
    # exactly one post_end per (spu, post with synapses there)
    pe_key = pe_spu * n + pe_post
    assert len(np.unique(pe_key)) == len(pe_key), \
        "duplicate post_end in one SPU"
    assert np.array_equal(np.unique(pe_key), np.unique(spu_i * n + post_v)), \
        "missing post_end"

    # (c) all ops of (spu, post) at slots <= send slot
    late = slot_i > ss[post_v]
    if late.any():
        i = int(np.argmax(late))
        raise AssertionError(
            f"op of post {int(post_v[i])} on SPU {int(spu_i[i])} at slot "
            f"{int(slot_i[i])} after its send slot {int(ss[post_v[i]])}")

    # (d) pre_end exactly on last reference per (spu, pre)
    key = spu_i * n + pre_v
    order = np.lexsort((slot_i, key))
    k_sorted, s_sorted = key[order], slot_i[order]
    is_last = np.r_[k_sorted[1:] != k_sorted[:-1], np.ones(min(len(key), 1),
                                                           bool)]
    fe_spu, fe_slot = np.nonzero(tables.pre_end)
    fkey = fe_spu * n + tables.pre[fe_spu, fe_slot]
    forder = np.lexsort((fe_slot, fkey))
    fk, fs = fkey[forder], fe_slot[forder]
    f_last = np.r_[fk[1:] != fk[:-1], np.ones(min(len(fk), 1), bool)]
    assert (np.array_equal(fk[f_last], k_sorted[is_last])
            and np.array_equal(fs[f_last], s_sorted[is_last])), \
        "pre_end flags wrong"
