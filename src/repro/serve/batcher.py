"""Library micro-batcher: the queue / pow2-bucket / drain logic that
used to live as demo code inside ``examples/serve_snn.py``.

The batcher is a *deterministic simulation* of a single-threaded
serving loop. Time is a simulated microsecond clock — arrivals come
from the caller, service times come from an explicit ``service_model``
(or, when none is given, from measuring the real engine call) — so
identical inputs always produce identical per-request latencies, which
is what makes the queue semantics property-testable.

Semantics (:class:`BatchPolicy`):

* requests are served strictly FIFO — a batch is always a contiguous
  run of the arrival-ordered queue;
* a batch **dispatches** when it is full (``max_batch`` requests) or
  when the oldest queued request has waited ``max_wait_us`` (with
  ``max_wait_us=0`` the batcher drains whatever has arrived, the
  original demo behavior);
* the real batch size is rounded up to the next **bucket** (default:
  powers of two capped at ``max_batch``) and padded with all-zero
  samples, so XLA compiles one program per bucket, not per batch size;
* the engine is serially busy: the next batch cannot dispatch before
  the previous one completes.

Per-request accounting lands in :class:`DrainResult` — dispatch /
completion / latency per request plus a :class:`BatchRecord` per
engine call.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """When to dispatch, and which padded batch shapes exist.

    max_batch: most requests per engine call.
    max_wait_us: how long the oldest queued request may wait for the
        batch to fill before dispatching anyway (0 = never hold).
    buckets: allowed padded batch sizes, ascending; defaults to the
        powers of two below ``max_batch`` plus ``max_batch`` itself.
    """
    max_batch: int = 8
    max_wait_us: float = 0.0
    buckets: tuple[int, ...] = ()

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_us < 0:
            raise ValueError(
                f"max_wait_us must be >= 0, got {self.max_wait_us}")
        buckets = tuple(int(b) for b in self.buckets)
        if not buckets:
            buckets = tuple(b for k in range(self.max_batch.bit_length())
                            if (b := 2 ** k) < self.max_batch)
            buckets += (self.max_batch,)
        if list(buckets) != sorted(set(buckets)) or buckets[0] < 1:
            raise ValueError(f"buckets must be ascending unique positive "
                             f"ints, got {buckets}")
        if buckets[-1] < self.max_batch:
            raise ValueError(f"largest bucket {buckets[-1]} cannot hold a "
                             f"full batch of {self.max_batch}")
        object.__setattr__(self, "buckets", buckets)

    def bucket_of(self, n: int) -> int:
        """Smallest allowed padded size holding ``n`` requests."""
        if not 1 <= n <= self.max_batch:
            raise ValueError(f"batch of {n} outside [1, {self.max_batch}]")
        for b in self.buckets:
            if b >= n:
                return b
        raise AssertionError("unreachable: buckets[-1] >= max_batch")


def linear_service_model(base_us: float = 200.0,
                         per_sample_us: float = 25.0):
    """Deterministic service-time model ``base + per_sample * bucket``.

    Used wherever reproducible latencies matter (the seeded example,
    smoke tests); swap in ``service_model=None`` to measure the real
    engine call instead.
    """
    def model(bucket: int) -> float:
        return base_us + per_sample_us * bucket
    return model


def latency_metrics(latencies_us: np.ndarray,
                    completion_us: np.ndarray) -> dict:
    """p50/p99/mean latency (ms) + simulated throughput (req/s) — the
    one definition shared by per-model and total metrics."""
    if not len(latencies_us):
        return {"requests": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                "mean_ms": 0.0, "throughput_rps": 0.0}
    arrivals = completion_us - latencies_us
    span_s = max(float(completion_us.max() - arrivals.min()), 1e-9) / 1e6
    p50, p99 = np.percentile(latencies_us, [50, 99])
    return {
        "requests": int(len(latencies_us)),
        "p50_ms": float(p50) / 1e3,
        "p99_ms": float(p99) / 1e3,
        "mean_ms": float(latencies_us.mean()) / 1e3,
        "throughput_rps": len(latencies_us) / span_s,
    }


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One engine call: requests [first, first+size) padded to bucket."""
    first: int
    size: int
    bucket: int
    dispatch_us: float
    service_us: float
    completion_us: float


@dataclasses.dataclass
class DrainResult:
    """Per-request accounting plus optional engine outputs."""
    latencies_us: np.ndarray          # [N]
    dispatch_us: np.ndarray           # [N] when the request's batch left
    completion_us: np.ndarray         # [N] arrival + latency
    batch_index: np.ndarray           # [N] which BatchRecord served it
    batches: list[BatchRecord]
    outputs: tuple | None = None      # (spikes [N,T,·], v [N,·], pkts [N,T])

    @property
    def n_requests(self) -> int:
        return len(self.latencies_us)

    def bucket_histogram(self) -> dict[int, int]:
        hist: dict[int, int] = {}
        for b in self.batches:
            hist[b.bucket] = hist.get(b.bucket, 0) + 1
        return hist

    def metrics(self) -> dict:
        """:func:`latency_metrics` plus batch/bucket accounting; the
        key set is stable, including for an empty drain."""
        m = latency_metrics(self.latencies_us, self.completion_us)
        m["batches"] = len(self.batches)
        m["buckets"] = self.bucket_histogram()
        return m


class MicroBatcher:
    """Drain an arrival-ordered request queue in padded micro-batches.

    runner: callable ``[b, T, n_in] -> (spikes, v, stats)`` — e.g.
        ``program.run`` or ``ShardedRunner.run``; ``None`` simulates
        the queue without executing anything (pure policy tests).
    service_model: callable ``bucket -> service_us``; ``None`` measures
        the wall clock of each runner call (requires a runner).
    """

    def __init__(self, policy: BatchPolicy | None = None, *,
                 runner=None, service_model=None):
        self.policy = policy or BatchPolicy()
        self.runner = runner
        self.service_model = service_model
        if runner is None and service_model is None:
            raise ValueError("need a service_model when there is no runner "
                             "to measure (simulation-only batcher)")

    def _warm_buckets(self, sample_shape: tuple, dtype) -> None:
        """Warm one compilation per policy bucket (measured mode).

        Preferred path: the runner's ``precompile(buckets, timesteps)``
        hook — the same AOT layer ``Program.load``/registry insert use
        (:mod:`repro.core.aot`), which lowers + compiles without
        executing anything. Exposed by ``Program.run`` /
        ``ShardedRunner.run`` bound methods and registry runners;
        plain-function runners fall back to throwaway zero-batch
        calls.
        """
        pre = getattr(self.runner, "precompile", None)
        if pre is None:
            owner = getattr(self.runner, "__self__", None)
            pre = getattr(owner, "precompile", None)
        if pre is not None:
            pre(self.policy.buckets, sample_shape[0])
            return
        for b in self.policy.buckets:
            self.runner(np.zeros((b,) + sample_shape, dtype))

    # -- queue simulation ---------------------------------------------------

    def _admit(self, arrivals: np.ndarray, i: int, clock: float
               ) -> tuple[int, float]:
        """How many requests join the batch starting at ``i``, and when
        the batch dispatches (full, or the oldest waited out)."""
        pol = self.policy
        n_total = len(arrivals)
        t0 = max(clock, float(arrivals[i]))      # oldest request ready
        horizon = (max(t0, float(arrivals[i]) + pol.max_wait_us)
                   if pol.max_wait_us > 0 else t0)
        n = 1
        while (n < pol.max_batch and i + n < n_total
               and arrivals[i + n] <= horizon):
            n += 1
        if n == pol.max_batch:                   # full: leave immediately
            dispatch = max(t0, float(arrivals[i + n - 1]))
        else:                                    # waited out the window
            dispatch = horizon
        return n, dispatch

    # -- public API ---------------------------------------------------------

    def drain(self, arrivals_us: np.ndarray,
              requests: np.ndarray | None = None) -> DrainResult:
        """Serve every request once, FIFO, under the policy.

        arrivals_us: nondecreasing arrival times (one per request).
        requests: binary ``[N, T, n_inputs]`` spike trains, required
        when the batcher owns a runner.
        """
        arrivals = np.asarray(arrivals_us, np.float64)
        if arrivals.ndim != 1:
            raise ValueError(f"arrivals_us must be 1-D, got shape "
                             f"{arrivals.shape}")
        if len(arrivals) > 1 and np.any(np.diff(arrivals) < 0):
            raise ValueError("arrivals_us must be nondecreasing (the queue "
                             "is FIFO in arrival order)")
        if self.runner is not None:
            if requests is None:
                raise ValueError("runner set but no requests given")
            requests = np.asarray(requests)
            if requests.ndim != 3 or len(requests) != len(arrivals):
                raise ValueError(f"requests must be [N, T, n_inputs] with "
                                 f"N == len(arrivals); got "
                                 f"{requests.shape} vs {len(arrivals)}")
        if (self.runner is not None and self.service_model is None
                and len(arrivals)):
            # measured mode: warm one engine compilation per bucket so
            # jit time never counts as service time on the first hit
            self._warm_buckets(requests.shape[1:], requests.dtype)
        n_total = len(arrivals)
        lat = np.zeros(n_total)
        disp = np.zeros(n_total)
        comp = np.zeros(n_total)
        b_idx = np.zeros(n_total, np.int64)
        batches: list[BatchRecord] = []
        out_s: list = []
        out_v: list = []
        out_p: list = []

        clock = 0.0
        i = 0
        while i < n_total:
            n, dispatch = self._admit(arrivals, i, clock)
            bucket = self.policy.bucket_of(n)
            measured_us = 0.0
            if self.runner is not None:
                batch = requests[i:i + n]
                if n < bucket:                   # pad to the bucket shape
                    pad = np.zeros((bucket - n,) + batch.shape[1:],
                                   batch.dtype)
                    batch = np.concatenate([batch, pad])
                t_wall = time.perf_counter()
                spikes, v, stats = self.runner(batch)
                measured_us = (time.perf_counter() - t_wall) * 1e6
                out_s.append(spikes[:n])
                out_v.append(v[:n])
                out_p.append(np.asarray(stats["packet_counts"])[:n])
            service_us = (self.service_model(bucket)
                          if self.service_model is not None else measured_us)
            completion = dispatch + service_us
            lat[i:i + n] = completion - arrivals[i:i + n]
            disp[i:i + n] = dispatch
            comp[i:i + n] = completion
            b_idx[i:i + n] = len(batches)
            batches.append(BatchRecord(i, n, bucket, dispatch, service_us,
                                       completion))
            clock = completion                   # engine serially busy
            i += n

        outputs = None
        if self.runner is not None and out_s:
            outputs = (np.concatenate(out_s), np.concatenate(out_v),
                       np.concatenate(out_p))
        return DrainResult(lat, disp, comp, b_idx, batches, outputs)
