"""Library micro-batcher: queue / pow2-bucket / drain logic plus the
real-time policies (bounded queues, shedding, deadlines) layered on it.

The batcher is a *deterministic simulation* of a serving loop. Time is
a simulated microsecond clock — arrivals come from the caller, service
times come from an explicit ``service_model`` (or, when none is given,
from measuring the real engine call) — so identical inputs always
produce identical per-request latencies, which is what makes the queue
semantics property-testable.

Semantics (:class:`BatchPolicy`):

* requests are served strictly FIFO — a batch is always the oldest
  still-queued run of the arrival-ordered queue;
* a batch **dispatches** when it is full (``max_batch`` requests) or
  when the oldest queued request has waited ``max_wait_us`` (with
  ``max_wait_us=0`` the batcher drains whatever has arrived, the
  original demo behavior);
* the real batch size is rounded up to the next **bucket** (default:
  powers of two capped at ``max_batch``) and padded with all-zero
  samples, so XLA compiles one program per bucket, not per batch size;
* the engine is serially busy: the next batch cannot dispatch before
  the previous one completes.

Overload semantics (all default OFF, preserving the original
unbounded-queue behavior bit-exactly):

* ``max_queue > 0`` bounds the number of *waiting* requests. An
  arrival that finds the queue full is handled by the ``shed`` policy:
  ``"reject"`` sheds the arriving request, ``"drop-oldest"`` sheds the
  head of the queue and admits the arrival, ``"degrade"`` (alias
  ``"degrade-to-smaller-bucket"``) never sheds — while the backlog
  exceeds ``max_queue`` the batcher stops holding for ``max_wait_us``
  and dispatches the largest *exact* bucket that fits the backlog, so
  no service time is spent on padding until the queue recovers.
* ``deadline_us > 0`` gives every request a dispatch deadline of
  ``arrival + deadline_us``. The batch hold window is deadline-aware
  (a partial batch dispatches early rather than expiring its head);
  a request still queued past its deadline — the engine was busy too
  long — is shed with reason ``"deadline"``. Dispatching exactly at
  the deadline still serves the request.

Shed requests never execute and never complete: their latency /
dispatch / completion entries are NaN, ``batch_index`` is -1, and the
shed reason + simulated shed time are recorded per request.

Per-request accounting lands in :class:`DrainResult` — dispatch /
completion / latency per request plus a :class:`BatchRecord` per
engine call, and a four-stage latency decomposition:

* ``queue_wait_us``  — waiting because the engine was busy with
  earlier batches (arrival until the engine freed up, clipped);
* ``fill_wait_us``   — waiting for the batch to form (hold window /
  later arrivals) once the engine could have taken it;
* ``pad_us``         — the share of service time spent on pad rows,
  ``service * (bucket - size) / bucket``;
* ``compute_us``     — the remaining service time.

The invariant ``queue_wait + fill_wait + pad + compute ==
latencies_us`` holds **bit-exactly**: ``latencies_us`` is *defined* as
that sum, evaluated left-to-right (:meth:`DrainResult.stage_sum`), and
``completion_us - arrival`` agrees with it to float rounding.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

# shed-reason codes stored in DrainResult.shed_reason (int8)
SHED_NONE = 0
SHED_QUEUE_FULL = 1
SHED_DEADLINE = 2
SHED_REASONS = {SHED_QUEUE_FULL: "queue_full", SHED_DEADLINE: "deadline"}

_SHED_POLICIES = ("reject", "drop-oldest", "degrade")
_SHED_ALIASES = {"degrade-to-smaller-bucket": "degrade"}


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """When to dispatch, which padded batch shapes exist, and what to
    do under overload.

    max_batch: most requests per engine call.
    max_wait_us: how long the oldest queued request may wait for the
        batch to fill before dispatching anyway (0 = never hold).
    buckets: allowed padded batch sizes, ascending; defaults to the
        powers of two below ``max_batch`` plus ``max_batch`` itself.
    max_queue: most requests allowed to *wait* (0 = unbounded). The
        bound is what makes backpressure explicit: overload becomes
        accounted shed events instead of unbounded queue growth.
    deadline_us: dispatch deadline per request, from its arrival
        (0 = none). The hold window is deadline-aware; requests the
        engine cannot reach in time are shed, never silently late.
    shed: overload policy when the queue is full — ``"reject"`` the
        arrival, ``"drop-oldest"`` waiting request, or ``"degrade"``
        to exact smaller buckets without shedding.
    """
    max_batch: int = 8
    max_wait_us: float = 0.0
    buckets: tuple[int, ...] = ()
    max_queue: int = 0
    deadline_us: float = 0.0
    shed: str = "reject"

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_us < 0:
            raise ValueError(
                f"max_wait_us must be >= 0, got {self.max_wait_us}")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")
        if self.deadline_us < 0:
            raise ValueError(
                f"deadline_us must be >= 0, got {self.deadline_us}")
        shed = _SHED_ALIASES.get(self.shed, self.shed)
        if shed not in _SHED_POLICIES:
            raise ValueError(f"shed must be one of {_SHED_POLICIES} "
                             f"(or alias 'degrade-to-smaller-bucket'), "
                             f"got {self.shed!r}")
        object.__setattr__(self, "shed", shed)
        buckets = tuple(int(b) for b in self.buckets)
        if not buckets:
            buckets = tuple(b for k in range(self.max_batch.bit_length())
                            if (b := 2 ** k) < self.max_batch)
            buckets += (self.max_batch,)
        if list(buckets) != sorted(set(buckets)) or buckets[0] < 1:
            raise ValueError(f"buckets must be ascending unique positive "
                             f"ints, got {buckets}")
        if buckets[-1] < self.max_batch:
            raise ValueError(f"largest bucket {buckets[-1]} cannot hold a "
                             f"full batch of {self.max_batch}")
        object.__setattr__(self, "buckets", buckets)

    def bucket_of(self, n: int) -> int:
        """Smallest allowed padded size holding ``n`` requests."""
        if not 1 <= n <= self.max_batch:
            raise ValueError(f"batch of {n} outside [1, {self.max_batch}]")
        for b in self.buckets:
            if b >= n:
                return b
        raise AssertionError("unreachable: buckets[-1] >= max_batch")

    def degrade_size(self, backlog: int) -> int:
        """Degraded batch size for ``backlog`` waiting requests: the
        largest bucket that fits exactly (no pad rows), capped at
        ``max_batch``; falls back to the plain size when even the
        smallest bucket is larger than the backlog."""
        n = min(backlog, self.max_batch)
        best = 0
        for b in self.buckets:
            if b <= n:
                best = b
        return best or n


def linear_service_model(base_us: float = 200.0,
                         per_sample_us: float = 25.0):
    """Deterministic service-time model ``base + per_sample * bucket``.

    Used wherever reproducible latencies matter (the seeded example,
    the soak harness, smoke tests); swap in ``service_model=None`` to
    measure the real engine call instead.
    """
    def model(bucket: int) -> float:
        return base_us + per_sample_us * bucket
    return model


def latency_metrics(latencies_us: np.ndarray,
                    completion_us: np.ndarray) -> dict:
    """p50/p99/mean latency (ms) + simulated throughput (req/s) — the
    one definition shared by per-model and total metrics."""
    if not len(latencies_us):
        return {"requests": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                "mean_ms": 0.0, "throughput_rps": 0.0}
    arrivals = completion_us - latencies_us
    span_s = max(float(completion_us.max() - arrivals.min()), 1e-9) / 1e6
    p50, p99 = np.percentile(latencies_us, [50, 99])
    return {
        "requests": int(len(latencies_us)),
        "p50_ms": float(p50) / 1e3,
        "p99_ms": float(p99) / 1e3,
        "mean_ms": float(latencies_us.mean()) / 1e3,
        "throughput_rps": len(latencies_us) / span_s,
    }


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One engine call serving ``members`` padded to ``bucket``.

    ``first``/``size`` describe the contiguous run ``[first,
    first+size)`` when nothing was shed; under shedding ``members``
    (arrival-ordered request indices) is authoritative and may skip
    shed indices. ``degraded`` marks a degrade-mode dispatch (exact
    bucket, no hold).
    """
    first: int
    size: int
    bucket: int
    dispatch_us: float
    service_us: float
    completion_us: float
    degraded: bool = False
    members: tuple[int, ...] = ()


@dataclasses.dataclass
class ShedEvent:
    """One shed request: which, why, and when (simulated µs)."""
    index: int
    reason: str
    t_us: float


@dataclasses.dataclass
class DrainResult:
    """Per-request accounting plus optional engine outputs.

    All arrays are indexed by the original request order. For shed
    requests ``latencies_us``/``dispatch_us``/``completion_us`` are
    NaN, ``batch_index`` is -1, stage entries are 0, and
    ``shed_reason``/``shed_time_us`` say why and when. ``outputs``
    rows align with ``np.flatnonzero(served)`` (FIFO serve order).
    """
    latencies_us: np.ndarray          # [N]
    dispatch_us: np.ndarray           # [N] when the request's batch left
    completion_us: np.ndarray         # [N] arrival + latency
    batch_index: np.ndarray           # [N] which BatchRecord served it
    batches: list[BatchRecord]
    outputs: tuple | None = None      # (spikes [n,T,·], v [n,·], pkts [n,T])
    queue_wait_us: np.ndarray | None = None   # [N] engine-busy wait
    fill_wait_us: np.ndarray | None = None    # [N] batch-formation wait
    pad_us: np.ndarray | None = None          # [N] pad-row service share
    compute_us: np.ndarray | None = None      # [N] real service share
    served: np.ndarray | None = None          # [N] bool
    shed_reason: np.ndarray | None = None     # [N] int8 SHED_* code
    shed_time_us: np.ndarray | None = None    # [N] NaN unless shed

    def __post_init__(self):
        n = len(self.latencies_us)
        if self.served is None:
            self.served = np.ones(n, bool)
        if self.shed_reason is None:
            self.shed_reason = np.zeros(n, np.int8)
        if self.shed_time_us is None:
            self.shed_time_us = np.full(n, np.nan)
        for f in ("queue_wait_us", "fill_wait_us", "pad_us", "compute_us"):
            if getattr(self, f) is None:
                setattr(self, f, np.zeros(n))

    @property
    def n_requests(self) -> int:
        return len(self.latencies_us)

    @property
    def n_served(self) -> int:
        return int(self.served.sum())

    @property
    def n_shed(self) -> int:
        return self.n_requests - self.n_served

    def shed_events(self) -> list[ShedEvent]:
        return [ShedEvent(int(i), SHED_REASONS[int(self.shed_reason[i])],
                          float(self.shed_time_us[i]))
                for i in np.flatnonzero(self.shed_reason)]

    def shed_counts(self) -> dict[str, int]:
        """{"queue_full": k, "deadline": m} — always both keys."""
        return {name: int((self.shed_reason == code).sum())
                for code, name in SHED_REASONS.items()}

    def stage_sum(self) -> np.ndarray:
        """THE summation order of the stage invariant: ``queue_wait +
        fill_wait + pad + compute`` left-to-right. ``latencies_us`` of
        served requests equals this bit-exactly by construction."""
        return (self.queue_wait_us + self.fill_wait_us
                + self.pad_us + self.compute_us)

    def bucket_histogram(self) -> dict[int, int]:
        hist: dict[int, int] = {}
        for b in self.batches:
            hist[b.bucket] = hist.get(b.bucket, 0) + 1
        return hist

    def metrics(self) -> dict:
        """:func:`latency_metrics` over *served* requests plus batch /
        bucket / shed / stage accounting; the key set is stable,
        including for an empty drain."""
        mask = self.served
        m = latency_metrics(self.latencies_us[mask],
                            self.completion_us[mask])
        m["batches"] = len(self.batches)
        m["buckets"] = self.bucket_histogram()
        shed = self.shed_counts()
        m["shed"] = shed
        m["shed_frac"] = (self.n_shed / self.n_requests
                          if self.n_requests else 0.0)
        m["deadline_misses"] = shed["deadline"]
        m["degraded_batches"] = sum(1 for b in self.batches if b.degraded)
        n_srv = self.n_served
        m["stages_us"] = {
            "queue_wait": float(self.queue_wait_us[mask].mean())
            if n_srv else 0.0,
            "batch_fill": float(self.fill_wait_us[mask].mean())
            if n_srv else 0.0,
            "pad": float(self.pad_us[mask].mean()) if n_srv else 0.0,
            "compute": float(self.compute_us[mask].mean())
            if n_srv else 0.0,
        }
        return m


# ---------------------------------------------------------------------------
# The event-driven queue simulation shared by MicroBatcher.drain,
# Server(timeline="shared") and the replay soak harness.
# ---------------------------------------------------------------------------

_INF = float("inf")


@dataclasses.dataclass
class _QueueSpec:
    """One FIFO queue feeding the (possibly shared) engine."""
    policy: BatchPolicy
    arrivals: np.ndarray                   # sorted nondecreasing float64
    requests: np.ndarray | None            # [N, T, n_in] or None
    runner: object | None                  # batch callable or None
    service_model: object | None           # bucket -> µs or None


class _QueueState:
    """Mutable per-queue simulation state + result accumulators."""

    def __init__(self, spec: _QueueSpec):
        n = len(spec.arrivals)
        self.spec = spec
        self.waiting: deque[int] = deque()
        self.free = 0.0                    # per-queue engine clock
        self.lat = np.zeros(n)
        self.disp = np.zeros(n)
        self.comp = np.zeros(n)
        self.qw = np.zeros(n)
        self.fw = np.zeros(n)
        self.pad = np.zeros(n)
        self.cu = np.zeros(n)
        self.b_idx = np.zeros(n, np.int64)
        self.served = np.zeros(n, bool)
        self.reason = np.zeros(n, np.int8)
        self.shed_t = np.full(n, np.nan)
        self.batches: list[BatchRecord] = []
        self.out_s: list = []
        self.out_v: list = []
        self.out_p: list = []

    def shed(self, i: int, code: int, t: float) -> None:
        self.reason[i] = code
        self.shed_t[i] = t
        self.lat[i] = self.disp[i] = self.comp[i] = np.nan
        self.b_idx[i] = -1

    def result(self) -> DrainResult:
        outputs = None
        if self.spec.runner is not None and self.out_s:
            outputs = (np.concatenate(self.out_s),
                       np.concatenate(self.out_v),
                       np.concatenate(self.out_p))
        return DrainResult(self.lat, self.disp, self.comp, self.b_idx,
                           self.batches, outputs, self.qw, self.fw,
                           self.pad, self.cu, self.served, self.reason,
                           self.shed_t)


def _simulate(specs: list[_QueueSpec], *,
              shared_engine: bool) -> list[_QueueState]:
    """Run every queue to completion on the simulated clock.

    ``shared_engine=True`` threads ONE serially-busy engine through
    all queues (dispatches interleave in time order, ties broken by
    queue order); ``False`` gives each queue its own engine clock.
    Event order at equal times: arrivals first (a request arriving
    exactly at a dispatch horizon joins the batch), then dispatches
    (dispatching exactly at a deadline serves the request), then
    deadline expiries.
    """
    states = [_QueueState(s) for s in specs]
    shared_free = 0.0
    now = 0.0      # time of the last processed event: dispatches never
    #                schedule into the past (e.g. when degrade overload
    #                collapses a hold window already partially elapsed)

    # merged arrival schedule: (time, queue, local index), stable order
    events = sorted((float(t), q, i)
                    for q, s in enumerate(specs)
                    for i, t in enumerate(s.arrivals))
    ev = 0

    def engine_free(q: int) -> float:
        return shared_free if shared_engine else states[q].free

    def candidates(q: int) -> tuple[float, float]:
        """(dispatch time, head-expiry time) for queue q, inf if n/a."""
        st = states[q]
        if not st.waiting:
            return _INF, _INF
        pol = st.spec.policy
        a = st.spec.arrivals
        head = st.waiting[0]
        t0 = max(engine_free(q), float(a[head]), now)
        overload = (pol.shed == "degrade" and pol.max_queue > 0
                    and len(st.waiting) > pol.max_queue)
        if pol.max_wait_us > 0 and not overload:
            hold = float(a[head]) + pol.max_wait_us
            if pol.deadline_us > 0:       # deadline-aware hold window
                hold = min(hold, float(a[head]) + pol.deadline_us)
            horizon = max(t0, hold)
        else:
            horizon = t0
        if len(st.waiting) >= pol.max_batch:
            dispatch = max(t0, float(a[st.waiting[pol.max_batch - 1]]))
        else:
            dispatch = horizon
        expiry = (float(a[head]) + pol.deadline_us
                  if pol.deadline_us > 0 else _INF)
        return dispatch, expiry

    def admit(q: int, i: int, t: float) -> None:
        st = states[q]
        pol = st.spec.policy
        if (pol.max_queue > 0 and len(st.waiting) >= pol.max_queue
                and pol.shed != "degrade"):
            if pol.shed == "reject":
                st.shed(i, SHED_QUEUE_FULL, t)
                return
            st.shed(st.waiting.popleft(), SHED_QUEUE_FULL, t)
        st.waiting.append(i)

    def dispatch(q: int, d: float) -> None:
        nonlocal shared_free
        st = states[q]
        spec = st.spec
        pol = spec.policy
        a = spec.arrivals
        free_before = engine_free(q)
        degraded = (pol.shed == "degrade" and pol.max_queue > 0
                    and len(st.waiting) > pol.max_queue)
        n = (pol.degrade_size(len(st.waiting)) if degraded
             else min(len(st.waiting), pol.max_batch))
        members = [st.waiting.popleft() for _ in range(n)]
        bucket = pol.bucket_of(n)
        measured_us = 0.0
        if spec.runner is not None:
            batch = spec.requests[np.asarray(members)]
            if n < bucket:                 # pad to the bucket shape
                padrows = np.zeros((bucket - n,) + batch.shape[1:],
                                   batch.dtype)
                batch = np.concatenate([batch, padrows])
            t_wall = time.perf_counter()
            spikes, v, stats = spec.runner(batch)
            measured_us = (time.perf_counter() - t_wall) * 1e6
            st.out_s.append(spikes[:n])
            st.out_v.append(v[:n])
            st.out_p.append(np.asarray(stats["packet_counts"])[:n])
        service_us = (spec.service_model(bucket)
                      if spec.service_model is not None else measured_us)
        completion = d + service_us
        pad_ratio = (bucket - n) / bucket
        for r in members:
            wait = d - float(a[r])
            q_wait = min(wait, max(0.0, free_before - float(a[r])))
            f_wait = wait - q_wait
            pad_v = service_us * pad_ratio
            cu_v = service_us - pad_v
            st.qw[r] = q_wait
            st.fw[r] = f_wait
            st.pad[r] = pad_v
            st.cu[r] = cu_v
            # latency is DEFINED as the stage sum (stage_sum order) so
            # the decomposition invariant holds bit-exactly
            st.lat[r] = ((q_wait + f_wait) + pad_v) + cu_v
            st.disp[r] = d
            st.comp[r] = completion
            st.b_idx[r] = len(st.batches)
            st.served[r] = True
        st.batches.append(BatchRecord(members[0], n, bucket, d, service_us,
                                      completion, degraded, tuple(members)))
        if shared_engine:
            shared_free = completion
        else:
            st.free = completion

    while True:
        t_arr = events[ev][0] if ev < len(events) else _INF
        best_d = best_e = _INF
        q_d = q_e = -1
        for q in range(len(states)):
            d, e = candidates(q)
            if d < best_d:
                best_d, q_d = d, q
            if e < best_e:
                best_e, q_e = e, q
        if t_arr == _INF and best_d == _INF and best_e == _INF:
            break
        if t_arr <= best_d and t_arr <= best_e:
            _, q, i = events[ev]
            ev += 1
            now = max(now, t_arr)
            admit(q, i, t_arr)
        elif best_d <= best_e:
            now = max(now, best_d)
            dispatch(q_d, best_d)
        else:
            now = max(now, best_e)
            st = states[q_e]
            st.shed(st.waiting.popleft(), SHED_DEADLINE, best_e)
    return states


# ---------------------------------------------------------------------------
# MicroBatcher: the public single-queue surface over the simulation.
# ---------------------------------------------------------------------------

class MicroBatcher:
    """Drain an arrival-ordered request queue in padded micro-batches.

    runner: callable ``[b, T, n_in] -> (spikes, v, stats)`` — e.g.
        ``program.run`` or ``ShardedRunner.run``; ``None`` simulates
        the queue without executing anything (pure policy tests).
    service_model: callable ``bucket -> service_us``; ``None`` measures
        the wall clock of each runner call (requires a runner).
    """

    def __init__(self, policy: BatchPolicy | None = None, *,
                 runner=None, service_model=None):
        self.policy = policy or BatchPolicy()
        self.runner = runner
        self.service_model = service_model
        if runner is None and service_model is None:
            raise ValueError("need a service_model when there is no runner "
                             "to measure (simulation-only batcher)")
        self._warmed: set[tuple] = set()   # (bucket, T, dtype) warmed keys

    def _warm_buckets(self, sample_shape: tuple, dtype) -> None:
        """Warm one compilation per policy bucket (measured mode),
        exactly once per ``(bucket, timesteps, dtype)`` key — repeated
        drains on the same shapes skip the warm-up entirely.

        Preferred path: the runner's ``precompile(buckets, timesteps)``
        hook — the same AOT layer ``Program.load``/registry insert use
        (:mod:`repro.core.aot`), which lowers + compiles without
        executing anything. Exposed by ``Program.run`` /
        ``ShardedRunner.run`` bound methods and registry runners;
        plain-function runners fall back to throwaway zero-batch
        calls.
        """
        t_steps = int(sample_shape[0])
        key_dtype = np.dtype(dtype).str
        todo = tuple(b for b in self.policy.buckets
                     if (b, t_steps, key_dtype) not in self._warmed)
        if not todo:
            return
        pre = getattr(self.runner, "precompile", None)
        if pre is None:
            owner = getattr(self.runner, "__self__", None)
            pre = getattr(owner, "precompile", None)
        if pre is not None:
            pre(todo, t_steps)
        else:
            for b in todo:
                self.runner(np.zeros((b,) + tuple(sample_shape), dtype))
        self._warmed.update((b, t_steps, key_dtype) for b in todo)

    def _queue_spec(self, arrivals_us: np.ndarray,
                    requests: np.ndarray | None) -> _QueueSpec:
        """Validate inputs, warm buckets, return the simulation spec."""
        arrivals = np.asarray(arrivals_us, np.float64)
        if arrivals.ndim != 1:
            raise ValueError(f"arrivals_us must be 1-D, got shape "
                             f"{arrivals.shape}")
        if len(arrivals) > 1 and np.any(np.diff(arrivals) < 0):
            raise ValueError("arrivals_us must be nondecreasing (the queue "
                             "is FIFO in arrival order)")
        if self.runner is not None:
            if requests is None:
                raise ValueError("runner set but no requests given")
            requests = np.asarray(requests)
            if requests.ndim != 3 or len(requests) != len(arrivals):
                raise ValueError(f"requests must be [N, T, n_inputs] with "
                                 f"N == len(arrivals); got "
                                 f"{requests.shape} vs {len(arrivals)}")
        if (self.runner is not None and self.service_model is None
                and len(arrivals)):
            # measured mode: warm one engine compilation per bucket so
            # jit time never counts as service time on the first hit
            self._warm_buckets(requests.shape[1:], requests.dtype)
        return _QueueSpec(self.policy, arrivals, requests, self.runner,
                          self.service_model)

    # -- public API ---------------------------------------------------------

    def drain(self, arrivals_us: np.ndarray,
              requests: np.ndarray | None = None) -> DrainResult:
        """Serve every request once, FIFO, under the policy.

        arrivals_us: nondecreasing arrival times (one per request).
        requests: binary ``[N, T, n_inputs]`` spike trains, required
        when the batcher owns a runner.
        """
        spec = self._queue_spec(arrivals_us, requests)
        return _simulate([spec], shared_engine=False)[0].result()


def drain_together(items: list[tuple["MicroBatcher", np.ndarray,
                                     np.ndarray | None]]
                   ) -> list[DrainResult]:
    """Drain several queues against ONE serially-shared engine.

    ``items`` is ``[(batcher, arrivals_us, requests-or-None), ...]``;
    queue order breaks simultaneous-dispatch ties. This is the
    timeline :class:`~repro.serve.server.Server` uses for its default
    ``timeline="shared"`` totals and what the replay soak harness
    replays traces through.
    """
    specs = [b._queue_spec(arr, req) for b, arr, req in items]
    return [st.result()
            for st in _simulate(specs, shared_engine=True)]
