"""Real-time asyncio serving front end with admission control.

Where :class:`~repro.serve.server.Server` *simulates* a serving loop
on a deterministic clock (drain a recorded stream, get exact
metrics), :class:`AsyncServer` *is* one: callers ``await submit(...)``
concurrently, every model owns a bounded FIFO queue drained by one
worker task, and overload surfaces as exceptions at the submission
site — explicit backpressure instead of unbounded queue growth.

The :class:`~repro.serve.batcher.BatchPolicy` semantics are the same
as the simulated batcher's, applied to the real clock:

* admission control at ``submit``: a full queue (``max_queue``)
  rejects the arrival (:class:`QueueFullError`), sheds the oldest
  waiting request (``drop-oldest`` — *that* submitter's await raises),
  or admits anyway and degrades batch sizing (``degrade``);
* the worker holds a partial batch up to ``max_wait_us`` (deadline-
  aware: it never holds a head past its dispatch deadline), pads to
  the policy bucket, and runs the engine serially per model;
* requests still queued past ``arrival + deadline_us`` are failed
  with :class:`DeadlineMissError` — shed requests NEVER execute.

Per-request latency decomposes into the same four stages as
:class:`~repro.serve.batcher.DrainResult` (queue wait / batch fill /
pad / compute), measured from real timestamps but *defined* as the
stage sum, so ``queue_wait_us + fill_wait_us + pad_us + compute_us ==
latency_us`` holds bit-exactly here too.

Execution: with a ``service_model`` the server sleeps the modeled
service time (pure policy behavior, no engine); without one it runs
the model's registry runner in a thread executor (jax releases the
GIL during compute) and the measured wall time is the service time.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque

import numpy as np

from repro.serve.batcher import BatchPolicy, latency_metrics
from repro.serve.registry import ProgramRegistry
from repro.serve.server import Request


class ShedError(RuntimeError):
    """A submitted request was shed instead of served."""
    reason = "shed"


class QueueFullError(ShedError):
    """Admission control rejected the request: the model queue was
    full (shed policy ``reject``), or the request was the oldest
    waiting when a newer one arrived (``drop-oldest``)."""
    reason = "queue_full"


class DeadlineMissError(ShedError):
    """The request was still queued past ``arrival + deadline_us``."""
    reason = "deadline"


@dataclasses.dataclass(frozen=True)
class CompletedRequest:
    """What a successful ``await submit(...)`` resolves to."""
    model: str
    stream: int
    latency_us: float                 # == the stage sum, bit-exactly
    queue_wait_us: float
    fill_wait_us: float
    pad_us: float
    compute_us: float
    bucket: int
    batch_size: int
    degraded: bool
    outputs: tuple | None = None      # (spikes [T,·], v [·], pkts [T])


@dataclasses.dataclass
class _Pending:
    ext: np.ndarray
    stream: int
    t_enq_us: float
    future: asyncio.Future


class AsyncServer:
    """Asyncio service over a :class:`ProgramRegistry`.

    Use as an async context manager or call ``start()``/``stop()``::

        async with AsyncServer(registry, policy=pol) as srv:
            done = await srv.submit(Request("m", ext, 0.0))

    Policy resolution per model: ``policies[name]`` > the policy
    registered with the model > ``policy``. ``clock`` injects a µs
    timestamp source (default ``time.monotonic``-based) — timestamps
    only feed metrics, never control flow ordering.
    """

    def __init__(self, registry: ProgramRegistry, *,
                 policy: BatchPolicy | None = None,
                 policies: dict[str, BatchPolicy] | None = None,
                 service_model=None, spec=None, clock=None):
        self.registry = registry
        self.policy = policy or BatchPolicy()
        self.policies = dict(policies or {})
        self.service_model = service_model
        self.spec = spec
        self._clock = clock or (lambda: time.monotonic() * 1e6)
        self._queues: dict[str, deque[_Pending]] = {}
        self._conds: dict[str, asyncio.Condition] = {}
        self._workers: dict[str, asyncio.Task] = {}
        self._free_us: dict[str, float] = {}
        self._completed: dict[str, list[CompletedRequest]] = {}
        self._completion_ts: dict[str, list[float]] = {}
        self._shed: dict[str, dict[str, int]] = {}
        self._degraded_batches: dict[str, int] = {}
        self._batch_count: dict[str, int] = {}
        self._dequeued: dict[str, int] = {}   # requests taken off a queue
        self._running = False

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "AsyncServer":
        if self._running:
            raise RuntimeError("AsyncServer already started")
        self._running = True
        now = self._clock()
        for name in self.registry.names():
            self._queues[name] = deque()
            self._conds[name] = asyncio.Condition()
            self._free_us[name] = now
            self._completed[name] = []
            self._completion_ts[name] = []
            self._shed[name] = {"queue_full": 0, "deadline": 0}
            self._degraded_batches[name] = 0
            self._batch_count[name] = 0
            self._dequeued[name] = 0
            self._workers[name] = asyncio.create_task(
                self._worker(name), name=f"serve-{name}")
        return self

    async def stop(self, *, drain: bool = True) -> None:
        """Stop the workers. ``drain=True`` serves everything already
        queued first; ``drain=False`` fails pending requests with
        :class:`ShedError` immediately."""
        self._running = False
        if not drain:
            for name, q in self._queues.items():
                while q:
                    p = q.popleft()
                    if not p.future.done():
                        p.future.set_exception(
                            ShedError(f"server for model {name!r} stopped "
                                      f"without draining"))
        for cond in self._conds.values():
            async with cond:
                cond.notify_all()
        for task in self._workers.values():
            await task
        self._workers.clear()

    async def __aenter__(self) -> "AsyncServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- submission (admission control happens HERE) ------------------------

    def policy_for(self, name: str) -> BatchPolicy:
        if name in self.policies:
            return self.policies[name]
        registered = self.registry.policy(name)
        return registered if registered is not None else self.policy

    async def submit(self, request: Request) -> CompletedRequest:
        """Submit one request; resolves when served, raises
        :class:`QueueFullError`/:class:`DeadlineMissError` when shed.
        ``request.arrival_us`` is ignored — the real clock stamps the
        arrival."""
        if not self._running:
            raise RuntimeError("AsyncServer not started")
        name = request.model
        if name not in self._queues:
            raise KeyError(f"request for unregistered model {name!r}; "
                           f"have {tuple(sorted(self._queues))}")
        pol = self.policy_for(name)
        q = self._queues[name]
        if (pol.max_queue > 0 and len(q) >= pol.max_queue
                and pol.shed != "degrade"):
            if pol.shed == "reject":
                self._shed[name]["queue_full"] += 1
                raise QueueFullError(
                    f"model {name!r} queue full "
                    f"({len(q)} waiting >= max_queue={pol.max_queue})")
            oldest = q.popleft()           # drop-oldest
            self._shed[name]["queue_full"] += 1
            if not oldest.future.done():
                oldest.future.set_exception(QueueFullError(
                    f"model {name!r} shed the oldest waiting request "
                    f"(drop-oldest, max_queue={pol.max_queue})"))
        pending = _Pending(np.asarray(request.ext), request.stream,
                           self._clock(),
                           asyncio.get_running_loop().create_future())
        q.append(pending)
        cond = self._conds[name]
        async with cond:
            cond.notify_all()
        return await pending.future

    # -- the per-model worker -----------------------------------------------

    async def _fill_batch(self, name: str, pol: BatchPolicy) -> None:
        """Hold for the batch to fill: up to ``max_wait_us`` from the
        head's enqueue (deadline-aware), ended early by a full batch,
        overload (degrade mode), or shutdown."""
        q = self._queues[name]
        cond = self._conds[name]
        head = q[0]
        hold_until = head.t_enq_us + pol.max_wait_us
        if pol.deadline_us > 0:
            hold_until = min(hold_until, head.t_enq_us + pol.deadline_us)
        while (self._running and q and q[0] is head
               and len(q) < pol.max_batch
               and not (pol.shed == "degrade" and pol.max_queue > 0
                        and len(q) > pol.max_queue)):
            remaining_s = (hold_until - self._clock()) / 1e6
            if remaining_s <= 0:
                return
            async with cond:
                try:
                    await asyncio.wait_for(cond.wait(), remaining_s)
                except asyncio.TimeoutError:
                    return

    def _run_engine(self, runner, batch: np.ndarray):
        return runner(batch)

    async def _worker(self, name: str) -> None:
        q = self._queues[name]
        cond = self._conds[name]
        pol = self.policy_for(name)
        runner = (None if self.service_model is not None
                  else self.registry.runner(name, self.spec))
        loop = asyncio.get_running_loop()
        while True:
            async with cond:
                while self._running and not q:
                    await cond.wait()
            if not q:
                if not self._running:
                    return
                continue
            if pol.max_wait_us > 0 and len(q) < pol.max_batch:
                await self._fill_batch(name, pol)
            # deadline purge: shed everything already past its deadline
            now = self._clock()
            while q and pol.deadline_us > 0 and \
                    q[0].t_enq_us + pol.deadline_us < now:
                p = q.popleft()
                self._shed[name]["deadline"] += 1
                if not p.future.done():
                    p.future.set_exception(DeadlineMissError(
                        f"model {name!r} request queued "
                        f"{(now - p.t_enq_us):.0f}us > deadline_us="
                        f"{pol.deadline_us:.0f}"))
            if not q:
                continue
            degraded = (pol.shed == "degrade" and pol.max_queue > 0
                        and len(q) > pol.max_queue)
            n = (pol.degrade_size(len(q)) if degraded
                 else min(len(q), pol.max_batch))
            members = [q.popleft() for _ in range(n)]
            self._dequeued[name] += n
            bucket = pol.bucket_of(n)
            dispatch = self._clock()
            outputs = None
            if runner is not None:
                batch = np.stack([p.ext for p in members])
                if n < bucket:
                    pad = np.zeros((bucket - n,) + batch.shape[1:],
                                   batch.dtype)
                    batch = np.concatenate([batch, pad])
                spikes, v, stats = await loop.run_in_executor(
                    None, self._run_engine, runner, batch)
                pkts = np.asarray(stats["packet_counts"])[:n]
                outputs = (spikes[:n], v[:n], pkts)
            else:
                await asyncio.sleep(self.service_model(bucket) / 1e6)
            completion = self._clock()
            service_us = completion - dispatch
            free_before = self._free_us[name]
            pad_ratio = (bucket - n) / bucket
            for j, p in enumerate(members):
                wait = dispatch - p.t_enq_us
                q_wait = min(wait, max(0.0, free_before - p.t_enq_us))
                f_wait = wait - q_wait
                pad_v = service_us * pad_ratio
                cu_v = service_us - pad_v
                done = CompletedRequest(
                    model=name, stream=p.stream,
                    latency_us=((q_wait + f_wait) + pad_v) + cu_v,
                    queue_wait_us=q_wait, fill_wait_us=f_wait,
                    pad_us=pad_v, compute_us=cu_v, bucket=bucket,
                    batch_size=n, degraded=degraded,
                    outputs=(None if outputs is None else
                             (outputs[0][j], outputs[1][j], outputs[2][j])))
                self._completed[name].append(done)
                self._completion_ts[name].append(completion)
                if not p.future.done():
                    p.future.set_result(done)
            self._free_us[name] = completion
            self._batch_count[name] += 1
            if degraded:
                self._degraded_batches[name] += 1

    # -- metrics ------------------------------------------------------------

    def metrics(self) -> dict:
        """Same shape as ``Server.serve``'s dict: per-model + total
        latency/shed/stage accounting from everything served so far."""
        models: dict[str, dict] = {}
        all_lat: list[float] = []
        all_comp: list[float] = []
        total_shed = {"queue_full": 0, "deadline": 0}
        stage_tot = {"queue_wait": 0.0, "batch_fill": 0.0, "pad": 0.0,
                     "compute": 0.0}
        n_total = 0
        for name in self._completed:
            done = self._completed[name]
            lat = np.asarray([c.latency_us for c in done])
            comp = np.asarray(self._completion_ts[name])
            m = latency_metrics(lat, comp)
            m["batches"] = self._batch_count[name]
            shed = dict(self._shed[name])
            n_req = len(done) + sum(shed.values())
            m["shed"] = shed
            m["shed_frac"] = (sum(shed.values()) / n_req) if n_req else 0.0
            m["deadline_misses"] = shed["deadline"]
            m["degraded_batches"] = self._degraded_batches[name]
            m["stages_us"] = {
                "queue_wait": float(np.mean([c.queue_wait_us
                                             for c in done])) if done
                else 0.0,
                "batch_fill": float(np.mean([c.fill_wait_us
                                             for c in done])) if done
                else 0.0,
                "pad": float(np.mean([c.pad_us for c in done])) if done
                else 0.0,
                "compute": float(np.mean([c.compute_us
                                          for c in done])) if done
                else 0.0,
            }
            models[name] = m
            all_lat.extend(lat.tolist())
            all_comp.extend(comp.tolist())
            for k in total_shed:
                total_shed[k] += shed[k]
            for c in done:
                stage_tot["queue_wait"] += c.queue_wait_us
                stage_tot["batch_fill"] += c.fill_wait_us
                stage_tot["pad"] += c.pad_us
                stage_tot["compute"] += c.compute_us
            n_total += n_req
        total = latency_metrics(np.asarray(all_lat), np.asarray(all_comp))
        total["models"] = len(models)
        total["timeline"] = "real"
        total["shed"] = total_shed
        total["shed_frac"] = (sum(total_shed.values()) / n_total
                              if n_total else 0.0)
        total["deadline_misses"] = total_shed["deadline"]
        n_done = len(all_lat)
        total["stages_us"] = {k: (v / n_done if n_done else 0.0)
                              for k, v in stage_tot.items()}
        return {"models": models, "total": total}
