"""Trace replay: sustained-load soak testing on the simulated clock.

The serving stack's policy semantics live in an event-driven
simulation (:mod:`repro.serve.batcher`), so soak testing is replay:
generate (or load) an arrival trace, drain it through the same
``MicroBatcher``/``drain_together`` code path the server uses, and
read the percentiles. Everything is deterministic — the same seed
produces the same trace, and the same trace produces bit-identical
per-request latencies — so p99/SLO and shed-rate bounds can be
*asserted*, not eyeballed.

:class:`ArrivalTrace` holds arrival times + client-stream tags and
builds the two canonical synthetic workloads:

* :meth:`ArrivalTrace.poisson` — memoryless arrivals at a target QPS;
* :meth:`ArrivalTrace.bursty` — periodic on/off modulation (an
  on-window at ``burst_factor`` × the base rate), the event-camera /
  market-data shape that actually stresses bounded queues.

:func:`replay` drains one trace per model against one shared engine
(or per-engine clocks) and returns a :class:`SoakReport` whose
``check``/``assert_slo`` encode the acceptance bars. Stage latencies
sum bit-exactly to end-to-end latency here for the same reason they do
everywhere else: the drain loop *defines* latency as that sum.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from repro.serve.batcher import (BatchPolicy, DrainResult, MicroBatcher,
                                 SHED_REASONS, drain_together)

_TRACE_KINDS = ("poisson", "bursty", "recorded")


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """A replayable arrival process: times (µs, nondecreasing) plus a
    client-stream tag per request and the generator's metadata."""
    arrivals_us: np.ndarray
    streams: np.ndarray
    duration_us: float
    kind: str = "recorded"
    seed: int | None = None

    def __post_init__(self):
        arr = np.asarray(self.arrivals_us, np.float64)
        if arr.ndim != 1:
            raise ValueError(f"arrivals_us must be 1-D, got {arr.shape}")
        if len(arr) > 1 and np.any(np.diff(arr) < 0):
            raise ValueError("arrivals_us must be nondecreasing")
        streams = np.asarray(self.streams, np.int64)
        if streams.shape != arr.shape:
            raise ValueError(f"streams shape {streams.shape} != arrivals "
                             f"shape {arr.shape}")
        if self.duration_us <= 0:
            raise ValueError(f"duration_us must be > 0, "
                             f"got {self.duration_us}")
        if self.kind not in _TRACE_KINDS:
            raise ValueError(f"kind must be one of {_TRACE_KINDS}, "
                             f"got {self.kind!r}")
        object.__setattr__(self, "arrivals_us", arr)
        object.__setattr__(self, "streams", streams)

    @property
    def n_requests(self) -> int:
        return len(self.arrivals_us)

    @property
    def duration_s(self) -> float:
        return self.duration_us / 1e6

    @property
    def offered_qps(self) -> float:
        return self.n_requests / self.duration_s

    # -- synthetic generators ------------------------------------------------

    @classmethod
    def poisson(cls, qps: float, duration_s: float, *, seed: int = 0,
                n_streams: int = 1) -> "ArrivalTrace":
        """Memoryless arrivals at ``qps`` for ``duration_s`` simulated
        seconds; streams are assigned round-robin-free (iid uniform)
        so FIFO-per-stream is a real property, not an artifact."""
        if qps <= 0 or duration_s <= 0:
            raise ValueError(f"qps and duration_s must be > 0, got "
                             f"{qps}, {duration_s}")
        rng = np.random.default_rng(seed)
        horizon = duration_s * 1e6
        # draw enough exponential gaps to cover the window w.h.p.,
        # then truncate — keeps generation O(n) and deterministic
        n_draw = max(16, int(qps * duration_s * 1.25) + 64)
        gaps = rng.exponential(1e6 / qps, n_draw)
        t = np.cumsum(gaps)
        while t[-1] < horizon:                 # pragma: no cover (rare)
            extra = rng.exponential(1e6 / qps, n_draw)
            t = np.concatenate([t, t[-1] + np.cumsum(extra)])
        t = t[t < horizon]
        streams = rng.integers(0, n_streams, len(t))
        return cls(t, streams, horizon, kind="poisson", seed=seed)

    @classmethod
    def bursty(cls, qps: float, duration_s: float, *, seed: int = 0,
               n_streams: int = 1, burst_factor: float = 4.0,
               period_s: float = 1.0, duty: float = 0.2) -> "ArrivalTrace":
        """On/off modulated Poisson averaging ``qps``: each
        ``period_s`` window spends ``duty`` of its span at
        ``burst_factor`` × the base rate and the rest at the
        complementary low rate (floored at 0), so the mean rate stays
        ``qps`` while bursts probe queue bounds and deadlines."""
        if not 0 < duty < 1:
            raise ValueError(f"duty must be in (0, 1), got {duty}")
        if burst_factor < 1:
            raise ValueError(f"burst_factor must be >= 1, "
                             f"got {burst_factor}")
        hi = qps * burst_factor
        lo = max((qps - duty * hi) / (1.0 - duty), 0.0)
        rng = np.random.default_rng(seed)
        horizon = duration_s * 1e6
        period_us = period_s * 1e6
        on_us = duty * period_us
        chunks = []
        start = 0.0
        while start < horizon:
            for rate, t0, t1 in ((hi, start, start + on_us),
                                 (lo, start + on_us, start + period_us)):
                t1 = min(t1, horizon)
                if rate <= 0 or t1 <= t0:
                    continue
                span = t1 - t0
                n_draw = max(4, int(rate / 1e6 * span * 1.5) + 32)
                t = t0 + np.cumsum(rng.exponential(1e6 / rate, n_draw))
                while t[-1] < t1:              # pragma: no cover (rare)
                    extra = rng.exponential(1e6 / rate, n_draw)
                    t = np.concatenate([t, t[-1] + np.cumsum(extra)])
                chunks.append(t[t < t1])
            start += period_us
        arrivals = (np.concatenate(chunks) if chunks
                    else np.zeros(0))
        streams = rng.integers(0, n_streams, len(arrivals))
        return cls(arrivals, streams, horizon, kind="bursty", seed=seed)

    # -- persistence ---------------------------------------------------------

    def save(self, path: str | Path) -> None:
        """Write the trace as ``.npz`` (portable, seed-independent)."""
        np.savez(Path(path), arrivals_us=self.arrivals_us,
                 streams=self.streams,
                 duration_us=np.float64(self.duration_us),
                 kind=np.str_(self.kind),
                 seed=np.int64(-1 if self.seed is None else self.seed))

    @classmethod
    def load(cls, path: str | Path) -> "ArrivalTrace":
        with np.load(Path(path)) as z:
            seed = int(z["seed"])
            return cls(z["arrivals_us"], z["streams"],
                       float(z["duration_us"]), kind=str(z["kind"]),
                       seed=None if seed < 0 else seed)


@dataclasses.dataclass
class SoakReport:
    """Aggregate view of one replay, with assertable acceptance bars.

    ``results`` holds the per-queue :class:`DrainResult`\\ s (full
    per-request accounting); the scalar fields are computed over every
    queue's served requests on the replay timeline.
    """
    results: dict[str, DrainResult]
    sim_seconds: float
    offered_qps: float
    requests: int
    served: int
    shed: dict[str, int]
    p50_ms: float
    p99_ms: float
    mean_ms: float
    stages_us: dict[str, float]
    stage_sum_exact: bool

    @property
    def shed_frac(self) -> float:
        return ((self.requests - self.served) / self.requests
                if self.requests else 0.0)

    @property
    def deadline_miss_frac(self) -> float:
        return (self.shed["deadline"] / self.requests
                if self.requests else 0.0)

    def fingerprint(self) -> tuple:
        """Bit-level digest for determinism checks: two replays of the
        same trace must produce equal fingerprints."""
        lat = np.concatenate(
            [r.latencies_us[r.served] for r in self.results.values()]
            or [np.zeros(0)])
        return (self.requests, self.served, tuple(sorted(self.shed.items())),
                lat.tobytes())

    def check(self, *, slo_p99_ms: float | None = None,
              max_shed_frac: float | None = None,
              max_deadline_miss_frac: float | None = None) -> list[str]:
        """Violated acceptance bars as human-readable strings
        (empty == pass). Stage-sum exactness is always checked."""
        bad = []
        if not self.stage_sum_exact:
            bad.append("stage latencies do not sum bit-exactly to "
                       "latencies_us")
        if slo_p99_ms is not None and self.p99_ms > slo_p99_ms:
            bad.append(f"p99 {self.p99_ms:.3f} ms > SLO {slo_p99_ms} ms")
        if max_shed_frac is not None and self.shed_frac > max_shed_frac:
            bad.append(f"shed_frac {self.shed_frac:.4f} > bound "
                       f"{max_shed_frac}")
        if (max_deadline_miss_frac is not None
                and self.deadline_miss_frac > max_deadline_miss_frac):
            bad.append(f"deadline_miss_frac {self.deadline_miss_frac:.4f} "
                       f"> bound {max_deadline_miss_frac}")
        return bad

    def assert_slo(self, **bounds) -> None:
        """Raise ``AssertionError`` listing every violated bar."""
        bad = self.check(**bounds)
        if bad:
            raise AssertionError("soak SLO violated:\n"
                                 + "\n".join(f"  - {b}" for b in bad))


def _as_map(value, names: list[str], what: str) -> dict:
    if isinstance(value, dict):
        missing = [n for n in names if n not in value]
        if missing:
            raise ValueError(f"no {what} for trace(s) {missing}")
        return value
    return {n: value for n in names}


def replay(traces, policy=None, service_model=None, *,
           shared: bool = True) -> SoakReport:
    """Replay arrival trace(s) through the drain simulation.

    traces: one :class:`ArrivalTrace` or ``{model_name: trace}``.
    policy: one :class:`BatchPolicy` or ``{model_name: policy}``
        (default ``BatchPolicy()``).
    service_model: ``bucket -> µs`` callable or ``{name: callable}``
        — required; replay is pure simulation, no engine runs.
    shared: ``True`` drains every queue against ONE serially-busy
        engine (the server's default timeline); ``False`` gives each
        queue its own engine clock.
    """
    if isinstance(traces, ArrivalTrace):
        traces = {"model": traces}
    if not traces:
        raise ValueError("need at least one trace to replay")
    names = sorted(traces)
    if service_model is None:
        raise ValueError("replay needs a service_model (bucket -> µs); "
                         "soak runs are pure simulation")
    policies = _as_map(policy if policy is not None else BatchPolicy(),
                       names, "policy")
    models = _as_map(service_model, names, "service_model")
    items = [(MicroBatcher(policies[n], service_model=models[n]),
              traces[n].arrivals_us, None) for n in names]
    if shared:
        drained = drain_together(items)
    else:
        drained = [b.drain(arr) for b, arr, _ in items]
    results = dict(zip(names, drained))

    lat = np.concatenate([r.latencies_us[r.served]
                          for r in results.values()])
    requests = sum(r.n_requests for r in results.values())
    served = sum(r.n_served for r in results.values())
    shed = {name: 0 for name in SHED_REASONS.values()}
    exact = True
    stage_cat: dict[str, list] = {"queue_wait": [], "batch_fill": [],
                                  "pad": [], "compute": []}
    for r in results.values():
        for k, v in r.shed_counts().items():
            shed[k] += v
        s = r.served
        exact = exact and bool(
            np.array_equal(r.stage_sum()[s], r.latencies_us[s]))
        stage_cat["queue_wait"].append(r.queue_wait_us[s])
        stage_cat["batch_fill"].append(r.fill_wait_us[s])
        stage_cat["pad"].append(r.pad_us[s])
        stage_cat["compute"].append(r.compute_us[s])
    sim_seconds = max(t.duration_s for t in traces.values())
    p50, p99 = (np.percentile(lat, [50, 99]) if len(lat)
                else (0.0, 0.0))
    return SoakReport(
        results=results,
        sim_seconds=sim_seconds,
        offered_qps=requests / sim_seconds,
        requests=requests,
        served=served,
        shed=shed,
        p50_ms=float(p50) / 1e3,
        p99_ms=float(p99) / 1e3,
        mean_ms=float(lat.mean()) / 1e3 if len(lat) else 0.0,
        stages_us={k: (float(np.concatenate(v).mean()) if served else 0.0)
                   for k, v in stage_cat.items()},
        stage_sum_exact=exact,
    )
