# SupraSNN serving subsystem: a loaded Program artifact as a
# first-class, multi-device, real-time service.
#   sharded      shard_map data parallelism over a jax mesh (pad-and-mask
#                ragged batches; bit-exact vs the single-device engine)
#   batcher      deterministic micro-batcher (simulated clock, BatchPolicy
#                with bounded queues / shedding / deadlines, pow2 buckets,
#                bit-exact per-stage latency decomposition)
#   registry     N loaded Programs by name, per-model engine + policy
#   server       request streams -> per-model queues -> metrics dict on an
#                explicit shared / per-engine timeline
#   async_server asyncio front-end: bounded queues, admission control,
#                backpressure as raised exceptions, real clock
#   replay       arrival-trace soak harness (Poisson / bursty generators,
#                deterministic SLO assertions)
from repro.serve.async_server import (AsyncServer, CompletedRequest,
                                      DeadlineMissError, QueueFullError,
                                      ShedError)
from repro.serve.batcher import (BatchPolicy, BatchRecord, DrainResult,
                                 MicroBatcher, SHED_DEADLINE, SHED_NONE,
                                 SHED_QUEUE_FULL, SHED_REASONS, ShedEvent,
                                 drain_together, latency_metrics,
                                 linear_service_model)
from repro.serve.registry import ProgramRegistry
from repro.serve.replay import ArrivalTrace, SoakReport, replay
from repro.serve.server import Request, Server
from repro.serve.sharded import ShardedRunner, sharded_runner

__all__ = [
    "ArrivalTrace", "AsyncServer",
    "BatchPolicy", "BatchRecord", "CompletedRequest",
    "DeadlineMissError", "DrainResult", "MicroBatcher",
    "ProgramRegistry", "QueueFullError", "Request",
    "SHED_DEADLINE", "SHED_NONE", "SHED_QUEUE_FULL", "SHED_REASONS",
    "Server", "ShardedRunner", "ShedError", "ShedEvent", "SoakReport",
    "drain_together", "latency_metrics", "linear_service_model",
    "replay", "sharded_runner",
]
