# SupraSNN serving subsystem: a loaded Program artifact as a
# first-class, multi-device service.
#   sharded    shard_map data parallelism over a jax mesh (pad-and-mask
#              ragged batches; bit-exact vs the single-device engine)
#   batcher    deterministic micro-batcher (simulated clock, BatchPolicy,
#              pow2 buckets, per-request latency accounting)
#   registry   N loaded Programs by name, per-model engine ownership
#   server     request streams -> per-model queues -> metrics dict
from repro.serve.batcher import (BatchPolicy, BatchRecord, DrainResult,
                                 MicroBatcher, latency_metrics,
                                 linear_service_model)
from repro.serve.registry import ProgramRegistry
from repro.serve.server import Request, Server
from repro.serve.sharded import ShardedRunner, sharded_runner

__all__ = [
    "BatchPolicy", "BatchRecord", "DrainResult", "MicroBatcher",
    "latency_metrics", "linear_service_model",
    "ProgramRegistry", "Request", "Server",
    "ShardedRunner", "sharded_runner",
]
