"""Artifact registry: N loaded ``Program``\\ s keyed by name.

A serving process loads each model artifact once (``Program.load`` —
never re-partitioning) and registers it under a unique name. Engine
ownership stays **per model**: compiled engines and sharded runners
live on each ``Program`` (lazily built, keyed on resolved build
options), so two registered models never share or evict each other's
compilations, and re-resolving a runner for the same model returns the
same object.
"""
from __future__ import annotations

from pathlib import Path

from repro.core.program import Program


class ProgramRegistry:
    """Name -> loaded :class:`~repro.core.program.Program`."""

    def __init__(self):
        self._programs: dict[str, Program] = {}

    # -- registration -------------------------------------------------------

    def register(self, name: str, program: Program) -> Program:
        """Register a loaded program; duplicate names are rejected."""
        if not name:
            raise ValueError("model name must be non-empty")
        if name in self._programs:
            raise ValueError(f"model {name!r} already registered; "
                             "unregister it first to replace")
        self._programs[name] = program
        return program

    def load(self, name: str, path: str | Path) -> Program:
        """``Program.load`` an artifact and register it under ``name``."""
        return self.register(name, Program.load(path))

    def unregister(self, name: str) -> Program:
        if name not in self._programs:
            raise KeyError(f"model {name!r} not registered")
        return self._programs.pop(name)

    # -- lookup -------------------------------------------------------------

    def get(self, name: str) -> Program:
        try:
            return self._programs[name]
        except KeyError:
            raise KeyError(f"model {name!r} not registered; have "
                           f"{self.names()}") from None

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._programs))

    def __contains__(self, name: str) -> bool:
        return name in self._programs

    def __len__(self) -> int:
        return len(self._programs)

    # -- per-model runners --------------------------------------------------

    def runner(self, name: str, *, sharded: bool = False, mesh=None):
        """The model's batch-callable: ``[b, T, n_in] -> (s, v, stats)``.

        Resolves to the program's owned engine (or owned sharded
        runner) — repeated calls reuse the same compiled object, and
        distinct models own distinct engines.
        """
        program = self.get(name)
        if sharded:
            return program.sharded_runner(mesh).run
        return program.run
